"""AOT lowering: L2/L1 JAX+Pallas → HLO text artifacts + manifest.json.

Interchange is **HLO text**, NOT serialized protos: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
re-assigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per model we emit four computations:

    <name>_init.hlo.txt       (seed: i32[])                     -> (params,)
    <name>_train.hlo.txt      (params, x, y, lr: f32[])         -> (params', loss)
    <name>_eval.hlo.txt       (params, x[E,..], y[E,..])        -> (loss, acc)
    <name>_consensus.hlo.txt  (stacked: f32[K,P], w: f32[K])    -> (mixed,)

plus a `manifest.json` describing shapes/dtypes so the Rust runtime can
marshal `Literal`s without re-deriving anything from Python.

Usage:  python -m compile.aot --out ../artifacts [--models mlp,transformer]
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.consensus import consensus_pallas
from .model import ModelSpec, all_models

CONSENSUS_K = 8  # max in-degree+1 supported by the XLA consensus path
MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def lower_model(spec: ModelSpec) -> dict:
    """Lower one model's four computations; returns {artifact_name: text}."""
    p = spec.param_count
    params = _spec((p,))
    lr = _spec((), jnp.float32)
    x_dtype = jnp.int32 if spec.name == "transformer" else jnp.float32
    x = _spec(spec.x_shape, x_dtype)
    y = _spec(spec.y_shape, jnp.int32)
    ex = _spec((spec.eval_batch, *spec.x_shape[1:]), x_dtype)
    ey = _spec((spec.eval_batch, *spec.y_shape[1:]), jnp.int32)

    def init_fn(seed):
        return (spec.init(jax.random.PRNGKey(seed)),)

    def train_fn(params, x, y, lr):
        return spec.train_step(params, x, y, lr)

    def eval_fn(params, x, y):
        return spec.eval_step(params, x, y)

    def consensus_fn(stacked, weights):
        return (consensus_pallas(stacked, weights),)

    out = {}
    out[f"{spec.name}_init.hlo.txt"] = to_hlo_text(
        jax.jit(init_fn).lower(_spec((), jnp.int32))
    )
    out[f"{spec.name}_train.hlo.txt"] = to_hlo_text(
        jax.jit(train_fn).lower(params, x, y, lr)
    )
    out[f"{spec.name}_eval.hlo.txt"] = to_hlo_text(
        jax.jit(eval_fn).lower(params, ex, ey)
    )
    out[f"{spec.name}_consensus.hlo.txt"] = to_hlo_text(
        jax.jit(consensus_fn).lower(_spec((CONSENSUS_K, p)), _spec((CONSENSUS_K,)))
    )
    return out


def manifest_entry(spec: ModelSpec) -> dict:
    x_dtype = "i32" if spec.name == "transformer" else "f32"
    return {
        "param_count": spec.param_count,
        "batch": spec.batch,
        "eval_batch": spec.eval_batch,
        "x_shape": list(spec.x_shape),
        "y_shape": list(spec.y_shape),
        "x_dtype": x_dtype,
        "consensus_k": CONSENSUS_K,
        "meta": spec.meta,
        "artifacts": {
            "init": f"{spec.name}_init.hlo.txt",
            "train": f"{spec.name}_train.hlo.txt",
            "eval": f"{spec.name}_eval.hlo.txt",
            "consensus": f"{spec.name}_consensus.hlo.txt",
        },
    }


def source_fingerprint() -> str:
    """Hash of the compile package — lets `make artifacts` skip no-op runs."""
    h = hashlib.sha256()
    pkg = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(pkg)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--models",
        default="mlp,transformer",
        help="comma-separated subset of models to lower",
    )
    ap.add_argument(
        "--force", action="store_true", help="re-lower even if fingerprint matches"
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    fp = source_fingerprint()

    wanted = [m.strip() for m in args.models.split(",") if m.strip()]
    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("fingerprint") == fp and set(wanted) <= set(
                old.get("models", {})
            ):
                print(f"artifacts up to date (fingerprint {fp}); skipping")
                return
        except (json.JSONDecodeError, OSError):
            pass

    models = all_models()
    manifest = {"version": MANIFEST_VERSION, "fingerprint": fp, "models": {}}
    for name in wanted:
        if name not in models:
            sys.exit(f"unknown model '{name}' (have {sorted(models)})")
        spec = models[name]
        print(f"lowering {name} (P={spec.param_count}) ...", flush=True)
        for fname, text in lower_model(spec).items():
            path = os.path.join(args.out, fname)
            with open(path, "w") as f:
                f.write(text)
            print(f"  wrote {fname} ({len(text) / 1e3:.0f} kB)")
        manifest["models"][name] = manifest_entry(spec)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote manifest.json (fingerprint {fp})")


if __name__ == "__main__":
    main()
