"""L2 — JAX model definitions (build-time only).

Two model families, both carrying **flat f32 parameter vectors** so the Rust
coordinator can treat a model as an opaque buffer (mixing, sending, storing)
and the AOT artifacts take exactly one `params` argument:

* ``mlp``         — classifier for the synthetic non-iid federated datasets
                    (the FEMNIST/Sentiment140 stand-in, DESIGN.md §3).
* ``transformer`` — small GPT-style char-LM (the Shakespeare stand-in).

Every dense contraction routes through the L1 Pallas matmul
(`kernels.matmul`, custom-vjp'd), so the forward *and* backward graphs lower
through the Pallas kernel into the same HLO module.

Each model provides pure functions:

    init(key)                        -> params_flat              f32[P]
    train_step(params, x, y, lr)     -> (params', mean_loss)
    eval_step(params, x, y)          -> (mean_loss, accuracy)
"""

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul


# ---------------------------------------------------------------------------
# Flat-parameter plumbing
# ---------------------------------------------------------------------------

Shapes = List[Tuple[str, Tuple[int, ...]]]


def param_count(shapes: Shapes) -> int:
    total = 0
    for _, shp in shapes:
        n = 1
        for d in shp:
            n *= d
        total += n
    return total


def unflatten(flat: jax.Array, shapes: Shapes) -> Dict[str, jax.Array]:
    """Slice the flat vector into named tensors (static offsets → fuses)."""
    out = {}
    off = 0
    for name, shp in shapes:
        n = 1
        for d in shp:
            n *= d
        out[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shp)
        off += n
    return out


def init_flat(key: jax.Array, shapes: Shapes) -> jax.Array:
    """He-style init per leaf, concatenated into the flat vector."""
    parts = []
    for i, (name, shp) in enumerate(shapes):
        k = jax.random.fold_in(key, i)
        if len(shp) >= 2:
            fan_in = 1
            for d in shp[:-1]:
                fan_in *= d
            scale = jnp.sqrt(2.0 / fan_in)
            parts.append((jax.random.normal(k, shp) * scale).reshape(-1))
        else:
            parts.append(jnp.zeros(shp).reshape(-1))
    return jnp.concatenate(parts).astype(jnp.float32)


def _dense(x2d: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Dense layer through the Pallas matmul."""
    return matmul(x2d, w) + b


def _softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy, numerically stable."""
    logits = logits - jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(logits), axis=-1))
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - picked)


# ---------------------------------------------------------------------------
# Model spec
# ---------------------------------------------------------------------------


@dataclass
class ModelSpec:
    name: str
    shapes: Shapes
    batch: int
    x_shape: Tuple[int, ...]       # per-train-batch input shape
    y_shape: Tuple[int, ...]
    eval_batch: int
    init: Callable
    train_step: Callable           # (params, x, y, lr) -> (params', loss)
    eval_step: Callable            # (params, x, y) -> (loss, acc)
    forward: Callable = None       # (params, x) -> logits (tests/diagnostics)
    meta: dict = field(default_factory=dict)

    @property
    def param_count(self) -> int:
        return param_count(self.shapes)


# ---------------------------------------------------------------------------
# MLP classifier
# ---------------------------------------------------------------------------


def make_mlp(dim=64, classes=10, hidden=(256, 128), batch=32, eval_batch=256) -> ModelSpec:
    widths = [dim, *hidden, classes]
    shapes: Shapes = []
    for i in range(len(widths) - 1):
        shapes.append((f"w{i}", (widths[i], widths[i + 1])))
        shapes.append((f"b{i}", (widths[i + 1],)))

    def forward(flat, x):
        p = unflatten(flat, shapes)
        h = x
        for i in range(len(widths) - 1):
            h = _dense(h, p[f"w{i}"], p[f"b{i}"])
            if i < len(widths) - 2:
                h = jax.nn.relu(h)
        return h

    def loss_fn(flat, x, y):
        return _softmax_xent(forward(flat, x), y)

    def train_step(flat, x, y, lr):
        loss, g = jax.value_and_grad(loss_fn)(flat, x, y)
        return flat - lr * g, loss

    def eval_step(flat, x, y):
        logits = forward(flat, x)
        loss = _softmax_xent(logits, y)
        acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss, acc

    def init(key):
        return init_flat(key, shapes)

    return ModelSpec(
        name="mlp",
        shapes=shapes,
        batch=batch,
        x_shape=(batch, dim),
        y_shape=(batch,),
        eval_batch=eval_batch,
        init=init,
        train_step=train_step,
        eval_step=eval_step,
        forward=forward,
        meta={"dim": dim, "classes": classes, "hidden": list(hidden)},
    )


# ---------------------------------------------------------------------------
# Transformer char-LM
# ---------------------------------------------------------------------------


def make_transformer(
    vocab=64, seq=64, d_model=128, n_layers=2, n_heads=4, batch=16, eval_batch=64
) -> ModelSpec:
    assert d_model % n_heads == 0
    d_head = d_model // n_heads
    d_ff = 4 * d_model

    shapes: Shapes = [("embed", (vocab, d_model)), ("pos", (seq, d_model))]
    for l in range(n_layers):
        shapes += [
            (f"l{l}.ln1_g", (d_model,)),
            (f"l{l}.qkv", (d_model, 3 * d_model)),
            (f"l{l}.proj", (d_model, d_model)),
            (f"l{l}.ln2_g", (d_model,)),
            (f"l{l}.ff1", (d_model, d_ff)),
            (f"l{l}.ff1_b", (d_ff,)),
            (f"l{l}.ff2", (d_ff, d_model)),
            (f"l{l}.ff2_b", (d_model,)),
        ]
    shapes += [("lnf_g", (d_model,)), ("unembed", (d_model, vocab))]

    def layernorm(x, g):
        mu = x.mean(axis=-1, keepdims=True)
        var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
        return (x - mu) / jnp.sqrt(var + 1e-5) * (1.0 + g)

    def forward(flat, tokens):
        p = unflatten(flat, shapes)
        b, t = tokens.shape
        h = p["embed"][tokens] + p["pos"][None, :t, :]
        mask = jnp.tril(jnp.ones((t, t), jnp.float32))
        for l in range(n_layers):
            x = layernorm(h, p[f"l{l}.ln1_g"])
            qkv = matmul(x.reshape(b * t, d_model), p[f"l{l}.qkv"]).reshape(
                b, t, 3, n_heads, d_head
            )
            q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
            att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(d_head)
            att = jnp.where(mask[None, None] > 0, att, -1e9)
            att = jax.nn.softmax(att, axis=-1)
            out = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b * t, d_model)
            h = h + matmul(out, p[f"l{l}.proj"]).reshape(b, t, d_model)
            x = layernorm(h, p[f"l{l}.ln2_g"]).reshape(b * t, d_model)
            ff = jax.nn.gelu(matmul(x, p[f"l{l}.ff1"]) + p[f"l{l}.ff1_b"])
            h = h + (matmul(ff, p[f"l{l}.ff2"]) + p[f"l{l}.ff2_b"]).reshape(
                b, t, d_model
            )
        h = layernorm(h, p["lnf_g"])
        return matmul(h.reshape(b * t, d_model), p["unembed"]).reshape(b, t, vocab)

    def loss_fn(flat, x, y):
        return _softmax_xent(forward(flat, x), y)

    def train_step(flat, x, y, lr):
        loss, g = jax.value_and_grad(loss_fn)(flat, x, y)
        return flat - lr * g, loss

    def eval_step(flat, x, y):
        logits = forward(flat, x)
        loss = _softmax_xent(logits, y)
        acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss, acc

    def init(key):
        return init_flat(key, shapes)

    return ModelSpec(
        name="transformer",
        shapes=shapes,
        batch=batch,
        x_shape=(batch, seq),
        y_shape=(batch, seq),
        eval_batch=eval_batch,
        init=init,
        train_step=train_step,
        eval_step=eval_step,
        forward=forward,
        meta={
            "vocab": vocab,
            "seq": seq,
            "d_model": d_model,
            "n_layers": n_layers,
            "n_heads": n_heads,
        },
    )


def all_models() -> Dict[str, ModelSpec]:
    """The models the AOT pipeline lowers by default."""
    return {"mlp": make_mlp(), "transformer": make_transformer()}
