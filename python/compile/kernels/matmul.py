"""L1 Pallas kernel: tiled matmul — the compute hot-spot of every dense
layer in the L2 models.

TPU-shaped schedule (DESIGN.md §Hardware-Adaptation): the grid iterates over
(M/bm, N/bn, K/bk); for each (i, j) output tile the kernel accumulates
bk-sized K-slabs in f32. BlockSpec expresses the HBM→VMEM movement that a
CUDA kernel would express with threadblocks + shared memory; the
(bm, bn) = (128, 128) default targets the MXU systolic array. The models run
in f32, so the output tile itself is the accumulator (no scratch needed, and
the revisited tile stays resident in VMEM across the K grid axis because it
is the innermost loop).

`interpret=True` everywhere — the CPU PJRT plugin cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md), so the kernel is *lowered to
plain HLO* with identical semantics; TPU efficiency is estimated
analytically (EXPERIMENTS.md §Perf).

Differentiability: `pallas_call` has no transpose rule, so `matmul` carries a
`jax.custom_vjp` whose backward pass reuses the same kernel
(dX = dY·Wᵀ, dW = Xᵀ·dY) — the whole fwd/bwd graph lowers through Pallas.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, nsteps_k):
    """One (bm, bn) output tile: accumulate over the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is ≤ target (keeps the grid exact)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def matmul_pallas(x: jax.Array, w: jax.Array, *, bm=128, bn=128, bk=128) -> jax.Array:
    """`x @ w` via the Pallas tiled kernel (f32 accumulate)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    nsteps_k = k // bk
    return pl.pallas_call(
        partial(_matmul_kernel, nsteps_k=nsteps_k),
        grid=(m // bm, n // bn, nsteps_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable Pallas matmul used by the L2 models."""
    return matmul_pallas(x, w)


def _matmul_fwd(x, w):
    return matmul_pallas(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    dx = matmul_pallas(g, w.T)
    dw = matmul_pallas(x.T, g)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint_bytes(m, n, k, bm=128, bn=128, bk=128, dtype_bytes=4):
    """Estimated VMEM working set of one grid step: x-tile + w-tile +
    out/accumulator tile. Used by the §Perf analysis (TPU VMEM is
    ~16 MiB/core; the default tiling uses ~0.19 MiB)."""
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes


def mxu_utilization_estimate(m, n, k, bm=128, bn=128, bk=128):
    """Fraction of MXU-issue slots doing useful work: the 128×128 systolic
    array is fully fed iff the tile dims are multiples of 128."""
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    eff = lambda b: min(b, 128) / 128.0  # noqa: E731
    return eff(bm) * eff(bn) * eff(bk)
