"""L1 Pallas kernel: consensus mixing — `out = Σ_k a[k] · W[k, :]`.

The DPASGD communication phase mixes K neighbour models (flat parameter
vectors) with consensus weights (Eq. 2's averaging step). As a BLAS-1
reduction it is memory-bound; the TPU schedule tiles the parameter axis so
each grid step streams a (K × bp) slab HBM→VMEM once and writes a bp-sized
output tile — the K axis stays resident, matching how the paper's silos
aggregate incoming models buffer-by-buffer.

On the Rust hot path the same operation runs natively
(`fl::consensus::mix_into`) to avoid an FFI round-trip for a memory-bound
op; this kernel is the XLA-side twin, validated against the Rust
implementation and `ref.consensus_ref`, and exercised end-to-end by
`fedtopo consensus-xla`.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _consensus_kernel(w_ref, a_ref, o_ref):
    # w_ref: (K, bp) slab, a_ref: (K,) weights, o_ref: (bp,) output tile.
    o_ref[...] = jnp.einsum(
        "k,kp->p", a_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def consensus_pallas(stacked: jax.Array, weights: jax.Array, *, bp=4096) -> jax.Array:
    """Mix K stacked flat models `stacked[K, P]` with `weights[K]`."""
    k, p = stacked.shape
    assert weights.shape == (k,)
    bp = _block(p, bp)
    return pl.pallas_call(
        _consensus_kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((k, bp), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), stacked.dtype),
        interpret=True,
    )(stacked, weights)


def vmem_footprint_bytes(k, p, bp=4096, dtype_bytes=4):
    """VMEM working set per grid step: (K+1)·bp floats + K weights."""
    bp = _block(p, bp)
    return (k * bp + bp + k) * dtype_bytes
