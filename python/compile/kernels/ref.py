"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the L1 kernels are validated against at build
time (pytest + hypothesis sweeps in python/tests/test_kernels.py). They are
deliberately the most obvious possible implementations.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain `x @ w` in f32."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def consensus_ref(stacked, weights):
    """`out[p] = Σ_k weights[k] · stacked[k, p]`."""
    return jnp.einsum("k,kp->p", weights, stacked)
