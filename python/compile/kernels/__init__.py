"""L1 — Pallas kernels for the compute hot-spots (build-time only)."""

from .matmul import matmul, matmul_pallas  # noqa: F401
from .consensus import consensus_pallas  # noqa: F401
from . import ref  # noqa: F401
