"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis-swept."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import consensus_pallas, matmul, matmul_pallas, ref
from compile.kernels.matmul import (
    _block,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels import consensus as consensus_mod

DIMS = st.integers(min_value=1, max_value=96)


def rand(shape, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


class TestMatmul:
    @settings(max_examples=40, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_all_shapes(self, m, k, n, seed):
        x = rand((m, k), seed)
        w = rand((k, n), seed + 1)
        np.testing.assert_allclose(
            matmul_pallas(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize("shape", [(128, 128, 128), (256, 64, 512), (1, 1, 1)])
    def test_mxu_shaped_and_degenerate(self, shape):
        m, k, n = shape
        x, w = rand((m, k), 0), rand((k, n), 1)
        np.testing.assert_allclose(
            matmul_pallas(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (64, 128, 16), (128, 128, 128)])
    def test_block_shape_invariance(self, bm, bn, bk):
        x, w = rand((64, 96), 2), rand((96, 48), 3)
        out = matmul_pallas(x, w, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(out, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)

    def test_gradients_match_ref(self):
        x, w = rand((32, 64), 4), rand((64, 16), 5)

        def loss_pallas(x, w):
            return (matmul(x, w) ** 2).sum()

        def loss_ref(x, w):
            return (ref.matmul_ref(x, w) ** 2).sum()

        gp = jax.grad(loss_pallas, argnums=(0, 1))(x, w)
        gr = jax.grad(loss_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gp[0], gr[0], rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(gp[1], gr[1], rtol=1e-3, atol=1e-3)

    def test_jit_compatible(self):
        f = jax.jit(lambda x, w: matmul(x, w))
        x, w = rand((16, 32), 6), rand((32, 8), 7)
        np.testing.assert_allclose(f(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)

    def test_block_divisor(self):
        assert _block(128, 128) == 128
        assert _block(96, 128) == 96
        assert _block(100, 64) == 50
        assert _block(7, 4) == 1

    def test_vmem_footprint_within_budget(self):
        # default tiling must fit comfortably in a 16 MiB VMEM core
        assert vmem_footprint_bytes(1024, 1024, 1024) < 1 << 20

    def test_mxu_estimate_monotone(self):
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert mxu_utilization_estimate(64, 128, 128) == 0.5
        assert mxu_utilization_estimate(10, 10, 10) < 0.01


# ---------------------------------------------------------------------------
# consensus
# ---------------------------------------------------------------------------


class TestConsensus:
    @settings(max_examples=30, deadline=None)
    @given(
        k=st.integers(1, 12),
        p=st.integers(1, 3000),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, k, p, seed):
        stacked = rand((k, p), seed)
        w = rand((k,), seed + 1)
        np.testing.assert_allclose(
            consensus_pallas(stacked, w),
            ref.consensus_ref(stacked, w),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_doubly_stochastic_weights_preserve_mean(self):
        stacked = rand((4, 1024), 8)
        w = jnp.full((4,), 0.25, jnp.float32)
        out = consensus_pallas(stacked, w)
        np.testing.assert_allclose(out, stacked.mean(axis=0), rtol=1e-5, atol=1e-5)

    def test_zero_padding_slots_ignored(self):
        # the Rust runtime pads to K=8 with zero weights; padded rows must
        # not affect the result
        real = rand((3, 512), 9)
        pad = jnp.zeros((5, 512), jnp.float32)
        stacked = jnp.concatenate([real, pad])
        w = jnp.array([0.5, 0.3, 0.2, 0, 0, 0, 0, 0], jnp.float32)
        np.testing.assert_allclose(
            consensus_pallas(stacked, w),
            ref.consensus_ref(real, w[:3]),
            rtol=1e-5,
            atol=1e-5,
        )

    @pytest.mark.parametrize("bp", [64, 1024, 4096])
    def test_block_size_invariance(self, bp):
        stacked, w = rand((8, 2048), 10), rand((8,), 11)
        np.testing.assert_allclose(
            consensus_pallas(stacked, w, bp=bp),
            ref.consensus_ref(stacked, w),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_vmem_estimate(self):
        assert consensus_mod.vmem_footprint_bytes(8, 1 << 20) < 1 << 19
