"""L2 model correctness: shapes, learning dynamics, flat-param plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    all_models,
    init_flat,
    make_mlp,
    make_transformer,
    param_count,
    unflatten,
)


def batch_for(spec, seed=0):
    rng = np.random.default_rng(seed)
    if spec.name == "transformer":
        x = jnp.asarray(
            rng.integers(0, spec.meta["vocab"], size=spec.x_shape), jnp.int32
        )
        y = jnp.asarray(
            rng.integers(0, spec.meta["vocab"], size=spec.y_shape), jnp.int32
        )
    else:
        x = jnp.asarray(rng.standard_normal(spec.x_shape), jnp.float32)
        y = jnp.asarray(
            rng.integers(0, spec.meta["classes"], size=spec.y_shape), jnp.int32
        )
    return x, y


class TestFlatParams:
    def test_param_count_mlp(self):
        spec = make_mlp(dim=64, classes=10, hidden=(256, 128))
        # 64·256+256 + 256·128+128 + 128·10+10
        assert spec.param_count == 64 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10

    def test_unflatten_roundtrip(self):
        shapes = [("a", (3, 4)), ("b", (5,)), ("c", (2, 2, 2))]
        flat = jnp.arange(param_count(shapes), dtype=jnp.float32)
        parts = unflatten(flat, shapes)
        assert parts["a"].shape == (3, 4)
        assert parts["b"].shape == (5,)
        assert parts["c"].shape == (2, 2, 2)
        recat = jnp.concatenate([parts[n].reshape(-1) for n, _ in shapes])
        np.testing.assert_array_equal(recat, flat)

    def test_init_deterministic_and_scaled(self):
        spec = make_mlp()
        a = spec.init(jax.random.PRNGKey(0))
        b = spec.init(jax.random.PRNGKey(0))
        c = spec.init(jax.random.PRNGKey(1))
        np.testing.assert_array_equal(a, b)
        assert not np.allclose(a, c)
        assert a.shape == (spec.param_count,)
        assert float(jnp.abs(a).max()) < 2.0  # He-scaled, no exploding init

    def test_biases_init_zero(self):
        shapes = [("w", (4, 4)), ("b", (4,))]
        flat = init_flat(jax.random.PRNGKey(0), shapes)
        np.testing.assert_array_equal(flat[-4:], jnp.zeros(4))


@pytest.mark.parametrize("name", ["mlp", "transformer"])
class TestTraining:
    def test_shapes(self, name):
        spec = all_models()[name]
        params = spec.init(jax.random.PRNGKey(0))
        x, y = batch_for(spec)
        new_params, loss = spec.train_step(params, x, y, jnp.float32(0.1))
        assert new_params.shape == params.shape
        assert loss.shape == ()
        l, acc = spec.eval_step(params, x, y)
        assert l.shape == () and acc.shape == ()

    def test_loss_decreases_on_fixed_batch(self, name):
        spec = all_models()[name]
        params = spec.init(jax.random.PRNGKey(0))
        x, y = batch_for(spec)
        step = jax.jit(spec.train_step)
        first = None
        loss = None
        for _ in range(20):
            params, loss = step(params, x, y, jnp.float32(0.05))
            first = first if first is not None else float(loss)
        assert float(loss) < 0.7 * first, f"{first} → {float(loss)}"

    def test_gradient_updates_finite(self, name):
        spec = all_models()[name]
        params = spec.init(jax.random.PRNGKey(3))
        x, y = batch_for(spec, 3)
        new_params, loss = spec.train_step(params, x, y, jnp.float32(0.1))
        assert bool(jnp.isfinite(loss))
        assert bool(jnp.all(jnp.isfinite(new_params)))
        # learning happened
        assert float(jnp.abs(new_params - params).max()) > 0

    def test_zero_lr_is_identity(self, name):
        spec = all_models()[name]
        params = spec.init(jax.random.PRNGKey(4))
        x, y = batch_for(spec, 4)
        new_params, _ = spec.train_step(params, x, y, jnp.float32(0.0))
        np.testing.assert_allclose(new_params, params, atol=1e-7)


class TestEval:
    def test_random_model_near_chance(self):
        spec = make_mlp()
        params = spec.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((512, spec.meta["dim"])), jnp.float32)
        y = jnp.asarray(rng.integers(0, spec.meta["classes"], 512), jnp.int32)
        loss, acc = spec.eval_step(params, x, y)
        assert abs(float(acc) - 1.0 / spec.meta["classes"]) < 0.15
        # He-init logits have O(1) variance, so the loss sits near—but above—
        # the log(C) entropy floor.
        assert np.log(spec.meta["classes"]) - 0.5 < float(loss) < 3.0 * np.log(
            spec.meta["classes"]
        )

    def test_transformer_causality(self):
        # changing a *future* token must not change earlier logits
        spec = make_transformer(vocab=16, seq=8, d_model=32, n_layers=1, n_heads=2,
                                batch=1)
        params = spec.init(jax.random.PRNGKey(5))
        x1 = jnp.zeros((1, 8), jnp.int32)
        x2 = x1.at[0, 7].set(3)
        logits1 = spec.forward(params, x1)
        logits2 = spec.forward(params, x2)
        # positions 0..6 must be identical; position 7 must differ
        np.testing.assert_allclose(logits1[:, :7], logits2[:, :7], atol=1e-5)
        assert float(jnp.abs(logits1[:, 7] - logits2[:, 7]).max()) > 1e-4

    def test_mlp_forward_matches_eval_loss(self):
        spec = make_mlp()
        params = spec.init(jax.random.PRNGKey(6))
        x, y = batch_for(spec, 6)
        logits = spec.forward(params, x)
        assert logits.shape == (spec.batch, spec.meta["classes"])
        acc_manual = float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))
        _, acc = spec.eval_step(params, x, y)
        assert abs(acc_manual - float(acc)) < 1e-6
