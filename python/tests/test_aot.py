"""AOT pipeline: artifacts emitted, manifest consistent, HLO text valid."""

import json
import os
import subprocess
import sys

import pytest

from compile.aot import (
    CONSENSUS_K,
    lower_model,
    manifest_entry,
    source_fingerprint,
    to_hlo_text,
)
from compile.model import make_mlp


@pytest.fixture(scope="module")
def mlp_artifacts():
    # small MLP keeps the test fast
    spec = make_mlp(dim=8, classes=4, hidden=(16,), batch=4, eval_batch=8)
    return spec, lower_model(spec)


class TestLowering:
    def test_all_four_artifacts(self, mlp_artifacts):
        spec, arts = mlp_artifacts
        expected = {
            f"{spec.name}_{kind}.hlo.txt"
            for kind in ("init", "train", "eval", "consensus")
        }
        assert set(arts) == expected

    def test_hlo_text_is_hlo(self, mlp_artifacts):
        _, arts = mlp_artifacts
        for name, text in arts.items():
            assert text.startswith("HloModule"), f"{name} not HLO text"
            assert "ENTRY" in text
            # 64-bit-id regression guard: text parse path never embeds raw
            # serialized protos
            assert "\x00" not in text

    def test_train_signature_shapes(self, mlp_artifacts):
        spec, arts = mlp_artifacts
        text = arts[f"{spec.name}_train.hlo.txt"]
        p = spec.param_count
        # params arg and result both f32[P]
        assert f"f32[{p}]" in text
        # batch input present
        assert f"f32[{spec.batch},{spec.meta['dim']}]" in text
        assert f"s32[{spec.batch}]" in text

    def test_consensus_signature(self, mlp_artifacts):
        spec, arts = mlp_artifacts
        text = arts[f"{spec.name}_consensus.hlo.txt"]
        assert f"f32[{CONSENSUS_K},{spec.param_count}]" in text
        assert f"f32[{CONSENSUS_K}]" in text


class TestManifest:
    def test_entry_fields(self):
        spec = make_mlp()
        e = manifest_entry(spec)
        assert e["param_count"] == spec.param_count
        assert e["x_shape"] == [spec.batch, spec.meta["dim"]]
        assert e["x_dtype"] == "f32"
        assert e["consensus_k"] == CONSENSUS_K
        assert set(e["artifacts"]) == {"init", "train", "eval", "consensus"}

    def test_fingerprint_stable(self):
        assert source_fingerprint() == source_fingerprint()
        assert len(source_fingerprint()) == 16


class TestCli:
    def test_skip_when_up_to_date(self, tmp_path):
        env = dict(os.environ)
        pkg_dir = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        run = lambda *extra: subprocess.run(  # noqa: E731
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out",
                str(tmp_path),
                "--models",
                "mlp",
                *extra,
            ],
            cwd=pkg_dir,
            env=env,
            capture_output=True,
            text=True,
            timeout=300,
        )
        first = run()
        assert first.returncode == 0, first.stderr
        assert "lowering mlp" in first.stdout
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert "mlp" in manifest["models"]
        second = run()
        assert second.returncode == 0, second.stderr
        assert "up to date" in second.stdout
