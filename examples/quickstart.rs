//! Quickstart: design every overlay for one network and compare cycle times.
//!
//! ```bash
//! cargo run --release --example quickstart [network]
//! ```

use anyhow::Result;
use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::table::Table;

fn main() -> Result<()> {
    let network = std::env::args().nth(1).unwrap_or_else(|| "gaia".into());
    let net = Underlay::builtin(&network)?;
    let wl = Workload::inaturalist();
    println!(
        "{}: {} silos, {} core links — training {} (M = {:.1} Mbit, T_c = {:.1} ms)",
        net.name,
        net.n_silos(),
        net.n_links(),
        wl.name,
        wl.model_mbits(),
        wl.tc_ms
    );

    let mut t = Table::new(
        "overlay comparison (10 Gbps access / 1 Gbps core, s = 1)",
        &["Overlay", "cycle time (ms)", "throughput (rounds/s)", "speedup vs STAR"],
    );
    let dm = DelayModel::new(&net, &wl, 1, 10e9, 1e9);
    let star_tau = design_with_underlay(OverlayKind::Star, &dm, &net, 0.5)?
        .cycle_time_ms(&dm);
    for kind in OverlayKind::all() {
        let overlay = design_with_underlay(kind, &dm, &net, 0.5)?;
        let tau = overlay.cycle_time_ms(&dm);
        t.row(vec![
            kind.name().to_string(),
            format!("{tau:.0}"),
            format!("{:.2}", 1000.0 / tau),
            format!("{:.2}x", star_tau / tau),
        ]);
    }
    t.print();

    // Show the winning ring.
    let ring = design_with_underlay(OverlayKind::Ring, &dm, &net, 0.5)?;
    let g = ring.static_graph().unwrap();
    print!("\nRING tour: ");
    let mut cur = 0usize;
    for _ in 0..net.n_silos() {
        print!("{} → ", net.sites[cur].name);
        cur = g.out_neighbors(cur)[0].0;
    }
    println!("{}", net.sites[0].name);
    Ok(())
}
