//! Topology explorer: sweep access capacities on any underlay, find the
//! regime crossovers, inspect critical circuits, and export overlays as GML
//! for external visualization.
//!
//! ```bash
//! cargo run --release --example topology_explorer -- geant
//! ```

use anyhow::Result;
use fedtopo::fl::workloads::Workload;
use fedtopo::maxplus::karp::max_cycle_mean_with_cycle;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::table::Table;

fn main() -> Result<()> {
    let network = std::env::args().nth(1).unwrap_or_else(|| "geant".into());
    let net = Underlay::builtin(&network)?;
    let wl = Workload::inaturalist();

    // 1. capacity sweep with crossover detection
    let kinds = [
        OverlayKind::Star,
        OverlayKind::MatchaPlus,
        OverlayKind::Mst,
        OverlayKind::Ring,
    ];
    let mut t = Table::new(
        &format!("access-capacity sweep on {network} (winner per row)"),
        &["Access (Mbps)", "STAR", "MATCHA+", "MST", "RING", "winner"],
    );
    let mut prev_winner = String::new();
    for &access in &[10e6, 50e6, 100e6, 500e6, 1e9, 5e9, 10e9, 50e9] {
        let dm = DelayModel::new(&net, &wl, 1, access, 1e9);
        let taus: Vec<f64> = kinds
            .iter()
            .map(|&k| {
                design_with_underlay(k, &dm, &net, 0.5)
                    .unwrap()
                    .cycle_time_ms(&dm)
            })
            .collect();
        let win = taus
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let winner = kinds[win].name().to_string();
        let mark = if winner != prev_winner && !prev_winner.is_empty() {
            format!("{winner}  <-- crossover")
        } else {
            winner.clone()
        };
        prev_winner = winner;
        t.row(vec![
            format!("{:.0}", access / 1e6),
            format!("{:.0}", taus[0]),
            format!("{:.0}", taus[1]),
            format!("{:.0}", taus[2]),
            format!("{:.0}", taus[3]),
            mark,
        ]);
    }
    t.print();

    // 2. critical circuit of the MST overlay (what limits its throughput)
    let dm = DelayModel::new(&net, &wl, 1, 1e9, 1e9);
    let mst = design_with_underlay(OverlayKind::Mst, &dm, &net, 0.5)?;
    let dd = dm.delay_digraph(mst.static_graph().unwrap());
    let (tau, cycle) = max_cycle_mean_with_cycle(&dd).unwrap();
    println!("\nMST critical circuit (τ = {tau:.1} ms): ");
    for w in cycle.windows(2) {
        println!("  {} → {}", net.sites[w[0]].name, net.sites[w[1]].name);
    }
    if cycle.len() == 1 {
        println!("  (self-loop at {} — computation-bound)", net.sites[cycle[0]].name);
    }

    // 3. GML export of underlay for external tooling
    let path = format!("{network}_underlay.gml");
    std::fs::write(&path, net.to_gml())?;
    println!("\nwrote {path}");
    Ok(())
}
