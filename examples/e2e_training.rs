//! End-to-end driver — the full three-layer stack on a real workload.
//!
//! Trains the AOT-compiled MLP (L1 Pallas matmuls inside an L2 JAX train
//! step, executed from Rust via PJRT) with DPASGD across the silos of a
//! chosen underlay, over both the STAR and the throughput-optimal RING,
//! while the network simulator reconstructs the wall-clock timeline. Proves
//! all layers compose: topology design → consensus orchestration → XLA
//! compute → max-plus timing. Results are logged to stdout and a JSON
//! report (`e2e_report.json`).
//!
//! ```bash
//! make artifacts && cargo run --release --features xla --example e2e_training -- \
//!     [network=aws-na] [rounds=150]
//! ```
//!
//! Requires the off-by-default `xla` cargo feature (the PJRT binding crate
//! is not part of the offline build — add it as a dependency in
//! rust/Cargo.toml per the comment there before enabling the feature).

#[cfg(not(feature = "xla"))]
fn main() {
    println!("e2e_training skipped: build with --features xla (and run `make artifacts`)");
}

#[cfg(feature = "xla")]
use anyhow::Result;
#[cfg(feature = "xla")]
use fedtopo::coordinator::leader::run_experiment;
#[cfg(feature = "xla")]
use fedtopo::fl::data::{DataConfig, FedDataset};
#[cfg(feature = "xla")]
use fedtopo::fl::dpasgd::DpasgdConfig;
#[cfg(feature = "xla")]
use fedtopo::fl::workloads::Workload;
#[cfg(feature = "xla")]
use fedtopo::netsim::delay::DelayModel;
#[cfg(feature = "xla")]
use fedtopo::netsim::underlay::Underlay;
#[cfg(feature = "xla")]
use fedtopo::runtime::client::XlaRuntime;
#[cfg(feature = "xla")]
use fedtopo::runtime::manifest::Manifest;
#[cfg(feature = "xla")]
use fedtopo::runtime::trainer::XlaTrainer;
#[cfg(feature = "xla")]
use fedtopo::topology::{design_with_underlay, OverlayKind};
#[cfg(feature = "xla")]
use fedtopo::util::json::Json;
#[cfg(feature = "xla")]
use fedtopo::util::table::Table;

#[cfg(feature = "xla")]
fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let network = args.first().cloned().unwrap_or_else(|| "aws-na".into());
    let rounds: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);

    let net = Underlay::builtin(&network)?;
    let n = net.n_silos();
    let wl = Workload::inaturalist();
    // paper Fig-2 regime: 100 Mbps access, 1 Gbps core
    let dm = DelayModel::new(&net, &wl, 1, 100e6, 1e9);

    let manifest = Manifest::load(&Manifest::default_dir())
        .map_err(|e| anyhow::anyhow!("{e} — run `make artifacts` first"))?;
    let mut rt = XlaRuntime::cpu()?;

    println!(
        "e2e: {n}-silo DPASGD on {network}, MLP ({} params) via PJRT, {rounds} rounds",
        manifest.model("mlp")?.param_count
    );

    let mut results = Vec::new();
    for kind in [OverlayKind::Star, OverlayKind::MatchaPlus, OverlayKind::Ring] {
        let overlay = design_with_underlay(kind, &dm, &net, 0.5)?;
        // identical non-iid data for every overlay
        let data = FedDataset::synthesize(&DataConfig {
            num_silos: n,
            dim: 64,
            num_classes: 10,
            alpha: 0.4,
            seed: 7,
            ..DataConfig::default()
        });
        let mut trainer = XlaTrainer::new(&mut rt, &manifest, "mlp", data, 0.1)?;
        let cfg = DpasgdConfig {
            rounds,
            s: 1,
            seed: 7,
            eval_every: (rounds / 15).max(1),
            ring_half_weights: false,
        };
        let t0 = std::time::Instant::now();
        let rep = run_experiment(&mut trainer, &overlay, &dm, &cfg)?;
        let real_s = t0.elapsed().as_secs_f64();
        println!(
            "{:<8} cycle {:>6.0} ms | simulated total {:>8.1} s | real compute {:>5.1} s | PJRT step {:>5.2} ms",
            kind.name(),
            rep.cycle_time_ms,
            rep.wallclock_ms.last().unwrap() / 1e3,
            real_s,
            trainer.mean_step_ms(),
        );
        results.push(rep);
    }

    // Summary: loss curves + the time-to-accuracy headline.
    let mut t = Table::new(
        "loss @ checkpoints (identical data/seed per overlay)",
        &["Round", "STAR loss", "MATCHA+ loss", "RING loss", "STAR t(s)", "RING t(s)"],
    );
    for i in 1..=6 {
        let k = i * rounds / 6;
        t.row(vec![
            k.to_string(),
            format!("{:.4}", results[0].train.records[k - 1].train_loss),
            format!("{:.4}", results[1].train.records[k - 1].train_loss),
            format!("{:.4}", results[2].train.records[k - 1].train_loss),
            format!("{:.1}", results[0].wallclock_ms[k] / 1e3),
            format!("{:.1}", results[2].wallclock_ms[k] / 1e3),
        ]);
    }
    t.print();

    let target = 0.80f32;
    println!("\ntime to {:.0}% eval accuracy (simulated):", target * 100.0);
    for rep in &results {
        match rep.time_to_accuracy_ms(target) {
            Some(ms) => println!("  {:<8} {:>8.1} s", rep.overlay, ms / 1e3),
            None => println!("  {:<8} not reached in {rounds} rounds", rep.overlay),
        }
    }

    // JSON report for EXPERIMENTS.md
    let report = Json::obj(vec![
        ("network", Json::str(&network)),
        ("rounds", Json::num(rounds as f64)),
        (
            "overlays",
            Json::arr(results.iter().map(|r| {
                Json::obj(vec![
                    ("name", Json::str(&r.overlay)),
                    ("cycle_time_ms", Json::num(r.cycle_time_ms)),
                    (
                        "final_loss",
                        Json::num(r.train.final_train_loss() as f64),
                    ),
                    (
                        "final_acc",
                        Json::num(
                            r.train
                                .records
                                .last()
                                .and_then(|x| x.test_acc)
                                .unwrap_or(f32::NAN) as f64,
                        ),
                    ),
                    (
                        "total_sim_time_s",
                        Json::num(r.wallclock_ms.last().unwrap() / 1e3),
                    ),
                    (
                        "loss_curve",
                        Json::f64_arr(
                            &r.train
                                .records
                                .iter()
                                .map(|x| x.train_loss as f64)
                                .collect::<Vec<_>>(),
                        ),
                    ),
                ])
            })),
        ),
    ]);
    std::fs::write("e2e_report.json", report.to_string())?;
    println!("\nwrote e2e_report.json");
    Ok(())
}
