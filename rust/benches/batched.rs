//! Batched-vs-per-cell stepping benches — the PR-6 perf-trajectory target.
//!
//! Two levels:
//!
//! * **kernel** — `step_csr_batched_into` over `S = 8` weight lanes vs
//!   8 independent `step_csr_into` calls, on *frozen* identically-perturbed
//!   weights, so the comparison isolates the SoA fold (ns/round and
//!   arcs/s); the batched/per-cell mean ratio is the headline speedup;
//! * **sweep** — `SweepSpec::run_timelines` over a structure-shared grid
//!   with the fast path on vs off (cells/s end to end, reweights included).
//!
//! CI `bench-smoke` runs this under `FEDTOPO_BENCH_QUICK=1` and archives
//! the [`fedtopo::util::bench::BENCH_SCHEMA`] JSON dump
//! (`FEDTOPO_BENCH_JSON=<path>`) as the committed-per-PR `BENCH_<pr>.json`
//! trajectory — see `bench/perf.md`. Wall-clock values never gate.

use fedtopo::coordinator::experiments::sweep::{ModelAxis, SweepSpec};
use fedtopo::fl::workloads::Workload;
use fedtopo::maxplus::csr::{BatchedCsrWeights, CsrDelayDigraph};
use fedtopo::maxplus::recurrence::{
    step_csr_batched_chunked_into, step_csr_batched_into, step_csr_chunked_into, step_csr_into,
};
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::scenario::{BatchedRoundState, Scenario};
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::bench::{quick_mode, Bench};

/// Lane count of the kernel comparison (the sweet spot for one cache line
/// of f64 lanes per arc).
const LANES: usize = 8;

/// A perturbation-heavy composite so the frozen weights are genuinely
/// diverged across lanes.
const SCENARIO: &str = "scenario:drift:0.3+straggler:3:x10+churn:p0.05";

/// Frozen-weight kernel comparison on one underlay: 8 per-cell steps vs
/// one batched pass over the same MST structure and the same weights.
fn bench_kernels(b: &mut Bench, spec: &str) {
    let net = Underlay::by_name(spec).unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    let overlay = design_with_underlay(OverlayKind::Mst, &dm, &net, 0.5).unwrap();
    let ov = dm.delay_csr(overlay.static_graph().unwrap());
    let lanes: Vec<(Scenario, u64)> = (0..LANES)
        .map(|l| (Scenario::by_name(SCENARIO).unwrap(), 7 + l as u64))
        .collect();

    // Freeze one round's perturbed weights, identically on both paths:
    // the batched lane array via BatchedRoundState::reweight, the per-cell
    // CSR clones via each lane's own reweight_parts.
    let mut brs = BatchedRoundState::new(dm.n, &lanes);
    brs.advance();
    let mut w = BatchedCsrWeights::broadcast(&ov.csr, LANES);
    brs.reweight(&dm, &ov.out_deg, &ov.in_deg, &ov.csr, &mut w);
    let mut csrs: Vec<CsrDelayDigraph> = (0..LANES).map(|_| ov.csr.clone()).collect();
    for (l, csr) in csrs.iter_mut().enumerate() {
        brs.lane_state(l).reweight_parts(&dm, &ov.out_deg, &ov.in_deg, csr);
    }

    let n = dm.n;
    let units = (ov.csr.arcs() * LANES) as f64;

    let mut prevs = vec![vec![0.0f64; n]; LANES];
    let mut nexts = vec![vec![0.0f64; n]; LANES];
    b.bench_throughput(
        &format!("per_cell_step_x{LANES}/{spec}"),
        units,
        "arcs",
        || {
            for (l, csr) in csrs.iter().enumerate() {
                step_csr_into(&prevs[l], csr, &mut nexts[l]);
            }
            std::mem::swap(&mut prevs, &mut nexts);
            prevs[0][0]
        },
    );

    let mut prev = vec![0.0f64; n * LANES];
    let mut next = vec![0.0f64; n * LANES];
    b.bench_throughput(
        &format!("batched_step_S{LANES}/{spec}"),
        units,
        "arcs",
        || {
            step_csr_batched_into(&prev, &ov.csr, &w, &mut next);
            std::mem::swap(&mut prev, &mut next);
            prev[0]
        },
    );
}

/// Row-partitioned-vs-sequential kernel comparison (PR 10): the same frozen
/// weights stepped by the sequential oracle and by the chunked kernels at a
/// fixed `parts = 4` with 4 resident intra-cell workers. Outputs are
/// bit-identical (pinned in `tests/csr_equiv.rs`); these rows measure only
/// the wall-clock delta, so the trajectory records where the size gate
/// should sit.
fn bench_chunked_kernels(b: &mut Bench, spec: &str) {
    const PARTS: usize = 4;
    let net = Underlay::by_name(spec).unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    let overlay = design_with_underlay(OverlayKind::Mst, &dm, &net, 0.5).unwrap();
    let ov = dm.delay_csr(overlay.static_graph().unwrap());
    let n = dm.n;

    fedtopo::util::parallel::set_intracell(PARTS);
    let mut prev = vec![0.0f64; n];
    let mut next = vec![0.0f64; n];
    b.bench_throughput(&format!("seq_step/{spec}"), ov.csr.arcs() as f64, "arcs", || {
        step_csr_into(&prev, &ov.csr, &mut next);
        std::mem::swap(&mut prev, &mut next);
        prev[0]
    });
    prev.iter_mut().for_each(|t| *t = 0.0);
    b.bench_throughput(
        &format!("chunked_step_p{PARTS}/{spec}"),
        ov.csr.arcs() as f64,
        "arcs",
        || {
            step_csr_chunked_into(&prev, &ov.csr, &mut next, PARTS);
            std::mem::swap(&mut prev, &mut next);
            prev[0]
        },
    );

    let w = BatchedCsrWeights::broadcast(&ov.csr, LANES);
    let mut bprev = vec![0.0f64; n * LANES];
    let mut bnext = vec![0.0f64; n * LANES];
    b.bench_throughput(
        &format!("batched_chunked_step_S{LANES}_p{PARTS}/{spec}"),
        (ov.csr.arcs() * LANES) as f64,
        "arcs",
        || {
            step_csr_batched_chunked_into(&bprev, &ov.csr, &w, &mut bnext, PARTS);
            std::mem::swap(&mut bprev, &mut bnext);
            bprev[0]
        },
    );
    fedtopo::util::parallel::set_intracell(0);
}

/// End-to-end sweep throughput (design + advance + reweight + step), fast
/// path on vs off, over a structure-shared grid.
fn bench_sweep(b: &mut Bench, rounds: usize) {
    let spec = SweepSpec {
        underlays: vec!["gaia".to_string(), "synth:waxman:60:seed7".to_string()],
        workloads: vec![Workload::inaturalist()],
        backends: vec!["backend:scalar".to_string()],
        models: vec![ModelAxis {
            s: 1,
            access_bps: 10e9,
            core_bps: 1e9,
        }],
        kinds: vec![OverlayKind::Mst, OverlayKind::Ring],
        scenarios: vec![
            "scenario:straggler:3:x10".to_string(),
            "scenario:drift:0.3+churn:p0.05".to_string(),
        ],
        seeds: vec![7, 8, 9, 10],
        c_b: 0.5,
    };
    let cells = spec.cells().len() as f64;
    for (label, batch) in [("batched", true), ("per_cell", false)] {
        b.bench_throughput(
            &format!("sweep_timelines_{rounds}r/{label}"),
            cells,
            "cells",
            || {
                spec.run_timelines(rounds, batch, |_cell, _ctx, tl| {
                    Ok(tl.round_completion(rounds))
                })
                .unwrap()
            },
        );
    }
}

fn main() {
    let quick = quick_mode();
    let mut b = Bench::new();

    let mut specs = vec!["gaia", "synth:waxman:200:seed7"];
    if !quick {
        specs.push("synth:ba:1000:seed7");
    }
    for spec in &specs {
        bench_kernels(&mut b, spec);
    }
    // PR-10 comparison rows: row partitioning pays above the size gate, so
    // the chunked benches run on the largest spec of each mode (plus a
    // deliberately-under-gate small one for the trajectory's contrast row).
    bench_chunked_kernels(&mut b, "gaia");
    bench_chunked_kernels(&mut b, specs[specs.len() - 1]);
    bench_sweep(&mut b, if quick { 30 } else { 100 });

    println!("{}", b.to_json());
    if let Some(path) = b.dump_json_if_requested() {
        println!("bench json written to {path}");
    }
    println!("{}", b.finish());
}
