//! Network-simulator benches: underlay construction, all-pairs routing,
//! Algorithm-3 timeline reconstruction.

use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::routing::{BwModel, Routes};
use fedtopo::netsim::timeline;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    for name in ["gaia", "geant", "ebone"] {
        b.bench(&format!("underlay_build/{name}"), || {
            Underlay::builtin(name).unwrap().n_silos()
        });
        let net = Underlay::builtin(name).unwrap();
        let pairs = (net.n_silos() * (net.n_silos() - 1) / 2) as f64;
        b.bench_throughput(
            &format!("all_pairs_routing/{name}"),
            pairs,
            "pairs",
            || Routes::compute(&net, 1e9, BwModel::MinCapacity).n(),
        );
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let overlay = design_with_underlay(OverlayKind::Mst, &dm, &net, 0.5).unwrap();
        let g = overlay.static_graph().unwrap().clone();
        b.bench(&format!("timeline_200_rounds/{name}"), || {
            timeline::round_completion_ms(&dm, &g, 200).len()
        });
    }
    // GML round-trip on the largest network
    let net = Underlay::builtin("ebone").unwrap();
    let gml_text = net.to_gml();
    b.bench_throughput(
        "gml_parse/ebone",
        gml_text.len() as f64,
        "B",
        || fedtopo::netsim::gml::parse_graph(&gml_text).unwrap().nodes.len(),
    );
    println!("{}", b.finish());
}
