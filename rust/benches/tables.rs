//! End-to-end experiment-harness benches — one per paper table/figure:
//! how long regenerating each artifact of the evaluation takes.

use fedtopo::coordinator::experiments::{bandwidth, cycle_table, fig3, fig4};
use fedtopo::fl::workloads::Workload;
use fedtopo::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let wl = Workload::inaturalist();

    b.bench("table3_single_row/gaia", || {
        cycle_table::cycle_row("gaia", &wl, 1, 10e9, 1e9, 0.5).unwrap().silos
    });
    b.bench("table3_single_row/ebone", || {
        cycle_table::cycle_row("ebone", &wl, 1, 10e9, 1e9, 0.5).unwrap().silos
    });
    b.bench("table9_single_row/gaia_full_inat", || {
        cycle_table::cycle_row("gaia", &Workload::full_inaturalist(), 1, 1e9, 1e9, 0.5)
            .unwrap()
            .silos
    });
    b.bench("fig3a_full_sweep/geant", || {
        fig3::sweep("geant", &wl, 1, 1e9, 0.5, None).unwrap().len()
    });
    b.bench("fig4_full_sweep/exodus", || {
        fig4::sweep("exodus", &wl, 1e9, 1e9, 0.5).unwrap().len()
    });
    b.bench("fig7_bandwidth_dist/geant", || {
        bandwidth::run("geant", 1e9).unwrap().render().len()
    });

    // Ablation: static Eq.-(3) delays (the paper's model) vs the
    // overlay-dependent core-congestion evaluator — both the cost of
    // evaluating them and the resulting cycle-time shift are of interest
    // (the shift itself is printed once).
    {
        use fedtopo::netsim::delay::DelayModel;
        use fedtopo::netsim::underlay::Underlay;
        use fedtopo::topology::{design_with_underlay, OverlayKind};
        let net = Underlay::builtin("geant").unwrap();
        let dm = DelayModel::new(&net, &wl, 1, 10e9, 1e9);
        let mst = design_with_underlay(OverlayKind::Mst, &dm, &net, 0.5).unwrap();
        let g = mst.static_graph().unwrap().clone();
        let tau_static: f64 = {
            let dd = dm.delay_digraph(&g);
            dd.cycle_time()
        };
        let tau_congested: f64 = {
            let mut dd = fedtopo::maxplus::DelayDigraph::new(g.n());
            for i in 0..g.n() {
                dd.arc(i, i, dm.compute_ms(i));
            }
            for (i, j, d) in dm.arc_delays_congested(&g) {
                dd.arc(i, j, d);
            }
            dd.cycle_time()
        };
        println!(
            "ablation geant/mst: τ static {tau_static:.0} ms vs congested {tau_congested:.0} ms"
        );
        b.bench("ablation_congested_delays/geant_mst", || {
            dm.arc_delays_congested(&g).len()
        });
        b.bench("ablation_static_delays/geant_mst", || {
            dm.arc_delays(&g).len()
        });
    }
    println!("{}", b.finish());
}
