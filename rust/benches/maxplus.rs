//! Max-plus engine benches: Karp cycle mean + recurrence simulation.
//!
//! The cycle-time engine sits inside MATCHA's Monte-Carlo loop (thousands of
//! calls per table cell) and Algorithm 1's candidate scan, so it is the L3
//! analytic hot path. §Perf target: ≪ 1 ms at 87 nodes.

use fedtopo::fl::workloads::Workload;
use fedtopo::maxplus::recurrence::Timeline;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    for name in ["gaia", "geant", "ebone"] {
        let net = Underlay::builtin(name).unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let ring = design_with_underlay(OverlayKind::Ring, &dm, &net, 0.5).unwrap();
        let g = ring.static_graph().unwrap().clone();
        let dd = dm.delay_digraph(&g);
        let n = net.n_silos();

        b.bench(&format!("karp_cycle_mean/{name}_n{n}"), || dd.cycle_time());
        b.bench(&format!("delay_digraph_build/{name}_n{n}"), || {
            fedtopo::util::bench::black_box(dm.delay_digraph(&g)).n
        });
        b.bench(&format!("recurrence_100_rounds/{name}_n{n}"), || {
            Timeline::simulate(&dd, 100).rounds()
        });
    }
    // MATCHA Monte-Carlo (the heaviest analytic path): 200 sampled rounds
    let net = Underlay::builtin("geant").unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    let m = fedtopo::topology::matcha::MatchaOverlay::over_graph(&net.core, 0.5);
    b.bench("matcha_mc_cycle_time_200r/geant", || {
        m.average_cycle_time_ms(&dm, 200, 1)
    });
    println!("{}", b.finish());
}
