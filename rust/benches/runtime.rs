//! PJRT runtime benches: artifact compile time, train-step latency, the
//! XLA consensus kernel vs the native Rust mixer.
//!
//! Skips (with a message) when `make artifacts` hasn't run, and requires
//! the off-by-default `xla` cargo feature (the PJRT binding crate is not
//! part of the offline build).

#[cfg(not(feature = "xla"))]
fn main() {
    println!("runtime bench skipped: built without the `xla` feature");
}

#[cfg(feature = "xla")]
use fedtopo::fl::data::{DataConfig, FedDataset};
#[cfg(feature = "xla")]
use fedtopo::fl::dpasgd::LocalTrainer;
#[cfg(feature = "xla")]
use fedtopo::runtime::client::{f32_literal, XlaRuntime};
#[cfg(feature = "xla")]
use fedtopo::runtime::manifest::Manifest;
#[cfg(feature = "xla")]
use fedtopo::runtime::trainer::XlaTrainer;
#[cfg(feature = "xla")]
use fedtopo::util::bench::Bench;
#[cfg(feature = "xla")]
use fedtopo::util::rng::Rng;

#[cfg(feature = "xla")]
fn main() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("runtime bench skipped: no artifacts (run `make artifacts`)");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let mut rt = XlaRuntime::cpu().unwrap();
    let mut b = Bench::new();

    let mlp = manifest.model("mlp").unwrap().clone();
    let data = FedDataset::synthesize(&DataConfig {
        num_silos: 2,
        dim: 64,
        test_samples: 512,
        ..DataConfig::default()
    });
    let mut trainer = XlaTrainer::new(&mut rt, &manifest, "mlp", data, 0.1).unwrap();
    let mut params = trainer.init(0, 1).unwrap();
    let mut rng = Rng::new(2);

    b.bench("pjrt_train_step/mlp_51k", || {
        trainer.step(0, &mut params, &mut rng).unwrap()
    });
    b.bench("pjrt_eval/mlp_51k_512samples", || {
        trainer.eval(&params).unwrap().1
    });

    // XLA consensus kernel vs native mixer at the same size
    let cons = rt.load(&mlp.consensus_file).unwrap();
    let k = mlp.consensus_k;
    let p = mlp.param_count;
    let stacked: Vec<f32> = (0..k * p).map(|i| (i % 97) as f32 * 0.01).collect();
    let mut weights = vec![0.0f32; k];
    weights[..3].copy_from_slice(&[0.5, 0.25, 0.25]);
    b.bench_throughput("xla_consensus_kernel/k8_p51k", (k * p * 4) as f64, "B", || {
        let outs = cons
            .run(&[
                f32_literal(&stacked, &[k, p]).unwrap(),
                f32_literal(&weights, &[k]).unwrap(),
            ])
            .unwrap();
        outs[0].element_count()
    });
    let mut out = vec![0.0f32; p];
    b.bench_throughput("native_consensus_mix/k3_p51k", (3 * p * 4) as f64, "B", || {
        out.iter_mut().for_each(|x| *x = 0.0);
        for (kk, &w) in weights[..3].iter().enumerate() {
            fedtopo::fl::consensus::axpy(w, &stacked[kk * p..(kk + 1) * p], &mut out);
        }
        out[0]
    });

    if let Ok(tf) = manifest.model("transformer") {
        let exe = rt.load(&tf.train_file);
        if let Ok(exe) = exe {
            let params: Vec<f32> = vec![0.01; tf.param_count];
            let x: Vec<i32> = (0..tf.x_shape.iter().product::<usize>())
                .map(|i| (i % 64) as i32)
                .collect();
            let y: Vec<i32> = x.clone();
            b.bench("pjrt_train_step/transformer_420k", || {
                let outs = exe
                    .run(&[
                        f32_literal(&params, &[tf.param_count]).unwrap(),
                        fedtopo::runtime::client::i32_literal(&x, &tf.x_shape).unwrap(),
                        fedtopo::runtime::client::i32_literal(&y, &tf.y_shape).unwrap(),
                        xla::Literal::scalar(0.01f32),
                    ])
                    .unwrap();
                outs[1].to_vec::<f32>().unwrap()[0]
            });
        }
    }
    println!("{}", b.finish());
}
