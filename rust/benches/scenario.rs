//! Scenario benches: static-vs-adaptive simulated wall-clock to round R
//! across scenarios × designers, plus the CPU cost of the dynamic
//! machinery. The grid runs through `coordinator::experiments::robustness`
//! — the same `SweepSpec` path `fedtopo robustness` and the CI determinism
//! gate exercise — instead of a bespoke loop.
//!
//! §Perf targets: adaptive ≥ 1.3× faster (simulated time-to-round-R) than
//! static for the tree designers under `scenario:straggler:3:x10` on gaia,
//! and the per-round dynamic digraph rebuild staying microseconds-cheap so
//! the scenario engine never dominates an experiment.

use fedtopo::coordinator::experiments::robustness::{self, RobustnessConfig};
use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::scenario::{simulate_scenario, Scenario};
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::bench::{quick_mode, Bench};

fn main() {
    let quick = quick_mode();
    let rounds = if quick { 120 } else { 400 };
    let networks: &[&str] = if quick {
        &["gaia"]
    } else {
        &["gaia", "geant", "synth:waxman:200:seed7"]
    };
    let kinds = vec![
        OverlayKind::Star,
        OverlayKind::Mst,
        OverlayKind::DeltaMbst,
        OverlayKind::Ring,
    ];

    println!(
        "static vs adaptive time-to-round-{rounds} (simulated ms; wall = CPU ms for the grid)"
    );
    for net_name in networks {
        for spec in Scenario::builtin_names() {
            let rcfg = RobustnessConfig {
                network: net_name.to_string(),
                workload: Workload::inaturalist(),
                s: 1,
                access_bps: 10e9,
                core_bps: 1e9,
                c_b: 0.5,
                scenario: spec.to_string(),
                rounds,
                window: 20,
                threshold: 1.3,
                seed: 7,
                kinds: kinds.clone(),
                backends: vec!["backend:scalar".to_string()],
                reroute: false,
            };
            let t0 = std::time::Instant::now();
            let rows = robustness::run(&rcfg).unwrap();
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            for r in &rows {
                println!(
                    "{:<28} {:<28} {:<11} {:>12.0} {:>12.0} {:>7.2}x {:>10} {:>8.0}ms",
                    net_name,
                    spec,
                    r.kind.name(),
                    r.static_ms,
                    r.adaptive_ms,
                    r.speedup(),
                    r.redesign_rounds.len(),
                    wall_ms / rows.len() as f64
                );
            }
        }
    }

    // CPU cost of the dynamic machinery itself.
    let mut b = Bench::new();
    let net = Underlay::builtin("gaia").unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    let ring = design_with_underlay(OverlayKind::Ring, &dm, &net, 0.5).unwrap();
    let g = ring.static_graph().unwrap().clone();
    for spec in ["scenario:identity", "scenario:drift:0.3+churn:p0.01"] {
        let sc = Scenario::by_name(spec).unwrap();
        b.bench(&format!("round_state/{spec}"), || {
            sc.process(dm.n, 7).advance()
        });
        b.bench(&format!("simulate_100_rounds/{spec}"), || {
            simulate_scenario(&dm, &g, &sc, 100, 7).round_completion(100)
        });
    }
    b.bench("static_simulate_100_rounds/baseline", || {
        fedtopo::maxplus::recurrence::Timeline::simulate(&dm.delay_digraph(&g), 100)
            .round_completion(100)
    });
    println!("{}", b.to_json());
    println!("{}", b.finish());
}
