//! Scale benches: Karp vs Howard max-cycle-mean, synthetic underlay
//! generation, and the full designer sweep as N grows — the designer grid
//! runs through the same `SweepSpec` path the CLI and the CI determinism
//! gate exercise (`coordinator::experiments::scale::sweep_rows`), not a
//! bespoke loop.
//!
//! §Perf targets: Howard ≥ 10× faster than Karp at N ≥ 500 on a Waxman
//! RING delay digraph (the ISSUE-1 acceptance bar), and sub-second
//! generator + designer time at N = 1000.

use fedtopo::coordinator::experiments::scale;
use fedtopo::fl::workloads::Workload;
use fedtopo::maxplus::{cycle_time_with, CycleSolver};
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::bench::{quick_mode, Bench};

fn main() {
    let mut b = Bench::new();
    let quick = quick_mode();
    let sizes: &[usize] = if quick { &[100, 500] } else { &[100, 500, 1000, 2000] };

    for &n in sizes {
        let spec = format!("synth:waxman:{n}:seed7");
        let net = Underlay::by_name(&spec).unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let ring = design_with_underlay(OverlayKind::Ring, &dm, &net, 0.5).unwrap();
        let dd = dm.delay_digraph(ring.static_graph().unwrap());

        b.bench(&format!("karp_cycle_mean/waxman_n{n}"), || {
            cycle_time_with(&dd, CycleSolver::Karp)
        });
        b.bench(&format!("howard_cycle_mean/waxman_n{n}"), || {
            cycle_time_with(&dd, CycleSolver::Howard)
        });
        b.bench(&format!("dispatch_auto/waxman_n{n}"), || dd.cycle_time());
    }

    // Underlay generators, one sample per family.
    let n = if quick { 200 } else { 1000 };
    for family in ["waxman", "ba", "geo", "grid"] {
        b.bench(&format!("generate/{family}_n{n}"), || {
            Underlay::by_name(&format!("synth:{family}:{n}:seed7")).unwrap().n_links()
        });
    }

    // The full sizes × designers grid through the SweepSpec engine — the
    // exact code path `fedtopo scale` and the CI determinism job run.
    // FEDTOPO_JOBS (or --jobs on the CLI) scales it across cores.
    let grid_sizes: &[usize] = if quick { &[100, 200] } else { &[200, 500, 1000] };
    let t0 = std::time::Instant::now();
    let rows = scale::sweep_rows(
        "waxman",
        grid_sizes,
        &Workload::inaturalist(),
        1,
        10e9,
        1e9,
        0.5,
        7,
    )
    .unwrap();
    println!(
        "sweep_rows waxman {grid_sizes:?}: {:.0} ms wall",
        t0.elapsed().as_secs_f64() * 1e3
    );
    scale::render("waxman", &Workload::inaturalist(), 1, 10e9, 0.5, 7, &rows).print();

    println!("{}", b.to_json());
    println!("{}", b.finish());
}
