//! Scale benches: Karp vs Howard max-cycle-mean, synthetic underlay
//! generation, and full designer runs as N grows.
//!
//! §Perf targets: Howard ≥ 10× faster than Karp at N ≥ 500 on a Waxman
//! RING delay digraph (the ISSUE-1 acceptance bar), and sub-second
//! generator + designer time at N = 1000.

use fedtopo::fl::workloads::Workload;
use fedtopo::maxplus::{cycle_time_with, CycleSolver};
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let quick = std::env::var("FEDTOPO_BENCH_QUICK").is_ok();
    let sizes: &[usize] = if quick { &[100, 500] } else { &[100, 500, 1000, 2000] };

    for &n in sizes {
        let spec = format!("synth:waxman:{n}:seed7");
        let net = Underlay::by_name(&spec).unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let ring = design_with_underlay(OverlayKind::Ring, &dm, &net, 0.5).unwrap();
        let dd = dm.delay_digraph(ring.static_graph().unwrap());

        b.bench(&format!("karp_cycle_mean/waxman_n{n}"), || {
            cycle_time_with(&dd, CycleSolver::Karp)
        });
        b.bench(&format!("howard_cycle_mean/waxman_n{n}"), || {
            cycle_time_with(&dd, CycleSolver::Howard)
        });
        b.bench(&format!("dispatch_auto/waxman_n{n}"), || dd.cycle_time());
    }

    // One-shot wall-time report (generation + each designer) at N = 1000 —
    // coarse numbers for EXPERIMENTS.md §Perf, cheaper than full benching.
    let n = if quick { 200 } else { 1000 };
    let t0 = std::time::Instant::now();
    let net = Underlay::by_name(&format!("synth:waxman:{n}:seed7")).unwrap();
    println!(
        "generate waxman n={n}: {:.1} ms ({} links)",
        t0.elapsed().as_secs_f64() * 1e3,
        net.n_links()
    );
    let t0 = std::time::Instant::now();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    println!("routes n={n}: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    for kind in OverlayKind::all() {
        let t0 = std::time::Instant::now();
        let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
        let tau = overlay.cycle_time_ms(&dm);
        println!(
            "design+tau {:<10} n={n}: {:>8.1} ms (tau {:.0} ms)",
            kind.name(),
            t0.elapsed().as_secs_f64() * 1e3,
            tau
        );
    }
    for family in ["waxman", "ba", "geo", "grid"] {
        let t0 = std::time::Instant::now();
        let u = Underlay::by_name(&format!("synth:{family}:{n}:seed7")).unwrap();
        println!(
            "generate {family:<7} n={n}: {:>7.1} ms ({} links)",
            t0.elapsed().as_secs_f64() * 1e3,
            u.n_links()
        );
    }
    println!("{}", b.finish());
}
