//! Train-engine benches: wall-time of the coupled DPASGD + timeline engine
//! per scenario, and of a full `fedtopo train` grid — the same
//! `coordinator::experiments::train` path the CLI and the CI determinism
//! gate exercise, folded onto `util::bench` like every other bench.
//!
//! §Perf target: the timeline + monitor machinery must stay a small
//! fraction of the training cost (the mixing AXPY and trainer steps
//! dominate), so coupling the loops never makes an experiment slower than
//! running them separately did.

use fedtopo::coordinator::experiments::train::{self, TrainConfig};
use fedtopo::fl::dpasgd::{self, DpasgdConfig, QuadraticTrainer};
use fedtopo::fl::trainsim::{self, TrainSimConfig};
use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::scenario::Scenario;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::bench::{quick_mode, Bench};

fn main() {
    let quick = quick_mode();
    let rounds = if quick { 40 } else { 120 };

    let net = Underlay::builtin("gaia").unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);

    let mut b = Bench::new();
    for spec in ["scenario:identity", "scenario:straggler:3:x10"] {
        let sc = Scenario::by_name(spec).unwrap();
        for (label, threshold) in [("static", f64::INFINITY), ("adaptive", 1.3)] {
            let cfg = TrainSimConfig {
                rounds,
                eval_every: 10,
                threshold,
                ..Default::default()
            };
            b.bench(&format!("trainsim_{rounds}r/{spec}/{label}"), || {
                let mut tr = QuadraticTrainer::new(dm.n, 16, 3);
                trainsim::run(&mut tr, OverlayKind::Mst, &dm, &net, &sc, &cfg)
                    .unwrap()
                    .total_ms()
            });
        }
    }

    // Decoupled reference: training alone (what the old fig2 loop paid
    // before the after-the-fact timeline replay).
    let overlay = design_with_underlay(OverlayKind::Mst, &dm, &net, 0.5).unwrap();
    b.bench(&format!("dpasgd_only_{rounds}r/baseline"), || {
        let mut tr = QuadraticTrainer::new(dm.n, 16, 3);
        let cfg = DpasgdConfig {
            rounds,
            eval_every: 10,
            ..Default::default()
        };
        dpasgd::run(&mut tr, &overlay, &cfg).unwrap().final_train_loss()
    });

    // Full grid through the experiment layer (CPU wall for the sweep; the
    // report itself contains only simulated quantities).
    let gcfg = TrainConfig {
        kinds: vec![OverlayKind::Star, OverlayKind::Mst, OverlayKind::Ring],
        scenarios: vec![
            "scenario:identity".to_string(),
            "scenario:straggler:3:x10".to_string(),
        ],
        rounds,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let rows = train::run(&gcfg).unwrap();
    println!(
        "train grid: {} cells in {:.0} ms (CPU)",
        rows.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    for r in &rows {
        println!(
            "{:<12} {:<28} {:<11} λ*={:>7.1}ms t_total={:>9.0}ms re-designs={}",
            r.network,
            r.scenario,
            r.kind.name(),
            r.lambda_star_ms,
            r.total_ms,
            r.redesign_rounds.len()
        );
    }

    println!("{}", b.to_json());
    println!("{}", b.finish());
}
