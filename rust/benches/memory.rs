//! Allocation gate for the PR-5 zero-alloc round stepping — not a timing
//! bench: a **counting global allocator** proves that the per-round hot
//! loops allocate nothing once warm.
//!
//! Two gate styles:
//!
//! * **windowed** — drive the scenario → reweight → `step_csr` loop (the
//!   exact composition `topology::adaptive` and `fl::trainsim` run) for a
//!   warm-up, snapshot the allocation counter, run N more rounds, and
//!   assert the counter did not move at all;
//! * **count-invariance** — whole-engine runs (`simulate_scenario`,
//!   `fl::trainsim::run`) at two different horizons must perform the *same
//!   number* of allocations: every buffer is sized by `rounds` (one
//!   allocation regardless of magnitude), so any per-round allocation
//!   would scale the count with the horizon.
//!
//! Wired into CI `bench-smoke` (`cargo bench --bench memory`), where
//! `FEDTOPO_BENCH_QUICK=1` shrinks the underlay and horizons.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fedtopo::fl::dpasgd::QuadraticTrainer;
use fedtopo::fl::trainsim::{self, TrainSimConfig};
use fedtopo::fl::workloads::Workload;
use fedtopo::maxplus::csr::BatchedCsrWeights;
use fedtopo::maxplus::recurrence::step_csr_batched_into;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::scenario::{simulate_scenario, BatchedRoundState, RoundState, Scenario};
use fedtopo::netsim::timeline::DynamicTimeline;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::bench::quick_mode;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(l.size() as u64, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(l.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Cumulative bytes requested from the allocator (never decremented —
/// freed memory still counts, which is exactly what the sub-quadratic
/// routing gate wants: a transient O(N²) grid can't hide behind a free).
fn bytes() -> u64 {
    BYTES.load(Ordering::Relaxed)
}

/// The composite that exercises every perturbation family's apply path.
const SCENARIO: &str =
    "scenario:drift:0.3+straggler:3:x10+churn:p0.05+silo-churn:p0.02+outage:4:p0.1:x3";

/// Windowed gate: the adaptive/trainsim round composition (advance_into →
/// reweight → step_csr) must perform ZERO allocations once warm.
fn gate_round_loop_zero_alloc(spec: &str, warm: usize, measure: usize) {
    let net = Underlay::by_name(spec).unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    for kind in [OverlayKind::Mst, OverlayKind::Ring] {
        let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
        let g = overlay.static_graph().unwrap();
        let mut ov = dm.delay_csr(g);
        let sc = Scenario::by_name(SCENARIO).unwrap();
        let mut proc = sc.process(dm.n, 7);
        let mut st = RoundState::unperturbed(dm.n, 0);
        let mut tl = DynamicTimeline::with_capacity(dm.n, warm + measure);
        for _ in 0..warm {
            proc.advance_into(&mut st);
            st.reweight(&dm, &mut ov);
            tl.step_csr(&ov.csr);
        }
        let before = allocs();
        for _ in 0..measure {
            proc.advance_into(&mut st);
            st.reweight(&dm, &mut ov);
            tl.step_csr(&ov.csr);
        }
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "{spec}/{kind:?}: {delta} allocations over {measure} warm rounds (must be 0)"
        );
        println!("round-loop {spec}/{}: 0 allocations over {measure} warm rounds ✓", kind.name());
        assert!(tl.last_completion_ms().is_finite());
    }
}

/// Windowed gate on the PR-6 batched SoA loop: advance `lanes` scenario
/// realizations → batched reweight → `step_csr_batched_into` must perform
/// ZERO allocations once warm, exactly like the per-cell loop it batches.
fn gate_batched_round_loop_zero_alloc(spec: &str, lanes: usize, warm: usize, measure: usize) {
    let net = Underlay::by_name(spec).unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    let overlay = design_with_underlay(OverlayKind::Mst, &dm, &net, 0.5).unwrap();
    let g = overlay.static_graph().unwrap();
    let ov = dm.delay_csr(g);
    let lane_specs: Vec<(Scenario, u64)> = (0..lanes)
        .map(|l| (Scenario::by_name(SCENARIO).unwrap(), 7 + l as u64))
        .collect();
    let mut brs = BatchedRoundState::new(dm.n, &lane_specs);
    let mut w = BatchedCsrWeights::broadcast(&ov.csr, lanes);
    let mut prev = vec![0.0f64; dm.n * lanes];
    let mut next = vec![0.0f64; dm.n * lanes];
    let mut round = |prev: &mut Vec<f64>, next: &mut Vec<f64>| {
        brs.advance();
        brs.reweight(&dm, &ov.out_deg, &ov.in_deg, &ov.csr, &mut w);
        step_csr_batched_into(prev, &ov.csr, &w, next);
        std::mem::swap(prev, next);
    };
    for _ in 0..warm {
        round(&mut prev, &mut next);
    }
    let before = allocs();
    for _ in 0..measure {
        round(&mut prev, &mut next);
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "{spec}: {delta} allocations over {measure} warm batched rounds × {lanes} lanes (must be 0)"
    );
    assert!(prev.iter().all(|t| t.is_finite()));
    println!(
        "batched round-loop {spec} (S={lanes}): 0 allocations over {measure} warm rounds ✓"
    );
}

/// PR-10 gate: the row-partitioned round loop — advance → reweight →
/// `step_csr_chunked_into` fanned across 4 resident intra-cell workers —
/// must perform ZERO allocations once warm, exactly like the sequential
/// loop it partitions. Everything per-worker (the threads themselves, the
/// pool's state) is paid once at pool spawn, which the warm-up window
/// absorbs; a dispatch is an epoch bump plus an atomic cursor, never a
/// per-part buffer. The chunked kernel is called directly (not through the
/// size gate) so the gate holds even for cells the auto dispatcher would
/// keep sequential.
fn gate_parallel_round_loop_zero_alloc(spec: &str, warm: usize, measure: usize) {
    use fedtopo::maxplus::recurrence::step_csr_chunked_into;
    use fedtopo::util::parallel::set_intracell;
    const PARTS: usize = 4;
    set_intracell(PARTS);
    let net = Underlay::by_name(spec).unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    let overlay = design_with_underlay(OverlayKind::Mst, &dm, &net, 0.5).unwrap();
    let mut ov = dm.delay_csr(overlay.static_graph().unwrap());
    let sc = Scenario::by_name(SCENARIO).unwrap();
    let mut proc = sc.process(dm.n, 7);
    let mut st = RoundState::unperturbed(dm.n, 0);
    let mut prev = vec![0.0f64; dm.n];
    let mut next = vec![0.0f64; dm.n];
    let mut round = |prev: &mut Vec<f64>, next: &mut Vec<f64>| {
        proc.advance_into(&mut st);
        st.reweight(&dm, &mut ov);
        step_csr_chunked_into(prev, &ov.csr, next, PARTS);
        std::mem::swap(prev, next);
    };
    for _ in 0..warm {
        round(&mut prev, &mut next);
    }
    let before = allocs();
    for _ in 0..measure {
        round(&mut prev, &mut next);
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "{spec}: {delta} allocations over {measure} warm chunked rounds × {PARTS} parts (must be 0)"
    );
    assert!(prev.iter().all(|t| t.is_finite()));
    set_intracell(0);
    println!(
        "parallel round-loop {spec} (parts={PARTS}): 0 allocations over {measure} warm rounds ✓"
    );
}

/// Count-invariance gate on `simulate_scenario`: the allocation COUNT must
/// not depend on the horizon (buffers are sized by `rounds` in one
/// allocation each; a per-round allocation would scale the count).
fn gate_simulate_scenario_count_invariant(spec: &str, r1: usize, r2: usize) {
    let net = Underlay::by_name(spec).unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    let overlay = design_with_underlay(OverlayKind::Mst, &dm, &net, 0.5).unwrap();
    let g = overlay.static_graph().unwrap();
    let sc = Scenario::by_name(SCENARIO).unwrap();
    let count = |rounds: usize| {
        let before = allocs();
        let tl = simulate_scenario(&dm, g, &sc, rounds, 7);
        assert!(tl.round_completion(rounds).is_finite());
        allocs() - before
    };
    // prime once (first run may warm lazily-initialized runtime state)
    count(r1);
    let a = count(r1);
    let b = count(r2);
    assert_eq!(
        a, b,
        "{spec}: simulate_scenario allocation count scales with rounds ({r1}→{a}, {r2}→{b})"
    );
    println!("simulate_scenario {spec}: {a} allocations at both {r1} and {r2} rounds ✓");
}

/// Count-invariance gate on the coupled training engine: same number of
/// allocations for a 3× longer horizon (eval disabled — evaluation
/// legitimately allocates the mean model; the *rounds* must not).
fn gate_trainsim_count_invariant(r1: usize, r2: usize) {
    let net = Underlay::builtin("gaia").unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    let sc = Scenario::by_name(SCENARIO).unwrap();
    let count = |rounds: usize| {
        let mut tr = QuadraticTrainer::new(dm.n, 8, 3);
        let cfg = TrainSimConfig {
            rounds,
            eval_every: 0,
            threshold: f64::INFINITY,
            ..Default::default()
        };
        let before = allocs();
        let rep = trainsim::run(&mut tr, OverlayKind::Mst, &dm, &net, &sc, &cfg).unwrap();
        assert!(rep.total_ms().is_finite());
        allocs() - before
    };
    count(r1);
    let a = count(r1);
    let b = count(r2);
    assert_eq!(
        a, b,
        "trainsim allocation count scales with rounds ({r1}→{a}, {r2}→{b})"
    );
    println!("trainsim gaia: {a} allocations at both {r1} and {r2} rounds ✓");
}

/// PR-7 gate: building `Routes` above the tier gate must never materialize
/// an O(N²) product. A dense latency grid alone is 8·N² bytes; the gate
/// asserts the *cumulative* bytes of the whole construction (landmark
/// Dijkstras included) stay under N²/4 — 32× below the dense backend — at
/// two sizes, so quadratic allocation cannot hide in constants.
fn gate_routes_tiered_sub_quadratic(n1: usize, n2: usize) {
    use fedtopo::netsim::routing::{BwModel, Routes, RoutingTier, ROUTES_DENSE_MAX_N};
    assert!(n1 > ROUTES_DENSE_MAX_N && n2 > ROUTES_DENSE_MAX_N);
    let measure = |n: usize| {
        let net = Underlay::by_name(&format!("synth:ba:{n}:seed7")).unwrap();
        let before = bytes();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        assert_eq!(r.tier(), RoutingTier::Landmark);
        // touch a few pairs so the lazy row path allocates what it will
        assert!(r.lat_ms(0, n - 1).is_finite());
        assert!(r.lat_ms(n / 2, n / 3) > 0.0);
        bytes() - before
    };
    for n in [n1, n2] {
        let used = measure(n);
        let cap = (n as u64) * (n as u64) / 4;
        assert!(
            used < cap,
            "Routes::compute at N={n} allocated {used} cumulative bytes \
             (≥ N²/4 = {cap}: an O(N²) product is back)"
        );
        println!(
            "tiered Routes N={n}: {:.1} MB cumulative < N²/4 = {:.1} MB ✓",
            used as f64 / 1e6,
            cap as f64 / 1e6
        );
    }
}

fn main() {
    let quick = quick_mode();
    let spec = if quick {
        "synth:waxman:60:seed7"
    } else {
        "synth:waxman:200:seed7"
    };
    let (warm, measure) = if quick { (20, 60) } else { (40, 200) };
    let lanes = if quick { 4 } else { 8 };
    gate_round_loop_zero_alloc(spec, warm, measure);
    gate_round_loop_zero_alloc("gaia", warm, measure);
    gate_batched_round_loop_zero_alloc(spec, lanes, warm, measure);
    gate_batched_round_loop_zero_alloc("gaia", lanes, warm, measure);
    gate_parallel_round_loop_zero_alloc(spec, warm, measure);
    gate_parallel_round_loop_zero_alloc("gaia", warm, measure);
    gate_simulate_scenario_count_invariant(spec, 40, 130);
    gate_trainsim_count_invariant(30, 90);
    if quick {
        gate_routes_tiered_sub_quadratic(4200, 8400);
    } else {
        gate_routes_tiered_sub_quadratic(6000, 12000);
    }
    println!("memory gates passed: per-round allocation count is 0 after warm-up");
}
