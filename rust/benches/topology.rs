//! Topology designer benches — one per Table-1 algorithm.
//!
//! §Perf target: designing any overlay for any built-in network ≪ 100 ms
//! (the orchestrator recomputes topologies "only occasionally", but the
//! Fig-3 sweeps call every designer dozens of times).

use fedtopo::fl::workloads::Workload;
use fedtopo::graph::matching::matching_decomposition;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{mbst, mst, ring, star};
use fedtopo::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    for name in ["gaia", "aws-na", "geant", "ebone"] {
        let net = Underlay::builtin(name).unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let n = net.n_silos();

        b.bench(&format!("design_star/{name}_n{n}"), || star::design(&dm).n());
        b.bench(&format!("design_mst/{name}_n{n}"), || mst::design(&dm).n());
        b.bench(&format!("design_ring/{name}_n{n}"), || {
            ring::design(&dm, false).n()
        });
        b.bench(&format!("design_ring_2opt/{name}_n{n}"), || {
            ring::design(&dm, true).n()
        });
        b.bench(&format!("design_delta_mbst/{name}_n{n}"), || {
            mbst::design(&dm).n()
        });
        b.bench(&format!("matching_decomposition/{name}"), || {
            matching_decomposition(&net.core).len()
        });
    }
    println!("{}", b.finish());
}
