//! Consensus-mixing benches — the L3 request-path hot loop.
//!
//! At Ebone scale the coordinator mixes 87 silo models per round; for the
//! iNaturalist ResNet-18 a model is 11.2 M f32 (~45 MB). §Perf target:
//! memory-bandwidth-bound AXPY (≥ 4 GB/s on one core).

use fedtopo::fl::consensus::{axpy, ConsensusMatrix};
use fedtopo::graph::UnGraph;
use fedtopo::util::bench::Bench;

fn ring_matrix(n: usize) -> ConsensusMatrix {
    let mut g = UnGraph::new(n);
    for i in 0..n {
        if !g.has_edge(i, (i + 1) % n) {
            g.add_edge(i, (i + 1) % n, 1.0);
        }
    }
    ConsensusMatrix::local_degree(&g.to_digraph())
}

fn main() {
    let mut b = Bench::new();

    // raw AXPY at three model scales
    for (label, p) in [("mlp_51k", 50_826), ("transformer_420k", 419_712), ("resnet18_11m", 11_217_000)] {
        let x = vec![0.5f32; p];
        let mut out = vec![0.0f32; p];
        b.bench_throughput(
            &format!("axpy/{label}"),
            (p * 4) as f64,
            "B",
            || {
                axpy(0.25, &x, &mut out);
                out[0]
            },
        );
    }

    // full consensus round: ring of N silos, per-silo mixing.
    // `apply_into` is the DPASGD hot path (ping-pong buffers, no alloc);
    // `apply` includes the allocation cost for comparison.
    for (n, p) in [(11usize, 419_712usize), (87, 419_712)] {
        let a = ring_matrix(n);
        let params: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32; p]).collect();
        let mut out: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0; p]).collect();
        b.bench_throughput(
            &format!("consensus_round_into/n{n}_p{p}"),
            (n * 3 * p * 4) as f64, // each silo reads deg+1≈3 models
            "B",
            || {
                a.apply_into(&params, &mut out);
                out[0][0]
            },
        );
        b.bench_throughput(
            &format!("consensus_round_alloc/n{n}_p{p}"),
            (n * 3 * p * 4) as f64,
            "B",
            || a.apply(&params).len(),
        );
    }
    println!("{}", b.finish());
}
