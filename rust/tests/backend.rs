//! Integration tests for the message-level backend layer: the scalar
//! default must be bit-identical to the pre-backend arithmetic end to end,
//! a backend axis must get its own common-random-number slice while
//! designers stay paired inside it, the re-route action must replay
//! deterministically, and malformed backend specs must fail with the
//! pinned registry error format.

use fedtopo::coordinator::experiments as exp;
use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::backend::BackendProfile;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};

/// `backend:scalar` prices every designer on every builtin exactly like
/// the pre-backend delay model — same bits, not just same values.
#[test]
fn scalar_backend_is_bit_identical_across_builtins_and_designers() {
    let wl = Workload::inaturalist();
    for name in Underlay::builtin_names() {
        let net = Underlay::by_name(name).unwrap();
        let plain = DelayModel::new(&net, &wl, 1, 10e9, 1e9);
        let scalar = DelayModel::new(&net, &wl, 1, 10e9, 1e9)
            .with_backend(BackendProfile::by_name("backend:scalar").unwrap());
        for kind in OverlayKind::all() {
            let a = design_with_underlay(kind, &plain, &net, 0.5)
                .unwrap()
                .cycle_time_ms(&plain);
            let b = design_with_underlay(kind, &scalar, &net, 0.5)
                .unwrap()
                .cycle_time_ms(&scalar);
            assert_eq!(a.to_bits(), b.to_bits(), "{name} / {}", kind.name());
        }
    }
}

/// The backend-extended scale pipeline with an explicit scalar axis
/// reproduces the legacy entry point byte for byte, report included.
#[test]
fn scale_report_with_explicit_scalar_axis_matches_the_legacy_path() {
    let wl = Workload::femnist();
    let specs = vec!["gaia".to_string(), "geant".to_string()];
    let kinds = vec![OverlayKind::Mst, OverlayKind::Ring];
    let legacy =
        exp::scale::sweep_rows_specs_kinds(specs.clone(), kinds.clone(), &wl, 1, 10e9, 1e9, 0.5, 7)
            .unwrap();
    let scalar = exp::scale::sweep_rows_specs_kinds_backends(
        specs,
        kinds,
        vec!["backend:scalar".to_string()],
        &wl,
        1,
        10e9,
        1e9,
        0.5,
        7,
    )
    .unwrap();
    assert_eq!(legacy.len(), scalar.len());
    for (a, b) in legacy.iter().zip(&scalar) {
        assert_eq!(a.spec, b.spec);
        for kind in [OverlayKind::Mst, OverlayKind::Ring] {
            assert_eq!(a.tau_of(kind).to_bits(), b.tau_of(kind).to_bits(), "{}", a.spec);
        }
    }
    // deterministic report fields only (solver wall times are excluded
    // from to_json), so the whole document is byte-comparable
    let doc = |rows| exp::scale::to_json("custom", &wl, 1, 10e9, 1e9, 0.5, 7, rows).to_string();
    assert_eq!(doc(&legacy), doc(&scalar));
    assert!(!doc(&legacy).contains("\"backend\""), "default shape must stay pre-backend");
}

/// A backend axis is its own CRN slice: designers inside one backend share
/// their perturbation/init draws (paired comparison), while distinct
/// backends draw independently — exactly like the workload axis.
#[test]
fn backend_axis_pairs_designers_within_a_slice_and_separates_slices() {
    let cfg = exp::train::TrainConfig {
        networks: vec!["gaia".to_string()],
        workloads: vec![Workload::femnist()],
        backends: vec!["backend:scalar".to_string(), "backend:grpc".to_string()],
        kinds: vec![OverlayKind::Mst, OverlayKind::Ring],
        scenarios: vec!["scenario:straggler:3:x10".to_string()],
        seeds: vec![7],
        s: 1,
        access_bps: 10e9,
        core_bps: 1e9,
        c_b: 0.5,
        rounds: 8,
        eval_every: 4,
        window: 20,
        threshold: f64::INFINITY,
        target_acc: 0.5,
        dim: 8,
    };
    let rows = exp::train::run(&cfg).unwrap();
    assert_eq!(rows.len(), 4);
    // enumeration is backend-major over designers: (scalar, Mst),
    // (scalar, Ring), (grpc, Mst), (grpc, Ring)
    assert_eq!(rows[0].backend, "backend:scalar");
    assert_eq!(rows[1].backend, "backend:scalar");
    assert_eq!(rows[2].backend, "backend:grpc");
    assert_eq!(rows[3].backend, "backend:grpc");
    assert_eq!(rows[0].kind, rows[2].kind);
    // within a slice, both designers trained the same initial model
    assert_eq!(
        rows[0].initial_train_loss.to_bits(),
        rows[1].initial_train_loss.to_bits()
    );
    assert_eq!(
        rows[2].initial_train_loss.to_bits(),
        rows[3].initial_train_loss.to_bits()
    );
    // across slices the draws are independent (distinct pair seeds)
    assert_ne!(
        rows[0].initial_train_loss.to_bits(),
        rows[2].initial_train_loss.to_bits()
    );
    // the designed promise compares across slices even though the
    // perturbation draws do not (λ* is priced on the unperturbed model,
    // and grpc dominates scalar edge-wise), so overhead only slows it
    assert!(rows[2].lambda_star_ms > rows[0].lambda_star_ms);
    assert!(rows[3].lambda_star_ms > rows[1].lambda_star_ms);
}

/// The re-route arm's decisions replay bit-for-bit: two runs of the same
/// robustness race produce byte-identical reports, fire rounds included.
#[test]
fn reroute_decision_trace_replays_deterministically() {
    let cfg = exp::robustness::RobustnessConfig {
        network: "gaia".to_string(),
        workload: Workload::inaturalist(),
        s: 1,
        access_bps: 10e9,
        core_bps: 1e9,
        c_b: 0.5,
        scenario: "scenario:straggler:3:x10".to_string(),
        rounds: 120,
        window: 20,
        threshold: 1.3,
        seed: 7,
        kinds: vec![OverlayKind::Mst],
        backends: vec!["backend:scalar".to_string()],
        reroute: true,
    };
    let first = exp::robustness::run(&cfg).unwrap();
    let second = exp::robustness::run(&cfg).unwrap();
    let doc = |rows| exp::robustness::to_json(&cfg, rows).to_string();
    assert_eq!(doc(&first), doc(&second));
    // the race actually ran: the re-route arm reported, and its fire
    // rounds replay identically
    assert!(first[0].reroute_ms.is_some());
    assert_eq!(first[0].reroute_rounds, second[0].reroute_rounds);
    assert!(doc(&first).contains("\"actions\":[\"design\",\"reroute\"]"));
}

/// Malformed backend specs fail with the registry's pinned error format —
/// the full string is API (clients and the serve protocol surface it).
#[test]
fn malformed_backend_spec_error_is_pinned() {
    let err = BackendProfile::by_name("grpc:pipe0").unwrap_err().to_string();
    assert_eq!(
        err,
        "cannot resolve backend 'grpc:pipe0': pipeline depth must be ≥ 1; \
         expected scalar | grpc | rdma, modifiers :chunk<bytes>[k|M|G], \
         :over<ms>, :pipe<depth> (e.g. grpc:chunk4M), optional 'backend:' \
         prefix"
    );
}
