//! PR-3 determinism gates, in-process: the ordered-merge contract of
//! `util::parallel`, the per-item seeding rule, and jobs-invariant
//! experiment output (`fedtopo scale` / `fedtopo robustness` JSON and the
//! MATCHA Monte-Carlo estimate). CI's `determinism` job enforces the same
//! property end-to-end by byte-comparing the binary's output across
//! `--jobs 1` and `--jobs 4`.
//!
use fedtopo::coordinator::experiments::robustness::{self, RobustnessConfig};
use fedtopo::coordinator::experiments::{cycle_table, scale};
use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::matcha::MatchaOverlay;
use fedtopo::topology::OverlayKind;
use fedtopo::util::parallel::{par_map_indexed_with, set_jobs};
use fedtopo::util::prop;
use std::sync::Mutex;

/// Serializes every test that flips the global jobs override — without it,
/// two concurrent `with_jobs` tests could compute both sides of a
/// parallel-vs-sequential pin at the same width, passing vacuously.
static JOBS_LOCK: Mutex<()> = Mutex::new(());

/// Evaluate `f` under an explicit worker count (exclusively — see
/// [`JOBS_LOCK`]), restoring auto after.
fn with_jobs<T>(jobs: usize, f: impl FnOnce() -> T) -> T {
    let _guard = JOBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_jobs(jobs);
    let out = f();
    set_jobs(0);
    out
}

#[test]
fn par_map_indexed_order_and_determinism_prop() {
    prop::check("ordered merge is jobs-invariant", 40, |g| {
        let v = g.vec_f64(0, 60);
        let reference: Vec<(usize, u64)> = v
            .iter()
            .enumerate()
            .map(|(i, x)| (i, (x * 3.5 + i as f64).to_bits()))
            .collect();
        for jobs in [1usize, 2, 7] {
            let got =
                par_map_indexed_with(jobs, &v, |i, x: &f64| (i, (x * 3.5 + i as f64).to_bits()));
            assert_eq!(got, reference, "jobs={jobs}");
        }
    });
}

#[test]
fn par_map_indexed_panic_propagates_for_every_worker_count() {
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    for jobs in [1usize, 2, 7] {
        let items: Vec<usize> = (0..24).collect();
        let r = std::panic::catch_unwind(|| {
            par_map_indexed_with(jobs, &items, |i, &x| {
                if x == 13 {
                    panic!("deterministic boom at {i}");
                }
                x
            })
        });
        let payload = r.expect_err("panic must cross the pool");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(
            msg.contains("deterministic boom at 13"),
            "jobs={jobs}: payload was '{msg}'"
        );
    }
    std::panic::set_hook(hook);
}

#[test]
fn matcha_parallel_monte_carlo_bit_identical_to_sequential_on_gaia() {
    let net = Underlay::builtin("gaia").unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    for overlay in [
        MatchaOverlay::over_complete(net.n_silos(), 0.5),
        MatchaOverlay::over_graph(&net.core, 0.5),
    ] {
        let sequential = with_jobs(1, || overlay.average_cycle_time_ms(&dm, 400, 42));
        let parallel = with_jobs(4, || overlay.average_cycle_time_ms(&dm, 400, 42));
        assert_eq!(
            sequential.to_bits(),
            parallel.to_bits(),
            "Monte-Carlo estimate drifted across thread counts: {sequential} vs {parallel}"
        );
        assert!(sequential > 0.0 && sequential.is_finite());
    }
}

#[test]
fn scale_json_bit_identical_between_jobs_1_and_4() {
    let wl = Workload::inaturalist();
    let report = |jobs: usize| {
        with_jobs(jobs, || {
            let rows = scale::sweep_rows("waxman", &[20, 30], &wl, 1, 10e9, 1e9, 0.5, 7).unwrap();
            scale::to_json("waxman", &wl, 1, 10e9, 1e9, 0.5, 7, &rows).to_string()
        })
    };
    let a = report(1);
    let b = report(4);
    assert_eq!(a, b, "`fedtopo scale --json` must not depend on --jobs");
    assert!(a.contains("\"experiment\":\"scale\""));
}

#[test]
fn robustness_json_bit_identical_between_jobs_1_and_4() {
    let cfg = RobustnessConfig {
        network: "gaia".to_string(),
        workload: Workload::inaturalist(),
        s: 1,
        access_bps: 10e9,
        core_bps: 1e9,
        c_b: 0.5,
        scenario: "scenario:straggler:3:x10".to_string(),
        rounds: 80,
        window: 20,
        threshold: 1.3,
        seed: 7,
        kinds: vec![OverlayKind::Mst, OverlayKind::Ring, OverlayKind::MatchaPlus],
        backends: vec!["backend:scalar".to_string()],
        reroute: false,
    };
    let report = |jobs: usize| {
        with_jobs(jobs, || {
            let rows = robustness::run(&cfg).unwrap();
            robustness::to_json(&cfg, &rows).to_string()
        })
    };
    let a = report(1);
    let b = report(4);
    assert_eq!(a, b, "`fedtopo robustness` JSON must not depend on --jobs");
    assert!(a.contains("\"scenario\":\"scenario:straggler:3:x10\""));
}

#[test]
fn cycle_table_rows_bit_identical_between_jobs_1_and_4() {
    let wl = Workload::inaturalist();
    let rows = |jobs: usize| {
        with_jobs(jobs, || {
            cycle_table::cycle_rows(&["gaia", "geant"], &wl, 1, 10e9, 1e9, 0.5).unwrap()
        })
    };
    let a = rows(1);
    let b = rows(4);
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.network, rb.network);
        for kind in OverlayKind::all() {
            assert_eq!(
                ra.tau_of(kind).to_bits(),
                rb.tau_of(kind).to_bits(),
                "{}/{kind:?}",
                ra.network
            );
        }
    }
}
