//! The paper's quantitative claims, asserted end-to-end.
//!
//! Absolute numbers come from *our* substrate (reconstructed topologies, the
//! Eq.-3 delay model), so every assertion targets the paper's *shape*: who
//! wins, by roughly what factor, and where crossovers fall. Table-by-table
//! measured-vs-paper numbers are recorded in EXPERIMENTS.md.

use fedtopo::coordinator::experiments::{cycle_table, fig3, fig4, table10};
use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::OverlayKind;

fn row(net: &str, s: usize, access: f64) -> cycle_table::CycleRow {
    cycle_table::cycle_row(net, &Workload::inaturalist(), s, access, 1e9, 0.5).unwrap()
}

// -- Table 3 -----------------------------------------------------------------

#[test]
fn table3_gaia_matches_paper_closely() {
    // paper: STAR 391, MATCHA 228, MST 138, RING 118 (±25% tolerance —
    // Gaia's site list is exactly reproducible so this is a tight check).
    let r = row("gaia", 1, 10e9);
    let close = |kind, paper: f64, tol: f64| {
        let v = r.tau_of(kind);
        assert!(
            (v - paper).abs() <= tol * paper,
            "{kind:?}: measured {v} vs paper {paper}"
        );
    };
    close(OverlayKind::Star, 391.0, 0.25);
    close(OverlayKind::Mst, 138.0, 0.25);
    close(OverlayKind::Ring, 118.0, 0.25);
}

#[test]
fn table3_aws_na_matches_paper_closely() {
    // paper: STAR 288, MST 90, RING 81.
    let r = row("aws-na", 1, 10e9);
    assert!((r.tau_of(OverlayKind::Star) - 288.0).abs() < 0.25 * 288.0);
    assert!((r.tau_of(OverlayKind::Mst) - 90.0).abs() < 0.3 * 90.0);
    assert!((r.tau_of(OverlayKind::Ring) - 81.0).abs() < 0.25 * 81.0);
}

#[test]
fn table3_ring_speedup_band() {
    // paper: RING is 2.65–3.4× faster than STAR on the synthetic meshes and
    // 8.8–9.4× on the big ISP networks.
    for (net, lo, hi) in [
        ("gaia", 2.0, 4.5),
        ("aws-na", 2.0, 4.5),
        ("exodus", 6.0, 20.0),
        ("ebone", 6.0, 20.0),
    ] {
        let r = row(net, 1, 10e9);
        let speedup = r.tau_of(OverlayKind::Star) / r.tau_of(OverlayKind::Ring);
        assert!(
            (lo..hi).contains(&speedup),
            "{net}: ring speedup {speedup} outside [{lo},{hi})"
        );
    }
}

#[test]
fn table3_matcha_plus_beats_matcha_on_sparse_underlays() {
    // paper Géant: MATCHA 452 vs MATCHA+ 106 — coloring the complete
    // connectivity graph is the wrong base on sparse networks.
    for net in ["geant", "exodus", "ebone"] {
        let r = row(net, 1, 10e9);
        assert!(
            r.tau_of(OverlayKind::MatchaPlus) < 0.6 * r.tau_of(OverlayKind::Matcha),
            "{net}"
        );
    }
}

#[test]
fn table3_trees_and_ring_cluster_together() {
    // paper: MST ≈ δ-MBST, both within ~50% of the RING at 10 Gbps access.
    for net in ["gaia", "aws-na", "geant", "exodus", "ebone"] {
        let r = row(net, 1, 10e9);
        let mst = r.tau_of(OverlayKind::Mst);
        let mbst = r.tau_of(OverlayKind::DeltaMbst);
        let ring = r.tau_of(OverlayKind::Ring);
        assert!((mst - mbst).abs() <= 0.2 * mst, "{net}: mst {mst} vs mbst {mbst}");
        assert!(mst <= 2.0 * ring && ring <= 2.0 * mst, "{net}: {mst} vs {ring}");
    }
}

// -- Tables 6-7 ---------------------------------------------------------------

#[test]
fn tables6_7_more_local_steps_compress_spread() {
    for net in ["gaia", "ebone"] {
        let spread = |s| {
            let r = row(net, s, 10e9);
            r.tau_of(OverlayKind::Star) / r.tau_of(OverlayKind::Ring)
        };
        let (s1, s5, s10) = (spread(1), spread(5), spread(10));
        assert!(s1 > s5 && s5 > s10, "{net}: {s1} {s5} {s10}");
    }
}

// -- Table 9 -------------------------------------------------------------------

#[test]
fn table9_full_inaturalist_slow_access_grows_speedups() {
    // paper: with M=161 Mbit and 1 Gbps access the ring speedup reaches
    // 3.8×(Gaia) … 19.5×(Ebone) and MST > δ-MBST > RING strictly.
    let wl = Workload::full_inaturalist();
    for (net, lo) in [("gaia", 2.5), ("ebone", 8.0)] {
        let r = cycle_table::cycle_row(net, &wl, 1, 1e9, 1e9, 0.5).unwrap();
        let speedup = r.tau_of(OverlayKind::Star) / r.tau_of(OverlayKind::Ring);
        assert!(speedup > lo, "{net}: {speedup}");
        assert!(r.tau_of(OverlayKind::Ring) <= r.tau_of(OverlayKind::DeltaMbst) * 1.05);
        assert!(r.tau_of(OverlayKind::DeltaMbst) <= r.tau_of(OverlayKind::Mst) * 1.05);
    }
}

// -- Figure 3 -------------------------------------------------------------------

#[test]
fn fig3a_slow_access_asymptotes() {
    // App. B: at slow homogeneous access, STAR/RING → 2N (= 80 on Géant).
    let data = fig3::sweep("geant", &Workload::inaturalist(), 1, 1e9, 0.5, None).unwrap();
    let (access, taus) = &data[0]; // 10 Mbps
    assert_eq!(*access, 10e6);
    let get = |k| taus.iter().find(|(kk, _)| *kk == k).unwrap().1;
    let ratio = get(OverlayKind::Star) / get(OverlayKind::Ring);
    assert!(
        (ratio - 80.0).abs() < 0.25 * 80.0,
        "STAR/RING at 10 Mbps = {ratio}, App. B predicts 2N = 80"
    );
    // RING → M/C = 42.88e6/1e7 * 1e3 / 1e3 … = 4288 ms
    assert!((get(OverlayKind::Ring) - 4288.0).abs() < 0.15 * 4288.0);
}

#[test]
fn fig3b_fast_hub_halves_the_gap_but_ring_still_wins() {
    let plain =
        fig3::sweep("geant", &Workload::inaturalist(), 1, 1e9, 0.5, None).unwrap();
    let fixed =
        fig3::sweep("geant", &Workload::inaturalist(), 1, 1e9, 0.5, Some(10e9)).unwrap();
    let get = |d: &[(f64, Vec<(OverlayKind, f64)>)], i: usize, k| {
        d[i].1.iter().find(|(kk, _)| *kk == k).unwrap().1
    };
    // at 100 Mbps (index 1): fixing the hub speeds the STAR up a lot …
    let star_plain = get(&plain, 1, OverlayKind::Star);
    let star_fixed = get(&fixed, 1, OverlayKind::Star);
    assert!(star_fixed < 0.5 * star_plain);
    // … but the RING still beats it (paper: "still is twice slower")
    let ring = get(&fixed, 1, OverlayKind::Ring);
    assert!(star_fixed > 1.3 * ring, "star {star_fixed} vs ring {ring}");
}

// -- Figure 4 --------------------------------------------------------------------

#[test]
fn fig4_speedup_decays_monotonically_with_s() {
    let data = fig4::sweep("exodus", &Workload::inaturalist(), 1e9, 1e9, 0.5).unwrap();
    let ring: Vec<f64> = data
        .iter()
        .map(|(_, v)| {
            v.iter()
                .find(|(k, _)| *k == OverlayKind::Ring)
                .unwrap()
                .1
        })
        .collect();
    for w in ring.windows(2) {
        assert!(w[1] <= w[0] + 1e-9, "{ring:?}");
    }
    assert!(ring[0] / ring[ring.len() - 1] > 3.0, "{ring:?}");
}

// -- Table 10 ---------------------------------------------------------------------

#[test]
fn table10_no_cb_rescues_matcha_at_100mbps() {
    let rows =
        table10::speedup_rows("aws-na", &Workload::inaturalist(), 1, 100e6, 1e9).unwrap();
    for (label, speedups) in &rows {
        if label.contains("underlay") {
            // MATCHA proper: the RING wins at every C_b (paper row 1).
            for sp in speedups {
                assert!(*sp > 1.0, "{label}: RING loses at some C_b ({sp})");
            }
        } else {
            // MATCHA over the RING/tree with tiny C_b skips most
            // communication, which inflates *cycle-time* throughput; the
            // paper's training-speedup metric (which charges the extra
            // rounds) still favors the RING. Cycle time alone must stay
            // within parity.
            for sp in speedups {
                assert!(*sp > 0.75, "{label}: MATCHA decisively faster ({sp})");
            }
        }
    }
}

// -- Beyond the paper: the Table-3 shape at synthetic scale ------------------------

#[test]
fn table3_shape_survives_on_synthetic_underlays() {
    // The paper's qualitative claim — designed overlays (RING/trees) beat
    // the STAR, by a growing factor on sparse networks — is not an artifact
    // of the five Table-3 topologies: it holds on seeded Waxman and
    // Barabási–Albert underlays at 200 silos (past anything the paper ran,
    // and above the Karp/Howard dispatch threshold).
    for family in ["waxman", "ba"] {
        let r = row(&format!("synth:{family}:200:seed7"), 1, 10e9);
        let star = r.tau_of(OverlayKind::Star);
        let ring = r.tau_of(OverlayKind::Ring);
        let mst = r.tau_of(OverlayKind::Mst);
        assert!(ring < star, "{family}: ring {ring} < star {star}");
        assert!(mst < star, "{family}: mst {mst} < star {star}");
        assert!(
            star / ring > 2.0,
            "{family}: ring speedup {} too small at 200 silos",
            star / ring
        );
    }
}

// -- Edge-capacitated regime (Prop. 3.1 context) -----------------------------------

#[test]
fn edge_capacitated_detection_matches_definition() {
    let net = Underlay::builtin("gaia").unwrap();
    let fast = DelayModel::new(&net, &Workload::inaturalist(), 1, 100e9, 1e9);
    let slow = DelayModel::new(&net, &Workload::inaturalist(), 1, 100e6, 1e9);
    assert!(fast.is_edge_capacitated());
    assert!(!slow.is_edge_capacitated());
}
