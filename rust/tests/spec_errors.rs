//! Pins the normalized resolver error format (the PR-8 `spec::Resolve`
//! contract): every string-resolved kind fails with
//!
//! ```text
//! cannot resolve <kind> '<input>': <reason>[ (in segment '<seg>')]
//!     [; expected <grammar>][; did you mean '<name>'?]
//! ```
//!
//! These are **exact-string** assertions on purpose — client scripts and
//! the serve protocol surface these messages verbatim, so drift is an API
//! break and should fail a test, not a code review.

use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::backend::BackendProfile;
use fedtopo::netsim::scenario::Scenario;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::OverlayKind;

fn msg_of<T>(r: anyhow::Result<T>) -> String {
    format!("{:#}", r.err().expect("expected a resolve error"))
}

#[test]
fn network_typo_pins_format_and_suggestion() {
    assert_eq!(
        msg_of(Underlay::by_name("gaiaa")),
        "cannot resolve network 'gaiaa': unknown network; expected \
         gaia|aws-na|geant|exodus|ebone or synth:<family>:<n>[:seed<u64>] \
         (family: waxman|ba|geo|grid); did you mean 'gaia'?"
    );
}

#[test]
fn synth_spec_errors_echo_the_full_input() {
    let msg = msg_of(Underlay::by_name("synth:waxman:zero"));
    assert!(
        msg.starts_with("cannot resolve network 'synth:waxman:zero': bad silo count 'zero'"),
        "{msg}"
    );
    let msg = msg_of(Underlay::by_name("synth:waxmann:50"));
    assert!(
        msg.starts_with("cannot resolve network 'synth:waxmann:50': unknown synth family 'waxmann'"),
        "{msg}"
    );
    assert!(msg.ends_with("did you mean 'waxman'?"), "{msg}");
}

#[test]
fn overlay_typo_pins_format_and_suggestion() {
    assert_eq!(
        msg_of(OverlayKind::by_name("rings")),
        "cannot resolve overlay 'rings': unknown overlay kind; expected \
         star|mst|delta-mbst|ring|matcha|matcha+ (aliases: mbst, matcha-plus); \
         did you mean 'ring'?"
    );
}

#[test]
fn workload_typo_pins_format_and_suggestion() {
    assert_eq!(
        msg_of(Workload::by_name("feminst")),
        "cannot resolve workload 'feminst': unknown workload; expected \
         shakespeare|femnist|sent140|inaturalist|full-inaturalist; \
         did you mean 'femnist'?"
    );
}

#[test]
fn scenario_single_error_echoes_the_callers_input() {
    // the stripped 'scenario:' prefix is restored in the echo, no segment
    let msg = msg_of(Scenario::by_name("scenario:drifty:0.1"));
    assert!(
        msg.starts_with(
            "cannot resolve scenario 'scenario:drifty:0.1': unknown scenario family 'drifty'"
        ),
        "{msg}"
    );
    assert!(!msg.contains("in segment"), "{msg}");
    assert!(msg.ends_with("did you mean 'drift'?"), "{msg}");
}

#[test]
fn scenario_composite_error_echoes_full_spec_and_failing_segment() {
    // the asymmetry this PR fixed: composites used to report only the bare
    // failing piece, losing which spec (and which segment) was at fault
    let msg = msg_of(Scenario::by_name("drift:0.1+bogus:1"));
    assert_eq!(
        msg,
        "cannot resolve scenario 'drift:0.1+bogus:1': unknown scenario family \
         'bogus' (in segment 'bogus:1'); expected identity | drift:<sigma> | \
         congestion:<period>:x<factor> | straggler:<count>:x<factor> | \
         churn:p<prob>[:x<penalty>] | silo-churn:p<prob>[:x<penalty>] | \
         outage:<regions>:p<prob>:x<factor>, '+'-composable, optional \
         'scenario:' prefix"
    );
}

#[test]
fn scenario_bad_argument_in_composite_names_the_segment() {
    let msg = msg_of(Scenario::by_name("scenario:straggler:3:x10+drift:-1"));
    assert!(
        msg.starts_with("cannot resolve scenario 'scenario:straggler:3:x10+drift:-1':"),
        "{msg}"
    );
    assert!(msg.contains("(in segment 'drift:-1')"), "{msg}");
}

#[test]
fn backend_typo_pins_format_and_suggestion() {
    assert_eq!(
        msg_of(BackendProfile::by_name("grcp")),
        "cannot resolve backend 'grcp': unknown backend 'grcp'; expected \
         scalar | grpc | rdma, modifiers :chunk<bytes>[k|M|G], :over<ms>, \
         :pipe<depth> (e.g. grpc:chunk4M), optional 'backend:' prefix; \
         did you mean 'grpc'?"
    );
}

#[test]
fn backend_modifier_errors_echo_the_full_input() {
    let msg = msg_of(BackendProfile::by_name("backend:grpc:chunk0"));
    assert!(
        msg.starts_with("cannot resolve backend 'backend:grpc:chunk0': chunk size must be ≥ 1 byte"),
        "{msg}"
    );
    let msg = msg_of(BackendProfile::by_name("scalar:pipe4"));
    assert!(
        msg.starts_with("cannot resolve backend 'scalar:pipe4': 'scalar' takes no modifiers"),
        "{msg}"
    );
}

#[test]
fn every_kind_reports_with_its_registry_label() {
    // uniform across all five kinds — the shape clients can match on
    for (msg, kind) in [
        (msg_of(Underlay::by_name("nope")), "network"),
        (msg_of(OverlayKind::by_name("nope")), "overlay"),
        (msg_of(Workload::by_name("nope")), "workload"),
        (msg_of(Scenario::by_name("nope")), "scenario"),
        (msg_of(BackendProfile::by_name("nope")), "backend"),
    ] {
        assert!(msg.starts_with(&format!("cannot resolve {kind} 'nope':")), "{msg}");
        assert!(msg.contains("; expected "), "{msg}");
    }
}
