//! PR-5 dense-vs-flat equivalence pins: the flat-memory graph core (CSR
//! delay digraphs, implicit-Kₙ designers, arena-backed routing) must be a
//! pure storage change — every migrated layer is pinned **bit-identical**
//! to its retained dense oracle:
//!
//! * routing: [`Routes`] vs [`routing::dense`] (latencies, bandwidths,
//!   hops, paths) — and therefore every λ* computed from either;
//! * designers: implicit-Kₙ MST / δ-MBST candidates vs Prim / δ-Prim over
//!   the materialized connectivity graphs; Christofides' two migrated
//!   phases (implicit MST + pair-list-free matching) vs their dense forms
//!   (the remaining phases — Euler walk, shortcut, orientation — are
//!   unchanged code, so pinning the inputs pins the ring);
//! * MATCHA: the implicit circle factorization vs the materialized one —
//!   same pairs, same sampled rounds, bit-equal Monte-Carlo λ*;
//! * timelines: `simulate_scenario` (reusable CSR, in-place reweights,
//!   zero-alloc stepping) vs `simulate_scenario_dense` (a fresh digraph
//!   per round) over composite scenarios.
//!
//! Coverage: builtins + `synth:{waxman,ba,geo,grid}` at N ∈ {10, 200},
//! thinning to waxman/ba × {mst, ring} at N = 2000 (the dense oracles
//! themselves are the cost ceiling — materializing K₂₀₀₀ per designer is
//! exactly what the flat core exists to avoid).

use fedtopo::fl::workloads::Workload;
use fedtopo::graph::mst::{delta_prim, prim};
use fedtopo::graph::UnGraph;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::routing::{self, BwModel, Routes};
use fedtopo::netsim::scenario::{
    simulate_scenario, simulate_scenario_batched, simulate_scenario_dense, Scenario,
};
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::matcha::MatchaOverlay;
use fedtopo::topology::{self, design_with_underlay, OverlayKind};

fn model(net: &Underlay) -> DelayModel {
    DelayModel::new(net, &Workload::inaturalist(), 1, 10e9, 1e9)
}

fn assert_graphs_bit_identical(a: &UnGraph, b: &UnGraph, what: &str) {
    assert_eq!(a.n(), b.n(), "{what}: node counts");
    assert_eq!(a.m(), b.m(), "{what}: edge counts");
    for (x, y) in a.edges().iter().zip(b.edges()) {
        assert_eq!((x.0, x.1), (y.0, y.1), "{what}: edge endpoints");
        assert_eq!(x.2.to_bits(), y.2.to_bits(), "{what}: edge weight");
    }
}

/// The small/mid grid: every family plus two builtins.
fn specs_small() -> Vec<String> {
    let mut v: Vec<String> = vec!["gaia".into(), "geant".into()];
    for family in ["waxman", "ba", "geo", "grid"] {
        for n in [10usize, 200] {
            v.push(format!("synth:{family}:{n}:seed7"));
        }
    }
    v
}

#[test]
fn routing_flat_matches_dense_oracle_across_specs() {
    for spec in specs_small() {
        let net = Underlay::by_name(&spec).unwrap();
        let caps = vec![1e9; net.core.m()];
        for bw in [BwModel::MinCapacity, BwModel::FairShare] {
            let flat = Routes::compute_with_capacities(&net, &caps, bw);
            let dense = routing::dense::compute_with_capacities(&net, &caps, bw);
            let n = net.n_silos();
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(
                        flat.lat_ms(i, j).to_bits(),
                        dense.lat_ms[i][j].to_bits(),
                        "{spec}/{bw:?}: lat({i},{j})"
                    );
                    assert_eq!(
                        flat.abw_bps(i, j).to_bits(),
                        dense.abw_bps[i][j].to_bits(),
                        "{spec}/{bw:?}: abw({i},{j})"
                    );
                    assert_eq!(flat.hops(i, j), dense.hops[i][j], "{spec}/{bw:?}");
                    let fp: Vec<usize> =
                        flat.path(i, j).iter().map(|&e| e as usize).collect();
                    assert_eq!(fp, dense.paths[i][j], "{spec}/{bw:?}: path({i},{j})");
                }
            }
        }
    }
}

#[test]
fn lambda_star_identical_on_dense_oracle_routes() {
    // Rebuild the delay model on top of the dense-oracle routing products
    // and re-run every designer: identical inputs bit-for-bit ⇒ identical
    // designs and identical λ*. This pins the whole designer + Eq.-(5)
    // stack against the routing migration at once.
    for spec in ["gaia", "synth:waxman:200:seed7", "synth:ba:200:seed7"] {
        let net = Underlay::by_name(spec).unwrap();
        let dm_flat = model(&net);
        let caps = vec![1e9; net.core.m()];
        let dense = routing::dense::compute_with_capacities(&net, &caps, BwModel::MinCapacity);
        let dm_dense = DelayModel::with_parts(
            dm_flat.s,
            dm_flat.model_bits,
            dm_flat.tc_ms.clone(),
            dm_flat.cup_bps.clone(),
            dm_flat.cdn_bps.clone(),
            Routes::from_dense(
                &dense.lat_ms,
                &dense.abw_bps,
                &dense.hops,
                vec![1e9; net.core.m()],
            ),
        );
        for kind in [
            OverlayKind::Star,
            OverlayKind::Mst,
            OverlayKind::DeltaMbst,
            OverlayKind::Ring,
        ] {
            let a = design_with_underlay(kind, &dm_flat, &net, 0.5).unwrap();
            let b = design_with_underlay(kind, &dm_dense, &net, 0.5).unwrap();
            let (ga, gb) = (a.static_graph().unwrap(), b.static_graph().unwrap());
            assert_eq!(ga.edges(), gb.edges(), "{spec}/{kind:?}: designs differ");
            assert_eq!(
                a.cycle_time_ms(&dm_flat).to_bits(),
                b.cycle_time_ms(&dm_dense).to_bits(),
                "{spec}/{kind:?}: λ* differs"
            );
        }
    }
}

#[test]
fn mst_designer_matches_dense_prim_across_specs() {
    for spec in specs_small() {
        let net = Underlay::by_name(&spec).unwrap();
        let dm = model(&net);
        let implicit = topology::mst::design_tree(&dm);
        let dense = prim(&topology::mst::connectivity_undirected(&dm)).unwrap();
        assert_graphs_bit_identical(&implicit, &dense, &format!("{spec}/mst"));
    }
}

#[test]
fn mbst_candidates_match_dense_delta_prim_across_specs() {
    // δ-PRIM is the phase with the trickiest tie-breaking (saturation
    // recomputes); pin every δ the designer actually tries.
    for spec in ["synth:waxman:10:seed7", "synth:geo:200:seed7", "geant"] {
        let net = Underlay::by_name(spec).unwrap();
        let dm = model(&net);
        let gcu = topology::mbst::connectivity_undirected(&dm);
        for (name, cand) in topology::mbst::candidates(&dm) {
            let delta = name.strip_suffix("-prim").and_then(|d| d.parse::<usize>().ok());
            if let Some(delta) = delta {
                let dense = delta_prim(&gcu, delta).unwrap();
                assert_graphs_bit_identical(&cand, &dense, &format!("{spec}/{name}"));
            }
        }
    }
}

#[test]
fn ring_phases_match_dense_forms_across_specs() {
    // The two migrated Christofides phases, against their dense oracles on
    // the real Prop.-3.6 weights. (Euler walk / shortcut / orientation are
    // unchanged code operating on these exact inputs.)
    use fedtopo::graph::csr::{implicit_prim, nn_greedy_matching};
    for spec in specs_small() {
        let net = Underlay::by_name(&spec).unwrap();
        let dm = model(&net);
        let w = |i: usize, j: usize| 0.5 * (dm.ring_weight(i, j) + dm.ring_weight(j, i));
        let mut tree = UnGraph::new(dm.n);
        for (u, v, wt) in implicit_prim(dm.n, w) {
            tree.add_edge(u, v, wt);
        }
        let dense_tree = prim(&UnGraph::complete_with(dm.n, w)).unwrap();
        assert_graphs_bit_identical(&tree, &dense_tree, &format!("{spec}/ring-mst"));
        let odd: Vec<usize> = (0..dm.n).filter(|&v| tree.degree(v) % 2 == 1).collect();
        let fast = nn_greedy_matching(&odd, w);
        let slow = topology::ring::greedy_matching_sorted(&odd, &w);
        assert_eq!(fast, slow, "{spec}/ring-matching");
    }
}

#[test]
fn matcha_implicit_circle_matches_explicit_across_sizes() {
    for n in [150usize, 2000] {
        let imp = MatchaOverlay::over_complete(n, 0.5);
        let exp = MatchaOverlay::over_complete_circle_explicit(n, 0.5);
        assert_eq!(imp.num_matchings(), exp.num_matchings(), "n={n}");
        for r in [0, 1, n / 2, imp.num_matchings() - 1] {
            assert_eq!(imp.matching_pairs(r), exp.matching_pairs(r), "n={n} r={r}");
        }
        let mut ra = fedtopo::util::rng::Rng::new(5);
        let mut rb = fedtopo::util::rng::Rng::new(5);
        assert_eq!(
            imp.sample_round(&mut ra).edges(),
            exp.sample_round(&mut rb).edges(),
            "n={n}"
        );
    }
    // Monte-Carlo λ* bit-equality on a mid-size model (cheap but complete).
    let net = Underlay::by_name("synth:waxman:150:seed7").unwrap();
    let dm = model(&net);
    let a = MatchaOverlay::over_complete(150, 0.5).average_cycle_time_ms(&dm, 300, 11);
    let b =
        MatchaOverlay::over_complete_circle_explicit(150, 0.5).average_cycle_time_ms(&dm, 300, 11);
    assert_eq!(a.to_bits(), b.to_bits());
}

#[test]
fn dynamic_timelines_match_dense_oracle_across_specs() {
    let scenarios = [
        "scenario:identity",
        "scenario:drift:0.3+churn:p0.05",
        "scenario:straggler:3:x10+outage:3:p0.2:x4",
    ];
    for spec in ["gaia", "synth:waxman:200:seed7", "synth:grid:200:seed7"] {
        let net = Underlay::by_name(spec).unwrap();
        let dm = model(&net);
        for kind in [OverlayKind::Mst, OverlayKind::Ring] {
            let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
            let g = overlay.static_graph().unwrap();
            for sc_name in scenarios {
                let sc = Scenario::by_name(sc_name).unwrap();
                let flat = simulate_scenario(&dm, g, &sc, 60, 7);
                let dense = simulate_scenario_dense(&dm, g, &sc, 60, 7);
                for k in 0..=60 {
                    for i in 0..dm.n {
                        assert_eq!(
                            flat.at(k, i).to_bits(),
                            dense.at(k, i).to_bits(),
                            "{spec}/{kind:?}/{sc_name}: t[{k}][{i}]"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn batched_lanes_match_per_cell_path_across_lane_counts() {
    // PR-6 acceptance pin: every lane of the batched SoA path equals the
    // per-cell `simulate_scenario` for that (scenario, seed) bit for bit —
    // synth underlays × designers × composite scenarios × S ∈ {1, 3, 8}
    // (S = 1 is the degenerate batched ≡ per-cell pin).
    let scenario_specs = [
        "scenario:identity",
        "scenario:drift:0.3+churn:p0.05",
        "scenario:straggler:3:x10+silo-churn:p0.1",
        "scenario:outage:3:p0.2:x4+congestion:10:x2",
    ];
    for spec in ["synth:waxman:10:seed7", "synth:geo:200:seed7", "gaia"] {
        let net = Underlay::by_name(spec).unwrap();
        let dm = model(&net);
        for kind in [OverlayKind::Mst, OverlayKind::Ring] {
            let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
            let g = overlay.static_graph().unwrap();
            for s in [1usize, 3, 8] {
                let lanes: Vec<(Scenario, u64)> = (0..s)
                    .map(|l| {
                        let spec = scenario_specs[l % scenario_specs.len()];
                        let seed = 7 + (l / scenario_specs.len()) as u64;
                        (Scenario::by_name(spec).unwrap(), seed)
                    })
                    .collect();
                let batched = simulate_scenario_batched(&dm, g, &lanes, 50);
                assert_eq!(batched.len(), s);
                for (l, (sc, seed)) in lanes.iter().enumerate() {
                    let reference = simulate_scenario(&dm, g, sc, 50, *seed);
                    for k in 0..=50 {
                        for i in 0..dm.n {
                            assert_eq!(
                                batched[l].at(k, i).to_bits(),
                                reference.at(k, i).to_bits(),
                                "{spec}/{kind:?}/S={s} lane {l} ({}): t[{k}][{i}]",
                                sc.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn chunked_kernels_match_sequential_oracles_bit_for_bit() {
    // PR-10 pin: the row-partitioned kernels vs the sequential oracles at
    // integration granularity — real overlay delay CSRs plus a hand-built
    // degenerate digraph (one isolated silo, one self-loop-only silo), with
    // intra-cell workers ∈ {1, 2, 7}, part counts that land chunk
    // boundaries mid-structure (including parts > rows), and batched lane
    // counts S ∈ {1, 3, 8}. Multi-round trajectories, compared bit for bit
    // every round, so a divergence anywhere would compound and be caught.
    use fedtopo::maxplus::csr::{BatchedCsrWeights, CsrDelayDigraph};
    use fedtopo::maxplus::recurrence::{
        step_csr_batched_chunked_into, step_csr_batched_into, step_csr_chunked_into, step_csr_into,
    };
    use fedtopo::maxplus::DelayDigraph;
    use fedtopo::util::parallel::set_intracell;

    let mut digraphs: Vec<(String, CsrDelayDigraph)> = Vec::new();
    for spec in ["gaia", "synth:waxman:200:seed7"] {
        let net = Underlay::by_name(spec).unwrap();
        let dm = model(&net);
        for kind in [OverlayKind::Mst, OverlayKind::Ring] {
            let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
            let ov = dm.delay_csr(overlay.static_graph().unwrap());
            digraphs.push((format!("{spec}/{kind:?}"), ov.csr.clone()));
        }
    }
    let mut dd = DelayDigraph::new(6);
    dd.arc(0, 1, 2.0);
    dd.arc(1, 0, 3.0);
    dd.arc(4, 5, 1.5);
    dd.arc(5, 4, 0.5);
    dd.arc(2, 2, 0.25); // silo 2: self-loop only
    dd.arc(0, 4, 1.0); // silo 3: no in-arcs at all
    dd.arc(1, 5, 2.5);
    digraphs.push(("degenerate".into(), CsrDelayDigraph::from_delay_digraph(&dd)));

    const ROUNDS: usize = 20;
    for (what, csr) in &digraphs {
        let n = csr.n();
        let start: Vec<f64> = (0..n).map(|i| (i % 13) as f64 * 0.37).collect();

        // Sequential-oracle trajectory.
        let mut seq = vec![start.clone()];
        let mut prev = start.clone();
        let mut next = vec![0.0f64; n];
        for _ in 0..ROUNDS {
            step_csr_into(&prev, csr, &mut next);
            std::mem::swap(&mut prev, &mut next);
            seq.push(prev.clone());
        }

        for workers in [1usize, 2, 7] {
            set_intracell(workers);
            for parts in [2usize, 3, 5, 16] {
                let mut prev = start.clone();
                let mut next = vec![0.0f64; n];
                for (k, expect) in seq.iter().enumerate().skip(1) {
                    step_csr_chunked_into(&prev, csr, &mut next, parts);
                    std::mem::swap(&mut prev, &mut next);
                    for i in 0..n {
                        assert_eq!(
                            prev[i].to_bits(),
                            expect[i].to_bits(),
                            "{what}: workers={workers} parts={parts} t[{k}][{i}]"
                        );
                    }
                }
            }
        }

        // Batched lanes: chunked vs sequential batched kernel, lane-varying
        // starting state over broadcast weights.
        set_intracell(7);
        for s in [1usize, 3, 8] {
            let w = BatchedCsrWeights::broadcast(csr, s);
            let start: Vec<f64> = (0..n * s).map(|x| (x % 17) as f64 * 0.29).collect();
            let (mut pa, mut na) = (start.clone(), vec![0.0f64; n * s]);
            let (mut pb, mut nb) = (start, vec![0.0f64; n * s]);
            for k in 0..ROUNDS {
                step_csr_batched_into(&pa, csr, &w, &mut na);
                std::mem::swap(&mut pa, &mut na);
                step_csr_batched_chunked_into(&pb, csr, &w, &mut nb, 5);
                std::mem::swap(&mut pb, &mut nb);
                for (x, (a, b)) in pa.iter().zip(&pb).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{what}: S={s} round {k} slot {x}");
                }
            }
        }
        set_intracell(0);
    }
}

#[test]
fn full_stack_equivalence_at_2000_silos() {
    // The top of the pinned range: designer outputs and timelines at
    // N = 2000, where the dense oracles are at their cost ceiling.
    for spec in ["synth:waxman:2000:seed7", "synth:ba:2000:seed7"] {
        let net = Underlay::by_name(spec).unwrap();
        let dm = model(&net);
        // MST: implicit vs dense Prim over the materialized K₂₀₀₀.
        let implicit = topology::mst::design_tree(&dm);
        let dense = prim(&topology::mst::connectivity_undirected(&dm)).unwrap();
        assert_graphs_bit_identical(&implicit, &dense, &format!("{spec}/mst@2000"));
        // Timeline: flat vs dense under a composite scenario, short horizon
        // (each dense round materializes a ~6000-arc digraph — the cost the
        // flat path deletes).
        let overlay = design_with_underlay(OverlayKind::Ring, &dm, &net, 0.5).unwrap();
        let g = overlay.static_graph().unwrap();
        let sc = Scenario::by_name("scenario:drift:0.2+outage:5:p0.1:x3").unwrap();
        let flat = simulate_scenario(&dm, g, &sc, 25, 7);
        let dense_tl = simulate_scenario_dense(&dm, g, &sc, 25, 7);
        for k in 0..=25 {
            for i in 0..dm.n {
                assert_eq!(
                    flat.at(k, i).to_bits(),
                    dense_tl.at(k, i).to_bits(),
                    "{spec}: t[{k}][{i}]"
                );
            }
        }
    }
}
