//! ISSUE-2 equivalence pins: the dynamic-network stack degenerates to the
//! static one, bit for bit, when nothing is dynamic.
//!
//! * `Timeline::simulate_dynamic` under the identity scenario reproduces
//!   `Timeline::simulate` exactly (every multiplier is an IEEE no-op);
//! * the adaptive loop with an infinite threshold never re-designs and
//!   realizes the identical trajectory.

use fedtopo::fl::workloads::Workload;
use fedtopo::maxplus::recurrence::Timeline;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::scenario::{simulate_scenario, Scenario};
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::adaptive::{run_adaptive, AdaptiveConfig, ThroughputMonitor};
use fedtopo::topology::{design_with_underlay, OverlayKind};

fn setup(name: &str) -> (Underlay, DelayModel) {
    let net = Underlay::builtin(name).unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    (net, dm)
}

fn assert_timelines_bit_identical(a: &Timeline, b: &Timeline, what: &str) {
    assert_eq!(a.rounds(), b.rounds(), "{what}: round counts differ");
    assert_eq!(a.n(), b.n(), "{what}: silo counts differ");
    for k in 0..=a.rounds() {
        for i in 0..a.n() {
            let (x, y) = (a.at(k, i), b.at(k, i));
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: t[{k}][{i}] {x} vs {y}");
        }
    }
}

#[test]
fn identity_scenario_reproduces_simulate_bit_for_bit() {
    for (net_name, kind) in [
        ("gaia", OverlayKind::Mst),
        ("gaia", OverlayKind::Ring),
        ("geant", OverlayKind::DeltaMbst),
    ] {
        let (net, dm) = setup(net_name);
        let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
        let g = overlay.static_graph().unwrap();
        let stat = Timeline::simulate(&dm.delay_digraph(g), 150);
        let dynamic = simulate_scenario(&dm, g, &Scenario::identity(), 150, 7);
        assert_timelines_bit_identical(&stat, &dynamic, &format!("{net_name}/{kind:?}"));
    }
}

#[test]
fn infinite_threshold_is_the_static_trajectory_bit_for_bit() {
    // Under a *non-trivial* scenario: the static baseline arm of the
    // adaptive loop must equal plain simulate_scenario on the designed
    // overlay — same scenario stream, same recurrence kernel, no re-design.
    let (net, dm) = setup("gaia");
    let sc = Scenario::by_name("scenario:straggler:3:x10").unwrap();
    let cfg = AdaptiveConfig {
        window: 20,
        threshold: f64::INFINITY,
        c_b: 0.5,
        seed: 7,
        ..AdaptiveConfig::default()
    };
    for kind in [OverlayKind::Mst, OverlayKind::Ring, OverlayKind::Star] {
        let run = run_adaptive(kind, &dm, &net, &sc, 100, &cfg).unwrap();
        assert!(run.redesign_rounds.is_empty(), "{kind:?} re-designed at ∞");
        let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
        let tl = simulate_scenario(&dm, overlay.static_graph().unwrap(), &sc, 100, 7);
        assert_eq!(run.completion_ms.len(), tl.rounds() + 1);
        for k in 0..=100 {
            assert_eq!(
                run.completion_ms[k].to_bits(),
                tl.round_completion(k).to_bits(),
                "{kind:?}: completion[{k}]"
            );
        }
    }
}

#[test]
fn identity_scenario_adaptive_equals_static_arm_bitwise() {
    // With nothing to react to, arming the monitor must change nothing.
    let (net, dm) = setup("gaia");
    let sc = Scenario::identity();
    let armed = AdaptiveConfig::default();
    let baseline = armed.static_baseline();
    let a = run_adaptive(OverlayKind::Mst, &dm, &net, &sc, 120, &armed).unwrap();
    let b = run_adaptive(OverlayKind::Mst, &dm, &net, &sc, 120, &baseline).unwrap();
    assert!(a.redesign_rounds.is_empty());
    for k in 0..=120 {
        assert_eq!(a.completion_ms[k].to_bits(), b.completion_ms[k].to_bits());
    }
}

#[test]
fn monitor_decision_replay_matches_run_adaptive_trace() {
    // PR-6 ring-buffer pin: a standalone ThroughputMonitor fed run_adaptive's
    // own realized per-round durations must reproduce its re-design trace
    // exactly — every fire round and every adopted baseline. This replays
    // through actual mid-run re-designs, so the ring's warm-eviction path
    // (full window, overwrite-oldest) and its post-rearm reset are both on
    // the line.
    let (net, dm) = setup("gaia");
    let sc = Scenario::by_name("scenario:straggler:3:x10").unwrap();
    let cfg = AdaptiveConfig {
        window: 20,
        threshold: 1.3,
        c_b: 0.5,
        seed: 7,
        ..AdaptiveConfig::default()
    };
    let run = run_adaptive(OverlayKind::Mst, &dm, &net, &sc, 200, &cfg).unwrap();
    assert!(
        !run.redesign_rounds.is_empty(),
        "pin needs at least one re-design to replay"
    );

    let mut m = ThroughputMonitor::new(cfg.window, cfg.threshold, dm.n, run.designed_tau_ms[0]);
    let mut fired = Vec::new();
    let mut ti = 0usize;
    for k in 0..200 {
        let dt = run.completion_ms[k + 1] - run.completion_ms[k];
        if let Some(mean) = m.observe(dt) {
            fired.push(k + 1);
            ti += 1;
            // Feeding the *adopted* baseline back as new_tau reproduces the
            // monitor state either way: a real re-design adopts it verbatim,
            // and a futile one's ratchet value mean/threshold is strictly
            // above the old baseline, so rearm adopts it verbatim too.
            let adopted = m.rearm(run.designed_tau_ms[ti], mean);
            assert_eq!(
                adopted.to_bits(),
                run.designed_tau_ms[ti].to_bits(),
                "replayed rearm #{ti} baseline"
            );
        }
    }
    assert_eq!(fired, run.redesign_rounds, "replayed fire rounds");
    assert_eq!(ti + 1, run.designed_tau_ms.len(), "replayed rearm count");
}

#[test]
fn acceptance_adaptive_beats_static_time_to_round_r() {
    // ISSUE-2 acceptance on the MST designer: under
    // scenario:straggler:3:x10 on gaia the re-designed overlay pushes the
    // stragglers {0, 3, 7} to the leaves and reaches round R well before
    // the static one (analysis: static τ ≈ 433 ms from the straggler–
    // straggler MST edge Virginia–Ireland, adaptive τ ≈ 254 ms, the s·T_c
    // compute floor).
    let (net, dm) = setup("gaia");
    let sc = Scenario::by_name("scenario:straggler:3:x10").unwrap();
    let cfg = AdaptiveConfig::default();
    let kind = OverlayKind::Mst;
    let adaptive = run_adaptive(kind, &dm, &net, &sc, 200, &cfg).unwrap();
    let stat = run_adaptive(kind, &dm, &net, &sc, 200, &cfg.static_baseline()).unwrap();
    assert!(
        adaptive.total_ms() < 0.9 * stat.total_ms(),
        "{kind:?}: adaptive {} vs static {}",
        adaptive.total_ms(),
        stat.total_ms()
    );
    assert!(!adaptive.redesign_rounds.is_empty());
    // the re-designed overlay's promise must be below the realized degraded
    // rate the static overlay suffers
    let last_tau = *adaptive.designed_tau_ms.last().unwrap();
    let static_rate = (stat.completion_ms[200] - stat.completion_ms[100]) / 100.0;
    assert!(
        last_tau < static_rate,
        "τ' {last_tau} vs static rate {static_rate}"
    );
}

#[test]
fn scenario_stream_is_shared_across_arms() {
    // Both arms see the same drift realization: seeds equal ⇒ the first
    // window (before any re-design can fire) is identical.
    let (net, dm) = setup("gaia");
    let sc = Scenario::by_name("scenario:drift:0.3").unwrap();
    let armed = AdaptiveConfig::default();
    let a = run_adaptive(OverlayKind::Ring, &dm, &net, &sc, 19, &armed).unwrap();
    let b = run_adaptive(OverlayKind::Ring, &dm, &net, &sc, 19, &armed.static_baseline())
        .unwrap();
    for k in 0..=19 {
        assert_eq!(a.completion_ms[k].to_bits(), b.completion_ms[k].to_bits());
    }
}
