//! Cross-module integration tests: the full stack minus the paper claims
//! (those live in paper_claims.rs).

use fedtopo::fl::data::{DataConfig, FedDataset};
use fedtopo::fl::dpasgd::{run, DpasgdConfig, QuadraticTrainer};
use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, Overlay, OverlayKind};
use fedtopo::util::prop::check;

fn dm_for(name: &str, access: f64, s: usize) -> (Underlay, DelayModel) {
    let net = Underlay::builtin(name).unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), s, access, 1e9);
    (net, dm)
}

#[test]
fn every_designer_on_every_network_is_strong_and_finite() {
    for name in Underlay::builtin_names() {
        let (net, dm) = dm_for(name, 10e9, 1);
        for kind in OverlayKind::all() {
            let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
            let tau = overlay.cycle_time_ms(&dm);
            assert!(
                tau.is_finite() && tau > 0.0,
                "{name}/{:?}: τ = {tau}",
                kind
            );
            if let Some(g) = overlay.static_graph() {
                assert!(g.is_strongly_connected(), "{name}/{kind:?}");
            }
        }
    }
}

#[test]
fn cycle_time_lower_bounded_by_compute() {
    // τ ≥ s·T_c always (the self-loop circuit).
    for s in [1usize, 5, 10] {
        let (net, dm) = dm_for("geant", 10e9, s);
        for kind in [OverlayKind::Ring, OverlayKind::Mst, OverlayKind::Star] {
            let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
            let tau = overlay.cycle_time_ms(&dm);
            let floor = s as f64 * 25.4;
            assert!(tau + 1e-9 >= floor, "{kind:?} s={s}: τ={tau} < {floor}");
        }
    }
}

#[test]
fn wallclock_matches_cycle_time_for_all_static_kinds() {
    let (net, dm) = dm_for("aws-na", 1e9, 1);
    for kind in [
        OverlayKind::Star,
        OverlayKind::Mst,
        OverlayKind::DeltaMbst,
        OverlayKind::Ring,
    ] {
        let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
        let wc = overlay.wallclock_ms(&dm, 200, 7);
        let slope = (wc[200] - wc[100]) / 100.0;
        let tau = overlay.cycle_time_ms(&dm);
        assert!(
            (slope - tau).abs() < 0.05 * tau,
            "{kind:?}: slope {slope} vs τ {tau}"
        );
    }
}

#[test]
fn dpasgd_converges_on_every_overlay_kind() {
    let (net, dm) = dm_for("gaia", 10e9, 1);
    for kind in OverlayKind::all() {
        let overlay: Overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
        let mut tr = QuadraticTrainer::new(11, 8, 5);
        let report = run(
            &mut tr,
            &overlay,
            &DpasgdConfig {
                rounds: 250,
                eval_every: 50,
                ..Default::default()
            },
        )
        .unwrap();
        let opt = tr.optimum();
        let dist: f32 = report
            .final_params_mean
            .iter()
            .zip(&opt)
            .map(|(&w, &o)| (w - o) * (w - o))
            .sum::<f32>()
            .sqrt();
        assert!(dist < 1.0, "{kind:?}: dist {dist}");
    }
}

#[test]
fn gml_export_reimport_preserves_cycle_times() {
    let (net, dm) = dm_for("geant", 10e9, 1);
    let text = net.to_gml();
    let net2 = Underlay::from_gml("geant", &text).unwrap();
    let dm2 = DelayModel::new(&net2, &Workload::inaturalist(), 1, 10e9, 1e9);
    for kind in [OverlayKind::Mst, OverlayKind::Ring] {
        let t1 = design_with_underlay(kind, &dm, &net, 0.5)
            .unwrap()
            .cycle_time_ms(&dm);
        let t2 = design_with_underlay(kind, &dm2, &net2, 0.5)
            .unwrap()
            .cycle_time_ms(&dm2);
        assert!((t1 - t2).abs() < 1e-6, "{kind:?}: {t1} vs {t2}");
    }
}

#[test]
fn data_partition_stats_match_paper_shape() {
    // Table-4-like skew at Ebone scale.
    let data = FedDataset::synthesize(&DataConfig {
        num_silos: 87,
        size_sigma: 1.2,
        alpha: 0.3,
        test_samples: 100,
        ..DataConfig::default()
    });
    let sizes = data.sizes();
    let max = *sizes.iter().max().unwrap() as f64;
    let min = *sizes.iter().min().unwrap() as f64;
    assert!(max / min > 5.0, "size skew {}", max / min);
    assert!(data.mean_pairwise_js() > 0.2, "js {}", data.mean_pairwise_js());
}

#[test]
fn prop_any_strong_overlay_cycle_time_sane() {
    // Random strong digraphs over Gaia: τ between the compute floor and the
    // all-pairs worst arc-delay bound.
    let (_, dm) = dm_for("gaia", 1e9, 1);
    check("random overlay τ sane", 40, |g| {
        let n = 11;
        let mut dg = fedtopo::graph::DiGraph::new(n);
        for i in 0..n {
            dg.add_edge(i, (i + 1) % n, 0.0); // strong ring base
        }
        for _ in 0..g.usize(0, 20) {
            let a = g.rng.usize(n);
            let b = g.rng.usize(n);
            if a != b && !dg.has_edge(a, b) {
                dg.add_edge(a, b, 0.0);
            }
        }
        let tau = dm.cycle_time_ms(&dg);
        assert!(tau >= 25.4 - 1e-9);
        // worst possible arc delay bound
        let worst = (0..n)
            .flat_map(|i| (0..n).filter(move |&j| j != i).map(move |j| (i, j)))
            .map(|(i, j)| dm.d_o(i, j, n, n))
            .fold(0.0f64, f64::max);
        assert!(tau <= worst + 1e-9, "τ={tau} worst={worst}");
    });
}

#[test]
fn failure_injection_unknown_inputs() {
    assert!(Underlay::builtin("atlantis").is_err());
    assert!(Workload::by_name("cifar").is_err());
    assert!(OverlayKind::by_name("hypercube").is_err());
    assert!(Underlay::from_gml("x", "graph [ node [ id 0 ] ]").is_err()); // no geo
    assert!(fedtopo::netsim::gml::parse_graph("nonsense [").is_err());
    assert!(Underlay::by_name("synth:smallworld:50").is_err());
    assert!(Underlay::by_name("synth:waxman:bad").is_err());
}

/// ISSUE-1 cross-validation: for every builtin underlay × every overlay
/// kind, the cycle time is bit-identical whether the Eq.-(5) solve routes
/// through Karp or through Howard. Static overlays are checked on their
/// materialized delay digraph; the MATCHA families (whose cycle time is a
/// recurrence simulation, not a cycle-mean solve) are checked on sampled
/// round digraphs plus determinism of the Monte-Carlo estimate itself.
#[test]
fn karp_and_howard_bit_identical_on_all_builtins() {
    use fedtopo::maxplus::{cycle_time_with, CycleSolver};
    for name in Underlay::builtin_names() {
        let (net, dm) = dm_for(name, 10e9, 1);
        for kind in OverlayKind::all() {
            let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
            match overlay.static_graph() {
                Some(g) => {
                    let dd = dm.delay_digraph(g);
                    let karp = cycle_time_with(&dd, CycleSolver::Karp).unwrap();
                    let howard = cycle_time_with(&dd, CycleSolver::Howard).unwrap();
                    assert_eq!(
                        karp.to_bits(),
                        howard.to_bits(),
                        "{name}/{kind:?}: karp {karp} vs howard {howard}"
                    );
                }
                None => {
                    for k in 0..5 {
                        let g = overlay.round_graph(k, 7);
                        let dd = dm.delay_digraph(&g);
                        let karp = cycle_time_with(&dd, CycleSolver::Karp).unwrap();
                        let howard = cycle_time_with(&dd, CycleSolver::Howard).unwrap();
                        assert_eq!(
                            karp.to_bits(),
                            howard.to_bits(),
                            "{name}/{kind:?} round {k}"
                        );
                    }
                    let a = overlay.cycle_time_ms(&dm);
                    let b = overlay.cycle_time_ms(&dm);
                    assert_eq!(a.to_bits(), b.to_bits(), "{name}/{kind:?} MC seed drift");
                }
            }
        }
    }
}

/// ISSUE-1 acceptance: every overlay kind designs successfully on a
/// 1000-silo synthetic underlay with finite positive τ and strong
/// connectivity.
#[test]
fn every_designer_scales_to_1000_silos() {
    let net = Underlay::by_name("synth:waxman:1000:seed7").unwrap();
    assert_eq!(net.n_silos(), 1000);
    assert!(net.core.is_connected());
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    for kind in OverlayKind::all() {
        let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
        let tau = overlay.cycle_time_ms(&dm);
        assert!(
            tau.is_finite() && tau > 0.0,
            "1000-silo {kind:?}: τ = {tau}"
        );
        if let Some(g) = overlay.static_graph() {
            assert!(g.is_strongly_connected(), "1000-silo {kind:?} not strong");
        }
    }
}

#[test]
fn synth_underlays_feed_the_full_stack() {
    // A synthetic spec behaves exactly like a builtin across the stack:
    // designers, GML round-trip, cycle times.
    let net = Underlay::by_name("synth:geo:60:seed3").unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    let ring = design_with_underlay(OverlayKind::Ring, &dm, &net, 0.5).unwrap();
    let tau = ring.cycle_time_ms(&dm);
    assert!(tau.is_finite() && tau > 0.0);
    let net2 = Underlay::from_gml("synth-reimport", &net.to_gml()).unwrap();
    assert_eq!(net2.n_silos(), 60);
    assert_eq!(net2.n_links(), net.n_links());
}
