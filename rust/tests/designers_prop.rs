//! ISSUE-2 designer invariants over seeded synthetic underlays.
//!
//! Structural properties every designer must keep as N grows, checked on
//! `synth:*` underlays at N ∈ {10, 50, 200} (the builtins are covered by
//! the golden suite; these pin the *shape*, not the numbers):
//!
//! * every static overlay is strongly connected;
//! * STAR has exactly 2(N−1) arcs (hub ↔ each silo);
//! * RING is a single directed Hamiltonian circuit (in/out degree 1,
//!   one cycle through all N silos);
//! * δ-MBST is a spanning tree that respects the degree bound of the
//!   Algorithm-1 candidate that won (2 for the Hamiltonian-path 2-BST,
//!   δ for a δ-PRIM tree).

use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, mbst, OverlayKind};

fn cases() -> Vec<(String, usize)> {
    let mut specs = Vec::new();
    for family in ["waxman", "ba", "geo", "grid"] {
        for n in [10usize, 50] {
            specs.push((format!("synth:{family}:{n}:seed7"), n));
        }
    }
    // one large instance per ISSUE-2 (betweenness hub + Howard dispatch path)
    specs.push(("synth:waxman:200:seed7".to_string(), 200));
    specs
}

fn model(spec: &str) -> (Underlay, DelayModel) {
    let net = Underlay::by_name(spec).unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    (net, dm)
}

#[test]
fn static_overlays_strongly_connected() {
    for (spec, n) in cases() {
        let (net, dm) = model(&spec);
        assert_eq!(net.n_silos(), n);
        for kind in [
            OverlayKind::Star,
            OverlayKind::Mst,
            OverlayKind::DeltaMbst,
            OverlayKind::Ring,
        ] {
            let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
            let g = overlay.static_graph().unwrap();
            assert_eq!(g.n(), n, "{spec}/{kind:?}");
            assert!(g.is_strongly_connected(), "{spec}/{kind:?} not strong");
        }
    }
}

#[test]
fn star_has_exactly_2n_minus_2_arcs() {
    for (spec, n) in cases() {
        let (net, dm) = model(&spec);
        let overlay = design_with_underlay(OverlayKind::Star, &dm, &net, 0.5).unwrap();
        let g = overlay.static_graph().unwrap();
        assert_eq!(g.m(), 2 * (n - 1), "{spec}: star arc count");
        // exactly one hub of degree n−1, all others degree 1
        let hubs: Vec<usize> = (0..n).filter(|&i| g.out_degree(i) == n - 1).collect();
        assert_eq!(hubs.len(), 1, "{spec}: hub count");
        for i in 0..n {
            if i != hubs[0] {
                assert_eq!(g.out_degree(i), 1, "{spec}: leaf {i}");
                assert_eq!(g.in_degree(i), 1, "{spec}: leaf {i}");
            }
        }
    }
}

#[test]
fn ring_is_a_single_hamiltonian_circuit() {
    for (spec, n) in cases() {
        let (net, dm) = model(&spec);
        let overlay = design_with_underlay(OverlayKind::Ring, &dm, &net, 0.5).unwrap();
        let g = overlay.static_graph().unwrap();
        for i in 0..n {
            assert_eq!(g.out_degree(i), 1, "{spec}: out-degree of {i}");
            assert_eq!(g.in_degree(i), 1, "{spec}: in-degree of {i}");
        }
        // follow the unique successor from 0: must visit all n silos before
        // returning (a single circuit, not a union of smaller ones)
        let mut seen = vec![false; n];
        let mut v = 0usize;
        for step in 0..n {
            assert!(!seen[v], "{spec}: revisited {v} at step {step}");
            seen[v] = true;
            v = g.out_neighbors(v)[0].0;
        }
        assert_eq!(v, 0, "{spec}: walk must close after n hops");
        assert!(seen.iter().all(|&s| s), "{spec}: circuit skipped silos");
    }
}

#[test]
fn delta_mbst_is_a_tree_and_respects_its_degree_bound() {
    // Check in the node-capacitated regime too (100 Mbps access), where the
    // degree bound is what the designer is actually paid for.
    for access in [10e9, 100e6] {
        for (spec, n) in cases() {
            let net = Underlay::by_name(&spec).unwrap();
            let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, access, 1e9);
            let (winner, tree) = mbst::design_named(&dm);
            assert_eq!(tree.m(), n - 1, "{spec}@{access}: not a spanning tree");
            assert!(tree.is_connected(), "{spec}@{access}: disconnected");
            let bound = if winner.starts_with("ham-path") {
                2
            } else {
                winner
                    .split('-')
                    .next()
                    .and_then(|d| d.parse::<usize>().ok())
                    .unwrap_or_else(|| panic!("{spec}: unrecognized candidate '{winner}'"))
            };
            assert!(
                tree.max_degree() <= bound,
                "{spec}@{access}: winner '{winner}' has degree {} > bound {bound}",
                tree.max_degree()
            );
        }
    }
}
