//! PR-7 routing-tier contracts, pinned through the public API.
//!
//! Three invariants carry the tiered backend:
//!
//! 1. **Lazy-exact ≡ dense** — a single-region tiered backend serves full
//!    on-demand Dijkstra rows; every product (latency, hops, available
//!    bandwidth) is bit-identical to the dense grids, for any LRU capacity
//!    and any query order.
//! 2. **Cache is not semantics** — λ*, designer selections, and raw
//!    latencies are bit-identical across cache capacities and eviction
//!    orders; only wall-clock may differ.
//! 3. **Landmark envelope** — intra-region pairs are bit-exact (the
//!    truncated Dijkstra settles the whole region); cross-region pairs
//!    report the latency of the real detour walk i → L(i) → L(j) → j, so
//!    approx ≥ exact (it is a walk in the same metric) and, by the triangle
//!    inequality on the shortest-path metric,
//!    approx ≤ exact + 2·(to(i) + from(j)).

use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::routing::{BwModel, Routes, RoutingTier, ROUTES_DENSE_MAX_N};
use fedtopo::netsim::underlay::Underlay;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::topology::{design_with_underlay, star, OverlayKind};

/// Build a delay model around explicitly-constructed routes (homogeneous
/// 10 Gbps access, the Table-3 default).
fn dm_with_routes(net: &Underlay, wl: &Workload, routes: Routes) -> DelayModel {
    let n = net.n_silos();
    DelayModel::with_parts(
        1,
        wl.model_bits,
        vec![wl.tc_ms; n],
        vec![10e9; n],
        vec![10e9; n],
        routes,
    )
}

#[test]
fn lazy_exact_bit_equal_to_dense_below_the_gate() {
    // All builtins plus synth N ∈ {200, 2000}: the lazy-exact tier serves
    // every ordered pair bit-identical to the dense grids.
    for name in [
        "gaia",
        "geant",
        "ebone",
        "synth:waxman:200:seed7",
        "synth:ba:2000:seed7",
    ] {
        let net = Underlay::by_name(name).unwrap();
        let n = net.n_silos();
        let dense = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        let lazy = Routes::compute_tiered(&net, 1e9, RoutingTier::LazyExact, 4);
        assert_eq!(lazy.tier(), RoutingTier::LazyExact, "{name}");
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    lazy.lat_ms(i, j).to_bits(),
                    dense.lat_ms(i, j).to_bits(),
                    "{name}: lat ({i},{j})"
                );
                assert_eq!(lazy.hops(i, j), dense.hops(i, j), "{name}: hops ({i},{j})");
            }
        }
        // spot-check the bandwidth product (uniform on both backends)
        for (i, j) in [(0, 1), (1, 0), (0, n - 1), (n / 2, n / 3)] {
            assert_eq!(
                lazy.abw_bps(i, j).to_bits(),
                dense.abw_bps(i, j).to_bits(),
                "{name}: abw ({i},{j})"
            );
        }
    }
}

#[test]
fn cache_capacity_and_eviction_order_never_change_results() {
    // The LRU is a performance switch: identical latencies and identical
    // derived products (λ*, MST edge set, star hub) for capacities 1, 7,
    // and 512, and for row-major vs column-major query orders (which evict
    // in completely different patterns at capacity 1).
    let net = Underlay::by_name("synth:waxman:300:seed7").unwrap();
    let wl = Workload::inaturalist();
    let n = net.n_silos();

    let routes = |cap: usize| Routes::compute_tiered(&net, 1e9, RoutingTier::Landmark, cap);

    // raw latencies, scrambled eviction: capacity-1 row-major vs
    // capacity-1 column-major vs capacity-512
    let a = routes(1);
    let b = routes(1);
    let c = routes(512);
    let mut row_major = Vec::with_capacity(n * n);
    for i in 0..n {
        for j in 0..n {
            row_major.push(a.lat_ms(i, j));
        }
    }
    for j in 0..n {
        for i in 0..n {
            let x = b.lat_ms(i, j);
            assert_eq!(
                x.to_bits(),
                row_major[i * n + j].to_bits(),
                "eviction order changed lat ({i},{j})"
            );
        }
    }
    for i in (0..n).step_by(17) {
        for j in (0..n).step_by(13) {
            assert_eq!(
                c.lat_ms(i, j).to_bits(),
                row_major[i * n + j].to_bits(),
                "capacity changed lat ({i},{j})"
            );
        }
    }

    // derived products across capacities
    let products = |cap: usize| {
        let dm = dm_with_routes(&net, &wl, routes(cap));
        let hub = star::choose_hub(&dm);
        let mst = design_with_underlay(OverlayKind::Mst, &dm, &net, 0.5).unwrap();
        let tau = mst.cycle_time_ms(&dm);
        let g = mst.static_graph().expect("MST is static");
        let mut edges: Vec<(usize, usize)> = g.edges().into_iter().map(|(u, v, _)| (u, v)).collect();
        edges.sort_unstable();
        (hub, edges, tau.to_bits())
    };
    let p1 = products(1);
    let p7 = products(7);
    let p512 = products(512);
    assert_eq!(p1, p7, "capacity 1 vs 7 changed a derived product");
    assert_eq!(p1, p512, "capacity 1 vs 512 changed a derived product");
}

#[test]
fn landmark_tier_is_exact_intra_region_and_bounded_cross_region() {
    for name in ["synth:waxman:400:seed7", "synth:geo:300:seed7"] {
        let net = Underlay::by_name(name).unwrap();
        let n = net.n_silos();
        let dense = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        let lm = Routes::compute_tiered(&net, 1e9, RoutingTier::Landmark, 8);
        assert_eq!(lm.tier(), RoutingTier::Landmark, "{name}");
        assert!(lm.landmark_nodes().is_some(), "{name}");
        let mut cross = 0usize;
        for i in 0..n {
            for j in 0..n {
                let exact = dense.lat_ms(i, j);
                let approx = lm.lat_ms(i, j);
                if lm.exact_pair(i, j) {
                    assert_eq!(
                        approx.to_bits(),
                        exact.to_bits(),
                        "{name}: intra-region ({i},{j}) not bit-exact"
                    );
                    assert_eq!(lm.hops(i, j), dense.hops(i, j), "{name}: hops ({i},{j})");
                } else {
                    cross += 1;
                    // the detour is a real walk in the same additive metric
                    assert!(
                        approx >= exact - 1e-6,
                        "{name}: ({i},{j}) approx {approx} below exact {exact}"
                    );
                    // triangle inequality through both landmarks
                    let (to_i, from_i) = lm.landmark_offsets_ms(i).unwrap();
                    let (to_j, from_j) = lm.landmark_offsets_ms(j).unwrap();
                    let bound = exact + 2.0 * (to_i + from_i + to_j + from_j) + 1e-6;
                    assert!(
                        approx <= bound,
                        "{name}: ({i},{j}) approx {approx} exceeds bound {bound} (exact {exact})"
                    );
                }
            }
        }
        assert!(cross > 0, "{name}: no cross-region pairs exercised");
    }
}

#[test]
fn striped_cache_capacities_straddling_the_stripe_count_are_pure_perf() {
    // PR-10: the exact-row LRU is striped (8 stripes at full capacity).
    // Capacities below, at, and just above the stripe count collapse to
    // fewer stripes with every stripe keeping ≥ 1 row; whatever the
    // striping, eviction, or contention pattern, latencies and hops must
    // stay bit-identical — capacity semantics unchanged from PR 7.
    let net = Underlay::by_name("synth:geo:300:seed7").unwrap();
    let n = net.n_silos();
    let routes = |cap: usize| Routes::compute_tiered(&net, 1e9, RoutingTier::Landmark, cap);
    let base = routes(512);
    let mut lat = Vec::with_capacity(n * n);
    for i in 0..n {
        for j in 0..n {
            lat.push(base.lat_ms(i, j));
        }
    }
    for cap in [1usize, 2, 3, 7, 8, 9] {
        let r = routes(cap);
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    r.lat_ms(i, j).to_bits(),
                    lat[i * n + j].to_bits(),
                    "cap={cap}: lat ({i},{j})"
                );
            }
        }
        for (i, j) in [(0, n - 1), (n / 2, n / 3), (n - 1, 1)] {
            assert_eq!(r.hops(i, j), base.hops(i, j), "cap={cap}: hops ({i},{j})");
        }
    }
}

#[test]
fn landmark_build_is_invariant_to_jobs_and_intracell_workers() {
    // PR-10: the landmark tier's R full Dijkstras and the per-region offset
    // fills fan out across the intra-cell pool, merged by region index.
    // The constructed backend must be byte-identical for any (--jobs,
    // --intracell) combination, the sequential baseline included.
    use fedtopo::util::parallel::{set_intracell, set_jobs};
    let net = Underlay::by_name("synth:waxman:400:seed7").unwrap();
    let n = net.n_silos();
    set_jobs(1);
    set_intracell(1);
    let base = Routes::compute_tiered(&net, 1e9, RoutingTier::Landmark, 8);
    for (jobs, intracell) in [(4usize, 0usize), (2, 3), (1, 7)] {
        set_jobs(jobs);
        set_intracell(intracell);
        let r = Routes::compute_tiered(&net, 1e9, RoutingTier::Landmark, 8);
        assert_eq!(r.tier(), RoutingTier::Landmark);
        assert_eq!(r.landmark_nodes(), base.landmark_nodes(), "jobs={jobs}/{intracell}");
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    r.lat_ms(i, j).to_bits(),
                    base.lat_ms(i, j).to_bits(),
                    "jobs={jobs} intracell={intracell}: lat ({i},{j})"
                );
            }
        }
        for i in 0..n {
            assert_eq!(
                r.landmark_offsets_ms(i),
                base.landmark_offsets_ms(i),
                "jobs={jobs} intracell={intracell}: offsets({i})"
            );
        }
    }
    set_jobs(0);
    set_intracell(0);
}

#[test]
fn above_the_gate_dispatch_is_landmark_with_no_dense_products() {
    // Just past ROUTES_DENSE_MAX_N the plain constructor must pick the
    // landmark tier on its own: no per-pair path arena, uniform bandwidth,
    // landmark candidates exposed to the designers.
    let n = ROUTES_DENSE_MAX_N + 104;
    let net = Underlay::by_name(&format!("synth:ba:{n}:seed7")).unwrap();
    let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
    assert_eq!(r.tier(), RoutingTier::Landmark);
    assert!(!r.has_paths(), "no O(N²) path arena above the gate");
    let lms = r.landmark_nodes().expect("landmark candidates exposed");
    assert!(lms.len() > 1 && lms.len() < n / 16, "R = {} landmarks", lms.len());
    assert_eq!(r.abw_bps(0, 1), 1e9);
    assert!(r.abw_bps(3, 3).is_infinite());
    // a few queries actually resolve: positive finite latencies, symmetric
    // underlay ⇒ loosely symmetric reported latencies
    for (i, j) in [(0, 1), (0, n - 1), (n / 2, n / 3)] {
        let l = r.lat_ms(i, j);
        assert!(l.is_finite() && l > 0.0, "lat({i},{j}) = {l}");
        assert!(r.hops(i, j) > 0);
    }
}
