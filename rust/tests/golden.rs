//! Golden-file regression tests: Table-3 cycle times λ* for every builtin
//! underlay × every `OverlayKind`, pinned to JSON fixtures under
//! `tests/golden/` — plus (PR 4) `train_<network>.json` time-to-accuracy
//! fixtures from the coupled training engine.
//!
//! * fixture present → computed values must match within 1e-6 relative
//!   (float-exact on one platform; the slack absorbs libm trig differences
//!   in the haversine latency model across platforms);
//! * fixture missing → it is generated, written, and the test passes with a
//!   note (self-priming: commit the generated files to pin the numbers);
//!   set `REQUIRE_GOLDEN=1` to fail on missing fixtures instead (for CI,
//!   once the fixtures are committed);
//! * `UPDATE_GOLDEN=1` → fixtures are rewritten unconditionally (the
//!   sanctioned regeneration path after an intentional model change).
//!
//! Both fixture families ride the same UPDATE_GOLDEN / REQUIRE_GOLDEN flow
//! and the same CI `golden` job (prime → strict re-check → artifact upload
//! → drift-vs-committed gate).

use fedtopo::coordinator::experiments::train::{self, TrainConfig};
use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::json::Json;
use std::path::PathBuf;

/// Table-3 configuration: iNaturalist, s = 1, 10 Gbps access, 1 Gbps core.
const S: usize = 1;
const ACCESS_BPS: f64 = 10e9;
const CORE_BPS: f64 = 1e9;
const C_B: f64 = 0.5;
const REL_TOL: f64 = 1e-6;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn compute_taus(name: &str) -> Vec<(&'static str, f64)> {
    let net = Underlay::builtin(name).unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), S, ACCESS_BPS, CORE_BPS);
    OverlayKind::all()
        .iter()
        .map(|&kind| {
            let overlay = design_with_underlay(kind, &dm, &net, C_B).unwrap();
            (kind.name(), overlay.cycle_time_ms(&dm))
        })
        .collect()
}

fn fixture_json(name: &str, taus: &[(&'static str, f64)]) -> Json {
    Json::obj(vec![
        ("network", Json::str(name)),
        (
            "config",
            Json::obj(vec![
                ("workload", Json::str("inaturalist")),
                ("s", Json::num(S as f64)),
                ("access_bps", Json::num(ACCESS_BPS)),
                ("core_bps", Json::num(CORE_BPS)),
                ("cb", Json::num(C_B)),
            ]),
        ),
        (
            "tau_ms",
            Json::obj(taus.iter().map(|&(k, v)| (k, Json::num(v))).collect()),
        ),
    ])
}

#[test]
fn golden_table3_cycle_times() {
    let dir = golden_dir();
    let env_is = |k: &str| std::env::var(k).map(|v| v == "1").unwrap_or(false);
    let update = env_is("UPDATE_GOLDEN");
    let require = env_is("REQUIRE_GOLDEN");
    let mut wrote = Vec::new();
    for &name in Underlay::builtin_names() {
        let taus = compute_taus(name);
        let path = dir.join(format!("{name}.json"));
        if !update && !path.exists() && require {
            panic!("{name}.json missing and REQUIRE_GOLDEN=1 — commit the fixtures");
        }
        if update || !path.exists() {
            std::fs::create_dir_all(&dir).expect("create tests/golden");
            let mut body = fixture_json(name, &taus).to_string();
            body.push('\n');
            std::fs::write(&path, body).expect("write golden fixture");
            wrote.push(name);
            continue;
        }
        let src = std::fs::read_to_string(&path).expect("read golden fixture");
        let v = Json::parse(&src).unwrap_or_else(|e| panic!("{name}.json: {e}"));
        assert_eq!(v.get("network").as_str(), Some(name), "{name}.json: network");
        let pinned = v.get("tau_ms");
        for (kind, tau) in &taus {
            let want = pinned
                .get(kind)
                .as_f64()
                .unwrap_or_else(|| panic!("{name}.json: missing tau_ms.{kind}"));
            let rel = (tau - want).abs() / want.abs().max(1e-12);
            assert!(
                rel <= REL_TOL,
                "{name}/{kind}: λ* drifted — computed {tau}, golden {want} \
                 (rel {rel:.2e}). If the change is intentional, regenerate \
                 with UPDATE_GOLDEN=1."
            );
        }
    }
    if !wrote.is_empty() {
        eprintln!(
            "golden: generated fixtures for {wrote:?} in {dir:?} — commit them to pin \
             Table-3 cycle times (regenerate with UPDATE_GOLDEN=1)."
        );
    }
}

// ---------------------------------------------------------------------------
// PR-4: time-to-accuracy fixtures from the coupled training engine
// ---------------------------------------------------------------------------

/// The pinned `fedtopo train` configuration: quadratic proxy, two
/// scenarios, all designers, paired seeds. Small enough to prime in
/// seconds, rich enough that a drift in the trainer, the consensus rule,
/// the scenario engine, or the timeline shows up as a changed number.
fn train_fixture_cfg(network: &str) -> TrainConfig {
    TrainConfig {
        networks: vec![network.to_string()],
        scenarios: vec![
            "scenario:identity".to_string(),
            "scenario:straggler:3:x10".to_string(),
        ],
        rounds: 60,
        ..Default::default()
    }
}

fn train_fixture_json(network: &str, cfg: &TrainConfig, rows: &[train::TrainRow]) -> Json {
    let cells = rows.iter().map(|r| {
        Json::obj(vec![
            ("overlay", Json::str(r.kind.name())),
            ("scenario", Json::str(&r.scenario)),
            ("lambda_star_ms", Json::num(r.lambda_star_ms)),
            (
                "time_to_target_ms",
                r.time_to_target_ms.map(Json::num).unwrap_or(Json::Null),
            ),
            (
                "rounds_to_target",
                r.rounds_to_target
                    .map(|k| Json::num(k as f64))
                    .unwrap_or(Json::Null),
            ),
            ("total_ms", Json::num(r.total_ms)),
            ("final_train_loss", Json::num(r.final_train_loss as f64)),
        ])
    });
    Json::obj(vec![
        ("network", Json::str(network)),
        (
            "config",
            Json::obj(vec![
                ("workload", Json::str(cfg.workloads[0].name)),
                ("rounds", Json::num(cfg.rounds as f64)),
                ("target_acc", Json::num(cfg.target_acc as f64)),
                ("dim", Json::num(cfg.dim as f64)),
                ("seed", Json::num(cfg.seeds[0] as f64)),
            ]),
        ),
        ("cells", Json::arr(cells)),
    ])
}

fn assert_rel_eq(got: f64, want: f64, what: &str) {
    let rel = (got - want).abs() / want.abs().max(1e-12);
    assert!(
        rel <= REL_TOL,
        "{what}: drifted — computed {got}, golden {want} (rel {rel:.2e}). \
         If the change is intentional, regenerate with UPDATE_GOLDEN=1."
    );
}

#[test]
fn golden_train_time_to_accuracy() {
    let dir = golden_dir();
    let env_is = |k: &str| std::env::var(k).map(|v| v == "1").unwrap_or(false);
    let update = env_is("UPDATE_GOLDEN");
    let require = env_is("REQUIRE_GOLDEN");
    let mut wrote = Vec::new();
    for name in ["gaia", "aws-na"] {
        let cfg = train_fixture_cfg(name);
        let rows = train::run(&cfg).unwrap();
        let path = dir.join(format!("train_{name}.json"));
        if !update && !path.exists() && require {
            panic!("train_{name}.json missing and REQUIRE_GOLDEN=1 — commit the fixtures");
        }
        if update || !path.exists() {
            std::fs::create_dir_all(&dir).expect("create tests/golden");
            let mut body = train_fixture_json(name, &cfg, &rows).to_string();
            body.push('\n');
            std::fs::write(&path, body).expect("write train golden fixture");
            wrote.push(name);
            continue;
        }
        let src = std::fs::read_to_string(&path).expect("read train golden fixture");
        let v = Json::parse(&src).unwrap_or_else(|e| panic!("train_{name}.json: {e}"));
        assert_eq!(v.get("network").as_str(), Some(name));
        let pinned = v
            .get("cells")
            .as_arr()
            .unwrap_or_else(|| panic!("train_{name}.json: missing cells array"));
        assert_eq!(pinned.len(), rows.len(), "train_{name}.json: cell count");
        for (cell, row) in pinned.iter().zip(&rows) {
            let what = format!("{name}/{}/{}", row.kind.name(), row.scenario);
            assert_eq!(cell.get("overlay").as_str(), Some(row.kind.name()), "{what}");
            assert_eq!(
                cell.get("scenario").as_str(),
                Some(row.scenario.as_str()),
                "{what}"
            );
            assert_rel_eq(
                row.lambda_star_ms,
                cell.get("lambda_star_ms").as_f64().unwrap(),
                &format!("{what}: lambda_star_ms"),
            );
            assert_rel_eq(
                row.total_ms,
                cell.get("total_ms").as_f64().unwrap(),
                &format!("{what}: total_ms"),
            );
            assert_rel_eq(
                row.final_train_loss as f64,
                cell.get("final_train_loss").as_f64().unwrap(),
                &format!("{what}: final_train_loss"),
            );
            match (row.time_to_target_ms, cell.get("time_to_target_ms").as_f64()) {
                (Some(got), Some(want)) => {
                    assert_rel_eq(got, want, &format!("{what}: time_to_target_ms"))
                }
                (None, None) => {}
                (got, want) => panic!("{what}: time_to_target_ms {got:?} vs {want:?}"),
            }
            assert_eq!(
                row.rounds_to_target.map(|k| k as f64),
                cell.get("rounds_to_target").as_f64(),
                "{what}: rounds_to_target"
            );
        }
    }
    if !wrote.is_empty() {
        eprintln!(
            "golden: generated train fixtures for {wrote:?} in {dir:?} — commit them to \
             pin time-to-accuracy (regenerate with UPDATE_GOLDEN=1)."
        );
    }
}

#[test]
fn golden_train_fixture_roundtrips_through_serializer() {
    let cfg = train_fixture_cfg("gaia");
    let rows = train::run(&cfg).unwrap();
    let json = train_fixture_json("gaia", &cfg, &rows);
    let re = Json::parse(&json.to_string()).unwrap();
    let cells = re.get("cells").as_arr().unwrap();
    assert_eq!(cells.len(), rows.len());
    for (cell, row) in cells.iter().zip(&rows) {
        let got = cell.get("total_ms").as_f64().unwrap();
        assert_eq!(got.to_bits(), row.total_ms.to_bits(), "{:?}", row.kind);
    }
}

#[test]
fn golden_fixtures_roundtrip_through_serializer() {
    // The fixture writer and the comparator must agree: serialize, parse
    // back, and the values survive exactly (f64 Display is shortest-
    // roundtrip in Rust).
    let taus = compute_taus("gaia");
    let json = fixture_json("gaia", &taus);
    let re = Json::parse(&json.to_string()).unwrap();
    for (kind, tau) in &taus {
        let got = re.get("tau_ms").get(kind).as_f64().unwrap();
        assert_eq!(got.to_bits(), tau.to_bits(), "{kind}");
    }
}
