//! Golden-file regression tests: Table-3 cycle times λ* for every builtin
//! underlay × every `OverlayKind`, pinned to JSON fixtures under
//! `tests/golden/`.
//!
//! * fixture present → computed values must match within 1e-6 relative
//!   (float-exact on one platform; the slack absorbs libm trig differences
//!   in the haversine latency model across platforms);
//! * fixture missing → it is generated, written, and the test passes with a
//!   note (self-priming: commit the generated files to pin the numbers);
//!   set `REQUIRE_GOLDEN=1` to fail on missing fixtures instead (for CI,
//!   once the fixtures are committed);
//! * `UPDATE_GOLDEN=1` → fixtures are rewritten unconditionally (the
//!   sanctioned regeneration path after an intentional model change).

use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::json::Json;
use std::path::PathBuf;

/// Table-3 configuration: iNaturalist, s = 1, 10 Gbps access, 1 Gbps core.
const S: usize = 1;
const ACCESS_BPS: f64 = 10e9;
const CORE_BPS: f64 = 1e9;
const C_B: f64 = 0.5;
const REL_TOL: f64 = 1e-6;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn compute_taus(name: &str) -> Vec<(&'static str, f64)> {
    let net = Underlay::builtin(name).unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), S, ACCESS_BPS, CORE_BPS);
    OverlayKind::all()
        .iter()
        .map(|&kind| {
            let overlay = design_with_underlay(kind, &dm, &net, C_B).unwrap();
            (kind.name(), overlay.cycle_time_ms(&dm))
        })
        .collect()
}

fn fixture_json(name: &str, taus: &[(&'static str, f64)]) -> Json {
    Json::obj(vec![
        ("network", Json::str(name)),
        (
            "config",
            Json::obj(vec![
                ("workload", Json::str("inaturalist")),
                ("s", Json::num(S as f64)),
                ("access_bps", Json::num(ACCESS_BPS)),
                ("core_bps", Json::num(CORE_BPS)),
                ("cb", Json::num(C_B)),
            ]),
        ),
        (
            "tau_ms",
            Json::obj(taus.iter().map(|&(k, v)| (k, Json::num(v))).collect()),
        ),
    ])
}

#[test]
fn golden_table3_cycle_times() {
    let dir = golden_dir();
    let env_is = |k: &str| std::env::var(k).map(|v| v == "1").unwrap_or(false);
    let update = env_is("UPDATE_GOLDEN");
    let require = env_is("REQUIRE_GOLDEN");
    let mut wrote = Vec::new();
    for &name in Underlay::builtin_names() {
        let taus = compute_taus(name);
        let path = dir.join(format!("{name}.json"));
        if !update && !path.exists() && require {
            panic!("{name}.json missing and REQUIRE_GOLDEN=1 — commit the fixtures");
        }
        if update || !path.exists() {
            std::fs::create_dir_all(&dir).expect("create tests/golden");
            let mut body = fixture_json(name, &taus).to_string();
            body.push('\n');
            std::fs::write(&path, body).expect("write golden fixture");
            wrote.push(name);
            continue;
        }
        let src = std::fs::read_to_string(&path).expect("read golden fixture");
        let v = Json::parse(&src).unwrap_or_else(|e| panic!("{name}.json: {e}"));
        assert_eq!(v.get("network").as_str(), Some(name), "{name}.json: network");
        let pinned = v.get("tau_ms");
        for (kind, tau) in &taus {
            let want = pinned
                .get(kind)
                .as_f64()
                .unwrap_or_else(|| panic!("{name}.json: missing tau_ms.{kind}"));
            let rel = (tau - want).abs() / want.abs().max(1e-12);
            assert!(
                rel <= REL_TOL,
                "{name}/{kind}: λ* drifted — computed {tau}, golden {want} \
                 (rel {rel:.2e}). If the change is intentional, regenerate \
                 with UPDATE_GOLDEN=1."
            );
        }
    }
    if !wrote.is_empty() {
        eprintln!(
            "golden: generated fixtures for {wrote:?} in {dir:?} — commit them to pin \
             Table-3 cycle times (regenerate with UPDATE_GOLDEN=1)."
        );
    }
}

#[test]
fn golden_fixtures_roundtrip_through_serializer() {
    // The fixture writer and the comparator must agree: serialize, parse
    // back, and the values survive exactly (f64 Display is shortest-
    // roundtrip in Rust).
    let taus = compute_taus("gaia");
    let json = fixture_json("gaia", &taus);
    let re = Json::parse(&json.to_string()).unwrap();
    for (kind, tau) in &taus {
        let got = re.get("tau_ms").get(kind).as_f64().unwrap();
        assert_eq!(got.to_bits(), tau.to_bits(), "{kind}");
    }
}
