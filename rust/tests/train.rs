//! ISSUE-4 pins for the wall-clock training engine.
//!
//! * **Static-path equivalence**: under `scenario:identity` with
//!   `threshold = ∞`, the engine reproduces the retired fig2 static path —
//!   [`fedtopo::fl::dpasgd::run`]'s (round, loss) sequence bit-for-bit, and
//!   `Timeline::simulate`'s completion times bit-for-bit (non-star static
//!   overlays; the STAR compatibility mode reproduces the closed-form
//!   progression instead).
//! * **Timeline equivalence**: the engine's timeline + re-design decisions
//!   equal `run_adaptive`'s under any scenario — training cannot perturb
//!   the simulated clock.
//! * **Consensus conservation**: the local-degree matrix is doubly
//!   stochastic on designed overlays over synthetic underlays, so mixing
//!   preserves the parameter mean over 100 rounds.
//! * **Jobs invariance**: `fedtopo train --json` bytes are identical for
//!   any worker count (the in-process half of CI's determinism gate).

use fedtopo::coordinator::experiments::train::{self, TrainConfig};
use fedtopo::fl::consensus::ConsensusMatrix;
use fedtopo::fl::dpasgd::{self, DpasgdConfig, QuadraticTrainer};
use fedtopo::fl::trainsim::{self, TrainSimConfig};
use fedtopo::fl::workloads::Workload;
use fedtopo::maxplus::recurrence::Timeline;
use fedtopo::netsim::delay::DelayModel;
use fedtopo::netsim::scenario::Scenario;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::adaptive::{run_adaptive, AdaptiveConfig};
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::parallel::set_jobs;
use fedtopo::util::rng::Rng;
use std::sync::Mutex;

/// Serializes the tests that flip the global jobs override (same rationale
/// as `tests/parallel.rs`).
static JOBS_LOCK: Mutex<()> = Mutex::new(());

fn with_jobs<T>(jobs: usize, f: impl FnOnce() -> T) -> T {
    let _guard = JOBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_jobs(jobs);
    let out = f();
    set_jobs(0);
    out
}

fn gaia() -> (Underlay, DelayModel) {
    let net = Underlay::builtin("gaia").unwrap();
    let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
    (net, dm)
}

#[test]
fn acceptance_identity_static_reproduces_dpasgd_bit_for_bit() {
    // The ISSUE-4 acceptance pin: scenario:identity + threshold = ∞ must
    // reproduce the static path's (round, loss) sequence bit-for-bit —
    // for static designers *and* the MATCHA processes (same round-graph
    // stream), including the evaluated points and the final mean model.
    let (net, dm) = gaia();
    for kind in [OverlayKind::Ring, OverlayKind::Mst, OverlayKind::MatchaPlus] {
        let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
        let mut tr_ref = QuadraticTrainer::new(dm.n, 8, 3);
        let reference = dpasgd::run(
            &mut tr_ref,
            &overlay,
            &DpasgdConfig {
                rounds: 80,
                s: 1,
                seed: 17,
                eval_every: 5,
                ring_half_weights: false,
            },
        )
        .unwrap();

        let mut tr = QuadraticTrainer::new(dm.n, 8, 3);
        let rep = trainsim::run(
            &mut tr,
            kind,
            &dm,
            &net,
            &Scenario::identity(),
            &TrainSimConfig {
                rounds: 80,
                s: 1,
                seed: 17,
                eval_every: 5,
                ..Default::default()
            },
        )
        .unwrap();

        assert_eq!(rep.train.records.len(), reference.records.len(), "{kind:?}");
        for (a, b) in rep.train.records.iter().zip(&reference.records) {
            assert_eq!(a.round, b.round);
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{kind:?}: round {} loss",
                a.round
            );
            assert_eq!(
                a.test_loss.map(f32::to_bits),
                b.test_loss.map(f32::to_bits),
                "{kind:?}: round {} eval loss",
                a.round
            );
            assert_eq!(
                a.test_acc.map(f32::to_bits),
                b.test_acc.map(f32::to_bits),
                "{kind:?}: round {} eval acc",
                a.round
            );
        }
        for (a, b) in rep
            .train
            .final_params_mean
            .iter()
            .zip(&reference.final_params_mean)
        {
            assert_eq!(a.to_bits(), b.to_bits(), "{kind:?}: final mean model");
        }
        assert!(rep.redesign_rounds.is_empty(), "{kind:?}: ∞ threshold");
    }
}

#[test]
fn acceptance_identity_timeline_is_simulate_bit_for_bit() {
    // Non-star static overlays: the engine's per-round stamps equal the
    // batch Algorithm-3 reconstruction exactly.
    let (net, dm) = gaia();
    for kind in [OverlayKind::Ring, OverlayKind::Mst, OverlayKind::DeltaMbst] {
        let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
        let g = overlay.static_graph().unwrap();
        let batch = Timeline::simulate(&dm.delay_digraph(g), 80);
        let mut tr = QuadraticTrainer::new(dm.n, 4, 1);
        let rep = trainsim::run(
            &mut tr,
            kind,
            &dm,
            &net,
            &Scenario::identity(),
            &TrainSimConfig {
                rounds: 80,
                eval_every: 0,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(rep.completion_ms.len(), 81, "{kind:?}");
        for k in 0..=80 {
            assert_eq!(
                rep.completion_ms[k].to_bits(),
                batch.round_completion(k).to_bits(),
                "{kind:?}: completion[{k}]"
            );
        }
    }
}

#[test]
fn training_never_perturbs_the_timeline_under_any_scenario() {
    // The engine's clock + re-design trace must equal run_adaptive's
    // (same seed, same monitor) — for a perturbing scenario and an armed
    // monitor, i.e. through actual mid-training re-designs.
    let (net, dm) = gaia();
    for (spec, threshold) in [
        ("scenario:straggler:3:x10", 1.3),
        ("scenario:drift:0.3+churn:p0.05", 1.3),
        ("scenario:congestion:30:x4", f64::INFINITY),
    ] {
        let sc = Scenario::by_name(spec).unwrap();
        let sim = run_adaptive(
            OverlayKind::Mst,
            &dm,
            &net,
            &sc,
            150,
            &AdaptiveConfig {
                window: 20,
                threshold,
                c_b: 0.5,
                seed: 17,
                ..AdaptiveConfig::default()
            },
        )
        .unwrap();
        let mut tr = QuadraticTrainer::new(dm.n, 8, 3);
        let rep = trainsim::run(
            &mut tr,
            OverlayKind::Mst,
            &dm,
            &net,
            &sc,
            &TrainSimConfig {
                rounds: 150,
                seed: 17,
                eval_every: 10,
                window: 20,
                threshold,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(rep.redesign_rounds, sim.redesign_rounds, "{spec}");
        assert_eq!(rep.designed_tau_ms.len(), sim.designed_tau_ms.len());
        for (a, b) in rep.designed_tau_ms.iter().zip(&sim.designed_tau_ms) {
            assert_eq!(a.to_bits(), b.to_bits(), "{spec}: promise");
        }
        for k in 0..=150 {
            assert_eq!(
                rep.completion_ms[k].to_bits(),
                sim.completion_ms[k].to_bits(),
                "{spec}: completion[{k}]"
            );
        }
    }
}

#[test]
fn trainsim_ring_monitor_trace_is_run_to_run_deterministic() {
    // PR-6 ring-buffer pin, training side: two identical trainsim runs under
    // a straggler with an armed monitor (window ≪ horizon, so the ring's
    // warm overwrite-oldest path carries many rounds between re-designs)
    // must produce bit-equal clocks, promises, and re-design traces — and
    // must actually re-design, or the pin isn't exercising eviction.
    let (net, dm) = gaia();
    let sc = Scenario::by_name("scenario:straggler:3:x10").unwrap();
    let run = || {
        let mut tr = QuadraticTrainer::new(dm.n, 8, 3);
        trainsim::run(
            &mut tr,
            OverlayKind::Mst,
            &dm,
            &net,
            &sc,
            &TrainSimConfig {
                rounds: 200,
                seed: 17,
                eval_every: 0,
                window: 20,
                threshold: 1.3,
                ..Default::default()
            },
        )
        .unwrap()
    };
    let a = run();
    let b = run();
    assert!(!a.redesign_rounds.is_empty(), "monitor must trip");
    assert_eq!(a.redesign_rounds, b.redesign_rounds);
    assert_eq!(a.designed_tau_ms.len(), b.designed_tau_ms.len());
    for (x, y) in a.designed_tau_ms.iter().zip(&b.designed_tau_ms) {
        assert_eq!(x.to_bits(), y.to_bits(), "promise");
    }
    for k in 0..=200 {
        assert_eq!(
            a.completion_ms[k].to_bits(),
            b.completion_ms[k].to_bits(),
            "completion[{k}]"
        );
    }
}

#[test]
fn consensus_mixing_conserves_the_parameter_mean_on_synth_underlays() {
    // Doubly-stochastic mixing preserves the global parameter mean to 1e-6
    // over 100 rounds. Degree-bounded designed overlays on synthetic
    // underlays; params at unit scale; the mean is accumulated in f64 so
    // the assertion measures the matrix, not the accumulator.
    for (spec, kind) in [
        ("synth:waxman:10:seed7", OverlayKind::Mst),
        ("synth:geo:50:seed7", OverlayKind::DeltaMbst),
        ("synth:ba:50:seed7", OverlayKind::Ring),
    ] {
        let net = Underlay::by_name(spec).unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
        let g = overlay.static_graph().unwrap();
        let a = ConsensusMatrix::local_degree(g);
        // designed overlays are undirected ⇒ the local-degree rule is
        // doubly stochastic and symmetric
        for s in a.col_sums() {
            assert!((s - 1.0).abs() < 1e-5, "{spec}: col sum {s}");
        }
        let n = net.n_silos();
        let dim = 4;
        let mut rng = Rng::new(42);
        let mut params: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.f32() * 0.2 - 0.1).collect())
            .collect();
        let mean64 = |ps: &[Vec<f32>]| -> Vec<f64> {
            let mut m = vec![0.0f64; dim];
            for p in ps {
                for (mi, &x) in m.iter_mut().zip(p.iter()) {
                    *mi += x as f64;
                }
            }
            m.iter_mut().for_each(|x| *x /= n as f64);
            m
        };
        let before = mean64(&params);
        let mut out: Vec<Vec<f32>> = vec![vec![0.0; dim]; n];
        for _ in 0..100 {
            a.apply_into(&params, &mut out);
            std::mem::swap(&mut params, &mut out);
        }
        let after = mean64(&params);
        for (d, (x, y)) in before.iter().zip(&after).enumerate() {
            assert!(
                (x - y).abs() < 1e-6,
                "{spec}/{kind:?}: mean[{d}] drifted {x} → {y}"
            );
        }
    }
}

#[test]
fn train_json_bit_identical_between_jobs_1_and_4() {
    let cfg = TrainConfig {
        kinds: vec![OverlayKind::Star, OverlayKind::Mst, OverlayKind::Ring],
        scenarios: vec![
            "scenario:identity".to_string(),
            "scenario:straggler:3:x10".to_string(),
        ],
        rounds: 30,
        ..Default::default()
    };
    let report = |jobs: usize| {
        with_jobs(jobs, || {
            let rows = train::run(&cfg).unwrap();
            train::to_json(&cfg, &rows).to_string()
        })
    };
    let a = report(1);
    let b = report(4);
    assert_eq!(a, b, "`fedtopo train --json` must not depend on --jobs");
    assert!(a.contains("\"experiment\":\"train\""));
    assert!(a.contains("\"all_loss_decreased\":true"));
}
