//! End-to-end tests of `fedtopo serve` over real sockets: spawn the built
//! binary on an ephemeral port and drive the NDJSON protocol, byte-comparing
//! daemon responses against the one-shot CLI — the tentpole invariant is
//! that they are **identical**, whatever the cache or concurrency did.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

/// A running daemon; killed on drop so failed tests never leak processes.
struct Daemon {
    child: Child,
    addr: String,
}

impl Daemon {
    /// Spawn `fedtopo serve --addr 127.0.0.1:0 --cache <cache>` and parse
    /// the announced ephemeral address from the first stdout line.
    fn spawn(cache: &str) -> Daemon {
        let mut child = Command::new(env!("CARGO_BIN_EXE_fedtopo"))
            .args(["serve", "--addr", "127.0.0.1:0", "--cache", cache])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn fedtopo serve");
        let mut line = String::new();
        BufReader::new(child.stdout.as_mut().expect("stdout piped"))
            .read_line(&mut line)
            .expect("read the listening line");
        // {"addr":"127.0.0.1:NNNNN","event":"listening","protocol":...}
        let addr = line
            .split("\"addr\":\"")
            .nth(1)
            .and_then(|rest| rest.split('"').next())
            .unwrap_or_else(|| panic!("no addr in listening line: {line:?}"))
            .to_string();
        assert!(
            line.contains("\"protocol\":\"fedtopo-serve/v1\""),
            "bad listening line: {line:?}"
        );
        Daemon { child, addr }
    }

    fn connect(&self) -> Conn {
        let stream = TcpStream::connect(&self.addr).expect("connect to daemon");
        stream
            .set_read_timeout(Some(Duration::from_secs(300)))
            .unwrap();
        Conn {
            reader: BufReader::new(stream.try_clone().expect("clone stream")),
            writer: BufWriter::new(stream),
        }
    }

    /// Graceful end: request shutdown, then reap the process.
    fn shutdown(mut self) {
        let mut c = self.connect();
        let ack = c.roundtrip(r#"{"kind":"shutdown"}"#);
        assert!(ack.contains("\"shutting_down\":true"), "{ack}");
        let _ = self.child.wait();
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Conn {
    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").expect("send");
        self.writer.flush().expect("flush");
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("recv");
        assert!(!line.is_empty(), "daemon closed the connection");
        line.trim_end_matches('\n').to_string()
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.send(line);
        self.recv()
    }
}

/// Run the one-shot CLI and return trimmed stdout.
fn cli(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_fedtopo"))
        .args(args)
        .output()
        .expect("run fedtopo");
    assert!(
        out.status.success(),
        "fedtopo {args:?} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8").trim().to_string()
}

/// The expected ok-envelope around a CLI JSON document.
fn envelope(id: &str, result: &str) -> String {
    format!("{{\"id\":{id},\"ok\":true,\"result\":{result}}}")
}

#[test]
fn design_response_is_byte_identical_to_the_cli() {
    let daemon = Daemon::spawn("16");
    let mut c = daemon.connect();
    let got = c.roundtrip(
        r#"{"id":1,"kind":"design","networks":"gaia","overlays":"ring,star","workload":"femnist"}"#,
    );
    let want = cli(&[
        "scale", "--networks", "gaia", "--overlays", "ring,star", "--workload", "femnist", "--json",
    ]);
    assert_eq!(got, envelope("1", &want));
    daemon.shutdown();
}

#[test]
fn simulate_response_is_byte_identical_to_the_cli() {
    let daemon = Daemon::spawn("16");
    let mut c = daemon.connect();
    let got = c.roundtrip(
        r#"{"id":2,"kind":"simulate","overlays":"ring","workloads":"femnist","rounds":8,"eval_every":4}"#,
    );
    let want = cli(&[
        "train", "--rounds", "8", "--eval-every", "4", "--overlays", "ring", "--workload",
        "femnist", "--json",
    ]);
    assert_eq!(got, envelope("2", &want));
    daemon.shutdown();
}

#[test]
fn cache_hit_is_byte_identical_to_cold_miss() {
    let daemon = Daemon::spawn("16");
    let mut c = daemon.connect();
    let req = r#"{"id":"q","kind":"cycle-time","network":"geant","overlay":"mst"}"#;
    let cold = c.roundtrip(req);
    let warm = c.roundtrip(req);
    assert_eq!(cold, warm, "hit vs miss must not change a single byte");
    // the stats kind (diagnostic, not byte-pinned) confirms a hit happened
    let stats = c.roundtrip(r#"{"kind":"stats"}"#);
    assert!(stats.contains("\"hits\":1"), "{stats}");
    // a cache-disabled daemon produces the same bytes again
    let uncached_daemon = Daemon::spawn("0");
    let uncached = uncached_daemon.connect().roundtrip(req);
    assert_eq!(cold, uncached, "cache capacity must not change bytes");
    uncached_daemon.shutdown();
    daemon.shutdown();
}

fn cycle_req(i: usize) -> String {
    const OVERLAYS: [&str; 8] =
        ["ring", "star", "mst", "delta-mbst", "ring", "star", "mst", "delta-mbst"];
    const NETWORKS: [&str; 8] =
        ["gaia", "gaia", "gaia", "gaia", "geant", "geant", "geant", "geant"];
    format!(
        r#"{{"id":{i},"kind":"cycle-time","network":"{}","overlay":"{}"}}"#,
        NETWORKS[i], OVERLAYS[i]
    )
}

#[test]
fn eight_way_concurrent_matches_sequential() {
    // 8 clients racing a cold daemon, each on its own connection; joining
    // the handles in spawn order collects responses in id order
    let daemon = Daemon::spawn("16");
    let concurrent: Vec<String> = std::thread::scope(|scope| {
        let daemon = &daemon;
        let handles: Vec<_> = (0..8)
            .map(|i| scope.spawn(move || daemon.connect().roundtrip(&cycle_req(i))))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .collect()
    });

    // the same 8 requests, sequentially, on one warm connection
    let mut c = daemon.connect();
    let sequential: Vec<String> = (0..8).map(|i| c.roundtrip(&cycle_req(i))).collect();

    assert_eq!(concurrent, sequential, "arrival order must not change bytes");
    daemon.shutdown();
}

#[test]
fn batch_line_matches_individual_requests_in_input_order() {
    let daemon = Daemon::spawn("16");
    let mut c = daemon.connect();
    c.send(
        r#"[{"id":0,"kind":"cycle-time","network":"gaia","overlay":"ring"},{"id":1,"kind":"ping"},{"id":2,"kind":"cycle-time","network":"gaia","overlay":"star"}]"#,
    );
    let batch: Vec<String> = (0..3).map(|_| c.recv()).collect();

    let singles = [
        c.roundtrip(r#"{"id":0,"kind":"cycle-time","network":"gaia","overlay":"ring"}"#),
        c.roundtrip(r#"{"id":1,"kind":"ping"}"#),
        c.roundtrip(r#"{"id":2,"kind":"cycle-time","network":"gaia","overlay":"star"}"#),
    ];
    assert_eq!(batch, singles, "batching must not change bytes or order");
    daemon.shutdown();
}

#[test]
fn streamed_simulate_emits_events_then_the_plain_response() {
    let daemon = Daemon::spawn("16");
    let mut c = daemon.connect();
    let plain = c.roundtrip(
        r#"{"id":9,"kind":"simulate","overlays":"ring","workloads":"femnist","rounds":6,"eval_every":2}"#,
    );
    c.send(
        r#"{"id":9,"kind":"simulate","overlays":"ring","workloads":"femnist","rounds":6,"eval_every":2,"stream":2}"#,
    );
    let mut events = Vec::new();
    let finale = loop {
        let line = c.recv();
        if line.contains("\"event\":\"rounds\"") {
            events.push(line);
        } else {
            break line;
        }
    };
    assert!(!events.is_empty(), "expected streamed round events");
    assert_eq!(finale, plain, "the streamed finale must match the plain bytes");
    daemon.shutdown();
}

#[test]
fn measure_invalidates_and_capabilities_render_the_registry() {
    let daemon = Daemon::spawn("16");
    let mut c = daemon.connect();
    c.roundtrip(r#"{"kind":"cycle-time","network":"gaia","overlay":"ring"}"#);
    let m = c.roundtrip(r#"{"kind":"measure","network":"gaia"}"#);
    assert!(m.contains("\"invalidated\":1"), "{m}");
    assert!(m.contains("\"fingerprint\":\""), "{m}");

    let caps = c.roundtrip(r#"{"kind":"capabilities"}"#);
    assert!(caps.contains("\"protocol\":\"fedtopo-serve/v1\""), "{caps}");
    for kind in [
        "\"network\":",
        "\"overlay\":",
        "\"workload\":",
        "\"scenario\":",
        "\"backend\":",
    ] {
        assert!(caps.contains(kind), "capabilities missing {kind}: {caps}");
    }
    // resolver errors surface verbatim, pinned format included
    let err = c.roundtrip(r#"{"id":3,"kind":"cycle-time","network":"gaiaa"}"#);
    assert!(err.contains("\"ok\":false"), "{err}");
    assert!(err.contains("cannot resolve network 'gaiaa'"), "{err}");
    assert!(err.contains("did you mean 'gaia'?"), "{err}");
    daemon.shutdown();
}
