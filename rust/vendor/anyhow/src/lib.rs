//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored shim provides
//! the (small) subset of anyhow's API the workspace actually uses:
//!
//! * [`Error`] — a single flattened message; context is prepended
//!   `"context: cause"`, which is what anyhow's `{:#}` alternate formatting
//!   prints for a chain.
//! * [`Result<T>`] with the `E = Error` default.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * `anyhow!`, `bail!`, `ensure!` macros (format-string forms).
//!
//! Swapping back to the real crate is a one-line Cargo.toml change; nothing
//! in the workspace relies on shim-specific behavior.

use std::fmt;

/// A flattened error: the full cause chain rendered into one string.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything printable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    fn wrap(context: impl fmt::Display, cause: impl fmt::Display) -> Error {
        Error {
            msg: format!("{context}: {cause}"),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes this blanket `From` coherent (same trick as real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with the error defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::wrap(context, e))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::wrap(f(), e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {args}")` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt {args}")` — early-return `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "fmt {args}")` — `bail!` unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse().context("parsing int")?;
        ensure!(v >= 0, "negative: {v}");
        Ok(v)
    }

    #[test]
    fn context_and_macros() {
        assert_eq!(parse("7").unwrap(), 7);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("parsing int: "));
        assert_eq!(parse("-1").unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        assert_eq!(
            none.with_context(|| format!("missing {}", 3)).unwrap_err().to_string(),
            "missing 3"
        );
        assert_eq!(Some(1u8).context("never").unwrap(), 1);
    }

    #[test]
    fn from_std_error_flattens_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "inner");
        let e: Error = io.into();
        assert!(e.to_string().contains("inner"));
    }

    #[test]
    fn bail_returns_err() {
        fn f() -> Result<()> {
            bail!("boom {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
    }
}
