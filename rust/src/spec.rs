//! One registry for every name-resolved domain object.
//!
//! Five families of strings name things in fedtopo: underlay networks
//! (`gaia`, `synth:waxman:500:seed7`), overlay designers (`ring`,
//! `delta-mbst`), Table-2 workloads (`femnist`), dynamic-network
//! scenarios (`scenario:straggler:3:x10`, `+`-composable), and
//! communication backends (`backend:grpc:chunk4M`). Before PR 8
//! each had its own `by_name` with its own error wording, and `--help`
//! repeated the name lists by hand. [`Resolve`] puts them all behind one
//! trait with
//!
//! * **one pinned error format** ([`ResolveError`]):
//!   `cannot resolve <kind> '<input>': <reason>[ (in segment '<seg>')];
//!   expected <grammar>[; did you mean '<name>'?]` — the full input is
//!   always echoed, and composite specs additionally name the failing
//!   segment (pre-PR-8, scenario errors echoed only the segment);
//! * **"did you mean" suggestions** computed from the registry names by
//!   edit distance ([`suggest`]);
//! * **machine-readable capabilities** ([`capabilities`]) that
//!   `fedtopo serve` returns verbatim and `--help` renders its name lists
//!   from ([`names_line`]), so docs cannot drift from the parser.
//!
//! Every string accepted before PR 8 is accepted unchanged. The legacy
//! entry points (`Underlay::by_name`, `Scenario::by_name`,
//! `OverlayKind::by_name`, `Workload::by_name`) remain as thin delegates
//! into this registry — calling them *is* calling the registry — so the
//! hundreds of existing call sites keep working while the parse logic and
//! error rendering live in exactly one place per kind.

use crate::util::json::Json;
use std::fmt;

/// The uniform resolver error: every kind renders identically.
///
/// Display format (pinned by `tests/spec_errors.rs`):
///
/// ```text
/// cannot resolve <kind> '<input>': <reason>[ (in segment '<segment>')]; \
/// expected <expected>[; did you mean '<suggestion>'?]
/// ```
#[derive(Clone, Debug)]
pub struct ResolveError {
    /// Registry kind label (`"network"`, `"overlay"`, `"workload"`,
    /// `"scenario"`, `"backend"`).
    pub kind: &'static str,
    /// The full input string as the caller supplied it.
    pub input: String,
    /// The failing segment of a composite spec (scenario `+`-chains).
    pub segment: Option<String>,
    /// What went wrong, without echoing the input (the format adds that).
    pub reason: String,
    /// The accepted grammar, rendered from the registry.
    pub expected: String,
    /// Closest registry name within edit distance, if any.
    pub suggestion: Option<String>,
}

impl ResolveError {
    /// Build an error for `kind`/`input`; `expected` comes from the
    /// resolver's [`Resolve::grammar`].
    pub fn new(kind: &'static str, input: &str, reason: impl Into<String>) -> ResolveError {
        ResolveError {
            kind,
            input: input.to_string(),
            segment: None,
            reason: reason.into(),
            expected: String::new(),
            suggestion: None,
        }
    }

    /// Attach the accepted grammar (builder style).
    pub fn expected(mut self, grammar: impl Into<String>) -> ResolveError {
        self.expected = grammar.into();
        self
    }

    /// Attach a "did you mean" candidate computed from `candidates`.
    pub fn suggest(mut self, got: &str, candidates: &[&str]) -> ResolveError {
        self.suggestion = suggest(got, candidates).map(|s| s.to_string());
        self
    }

    /// Re-home an error raised while parsing one segment of a composite
    /// spec: echo the full input and name the failing segment.
    pub fn in_composite(mut self, full_input: &str, segment: &str) -> ResolveError {
        self.input = full_input.to_string();
        self.segment = Some(segment.to_string());
        self
    }

    /// Re-home an error to the caller's verbatim input (e.g. restore a
    /// stripped `scenario:`/`synth:` prefix) without marking a segment.
    pub fn for_input(mut self, full_input: &str) -> ResolveError {
        self.input = full_input.to_string();
        self
    }
}

impl fmt::Display for ResolveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "cannot resolve {} '{}': {}",
            self.kind, self.input, self.reason
        )?;
        if let Some(seg) = &self.segment {
            write!(f, " (in segment '{seg}')")?;
        }
        if !self.expected.is_empty() {
            write!(f, "; expected {}", self.expected)?;
        }
        if let Some(s) = &self.suggestion {
            write!(f, "; did you mean '{s}'?")?;
        }
        Ok(())
    }
}

impl std::error::Error for ResolveError {}

/// A name-resolved domain object: one registry entry per implementor.
///
/// Implementors: [`crate::netsim::underlay::Underlay`] (`network`),
/// [`crate::topology::OverlayKind`] (`overlay`),
/// [`crate::fl::workloads::Workload`] (`workload`),
/// [`crate::netsim::scenario::Scenario`] (`scenario`),
/// [`crate::netsim::backend::BackendProfile`] (`backend`).
///
/// # Examples
///
/// ```
/// use fedtopo::netsim::underlay::Underlay;
/// use fedtopo::spec::Resolve;
///
/// let net = <Underlay as Resolve>::resolve("gaia").unwrap();
/// assert_eq!(net.n_silos(), 11);
///
/// // every kind fails with the same pinned error shape
/// let err = <Underlay as Resolve>::resolve("gaiaa").unwrap_err();
/// let msg = err.to_string();
/// assert!(msg.starts_with("cannot resolve network 'gaiaa': unknown network"));
/// assert!(msg.ends_with("did you mean 'gaia'?"));
/// ```
pub trait Resolve: Sized {
    /// Registry kind label, used in error messages and capabilities.
    const KIND: &'static str;

    /// Canonical fixed names accepted verbatim (suggestion candidates;
    /// for scenarios these are the perturbation families).
    fn names() -> Vec<&'static str>;

    /// Accepted alternative spellings (suggestion candidates too).
    fn aliases() -> Vec<&'static str> {
        Vec::new()
    }

    /// One-line human summary of the accepted grammar; rendered into every
    /// error's `expected` clause, `--help`, and capabilities.
    fn grammar() -> String;

    /// Parse an input string into the domain object with the structured
    /// error. Implementations build errors with [`ResolveError::new`]; the
    /// provided [`Resolve::resolve`] wrapper is what call sites use.
    fn parse_spec(input: &str) -> Result<Self, ResolveError>;

    /// The registry entry point: parse, with the uniform error rendered
    /// into [`anyhow::Error`] for the existing `Result` plumbing.
    fn resolve(input: &str) -> anyhow::Result<Self> {
        Self::parse_spec(input).map_err(anyhow::Error::msg)
    }
}

/// Closest candidate within Damerau-ish edit distance 2 (plain Levenshtein;
/// ties break toward the earlier registry name). `None` when nothing is
/// close enough — a wild typo gets no guess.
pub fn suggest<'a>(got: &str, candidates: &[&'a str]) -> Option<&'a str> {
    let got = got.to_ascii_lowercase();
    let mut best: Option<(usize, &str)> = None;
    for &c in candidates {
        let d = levenshtein(&got, &c.to_ascii_lowercase());
        if d <= 2 && best.map_or(true, |(bd, _)| d < bd) {
            best = Some((d, c));
        }
    }
    // Identical strings never reach here (they would have resolved), but
    // guard anyway: a distance-0 "suggestion" of the input itself is noise.
    best.and_then(|(d, c)| if d == 0 { None } else { Some(c) })
}

fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// One kind's registry row (names, aliases, grammar).
#[derive(Clone, Debug)]
pub struct KindEntry {
    pub kind: &'static str,
    pub names: Vec<&'static str>,
    pub aliases: Vec<&'static str>,
    pub grammar: String,
}

/// Build the registry row for one implementor.
pub fn entry<T: Resolve>() -> KindEntry {
    KindEntry {
        kind: T::KIND,
        names: T::names(),
        aliases: T::aliases(),
        grammar: T::grammar(),
    }
}

/// The full registry, one row per resolvable kind (stable order).
pub fn registry() -> Vec<KindEntry> {
    vec![
        entry::<crate::netsim::underlay::Underlay>(),
        entry::<crate::topology::OverlayKind>(),
        entry::<crate::fl::workloads::Workload>(),
        entry::<crate::netsim::scenario::Scenario>(),
        entry::<crate::netsim::backend::BackendProfile>(),
    ]
}

/// `a|b|c` — the pipe-joined canonical names, for `--help` text.
pub fn names_line<T: Resolve>() -> String {
    T::names().join("|")
}

/// Machine-readable registry dump: the `capabilities` payload of
/// `fedtopo serve`, and the single source `--help` name lists render from.
pub fn capabilities() -> Json {
    let kinds = registry()
        .into_iter()
        .map(|e| {
            (
                e.kind,
                Json::obj(vec![
                    ("names", Json::arr(e.names.iter().map(|n| Json::str(n)))),
                    ("aliases", Json::arr(e.aliases.iter().map(|n| Json::str(n)))),
                    ("grammar", Json::str(&e.grammar)),
                ]),
            )
        })
        .collect::<Vec<_>>();
    // Json::obj takes (&str, Json) pairs; kind labels are 'static.
    Json::obj(kinds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::backend::BackendProfile;
    use crate::netsim::scenario::Scenario;
    use crate::netsim::underlay::Underlay;
    use crate::topology::OverlayKind;

    #[test]
    fn error_format_is_pinned() {
        let e = ResolveError::new("network", "gaiaa", "unknown network")
            .expected("gaia|geant")
            .suggest("gaiaa", &["gaia", "geant"]);
        assert_eq!(
            e.to_string(),
            "cannot resolve network 'gaiaa': unknown network; expected gaia|geant; \
             did you mean 'gaia'?"
        );
        let e = ResolveError::new("scenario", "bogus:1", "unknown scenario family 'bogus'")
            .expected("identity | drift:<sigma>")
            .in_composite("drift:0.3+bogus:1", "bogus:1");
        assert_eq!(
            e.to_string(),
            "cannot resolve scenario 'drift:0.3+bogus:1': unknown scenario family \
             'bogus' (in segment 'bogus:1'); expected identity | drift:<sigma>"
        );
    }

    #[test]
    fn suggest_by_edit_distance() {
        assert_eq!(suggest("gaiaa", &["gaia", "geant"]), Some("gaia"));
        assert_eq!(suggest("rings", &["ring", "star"]), Some("ring"));
        assert_eq!(suggest("feminst", &["femnist", "sent140"]), Some("femnist"));
        assert_eq!(suggest("zzzzz", &["gaia", "geant"]), None);
    }

    #[test]
    fn registry_covers_all_five_kinds() {
        let kinds: Vec<&str> = registry().iter().map(|e| e.kind).collect();
        assert_eq!(kinds, ["network", "overlay", "workload", "scenario", "backend"]);
        for e in registry() {
            assert!(!e.names.is_empty(), "{} has no names", e.kind);
            assert!(!e.grammar.is_empty(), "{} has no grammar", e.kind);
        }
    }

    #[test]
    fn every_registry_name_resolves() {
        for n in <Underlay as Resolve>::names() {
            assert!(Underlay::by_name(n).is_ok(), "network {n}");
        }
        for n in <OverlayKind as Resolve>::names()
            .into_iter()
            .chain(<OverlayKind as Resolve>::aliases())
        {
            assert!(OverlayKind::by_name(n).is_ok(), "overlay {n}");
        }
        for n in <Workload as Resolve>::names() {
            assert!(Workload::by_name(n).is_ok(), "workload {n}");
        }
        for n in <Scenario as Resolve>::names() {
            // families are the names; identity alone is a full spec, the
            // rest need arguments — resolve the builtin exemplars instead
            assert!(Scenario::by_name("identity").is_ok(), "{n} family list");
        }
        for s in Scenario::builtin_names() {
            assert!(Scenario::by_name(s).is_ok(), "scenario {s}");
        }
        for n in <BackendProfile as Resolve>::names() {
            assert!(BackendProfile::by_name(n).is_ok(), "backend {n}");
        }
    }

    #[test]
    fn capabilities_render_from_the_registry() {
        let caps = capabilities();
        let net = caps.get("network");
        assert!(net
            .get("names")
            .as_arr()
            .unwrap()
            .iter()
            .any(|n| n.as_str() == Some("gaia")));
        assert!(caps.get("scenario").get("grammar").as_str().unwrap().contains("drift"));
        assert!(caps.get("overlay").get("grammar").as_str().unwrap().contains("delta-mbst"));
        assert!(caps.get("backend").get("grammar").as_str().unwrap().contains("chunk"));
        // canonical serialization round-trips
        let s = caps.to_string();
        assert_eq!(Json::parse(&s).unwrap().to_string(), s);
    }
}
