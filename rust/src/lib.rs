//! # fedtopo — Throughput-Optimal Topology Design for Cross-Silo Federated Learning
//!
//! A production-shaped reproduction of Marfoq, Neglia, Xu & Vidal (NeurIPS
//! 2020). The library provides:
//!
//! * [`graph`] — directed/undirected graph substrate: Dijkstra, Prim MST,
//!   degree-bounded Prim (δ-PRIM), maximal-matching decomposition, Brandes
//!   betweenness centrality, tree-cube Hamiltonian paths — plus
//!   [`graph::csr`], the flat-storage core: CSR adjacency and implicit-Kₙ
//!   algorithm variants (Prim / δ-PRIM / Borůvka / greedy matching driven
//!   by a weight callback, O(N) memory) that the designers run on.
//! * [`maxplus`] — linear systems in the (max, +) algebra: the *cycle
//!   time* of Eq. (5) via two exact solvers — Karp (Θ(V·E), small graphs)
//!   and Howard policy iteration (sparse, large graphs) — behind a
//!   size-based dispatch ([`maxplus::HOWARD_MIN_N`]), plus the exact event
//!   recurrence of Eq. (4) — with a reusable CSR delay digraph
//!   ([`maxplus::csr`]) and double-buffered step kernels so per-round
//!   simulation allocates nothing — and max-plus matrix operators.
//! * [`netsim`] — the network simulator: geographic underlays (Gaia,
//!   AWS North America, Géant, Exodus, Ebone), seeded synthetic underlay
//!   generators addressed as `synth:<family>:<n>[:seed<u64>]` (Waxman,
//!   Barabási–Albert, random-geometric, grid — up to 50 000 silos on the
//!   PR-5 flat-storage core), a GML parser, geodesic latency, flat
//!   arena-backed shortest-path routing, and the end-to-end delay model of
//!   Eq. (3) — priced through a pluggable message-level *backend*
//!   ([`netsim::backend`]: `backend:grpc`, `backend:rdma`,
//!   chunk/overhead/pipeline modifiers; the default `backend:scalar` is
//!   bit-identical to the plain Eq.-(3) wire time) — plus dynamic-network
//!   *scenarios* (`scenario:<family>:<args>` specs: bandwidth drift,
//!   periodic congestion, stragglers, link/silo churn, correlated regional
//!   outages) with a per-round time-varying simulation.
//! * [`topology`] — **the paper's contribution**: overlay designers (STAR,
//!   MST of Prop. 3.1, δ-MBST of Alg. 1 / Prop. 3.5, Christofides RING of
//!   Props. 3.3/3.6), the MATCHA / MATCHA⁺ baselines, and an adaptive
//!   monitor/re-design loop that re-runs any designer when realized
//!   throughput degrades under a scenario.
//! * [`fl`] — decentralized periodic-averaging SGD (DPASGD, Eq. (2)):
//!   consensus matrices, non-iid data partitioning, the training
//!   orchestrator, the Table-2 workload catalogue, and the wall-clock
//!   time-to-accuracy engine ([`fl::trainsim`]) that interleaves DPASGD
//!   rounds with the Eq.-(4) recurrence under dynamic-network scenarios,
//!   re-designing topology *and* consensus matrix mid-training when the
//!   throughput monitor trips (`fedtopo train`).
//! * [`runtime`] — the PJRT bridge: loads AOT-compiled HLO-text artifacts
//!   produced by `python/compile/aot.py` and executes them from the Rust
//!   hot path. Python never runs at request time. (Gated behind the
//!   off-by-default `xla` cargo feature — the binding crate and artifacts
//!   are not part of the offline build; everything else falls back to the
//!   quadratic proxy trainer.)
//! * [`coordinator`] — leader process: experiment harness reproducing every
//!   table and figure of the paper — each grid a declarative
//!   [`coordinator::experiments::sweep::SweepSpec`] executed on the
//!   deterministic `--jobs` pool — plus configuration, reporting, and
//!   [`coordinator::serve`], the resident NDJSON-over-TCP daemon whose
//!   responses are byte-identical to the one-shot CLI.
//! * [`spec`] — the name registry: every string-resolved domain object
//!   (underlays, overlays, workloads, scenarios, backends) behind one
//!   [`spec::Resolve`] trait with a uniform pinned error format, "did you
//!   mean" suggestions, and machine-readable capabilities that `--help`
//!   and `fedtopo serve` render from.
//! * [`util`] — zero-dependency substrates: seeded PRNG, JSON, CLI parsing,
//!   statistics, a micro-benchmark harness, a property-testing helper, and
//!   [`util::parallel`] — a scoped-thread pool whose ordered-merge contract
//!   makes every sweep bit-identical for any worker count.
//!
//! ## Quick start
//!
//! ```no_run
//! use fedtopo::netsim::underlay::Underlay;
//! use fedtopo::netsim::delay::DelayModel;
//! use fedtopo::topology::{design, OverlayKind};
//! use fedtopo::fl::workloads::Workload;
//!
//! let net = Underlay::builtin("gaia").unwrap();
//! let wl = Workload::inaturalist();
//! let model = DelayModel::new(&net, &wl, /*s=*/1, /*access bps=*/10e9, 1e9);
//! let overlay = design(OverlayKind::Ring, &model, 0.5).unwrap();
//! println!("cycle time = {:.1} ms", overlay.cycle_time_ms(&model));
//! ```

// Research-style code: index loops over dense matrices are the house idiom.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

/// Narrative documentation, embedded from the repo's `docs/` directory so
/// rustdoc renders it and CI gates it: a broken intra-doc link in
/// `docs/ARCHITECTURE.md` or `docs/PROTOCOL.md` fails `cargo doc`
/// (`RUSTDOCFLAGS=-D warnings`) exactly like one in a `///` comment.
pub mod docs {
    #[doc = include_str!("../../docs/ARCHITECTURE.md")]
    pub mod architecture {}

    #[doc = include_str!("../../docs/PROTOCOL.md")]
    pub mod protocol {}
}

pub mod util;
pub mod spec;
pub mod graph;
pub mod maxplus;
pub mod netsim;
pub mod topology;
pub mod fl;
pub mod runtime;
pub mod coordinator;
