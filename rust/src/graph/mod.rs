//! Graph substrate: weighted directed & undirected graphs plus the
//! algorithms the topology designers are built from.
//!
//! * [`DiGraph`] / [`UnGraph`] — adjacency-list graphs with f64 weights.
//! * [`csr`] — flat CSR storage and the implicit-Kₙ algorithm variants
//!   (Prim / δ-PRIM / Borůvka / greedy matching via a weight callback, O(N)
//!   memory — the PR-5 designer substrate).
//! * [`shortest_path`] — Dijkstra (single-source and all-pairs).
//! * [`mst`] — Prim's MST and the degree-bounded δ-PRIM (paper Alg. 2).
//! * [`matching`] — Misra–Gries edge coloring → matching decomposition
//!   (the MATCHA substrate).
//! * [`centrality`] — Brandes betweenness/load centrality (STAR hub choice).
//! * [`hamiltonian`] — Hamiltonian path in the cube of a tree (Sekanina /
//!   Karaganis construction used by Alg. 1 for the 2-MBST approximation).

pub mod csr;
pub mod shortest_path;
pub mod mst;
pub mod matching;
pub mod centrality;
pub mod hamiltonian;

/// A weighted directed graph over nodes `0..n`.
#[derive(Clone, Debug, Default)]
pub struct DiGraph {
    n: usize,
    /// out-adjacency: `adj[u] = [(v, w), ...]`
    out: Vec<Vec<(usize, f64)>>,
    /// in-adjacency mirror, kept in sync for O(deg) in-neighbour queries.
    inn: Vec<Vec<(usize, f64)>>,
}

impl DiGraph {
    pub fn new(n: usize) -> DiGraph {
        DiGraph {
            n,
            out: vec![Vec::new(); n],
            inn: vec![Vec::new(); n],
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn m(&self) -> usize {
        self.out.iter().map(|a| a.len()).sum()
    }

    pub fn add_edge(&mut self, u: usize, v: usize, w: f64) {
        assert!(u < self.n && v < self.n, "edge ({u},{v}) out of range");
        assert!(u != v, "self-loops are represented implicitly");
        self.out[u].push((v, w));
        self.inn[v].push((u, w));
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.out[u].iter().any(|&(x, _)| x == v)
    }

    pub fn weight(&self, u: usize, v: usize) -> Option<f64> {
        self.out[u].iter().find(|&&(x, _)| x == v).map(|&(_, w)| w)
    }

    pub fn set_weight(&mut self, u: usize, v: usize, w: f64) {
        for e in &mut self.out[u] {
            if e.0 == v {
                e.1 = w;
            }
        }
        for e in &mut self.inn[v] {
            if e.0 == u {
                e.1 = w;
            }
        }
    }

    pub fn out_neighbors(&self, u: usize) -> &[(usize, f64)] {
        &self.out[u]
    }

    pub fn in_neighbors(&self, v: usize) -> &[(usize, f64)] {
        &self.inn[v]
    }

    pub fn out_degree(&self, u: usize) -> usize {
        self.out[u].len()
    }

    pub fn in_degree(&self, v: usize) -> usize {
        self.inn[v].len()
    }

    /// All edges as (u, v, w) triples in deterministic order.
    pub fn edges(&self) -> Vec<(usize, usize, f64)> {
        let mut es = Vec::with_capacity(self.m());
        for u in 0..self.n {
            for &(v, w) in &self.out[u] {
                es.push((u, v, w));
            }
        }
        es
    }

    /// Strong connectivity via two DFS passes (forward + reverse).
    pub fn is_strongly_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let reach = |adj: &Vec<Vec<(usize, f64)>>| -> usize {
            let mut seen = vec![false; self.n];
            let mut stack = vec![0usize];
            seen[0] = true;
            let mut count = 1;
            while let Some(u) = stack.pop() {
                for &(v, _) in &adj[u] {
                    if !seen[v] {
                        seen[v] = true;
                        count += 1;
                        stack.push(v);
                    }
                }
            }
            count
        };
        reach(&self.out) == self.n && reach(&self.inn) == self.n
    }
}

/// A weighted undirected graph over nodes `0..n`. Stored as an explicit edge
/// list plus adjacency (edge indices) so algorithms can address edges.
#[derive(Clone, Debug, Default)]
pub struct UnGraph {
    n: usize,
    edges: Vec<(usize, usize, f64)>,
    /// adjacency as (neighbor, edge index)
    adj: Vec<Vec<(usize, usize)>>,
}

impl UnGraph {
    pub fn new(n: usize) -> UnGraph {
        UnGraph {
            n,
            edges: Vec::new(),
            adj: vec![Vec::new(); n],
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn m(&self) -> usize {
        self.edges.len()
    }

    /// Add edge; returns its index. Parallel edges are rejected.
    pub fn add_edge(&mut self, u: usize, v: usize, w: f64) -> usize {
        assert!(u < self.n && v < self.n, "edge ({u},{v}) out of range");
        assert!(u != v, "no self-loops");
        assert!(
            !self.has_edge(u, v),
            "parallel edge ({u},{v}) — use set_weight"
        );
        let idx = self.edges.len();
        self.edges.push((u.min(v), u.max(v), w));
        self.adj[u].push((v, idx));
        self.adj[v].push((u, idx));
        idx
    }

    /// Complete graph over `n` nodes with `w(i, j)` weights. Bulk-builds
    /// the edge list directly — O(n²), versus O(n³) for n² [`add_edge`]
    /// calls whose duplicate scan is pointless here. The designers build
    /// connectivity graphs through this on the way to 1000+ silos.
    ///
    /// [`add_edge`]: UnGraph::add_edge
    pub fn complete_with(n: usize, mut w: impl FnMut(usize, usize) -> f64) -> UnGraph {
        let mut g = UnGraph::new(n);
        g.edges.reserve(n * n.saturating_sub(1) / 2);
        for i in 0..n {
            for j in i + 1..n {
                let idx = g.edges.len();
                g.edges.push((i, j, w(i, j)));
                g.adj[i].push((j, idx));
                g.adj[j].push((i, idx));
            }
        }
        g
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u].iter().any(|&(x, _)| x == v)
    }

    pub fn weight(&self, u: usize, v: usize) -> Option<f64> {
        self.adj[u]
            .iter()
            .find(|&&(x, _)| x == v)
            .map(|&(_, i)| self.edges[i].2)
    }

    pub fn edge(&self, idx: usize) -> (usize, usize, f64) {
        self.edges[idx]
    }

    pub fn edges(&self) -> &[(usize, usize, f64)] {
        &self.edges
    }

    /// Neighbors as (node, edge index).
    pub fn neighbors(&self, u: usize) -> &[(usize, usize)] {
        &self.adj[u]
    }

    pub fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|u| self.degree(u)).max().unwrap_or(0)
    }

    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &(v, _) in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.n
    }

    /// Sum of all edge weights.
    pub fn total_weight(&self) -> f64 {
        self.edges.iter().map(|&(_, _, w)| w).sum()
    }

    /// Maximum edge weight (the *bottleneck* when `self` is a tree).
    pub fn bottleneck(&self) -> f64 {
        self.edges
            .iter()
            .map(|&(_, _, w)| w)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// The symmetric directed view: each undirected edge becomes two arcs of
    /// the same weight (how an undirected overlay enters the max-plus model).
    pub fn to_digraph(&self) -> DiGraph {
        let mut g = DiGraph::new(self.n);
        for &(u, v, w) in &self.edges {
            g.add_edge(u, v, w);
            g.add_edge(v, u, w);
        }
        g
    }

    /// Build the symmetric closure of a digraph: keep (u,v) iff both (u,v)
    /// and (v,u) exist; weight = mean of the two directions. This is the
    /// paper's G_c^(u) construction (Prop. 3.1 / Alg. 1 lines 1-3).
    pub fn symmetrized(g: &DiGraph) -> UnGraph {
        let mut un = UnGraph::new(g.n());
        for u in 0..g.n() {
            for &(v, w_uv) in g.out_neighbors(u) {
                if u < v {
                    if let Some(w_vu) = g.weight(v, u) {
                        un.add_edge(u, v, 0.5 * (w_uv + w_vu));
                    }
                }
            }
        }
        un
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digraph_basics() {
        let mut g = DiGraph::new(3);
        g.add_edge(0, 1, 1.5);
        g.add_edge(1, 2, 2.5);
        g.add_edge(2, 0, 3.5);
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(1, 0));
        assert_eq!(g.weight(1, 2), Some(2.5));
        assert_eq!(g.out_degree(0), 1);
        assert_eq!(g.in_degree(0), 1);
        assert!(g.is_strongly_connected());
    }

    #[test]
    fn digraph_not_strong_without_back_edge() {
        let mut g = DiGraph::new(3);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 1.0);
        assert!(!g.is_strongly_connected());
    }

    #[test]
    fn ungraph_basics() {
        let mut g = UnGraph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 2.0);
        g.add_edge(2, 3, 3.0);
        assert!(g.is_connected());
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.max_degree(), 2);
        assert_eq!(g.weight(2, 1), Some(2.0));
        assert_eq!(g.total_weight(), 6.0);
        assert_eq!(g.bottleneck(), 3.0);
    }

    #[test]
    fn ungraph_disconnected() {
        let mut g = UnGraph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(2, 3, 1.0);
        assert!(!g.is_connected());
    }

    #[test]
    fn to_digraph_symmetric() {
        let mut g = UnGraph::new(3);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 2.0);
        let d = g.to_digraph();
        assert_eq!(d.m(), 4);
        assert!(d.has_edge(0, 1) && d.has_edge(1, 0));
        assert!(d.is_strongly_connected());
    }

    #[test]
    fn symmetrized_takes_mean_and_drops_one_way() {
        let mut d = DiGraph::new(3);
        d.add_edge(0, 1, 1.0);
        d.add_edge(1, 0, 3.0);
        d.add_edge(1, 2, 5.0); // no reverse arc → dropped
        let u = UnGraph::symmetrized(&d);
        assert_eq!(u.m(), 1);
        assert_eq!(u.weight(0, 1), Some(2.0));
    }

    #[test]
    #[should_panic(expected = "parallel edge")]
    fn parallel_edges_rejected() {
        let mut g = UnGraph::new(2);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 0, 2.0);
    }

    #[test]
    fn complete_with_matches_incremental_build() {
        let w = |i: usize, j: usize| (i * 10 + j) as f64;
        let fast = UnGraph::complete_with(6, w);
        let mut slow = UnGraph::new(6);
        for i in 0..6 {
            for j in i + 1..6 {
                slow.add_edge(i, j, w(i, j));
            }
        }
        assert_eq!(fast.edges(), slow.edges());
        assert_eq!(fast.m(), 15);
        assert!(fast.is_connected());
        assert_eq!(fast.weight(2, 4), Some(24.0));
        assert_eq!(fast.degree(0), 5);
    }
}
