//! Prim's minimum spanning tree and the degree-bounded δ-PRIM heuristic.
//!
//! * [`prim`] — classic Prim with a binary heap: the solver behind
//!   Prop. 3.1 (the MST of G_c^(u) is throughput-optimal for undirected
//!   overlays on edge-capacitated networks).
//! * [`delta_prim`] — the paper's Algorithm 2 ([Andersen & Ras 2019]):
//!   Prim restricted to attach new vertices only to tree nodes whose degree
//!   is still below δ. Produces the δ-BST candidates of Algorithm 1.

use super::UnGraph;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(PartialEq)]
struct Cand {
    w: f64,
    u: usize, // tree endpoint
    v: usize, // fresh endpoint
}
impl Eq for Cand {}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .w
            .partial_cmp(&self.w)
            .unwrap_or(Ordering::Equal)
            .then_with(|| (other.u, other.v).cmp(&(self.u, self.v)))
    }
}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Prim's algorithm from node 0. Returns the MST as a new [`UnGraph`]
/// (same node set), or `None` if `g` is disconnected.
pub fn prim(g: &UnGraph) -> Option<UnGraph> {
    delta_prim(g, usize::MAX)
}

/// δ-PRIM (paper Algorithm 2): grow a spanning tree greedily, but only from
/// tree vertices of degree < δ. With δ = ∞ this is exactly Prim. For finite
/// δ the result is a degree-≤δ spanning tree when one is reachable greedily;
/// returns `None` if the greedy growth gets stuck (or `g` disconnected).
pub fn delta_prim(g: &UnGraph, delta: usize) -> Option<UnGraph> {
    let n = g.n();
    if n == 0 {
        return Some(UnGraph::new(0));
    }
    let mut tree = UnGraph::new(n);
    let mut in_tree = vec![false; n];
    let mut degree = vec![0usize; n];
    let mut heap = BinaryHeap::new();
    in_tree[0] = true;
    for &(v, eidx) in g.neighbors(0) {
        heap.push(Cand {
            w: g.edge(eidx).2,
            u: 0,
            v,
        });
    }
    let mut added = 0usize;
    while added < n - 1 {
        let Cand { w, u, v } = heap.pop()?;
        if in_tree[v] || degree[u] >= delta {
            continue;
        }
        in_tree[v] = true;
        degree[u] += 1;
        degree[v] += 1;
        tree.add_edge(u, v, w);
        added += 1;
        for &(x, eidx) in g.neighbors(v) {
            if !in_tree[x] {
                heap.push(Cand {
                    w: g.edge(eidx).2,
                    u: v,
                    v: x,
                });
            }
        }
    }
    Some(tree)
}

/// Kruskal-style *minimum bottleneck* check helper: the MST is also an MBST
/// (a classic fact), so `prim(g).bottleneck()` is the minimum bottleneck of
/// any spanning tree. Exposed for tests and for Alg. 1 analysis.
pub fn min_bottleneck(g: &UnGraph) -> Option<f64> {
    prim(g).map(|t| t.bottleneck())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Rng;

    fn complete_graph(n: usize, seed: u64) -> UnGraph {
        let mut rng = Rng::new(seed);
        let mut g = UnGraph::new(n);
        for i in 0..n {
            for j in i + 1..n {
                g.add_edge(i, j, 1.0 + rng.f64() * 9.0);
            }
        }
        g
    }

    #[test]
    fn prim_small_known() {
        let mut g = UnGraph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 2.0);
        g.add_edge(2, 3, 3.0);
        g.add_edge(0, 3, 10.0);
        g.add_edge(0, 2, 9.0);
        let t = prim(&g).unwrap();
        assert_eq!(t.m(), 3);
        assert_eq!(t.total_weight(), 6.0);
    }

    #[test]
    fn prim_disconnected_none() {
        let mut g = UnGraph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(2, 3, 1.0);
        assert!(prim(&g).is_none());
    }

    #[test]
    fn delta_prim_respects_bound() {
        let g = complete_graph(20, 7);
        for delta in 2..6 {
            let t = delta_prim(&g, delta).unwrap();
            assert_eq!(t.m(), 19);
            assert!(t.is_connected());
            assert!(t.max_degree() <= delta, "δ={delta}");
        }
    }

    #[test]
    fn delta_2_is_hamiltonian_path() {
        let g = complete_graph(15, 3);
        let t = delta_prim(&g, 2).unwrap();
        assert!(t.max_degree() <= 2);
        assert!(t.is_connected());
        // A connected degree-≤2 tree is a path: exactly two degree-1 nodes.
        let leaves = (0..t.n()).filter(|&u| t.degree(u) == 1).count();
        assert_eq!(leaves, 2);
    }

    #[test]
    fn prim_weight_leq_delta_prim() {
        // Tightening δ can only increase total weight.
        let g = complete_graph(16, 11);
        let w_inf = prim(&g).unwrap().total_weight();
        let mut prev = f64::INFINITY;
        for delta in [2usize, 3, 4, 8] {
            let w = delta_prim(&g, delta).unwrap().total_weight();
            assert!(w + 1e-9 >= w_inf);
            // not strictly monotone in general, but must never beat the MST
            prev = prev.min(w);
        }
        assert!(prev + 1e-9 >= w_inf);
    }

    #[test]
    fn prop_prim_is_spanning_tree_with_cut_optimal_bottleneck() {
        check("prim spanning tree properties", 60, |g: &mut Gen| {
            let (n, edges) = g.connected_graph(2, 30);
            let mut un = UnGraph::new(n);
            for &(a, b) in &edges {
                if !un.has_edge(a, b) {
                    un.add_edge(a, b, g.f64(0.1, 100.0));
                }
            }
            let t = prim(&un).expect("connected input");
            assert_eq!(t.m(), n - 1);
            assert!(t.is_connected());
            // MST is a minimum bottleneck spanning tree: its bottleneck is
            // ≤ the bottleneck of a few random alternative spanning trees
            // (built by randomized Kruskal on shuffled edges).
            let mst_b = t.bottleneck();
            let mut order: Vec<usize> = (0..un.m()).collect();
            g.rng.shuffle(&mut order);
            let mut parent: Vec<usize> = (0..n).collect();
            fn find(p: &mut Vec<usize>, x: usize) -> usize {
                if p[x] != x {
                    let r = find(p, p[x]);
                    p[x] = r;
                }
                p[x]
            }
            let mut alt_b = f64::NEG_INFINITY;
            for &ei in &order {
                let (a, b, w) = un.edge(ei);
                let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
                if ra != rb {
                    parent[ra] = rb;
                    alt_b = alt_b.max(w);
                }
            }
            assert!(mst_b <= alt_b + 1e-9, "mst bottleneck {mst_b} > alt {alt_b}");
        });
    }
}
