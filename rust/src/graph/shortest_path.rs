//! Dijkstra shortest paths over the underlay.
//!
//! The network simulator routes silo-to-silo traffic along latency-shortest
//! paths (paper App. G.1: "shortest path routing with the geographical
//! distance (or equivalently the latency) as link cost"), then computes the
//! available bandwidth of each route as the minimum core-link capacity along
//! it. Both need single-source shortest-path *trees* with predecessor
//! recovery, provided here.

use super::UnGraph;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Result of a single-source run: distance and predecessor per node.
#[derive(Clone, Debug)]
pub struct ShortestPaths {
    pub source: usize,
    pub dist: Vec<f64>,
    /// `pred[v]` = previous node on the shortest path from source to v.
    pub pred: Vec<Option<usize>>,
}

impl ShortestPaths {
    /// Reconstruct the path source → target (inclusive). `None` if target is
    /// unreachable.
    pub fn path_to(&self, target: usize) -> Option<Vec<usize>> {
        if self.dist[target].is_infinite() {
            return None;
        }
        let mut path = vec![target];
        let mut cur = target;
        while let Some(p) = self.pred[cur] {
            path.push(p);
            cur = p;
        }
        if cur != self.source {
            return None;
        }
        path.reverse();
        Some(path)
    }
}

#[derive(PartialEq)]
struct HeapItem {
    dist: f64,
    node: usize,
}

impl Eq for HeapItem {}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on dist; tie-break on node id for determinism.
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.node.cmp(&self.node))
    }
}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Dijkstra from `source` over non-negative edge weights.
pub fn dijkstra(g: &UnGraph, source: usize) -> ShortestPaths {
    let n = g.n();
    let mut dist = vec![f64::INFINITY; n];
    let mut pred = vec![None; n];
    let mut done = vec![false; n];
    let mut heap = BinaryHeap::new();
    dist[source] = 0.0;
    heap.push(HeapItem {
        dist: 0.0,
        node: source,
    });
    while let Some(HeapItem { dist: d, node: u }) = heap.pop() {
        if done[u] {
            continue;
        }
        done[u] = true;
        for &(v, eidx) in g.neighbors(u) {
            let w = g.edge(eidx).2;
            debug_assert!(w >= 0.0, "negative weight on edge {eidx}");
            let nd = d + w;
            if nd < dist[v] {
                dist[v] = nd;
                pred[v] = Some(u);
                heap.push(HeapItem { dist: nd, node: v });
            }
        }
    }
    ShortestPaths { source, dist, pred }
}

/// Early-exit Dijkstra: identical relaxation and heap ordering to
/// [`dijkstra`], stopped as soon as `target` settles. A truncated run's
/// settled prefix is bit-identical to the full run's, so `dist[target]`
/// and `path_to(target)` match [`dijkstra`] exactly — only nodes farther
/// than `target` are left unexplored (∞ / no predecessor). Single-pair
/// helpers (`routing::pair_latency_ms`) use this to avoid paying for the
/// whole source row.
pub fn dijkstra_to(g: &UnGraph, source: usize, target: usize) -> ShortestPaths {
    let n = g.n();
    let mut dist = vec![f64::INFINITY; n];
    let mut pred = vec![None; n];
    let mut done = vec![false; n];
    let mut heap = BinaryHeap::new();
    dist[source] = 0.0;
    heap.push(HeapItem {
        dist: 0.0,
        node: source,
    });
    while let Some(HeapItem { dist: d, node: u }) = heap.pop() {
        if done[u] {
            continue;
        }
        done[u] = true;
        if u == target {
            break;
        }
        for &(v, eidx) in g.neighbors(u) {
            let w = g.edge(eidx).2;
            debug_assert!(w >= 0.0, "negative weight on edge {eidx}");
            let nd = d + w;
            if nd < dist[v] {
                dist[v] = nd;
                pred[v] = Some(u);
                heap.push(HeapItem { dist: nd, node: v });
            }
        }
    }
    ShortestPaths { source, dist, pred }
}

/// All-pairs shortest paths: one Dijkstra per node. O(V·(E+V) log V) — fine
/// for the ≤ 100-node underlays of the cross-silo setting.
pub fn all_pairs(g: &UnGraph) -> Vec<ShortestPaths> {
    (0..g.n()).map(|s| dijkstra(g, s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> UnGraph {
        //    1
        //  /   \
        // 0     3 --- 4
        //  \   /
        //    2
        let mut g = UnGraph::new(5);
        g.add_edge(0, 1, 1.0);
        g.add_edge(0, 2, 4.0);
        g.add_edge(1, 3, 1.0);
        g.add_edge(2, 3, 1.0);
        g.add_edge(3, 4, 2.0);
        g
    }

    #[test]
    fn distances_correct() {
        let sp = dijkstra(&diamond(), 0);
        assert_eq!(sp.dist, vec![0.0, 1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn path_reconstruction() {
        let sp = dijkstra(&diamond(), 0);
        assert_eq!(sp.path_to(4).unwrap(), vec![0, 1, 3, 4]);
        assert_eq!(sp.path_to(0).unwrap(), vec![0]);
        // 0→2 direct edge costs 4, via 1-3 costs 3
        assert_eq!(sp.path_to(2).unwrap(), vec![0, 1, 3, 2]);
    }

    #[test]
    fn unreachable_is_infinite() {
        let mut g = UnGraph::new(3);
        g.add_edge(0, 1, 1.0);
        let sp = dijkstra(&g, 0);
        assert!(sp.dist[2].is_infinite());
        assert!(sp.path_to(2).is_none());
    }

    #[test]
    fn all_pairs_symmetric() {
        let g = diamond();
        let ap = all_pairs(&g);
        for i in 0..g.n() {
            for j in 0..g.n() {
                assert!((ap[i].dist[j] - ap[j].dist[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn early_exit_matches_full_run_on_settled_prefix() {
        let g = diamond();
        for s in 0..g.n() {
            let full = dijkstra(&g, s);
            for t in 0..g.n() {
                let cut = dijkstra_to(&g, s, t);
                assert_eq!(
                    cut.dist[t].to_bits(),
                    full.dist[t].to_bits(),
                    "dist {s}→{t}"
                );
                assert_eq!(cut.path_to(t), full.path_to(t), "path {s}→{t}");
                // every node the truncated run settled agrees bit-for-bit
                for v in 0..g.n() {
                    if cut.dist[v].is_finite() && cut.dist[v] <= cut.dist[t] {
                        assert_eq!(cut.dist[v].to_bits(), full.dist[v].to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn early_exit_unreachable_target() {
        let mut g = UnGraph::new(3);
        g.add_edge(0, 1, 1.0);
        let sp = dijkstra_to(&g, 0, 2);
        assert!(sp.dist[2].is_infinite());
        assert!(sp.path_to(2).is_none());
    }

    #[test]
    fn triangle_inequality_holds() {
        // Shortest-path metric always satisfies the triangle inequality —
        // the property the Euclidean-G_c assumption rests on (Sect. 3.1).
        let g = diamond();
        let ap = all_pairs(&g);
        for i in 0..g.n() {
            for j in 0..g.n() {
                for k in 0..g.n() {
                    assert!(ap[i].dist[j] <= ap[i].dist[k] + ap[k].dist[j] + 1e-12);
                }
            }
        }
    }
}
