//! Brandes betweenness (load) centrality.
//!
//! The paper places the STAR orchestrator "at the node with the highest load
//! centrality [11]" (Brandes' variant of shortest-path betweenness). We
//! implement weighted Brandes: one Dijkstra per source with dependency
//! back-propagation, O(V·E + V² log V).

use super::UnGraph;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(PartialEq)]
struct Item {
    d: f64,
    v: usize,
}
impl Eq for Item {}
impl Ord for Item {
    fn cmp(&self, o: &Self) -> Ordering {
        o.d.partial_cmp(&self.d)
            .unwrap_or(Ordering::Equal)
            .then_with(|| o.v.cmp(&self.v))
    }
}
impl PartialOrd for Item {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

/// Weighted betweenness centrality of every node (undirected, Brandes 2001,
/// endpoints excluded, each unordered pair counted once).
pub fn betweenness(g: &UnGraph) -> Vec<f64> {
    let n = g.n();
    let mut bc = vec![0.0f64; n];
    for s in 0..n {
        // Dijkstra with shortest-path counting.
        let mut dist = vec![f64::INFINITY; n];
        let mut sigma = vec![0.0f64; n]; // # shortest paths
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut stack: Vec<usize> = Vec::new(); // nodes in non-decreasing dist order
        let mut done = vec![false; n];
        let mut heap = BinaryHeap::new();
        dist[s] = 0.0;
        sigma[s] = 1.0;
        heap.push(Item { d: 0.0, v: s });
        while let Some(Item { d, v }) = heap.pop() {
            if done[v] {
                continue;
            }
            done[v] = true;
            stack.push(v);
            for &(w, eidx) in g.neighbors(v) {
                let wt = g.edge(eidx).2;
                let nd = d + wt;
                if nd < dist[w] - 1e-12 {
                    dist[w] = nd;
                    sigma[w] = sigma[v];
                    preds[w].clear();
                    preds[w].push(v);
                    heap.push(Item { d: nd, v: w });
                } else if (nd - dist[w]).abs() <= 1e-12 && !done[w] {
                    sigma[w] += sigma[v];
                    preds[w].push(v);
                }
            }
        }
        // Dependency accumulation.
        let mut delta = vec![0.0f64; n];
        while let Some(w) = stack.pop() {
            for &v in &preds[w] {
                delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w]);
            }
            if w != s {
                bc[w] += delta[w];
            }
        }
    }
    // Undirected: every pair was counted twice (once per endpoint as source).
    bc.iter_mut().for_each(|x| *x *= 0.5);
    bc
}

/// Index of the most central node (ties broken toward the smaller id, so the
/// STAR hub is deterministic).
pub fn most_central(g: &UnGraph) -> usize {
    let bc = betweenness(g);
    let mut best = 0;
    for i in 1..g.n() {
        if bc[i] > bc[best] + 1e-12 {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_graph_center_has_max() {
        // 0-1-2-3-4: node 2 lies on the most shortest paths.
        let mut g = UnGraph::new(5);
        for i in 0..4 {
            g.add_edge(i, i + 1, 1.0);
        }
        let bc = betweenness(&g);
        // exact values for P5: [0, 3, 4, 3, 0]
        assert_eq!(bc, vec![0.0, 3.0, 4.0, 3.0, 0.0]);
        assert_eq!(most_central(&g), 2);
    }

    #[test]
    fn star_graph_hub_dominates() {
        let mut g = UnGraph::new(6);
        for i in 1..6 {
            g.add_edge(0, i, 1.0);
        }
        let bc = betweenness(&g);
        // hub carries all C(5,2)=10 pairs; leaves carry none.
        assert_eq!(bc[0], 10.0);
        assert!(bc[1..].iter().all(|&x| x == 0.0));
        assert_eq!(most_central(&g), 0);
    }

    #[test]
    fn cycle_graph_symmetric() {
        let mut g = UnGraph::new(6);
        for i in 0..6 {
            g.add_edge(i, (i + 1) % 6, 1.0);
        }
        let bc = betweenness(&g);
        for w in bc.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-9, "{bc:?}");
        }
    }

    #[test]
    fn weights_shift_centrality() {
        // Triangle with a heavy edge: traffic routes around it through node 2.
        let mut g = UnGraph::new(3);
        g.add_edge(0, 1, 10.0);
        g.add_edge(1, 2, 1.0);
        g.add_edge(0, 2, 1.0);
        let bc = betweenness(&g);
        assert!(bc[2] > bc[0]);
        assert!(bc[2] > bc[1]);
    }

    #[test]
    fn split_shortest_paths_share_credit() {
        // 4-cycle: two equal shortest paths between opposite corners;
        // each intermediate gets half a pair from each opposite pair.
        let mut g = UnGraph::new(4);
        for i in 0..4 {
            g.add_edge(i, (i + 1) % 4, 1.0);
        }
        let bc = betweenness(&g);
        for &x in &bc {
            assert!((x - 0.5).abs() < 1e-9, "{bc:?}");
        }
    }
}
