//! Matching decomposition via Misra–Gries edge coloring.
//!
//! MATCHA (Wang et al. 2019) decomposes the connectivity/underlay graph into
//! matchings and activates a random subset each round. The decomposition is
//! exactly a proper *edge coloring*: every color class is a matching. The
//! Misra–Gries algorithm colors any simple graph with at most Δ+1 colors
//! (one more than the trivial lower bound Δ), matching the paper's
//! Appendix-B assumption that MATCHA⁺ uses `max_degree(G_u) + 1` matchings.

use super::UnGraph;

const UNCOLORED: usize = usize::MAX;

/// A proper edge coloring: `color[e]` for each edge index of `g`.
pub struct EdgeColoring {
    pub color: Vec<usize>,
    pub num_colors: usize,
}

/// Misra–Gries edge coloring with ≤ Δ+1 colors.
pub fn misra_gries(g: &UnGraph) -> EdgeColoring {
    let n = g.n();
    let m = g.m();
    let max_colors = g.max_degree() + 1;
    let mut color = vec![UNCOLORED; m];

    // color_at[v][c] = edge index at v colored c (or UNCOLORED).
    let mut color_at: Vec<Vec<usize>> = vec![vec![UNCOLORED; max_colors]; n];

    let other = |e: usize, x: usize| -> usize {
        let (a, b, _) = g.edge(e);
        if a == x {
            b
        } else {
            a
        }
    };

    let free_color = |color_at: &Vec<Vec<usize>>, x: usize| -> usize {
        (0..max_colors)
            .find(|&c| color_at[x][c] == UNCOLORED)
            .expect("Δ+1 colors always leave one free")
    };

    let is_free = |color_at: &Vec<Vec<usize>>, x: usize, c: usize| color_at[x][c] == UNCOLORED;

    for e0 in 0..m {
        if color[e0] != UNCOLORED {
            continue;
        }
        let (u, v0, _) = g.edge(e0);

        // --- Build a maximal fan of u starting at v0. ------------------
        // fan[i] = (neighbor x, edge index (u,x)); invariant: the color of
        // fan[i+1]'s edge is free on fan[i].
        let build_fan = |color: &Vec<usize>, color_at: &Vec<Vec<usize>>| -> Vec<(usize, usize)> {
            let mut fan = vec![(v0, e0)];
            let mut in_fan = vec![false; n];
            in_fan[v0] = true;
            loop {
                let last = fan.last().unwrap().0;
                let mut extended = false;
                for &(x, ex) in g.neighbors(u) {
                    if in_fan[x] || color[ex] == UNCOLORED {
                        continue;
                    }
                    if is_free(color_at, last, color[ex]) {
                        fan.push((x, ex));
                        in_fan[x] = true;
                        extended = true;
                        break;
                    }
                }
                if !extended {
                    return fan;
                }
            }
        };

        let fan = build_fan(&color, &color_at);
        let c = free_color(&color_at, u);
        let d = free_color(&color_at, fan.last().unwrap().0);

        // --- Invert the cd-path starting at u. --------------------------
        // Maximal path from u along edges alternately colored d, c, d, ...
        if c != d {
            let mut x = u;
            let mut want = d;
            let mut path = Vec::new();
            loop {
                let e = color_at[x][want];
                if e == UNCOLORED {
                    break;
                }
                path.push(e);
                x = other(e, x);
                want = if want == d { c } else { d };
            }
            // Two-phase flip: clearing and re-adding per edge would corrupt
            // color_at at shared path vertices (edge k's new color lands in
            // the slot edge k+1 then clears). Uncolor everything first.
            for &e in &path {
                let (a, b, _) = g.edge(e);
                let old = color[e];
                if color_at[a][old] == e {
                    color_at[a][old] = UNCOLORED;
                }
                if color_at[b][old] == e {
                    color_at[b][old] = UNCOLORED;
                }
            }
            for &e in &path {
                let (a, b, _) = g.edge(e);
                let new = if color[e] == c { d } else { c };
                color[e] = new;
                color_at[a][new] = e;
                color_at[b][new] = e;
            }
        }

        // --- Find w ∈ fan with d free on w and fan[0..=w] still a fan. --
        // Extra guard (correctness-critical): no prefix edge (u, F[1..=j])
        // may itself be colored d, otherwise the rotation would leave two
        // d-colored edges at u. Since u has at most one d-colored edge
        // (u, F[h]), the fan property guarantees d is free on F[h-1], so a
        // valid w always exists (Misra & Gries 1992, case analysis).
        let mut w_idx = None;
        'outer: for j in 0..fan.len() {
            if !is_free(&color_at, fan[j].0, d) {
                continue;
            }
            // prefix fan check under current colors + no-d-in-prefix guard
            for i in 0..j {
                let ce = color[fan[i + 1].1];
                if ce == UNCOLORED || ce == d || !is_free(&color_at, fan[i].0, ce) {
                    continue 'outer;
                }
            }
            w_idx = Some(j);
            break;
        }
        let w_idx = w_idx.expect("Misra–Gries invariant: some fan prefix accepts d");

        // --- Rotate the fan prefix and color (u, w) with d. -------------
        for i in 0..w_idx {
            let e_i = fan[i].1;
            let e_next = fan[i + 1].1;
            let cn = color[e_next];
            // uncolor e_next, give its color to e_i
            let (a, b, _) = g.edge(e_next);
            color_at[a][cn] = UNCOLORED;
            color_at[b][cn] = UNCOLORED;
            color[e_next] = UNCOLORED;
            if color[e_i] != UNCOLORED {
                let (p, q, _) = g.edge(e_i);
                let old = color[e_i];
                color_at[p][old] = UNCOLORED;
                color_at[q][old] = UNCOLORED;
            }
            let (p, q, _) = g.edge(e_i);
            color[e_i] = cn;
            color_at[p][cn] = e_i;
            color_at[q][cn] = e_i;
        }
        let e_w = fan[w_idx].1;
        if color[e_w] != UNCOLORED {
            let (p, q, _) = g.edge(e_w);
            let old = color[e_w];
            color_at[p][old] = UNCOLORED;
            color_at[q][old] = UNCOLORED;
        }
        let (p, q, _) = g.edge(e_w);
        color[e_w] = d;
        color_at[p][d] = e_w;
        color_at[q][d] = e_w;
    }

    let num_colors = color.iter().map(|&c| c + 1).max().unwrap_or(0);
    EdgeColoring { color, num_colors }
}

/// Decompose `g`'s edges into matchings (color classes), each a list of edge
/// indices. At most Δ+1 matchings; classes are sorted by size descending so
/// "activate a fraction C_b of matchings" favors the big ones first — same
/// convention as MATCHA's spectral-weight ordering fallback.
pub fn matching_decomposition(g: &UnGraph) -> Vec<Vec<usize>> {
    let coloring = misra_gries(g);
    let mut classes: Vec<Vec<usize>> = vec![Vec::new(); coloring.num_colors];
    for (e, &c) in coloring.color.iter().enumerate() {
        classes[c].push(e);
    }
    classes.retain(|c| !c.is_empty());
    classes.sort_by_key(|c| std::cmp::Reverse(c.len()));
    classes
}

/// Check that `edges` (indices into g) form a matching.
pub fn is_matching(g: &UnGraph, edges: &[usize]) -> bool {
    let mut used = vec![false; g.n()];
    for &e in edges {
        let (a, b, _) = g.edge(e);
        if used[a] || used[b] {
            return false;
        }
        used[a] = true;
        used[b] = true;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn validate(g: &UnGraph) {
        let col = misra_gries(g);
        // proper: no two incident edges share a color
        for u in 0..g.n() {
            let mut seen = std::collections::HashSet::new();
            for &(_, e) in g.neighbors(u) {
                assert_ne!(col.color[e], UNCOLORED, "edge {e} uncolored");
                assert!(seen.insert(col.color[e]), "color clash at node {u}");
            }
        }
        assert!(
            col.num_colors <= g.max_degree() + 1,
            "used {} colors for Δ={}",
            col.num_colors,
            g.max_degree()
        );
    }

    #[test]
    fn colors_triangle() {
        let mut g = UnGraph::new(3);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 1.0);
        g.add_edge(0, 2, 1.0);
        validate(&g); // Δ=2, needs 3 colors
        assert_eq!(misra_gries(&g).num_colors, 3);
    }

    #[test]
    fn colors_star() {
        let mut g = UnGraph::new(6);
        for i in 1..6 {
            g.add_edge(0, i, 1.0);
        }
        validate(&g);
        // A star is Δ-edge-colorable
        assert_eq!(misra_gries(&g).num_colors, 5);
    }

    #[test]
    fn colors_complete_graphs() {
        for n in 2..12 {
            let mut g = UnGraph::new(n);
            for i in 0..n {
                for j in i + 1..n {
                    g.add_edge(i, j, 1.0);
                }
            }
            validate(&g);
        }
    }

    #[test]
    fn colors_even_cycle_with_two() {
        let mut g = UnGraph::new(6);
        for i in 0..6 {
            g.add_edge(i, (i + 1) % 6, 1.0);
        }
        validate(&g);
        assert!(misra_gries(&g).num_colors <= 3); // even cycle: 2, odd: 3
    }

    #[test]
    fn decomposition_covers_all_edges_once() {
        let mut g = UnGraph::new(7);
        for i in 0..7 {
            for j in i + 1..7 {
                if (i + j) % 2 == 0 || j == i + 1 {
                    g.add_edge(i, j, 1.0);
                }
            }
        }
        let classes = matching_decomposition(&g);
        let mut seen = vec![false; g.m()];
        for cls in &classes {
            assert!(is_matching(&g, cls));
            for &e in cls {
                assert!(!seen[e], "edge {e} in two classes");
                seen[e] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        assert!(classes.len() <= g.max_degree() + 1);
        // sorted by size descending
        assert!(classes.windows(2).all(|w| w[0].len() >= w[1].len()));
    }

    #[test]
    fn prop_random_graphs_properly_colored() {
        check("misra-gries proper on random graphs", 80, |g: &mut Gen| {
            let (n, edges) = g.connected_graph(2, 40);
            let mut un = UnGraph::new(n);
            for &(a, b) in &edges {
                if !un.has_edge(a, b) {
                    un.add_edge(a, b, 1.0);
                }
            }
            validate(&un);
            let classes = matching_decomposition(&un);
            for cls in &classes {
                assert!(is_matching(&un, cls));
            }
            let total: usize = classes.iter().map(|c| c.len()).sum();
            assert_eq!(total, un.m());
        });
    }
}
