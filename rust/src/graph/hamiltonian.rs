//! Hamiltonian path in the cube of a tree (Sekanina 1960 / Karaganis 1968).
//!
//! Algorithm 1 (the node-capacitated δ-MBST designer) needs a Hamiltonian
//! path in T³, where T is an MST: consecutive path vertices are then within
//! tree-distance 3, which bounds the path's bottleneck by 3× the tree's
//! bottleneck (Andersen & Ras 2016, Thm. 8). We implement the constructive
//! proof that the cube of a tree is Hamiltonian-*connected*:
//!
//! `ham_path(T, u, v)` returns a Hamiltonian u→v path of T³. Induction: let
//! (a=u, b) be the first edge on the tree path u→v. Removing it splits T
//! into T_a ∋ u and T_b ∋ b,v. Recurse on T_a from u to z_a (a neighbour of
//! u in T_a, or u itself if T_a is a singleton) and on T_b from z_b to v
//! (z_b = b, or a neighbour of b if b = v). The junction hop z_a → first(P_b)
//! has tree distance ≤ 1 + 1 + 1 = 3. ∎

use super::UnGraph;

/// Hamiltonian path of `tree`³ from `u` to `v` (u ≠ v unless n == 1).
/// `tree` must be a tree (connected, n-1 edges); panics otherwise.
pub fn ham_path(tree: &UnGraph, u: usize, v: usize) -> Vec<usize> {
    assert!(tree.is_connected(), "ham_path requires a tree");
    assert_eq!(tree.m(), tree.n().saturating_sub(1), "input is not a tree");
    // Work on an adjacency copy we can "split" via membership masks.
    let mut active = vec![true; tree.n()];
    let mut out = Vec::with_capacity(tree.n());
    rec(tree, &mut active, u, v, &mut out);
    out
}

/// Convenience: Hamiltonian path starting anywhere (endpoints chosen as two
/// leaves of the tree, which tends to give low-stretch paths).
pub fn ham_path_any(tree: &UnGraph) -> Vec<usize> {
    let n = tree.n();
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![0];
    }
    let leaves: Vec<usize> = (0..n).filter(|&x| tree.degree(x) == 1).collect();
    let (a, b) = match leaves.len() {
        0 => (0, n - 1),
        1 => (leaves[0], (leaves[0] + 1) % n),
        _ => (leaves[0], *leaves.last().unwrap()),
    };
    ham_path(tree, a, b)
}

/// BFS within the `active` mask from `from`, returning parent pointers.
/// Used to find the first edge on the u→v tree path and component splits.
fn bfs_parents(tree: &UnGraph, active: &[bool], from: usize) -> Vec<Option<usize>> {
    let mut parent = vec![None; tree.n()];
    let mut seen = vec![false; tree.n()];
    seen[from] = true;
    let mut queue = std::collections::VecDeque::from([from]);
    while let Some(x) = queue.pop_front() {
        for &(y, _) in tree.neighbors(x) {
            if active[y] && !seen[y] {
                seen[y] = true;
                parent[y] = Some(x);
                queue.push_back(y);
            }
        }
    }
    parent
}

/// Collect the component of `root` in the active mask, excluding anything on
/// the other side of the removed edge (the mask has already been updated).
fn component(tree: &UnGraph, active: &[bool], root: usize) -> Vec<usize> {
    let mut seen = vec![false; tree.n()];
    seen[root] = true;
    let mut stack = vec![root];
    let mut comp = vec![root];
    while let Some(x) = stack.pop() {
        for &(y, _) in tree.neighbors(x) {
            if active[y] && !seen[y] {
                seen[y] = true;
                comp.push(y);
                stack.push(y);
            }
        }
    }
    comp
}

fn rec(tree: &UnGraph, active: &mut Vec<bool>, u: usize, v: usize, out: &mut Vec<usize>) {
    // Size of the current active component containing u.
    let comp = component(tree, active, u);
    if comp.len() == 1 {
        out.push(u);
        return;
    }
    debug_assert!(u != v, "distinct endpoints required for |T| > 1");

    // First edge (u, b) on the tree path u → v within the active component.
    let parent = bfs_parents(tree, active, u);
    debug_assert!(parent[v].is_some() || v == u, "v not in u's component");
    let mut b = v;
    while let Some(p) = parent[b] {
        if p == u {
            break;
        }
        b = p;
    }
    debug_assert_eq!(parent[b], Some(u));

    // Split: deactivate the edge by masking each side while recursing.
    // Side A = component of u without b; side B = component of b without u.
    active[b] = false;
    let side_a = component(tree, active, u);
    active[b] = true;
    active[u] = false;
    let side_b = component(tree, active, b);
    active[u] = true;

    // Endpoint inside A: a neighbour of u in A if any, else u (singleton).
    let mut mask_a = active.clone();
    for i in 0..tree.n() {
        if !side_a.contains(&i) {
            mask_a[i] = false;
        }
    }
    let z_a = tree
        .neighbors(u)
        .iter()
        .map(|&(x, _)| x)
        .find(|&x| mask_a[x]);
    match z_a {
        Some(z) => rec(tree, &mut mask_a, u, z, out),
        None => out.push(u),
    }

    // Endpoint inside B: start at z_b, end at v. If b == v, start from a
    // neighbour of b in B (exists because |B| > 1 when b == v and |B| ≥ 2).
    let mut mask_b = active.clone();
    for i in 0..tree.n() {
        if !side_b.contains(&i) {
            mask_b[i] = false;
        }
    }
    if side_b.len() == 1 {
        out.push(b);
        return;
    }
    if b == v {
        let z_b = tree
            .neighbors(b)
            .iter()
            .map(|&(x, _)| x)
            .find(|&x| mask_b[x])
            .expect("non-singleton component has a neighbour");
        // Path from z_b to ... we need to END at v=b: build b→z_b and reverse.
        let mut sub = Vec::new();
        rec(tree, &mut mask_b, b, z_b, &mut sub);
        sub.reverse();
        // sub now runs z_b → … → b; its head z_b is within distance 1 of b,
        // hence ≤ 3 of the previous path tail.
        out.extend(sub);
    } else {
        rec(tree, &mut mask_b, b, v, out);
    }
}

/// Tree distance between consecutive vertices of `path` (for validation):
/// returns the maximum hop distance measured in `tree`.
pub fn max_stretch(tree: &UnGraph, path: &[usize]) -> usize {
    let mut max_d = 0;
    for w in path.windows(2) {
        // BFS distance in tree between w[0], w[1].
        let mut dist = vec![usize::MAX; tree.n()];
        dist[w[0]] = 0;
        let mut q = std::collections::VecDeque::from([w[0]]);
        while let Some(x) = q.pop_front() {
            if x == w[1] {
                break;
            }
            for &(y, _) in tree.neighbors(x) {
                if dist[y] == usize::MAX {
                    dist[y] = dist[x] + 1;
                    q.push_back(y);
                }
            }
        }
        max_d = max_d.max(dist[w[1]]);
    }
    max_d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Rng;

    fn validate(tree: &UnGraph, path: &[usize]) {
        assert_eq!(path.len(), tree.n(), "not Hamiltonian: {path:?}");
        let mut seen = vec![false; tree.n()];
        for &x in path {
            assert!(!seen[x], "repeated vertex {x}");
            seen[x] = true;
        }
        assert!(
            max_stretch(tree, path) <= 3,
            "stretch > 3 for path {path:?}"
        );
    }

    #[test]
    fn path_graph() {
        let mut t = UnGraph::new(5);
        for i in 0..4 {
            t.add_edge(i, i + 1, 1.0);
        }
        let p = ham_path(&t, 0, 4);
        validate(&t, &p);
        assert_eq!(p[0], 0);
        assert_eq!(p[4], 4);
    }

    #[test]
    fn star_graph() {
        let mut t = UnGraph::new(6);
        for i in 1..6 {
            t.add_edge(0, i, 1.0);
        }
        let p = ham_path(&t, 1, 5);
        validate(&t, &p);
        assert_eq!(p[0], 1);
        assert_eq!(*p.last().unwrap(), 5);
    }

    #[test]
    fn binary_tree() {
        // perfect binary tree on 7 nodes
        let mut t = UnGraph::new(7);
        for i in 0..3 {
            t.add_edge(i, 2 * i + 1, 1.0);
            t.add_edge(i, 2 * i + 2, 1.0);
        }
        for (a, b) in [(3, 6), (0, 6), (3, 4)] {
            let p = ham_path(&t, a, b);
            validate(&t, &p);
            assert_eq!(p[0], a);
            assert_eq!(*p.last().unwrap(), b);
        }
    }

    #[test]
    fn singleton_and_pair() {
        let t1 = UnGraph::new(1);
        assert_eq!(ham_path(&t1, 0, 0), vec![0]);
        let mut t2 = UnGraph::new(2);
        t2.add_edge(0, 1, 1.0);
        assert_eq!(ham_path(&t2, 0, 1), vec![0, 1]);
        assert_eq!(ham_path(&t2, 1, 0), vec![1, 0]);
    }

    #[test]
    fn caterpillar() {
        // spine 0-1-2-3 with legs hanging off each spine node
        let mut t = UnGraph::new(8);
        t.add_edge(0, 1, 1.0);
        t.add_edge(1, 2, 1.0);
        t.add_edge(2, 3, 1.0);
        t.add_edge(0, 4, 1.0);
        t.add_edge(1, 5, 1.0);
        t.add_edge(2, 6, 1.0);
        t.add_edge(3, 7, 1.0);
        let p = ham_path(&t, 4, 7);
        validate(&t, &p);
    }

    #[test]
    fn prop_random_trees_stretch_le_3() {
        check("cube hamiltonian path on random trees", 80, |g: &mut Gen| {
            let n = g.usize(2, 40);
            let mut rng = Rng::new(g.rng.next_u64());
            let mut t = UnGraph::new(n);
            for i in 1..n {
                let j = rng.usize(i);
                t.add_edge(j, i, 1.0);
            }
            let a = rng.usize(n);
            let mut b = rng.usize(n);
            if b == a {
                b = (b + 1) % n;
            }
            let p = ham_path(&t, a, b);
            validate(&t, &p);
            assert_eq!(p[0], a);
            assert_eq!(*p.last().unwrap(), b);
        });
    }

    #[test]
    fn ham_path_any_works() {
        let mut t = UnGraph::new(10);
        for i in 1..10 {
            t.add_edge(i / 2, i, 1.0);
        }
        let p = ham_path_any(&t);
        validate(&t, &p);
    }
}
