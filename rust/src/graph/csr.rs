//! Flat (CSR) graph storage and implicit-Kₙ algorithms — the PR-5 memory
//! contract for the designer substrate.
//!
//! Two ideas, one module:
//!
//! * [`Csr`] — an undirected graph in compressed-sparse-row form: one
//!   offsets array plus flat neighbor/edge-id/weight arrays. Zero per-node
//!   allocations, cache-linear neighbor scans; built once from an
//!   [`UnGraph`] and preserving its adjacency order exactly (so algorithms
//!   migrated onto it keep their tie-breaking, bit for bit).
//! * **implicit-Kₙ algorithms** — the topology designers all operate on the
//!   *complete* graph over N silos, whose materialized form
//!   ([`UnGraph::complete_with`]) costs Θ(N²) stored edges plus adjacency.
//!   The variants here ([`implicit_prim`], [`implicit_delta_prim`],
//!   [`implicit_boruvka`], [`nn_greedy_matching`], [`nn_tour`]) take a
//!   weight *callback* `w(i, j)` instead and run in **O(N) memory**. Each is
//!   pinned bit-identical (same selections, same tie-breaks, same output
//!   order) to its materialized counterpart in `graph::mst` /
//!   `topology::ring`, which stay alive as the dense equivalence oracles.
//!
//! Tie-breaking contract: wherever the heap-based dense algorithms order
//! candidates by `(weight, u, v)` (weight first, then endpoint indices),
//! the implicit variants reproduce exactly that order. The weight callback
//! is always invoked as `w(min(i,j), max(i,j))`, matching
//! [`UnGraph::complete_with`]'s upper-triangle evaluation, so even
//! float-asymmetric callbacks see identical operands.

use super::UnGraph;

/// An undirected graph in CSR form: neighbors of `u` are
/// `nbr[off[u]..off[u+1]]`, with parallel edge-id and weight arrays.
/// Neighbor order per node equals the source [`UnGraph`]'s adjacency
/// (insertion) order.
#[derive(Clone, Debug)]
pub struct Csr {
    n: usize,
    off: Vec<usize>,
    nbr: Vec<u32>,
    eid: Vec<u32>,
    w: Vec<f64>,
}

impl Csr {
    /// Flatten an [`UnGraph`] (both directions of every edge).
    pub fn from_ungraph(g: &UnGraph) -> Csr {
        let n = g.n();
        let mut off = Vec::with_capacity(n + 1);
        off.push(0usize);
        for u in 0..n {
            off.push(off[u] + g.degree(u));
        }
        let m2 = off[n];
        let mut nbr = Vec::with_capacity(m2);
        let mut eid = Vec::with_capacity(m2);
        let mut w = Vec::with_capacity(m2);
        for u in 0..n {
            for &(v, e) in g.neighbors(u) {
                nbr.push(v as u32);
                eid.push(e as u32);
                w.push(g.edge(e).2);
            }
        }
        Csr { n, off, nbr, eid, w }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of stored half-edges (2× the undirected edge count).
    pub fn half_edges(&self) -> usize {
        self.nbr.len()
    }

    /// Neighbors of `u` as parallel slices `(nbr, eid, w)`.
    #[inline]
    pub fn neighbors(&self, u: usize) -> (&[u32], &[u32], &[f64]) {
        let (a, b) = (self.off[u], self.off[u + 1]);
        (&self.nbr[a..b], &self.eid[a..b], &self.w[a..b])
    }
}

/// Canonical upper-triangle invocation of a symmetric weight callback:
/// always `w(min, max)`, the orientation [`UnGraph::complete_with`] uses.
#[inline]
fn w_uv(w: &mut impl FnMut(usize, usize) -> f64, u: usize, v: usize) -> f64 {
    if u < v {
        w(u, v)
    } else {
        w(v, u)
    }
}

/// Is candidate `(d, u)` strictly better than `(best_d, best_u)` under the
/// dense heap's `(weight, u, v)` order (`v` fixed)?
#[inline]
fn better(d: f64, u: usize, best_d: f64, best_u: usize) -> bool {
    d < best_d || (d == best_d && u < best_u)
}

/// Prim's MST over the **implicit complete graph** on `n` nodes with weights
/// `w(i, j)` — O(N) memory, O(N²) weight evaluations. Returns the tree
/// edges `(u, v, w)` as (tree endpoint, attached node, weight) in selection
/// order: the exact sequence `graph::mst::prim` emits on
/// [`UnGraph::complete_with`]`(n, w)` (same `(weight, u, v)` tie-breaks),
/// pinned by the dense-equivalence tests.
pub fn implicit_prim(
    n: usize,
    w: impl FnMut(usize, usize) -> f64,
) -> Vec<(usize, usize, f64)> {
    implicit_delta_prim(n, usize::MAX, w).expect("complete graph is connected")
}

/// δ-PRIM (paper Algorithm 2) over the implicit complete graph: grow the
/// tree greedily, attaching only to tree nodes of degree < `delta`. With
/// `delta = usize::MAX` this is exactly [`implicit_prim`]. Returns `None`
/// when the greedy growth gets stuck (only possible for finite δ ≤ 1 on
/// n > 2, mirroring `graph::mst::delta_prim`'s heap exhausting).
pub fn implicit_delta_prim(
    n: usize,
    delta: usize,
    mut w: impl FnMut(usize, usize) -> f64,
) -> Option<Vec<(usize, usize, f64)>> {
    if n == 0 {
        return Some(Vec::new());
    }
    if delta == 0 && n > 1 {
        return None; // the heap form exhausts immediately: no eligible arcs
    }
    let mut in_tree = vec![false; n];
    let mut degree = vec![0usize; n];
    // Per fresh node v: the best eligible tree endpoint, min by (w, u).
    let mut best_d = vec![f64::INFINITY; n];
    let mut best_u = vec![usize::MAX; n];
    in_tree[0] = true;
    for v in 1..n {
        best_d[v] = w_uv(&mut w, 0, v);
        best_u[v] = 0;
    }
    let mut edges = Vec::with_capacity(n.saturating_sub(1));
    while edges.len() < n - 1 {
        // Global selection: min (best_d, best_u, v) over fresh v — exactly
        // the dense heap's pop order over all valid candidates.
        let mut v_star = usize::MAX;
        for v in 0..n {
            if in_tree[v] || best_u[v] == usize::MAX {
                continue;
            }
            if v_star == usize::MAX
                || better(best_d[v], best_u[v], best_d[v_star], best_u[v_star])
            {
                v_star = v;
            }
        }
        if v_star == usize::MAX {
            return None; // greedy growth stuck (finite δ)
        }
        let u_star = best_u[v_star];
        edges.push((u_star, v_star, best_d[v_star]));
        in_tree[v_star] = true;
        degree[u_star] += 1;
        degree[v_star] += 1;

        // The new tree node offers itself to every fresh node (if eligible).
        if degree[v_star] < delta {
            for v in 0..n {
                if !in_tree[v] {
                    let d = w_uv(&mut w, v_star, v);
                    if better(d, v_star, best_d[v], best_u[v]) {
                        best_d[v] = d;
                        best_u[v] = v_star;
                    }
                }
            }
        }
        // Saturated endpoints invalidate the fresh nodes pointing at them:
        // recompute those nodes' best over the still-eligible tree set.
        // (Degrees only grow, so a recomputation can't resurrect anyone.)
        for sat in [u_star, v_star] {
            if delta != usize::MAX && degree[sat] == delta {
                for v in 0..n {
                    if in_tree[v] || best_u[v] != sat {
                        continue;
                    }
                    best_d[v] = f64::INFINITY;
                    best_u[v] = usize::MAX;
                    for u in 0..n {
                        if in_tree[u] && degree[u] < delta {
                            let d = w_uv(&mut w, u, v);
                            if better(d, u, best_d[v], best_u[v]) {
                                best_d[v] = d;
                                best_u[v] = u;
                            }
                        }
                    }
                }
            }
        }
    }
    Some(edges)
}

/// Borůvka's MST over the implicit complete graph — the phase-parallel
/// O(N)-memory alternative to [`implicit_prim`] (each phase scans all pairs
/// once; O(log N) phases). Component merges pick each component's minimum
/// outgoing edge under the `(weight, min-endpoint, max-endpoint)` order, so
/// with distinct weights the result is the unique MST (equal to Prim's edge
/// set; the *selection order* differs, hence this is a cross-check variant,
/// not the designers' bit-pinned path).
pub fn implicit_boruvka(
    n: usize,
    mut w: impl FnMut(usize, usize) -> f64,
) -> Vec<(usize, usize, f64)> {
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(p: &mut [usize], mut x: usize) -> usize {
        while p[x] != x {
            p[x] = p[p[x]];
            x = p[x];
        }
        x
    }
    let mut edges = Vec::with_capacity(n.saturating_sub(1));
    if n == 0 {
        return edges;
    }
    while edges.len() < n - 1 {
        // Min outgoing edge per component root: (w, a, b, valid).
        let mut best: Vec<(f64, usize, usize)> = vec![(f64::INFINITY, usize::MAX, usize::MAX); n];
        for a in 0..n {
            for b in a + 1..n {
                let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
                if ra == rb {
                    continue;
                }
                let d = w(a, b);
                for r in [ra, rb] {
                    let cur = best[r];
                    if d < cur.0 || (d == cur.0 && (a, b) < (cur.1, cur.2)) {
                        best[r] = (d, a, b);
                    }
                }
            }
        }
        let mut merged_any = false;
        for r in 0..n {
            let (d, a, b) = best[r];
            if a == usize::MAX || find(&mut parent, r) != r {
                continue;
            }
            let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
            if ra != rb {
                parent[ra] = rb;
                edges.push((a, b, d));
                merged_any = true;
            }
        }
        assert!(merged_any, "boruvka must merge every phase on a complete graph");
    }
    edges
}

/// Greedy minimum-weight perfect matching on the (ascending) node list
/// `nodes` under `w`, **without** materializing the O(f²) pair list: the
/// classic sort-all-pairs greedy accepts, at every step, the minimum
/// `(weight, a, b)` pair among still-free nodes — which this computes via
/// per-node nearest-free-partner pointers (recomputed only when a node's
/// partner gets matched away). Bit-identical output to
/// `topology::ring::greedy_matching_sorted`, the retained dense oracle.
pub fn nn_greedy_matching(
    nodes: &[usize],
    mut w: impl FnMut(usize, usize) -> f64,
) -> Vec<(usize, usize)> {
    let f = nodes.len();
    debug_assert!(nodes.windows(2).all(|p| p[0] < p[1]), "nodes must ascend");
    let mut alive = vec![true; f];
    let mut alive_count = f;
    // Per position p: best free partner position, min by (w, min-id, max-id).
    let mut nn: Vec<(f64, usize)> = vec![(f64::INFINITY, usize::MAX); f];
    let recompute = |p: usize, alive: &[bool], w: &mut dyn FnMut(usize, usize) -> f64| {
        let mut best = (f64::INFINITY, usize::MAX);
        for q in 0..alive.len() {
            if q == p || !alive[q] {
                continue;
            }
            let (a, b) = (nodes[p.min(q)], nodes[p.max(q)]);
            let d = w(a, b);
            // order pairs by (w, a, b); for fixed p that is (w, q) since
            // the node list ascends
            if d < best.0 || (d == best.0 && q < best.1) {
                best = (d, q);
            }
        }
        best
    };
    for p in 0..f {
        nn[p] = recompute(p, &alive, &mut w);
    }
    let mut matching = Vec::with_capacity(f / 2);
    while alive_count >= 2 {
        // Global minimum pair = min over free p of (nn_w, pair ids).
        let mut p_star = usize::MAX;
        for p in 0..f {
            if !alive[p] || nn[p].1 == usize::MAX {
                continue;
            }
            if p_star == usize::MAX {
                p_star = p;
                continue;
            }
            let (da, qa) = nn[p];
            let (db, qb) = nn[p_star];
            let ka = (da, nodes[p.min(qa)], nodes[p.max(qa)]);
            let kb = (db, nodes[p_star.min(qb)], nodes[p_star.max(qb)]);
            if ka.0 < kb.0 || (ka.0 == kb.0 && (ka.1, ka.2) < (kb.1, kb.2)) {
                p_star = p;
            }
        }
        if p_star == usize::MAX {
            break;
        }
        let q_star = nn[p_star].1;
        let (a, b) = (p_star.min(q_star), p_star.max(q_star));
        matching.push((nodes[a], nodes[b]));
        alive[a] = false;
        alive[b] = false;
        alive_count -= 2;
        if alive_count < 2 {
            break;
        }
        for p in 0..f {
            if alive[p] && (nn[p].1 == a || nn[p].1 == b) {
                nn[p] = recompute(p, &alive, &mut w);
            }
        }
    }
    matching
}

/// Nearest-neighbor tour over the implicit complete graph (the "greedy
/// ring"): start at `start`, repeatedly hop to the closest unvisited node
/// (ties broken by index). O(N²) time, O(N) memory — the cheap reference
/// tour for when Christofides' matching phase is too heavy (a quality
/// floor the designed ring must beat, not a designer itself).
pub fn nn_tour(n: usize, start: usize, mut w: impl FnMut(usize, usize) -> f64) -> Vec<usize> {
    assert!(start < n);
    let mut visited = vec![false; n];
    let mut tour = Vec::with_capacity(n);
    let mut cur = start;
    visited[cur] = true;
    tour.push(cur);
    for _ in 1..n {
        let mut best = usize::MAX;
        let mut best_d = f64::INFINITY;
        for v in 0..n {
            if !visited[v] {
                let d = w_uv(&mut w, cur, v);
                if d < best_d || (d == best_d && v < best) {
                    best_d = d;
                    best = v;
                }
            }
        }
        visited[best] = true;
        tour.push(best);
        cur = best;
    }
    tour
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::mst::{delta_prim, prim};
    use crate::util::rng::Rng;

    /// Pseudo-random but deterministic symmetric weight table.
    fn rand_w(n: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        let mut t = vec![vec![0.0f64; n]; n];
        for i in 0..n {
            for j in i + 1..n {
                let x = 1.0 + 99.0 * rng.f64();
                t[i][j] = x;
                t[j][i] = x;
            }
        }
        t
    }

    #[test]
    fn csr_preserves_adjacency_order() {
        let mut g = UnGraph::new(4);
        g.add_edge(0, 2, 1.0);
        g.add_edge(0, 1, 2.0);
        g.add_edge(1, 3, 3.0);
        let c = Csr::from_ungraph(&g);
        assert_eq!(c.n(), 4);
        assert_eq!(c.half_edges(), 6);
        let (nbr, eid, w) = c.neighbors(0);
        assert_eq!(nbr, &[2, 1]);
        assert_eq!(eid, &[0, 1]);
        assert_eq!(w, &[1.0, 2.0]);
        let (nbr, _, _) = c.neighbors(3);
        assert_eq!(nbr, &[1]);
    }

    #[test]
    fn implicit_prim_matches_dense_prim_bitwise() {
        for seed in [1u64, 7, 42] {
            let n = 23;
            let t = rand_w(n, seed);
            let dense = prim(&UnGraph::complete_with(n, |i, j| t[i][j])).unwrap();
            let implicit = implicit_prim(n, |i, j| t[i][j]);
            assert_eq!(implicit.len(), n - 1);
            let dense_edges = dense.edges();
            for (k, &(u, v, w)) in implicit.iter().enumerate() {
                let (a, b, wd) = dense_edges[k];
                assert_eq!((u.min(v), u.max(v)), (a, b), "seed {seed} edge {k}");
                assert_eq!(w.to_bits(), wd.to_bits(), "seed {seed} edge {k}");
            }
        }
    }

    #[test]
    fn implicit_prim_matches_dense_under_ties() {
        // All-equal weights: pure tie-break territory.
        let n = 12;
        let dense = prim(&UnGraph::complete_with(n, |_, _| 5.0)).unwrap();
        let implicit = implicit_prim(n, |_, _| 5.0);
        let dense_edges = dense.edges();
        for (k, &(u, v, _)) in implicit.iter().enumerate() {
            assert_eq!((u.min(v), u.max(v)), (dense_edges[k].0, dense_edges[k].1));
        }
    }

    #[test]
    fn implicit_delta_prim_matches_dense_for_all_deltas() {
        for seed in [3u64, 11] {
            let n = 18;
            let t = rand_w(n, seed);
            for delta in 2..6usize {
                let dense =
                    delta_prim(&UnGraph::complete_with(n, |i, j| t[i][j]), delta).unwrap();
                let implicit = implicit_delta_prim(n, delta, |i, j| t[i][j]).unwrap();
                assert_eq!(implicit.len(), n - 1);
                let mut deg = vec![0usize; n];
                let dense_edges = dense.edges();
                for (k, &(u, v, w)) in implicit.iter().enumerate() {
                    deg[u] += 1;
                    deg[v] += 1;
                    let (a, b, wd) = dense_edges[k];
                    assert_eq!((u.min(v), u.max(v)), (a, b), "δ={delta} edge {k}");
                    assert_eq!(w.to_bits(), wd.to_bits());
                }
                assert!(deg.iter().all(|&d| d <= delta), "δ={delta}");
            }
        }
    }

    #[test]
    fn boruvka_finds_the_same_mst_weight() {
        for seed in [5u64, 9] {
            let n = 30;
            let t = rand_w(n, seed); // distinct weights a.s. → unique MST
            let prim_edges = implicit_prim(n, |i, j| t[i][j]);
            let bor_edges = implicit_boruvka(n, |i, j| t[i][j]);
            assert_eq!(bor_edges.len(), n - 1);
            let norm = |es: &[(usize, usize, f64)]| {
                let mut v: Vec<(usize, usize, u64)> = es
                    .iter()
                    .map(|&(u, w_, d)| (u.min(w_), u.max(w_), d.to_bits()))
                    .collect();
                v.sort_unstable();
                v
            };
            assert_eq!(norm(&prim_edges), norm(&bor_edges), "seed {seed}");
        }
    }

    #[test]
    fn nn_matching_pairs_everyone_and_is_greedy_min_first() {
        let nodes: Vec<usize> = vec![0, 2, 3, 5, 8, 9];
        let t = rand_w(10, 13);
        let m = nn_greedy_matching(&nodes, |i, j| t[i][j]);
        assert_eq!(m.len(), 3);
        let mut used = std::collections::HashSet::new();
        for &(a, b) in &m {
            assert!(a < b);
            assert!(used.insert(a) && used.insert(b));
        }
        // first accepted pair is the global minimum pair
        let mut min_pair = (f64::INFINITY, 0usize, 0usize);
        for (x, &a) in nodes.iter().enumerate() {
            for &b in &nodes[x + 1..] {
                if t[a][b] < min_pair.0 {
                    min_pair = (t[a][b], a, b);
                }
            }
        }
        assert_eq!((m[0].0, m[0].1), (min_pair.1, min_pair.2));
    }

    #[test]
    fn nn_tour_is_a_permutation_starting_at_start() {
        let t = rand_w(15, 21);
        let tour = nn_tour(15, 4, |i, j| t[i][j]);
        assert_eq!(tour[0], 4);
        let mut s = tour.clone();
        s.sort_unstable();
        assert_eq!(s, (0..15).collect::<Vec<_>>());
    }
}
