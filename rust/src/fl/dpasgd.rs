//! DPASGD — decentralized periodic averaging SGD (Eq. 2).
//!
//! Each round, every silo performs `s` local mini-batch SGD steps, sends its
//! model to its out-neighbours in the round's communication graph, and mixes
//! the received models with the consensus matrix built by the local-degree
//! rule. The compute itself lives behind the [`LocalTrainer`] trait: the
//! production implementation is `XlaTrainer` (AOT-compiled JAX/Pallas via
//! PJRT, behind the `xla` feature); tests use the closed-form
//! [`QuadraticTrainer`] so the orchestration logic is verified without
//! artifacts.

use super::consensus::ConsensusMatrix;
use crate::topology::Overlay;
use crate::util::rng::Rng;
use anyhow::Result;

/// Model parameters: a flat f32 buffer (layout fixed by the AOT manifest).
pub type Params = Vec<f32>;

/// The per-silo compute interface.
pub trait LocalTrainer {
    /// Number of parameters in the flat buffer.
    fn param_count(&self) -> usize;
    /// Initialize silo `silo`'s parameters. All silos must start from the
    /// *same* point for DPASGD's convergence theory, so implementations
    /// should ignore `silo` unless deliberately experimenting.
    fn init(&mut self, silo: usize, seed: u64) -> Result<Params>;
    /// One local mini-batch SGD step; returns the mini-batch training loss.
    fn step(&mut self, silo: usize, params: &mut Params, rng: &mut Rng) -> Result<f32>;
    /// Evaluate (loss, accuracy) of `params` on the shared test set.
    fn eval(&mut self, params: &Params) -> Result<(f32, f32)>;
}

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct DpasgdConfig {
    pub rounds: usize,
    /// local steps per round (the paper's `s`).
    pub s: usize,
    pub seed: u64,
    /// evaluate the mean model every `eval_every` rounds (0 = never).
    pub eval_every: usize,
    /// use the ring-optimal ½ consensus matrix when the overlay is a ring.
    pub ring_half_weights: bool,
}

impl Default for DpasgdConfig {
    fn default() -> Self {
        DpasgdConfig {
            rounds: 100,
            s: 1,
            seed: 17,
            eval_every: 10,
            ring_half_weights: false,
        }
    }
}

/// Per-round training record.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// mean local training loss across silos (over the s local steps).
    pub train_loss: f32,
    /// test loss/accuracy of the silo-averaged model (if evaluated).
    pub test_loss: Option<f32>,
    pub test_acc: Option<f32>,
}

/// Full training report.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub records: Vec<RoundRecord>,
    pub final_params_mean: Params,
}

impl TrainReport {
    pub fn final_train_loss(&self) -> f32 {
        self.records.last().map(|r| r.train_loss).unwrap_or(f32::NAN)
    }

    /// First round whose *evaluated* accuracy reaches `target` (paper's
    /// "time to reach training accuracy X%" metric), if ever.
    pub fn rounds_to_accuracy(&self, target: f32) -> Option<usize> {
        self.records
            .iter()
            .find(|r| r.test_acc.map(|a| a >= target).unwrap_or(false))
            .map(|r| r.round)
    }
}

/// The per-(round, silo) fork tag of the local-phase RNG stream. One shared
/// definition keeps [`run`] and the wall-clock engine
/// ([`crate::fl::trainsim::run`]) drawing identical mini-batch noise, which
/// is what makes their (round, loss) sequences bit-identical under the
/// identity scenario (pinned by `tests/train.rs`).
#[inline]
pub(crate) fn silo_stream_tag(k: usize, i: usize) -> u64 {
    (k as u64) << 20 | i as u64
}

/// The consensus matrix DPASGD mixes with on a round graph: the paper's
/// local-degree rule, or the ring-optimal ½ matrix when requested and the
/// graph is a directed ring. Shared by [`run`] and
/// [`crate::fl::trainsim::run`] (which must rebuild it whenever an adaptive
/// re-design swaps the overlay mid-training).
pub fn consensus_for(g: &crate::graph::DiGraph, ring_half_weights: bool) -> ConsensusMatrix {
    let n = g.n();
    if ring_half_weights && (0..n).all(|i| g.in_degree(i) == 1) {
        ConsensusMatrix::ring_half(g)
    } else {
        ConsensusMatrix::local_degree(g)
    }
}

/// Run DPASGD over an overlay.
pub fn run(
    trainer: &mut dyn LocalTrainer,
    overlay: &Overlay,
    cfg: &DpasgdConfig,
) -> Result<TrainReport> {
    let n = overlay.n();
    let mut rng = Rng::new(cfg.seed);
    // Common initialization (silo 0's init broadcast — Eq. 2 assumes a
    // shared starting point).
    let w0 = trainer.init(0, cfg.seed)?;
    let p_len = w0.len();
    let mut params: Vec<Params> = vec![w0; n];
    // ping-pong buffer for the mixing phase (no per-round allocation)
    let mut mixed: Vec<Params> = vec![vec![0.0; p_len]; n];
    let mut records = Vec::with_capacity(cfg.rounds);

    for k in 0..cfg.rounds {
        // --- local phase: s mini-batch steps per silo -------------------
        let mut loss_sum = 0.0f32;
        for (i, p) in params.iter_mut().enumerate() {
            let mut srng = rng.fork(silo_stream_tag(k, i));
            for _ in 0..cfg.s {
                loss_sum += trainer.step(i, p, &mut srng)?;
            }
        }
        let train_loss = loss_sum / (n * cfg.s) as f32;

        // --- communication phase: mix over the round graph --------------
        let g = overlay.round_graph(k, cfg.seed);
        let a = consensus_for(&g, cfg.ring_half_weights);
        a.apply_into(&params, &mut mixed);
        std::mem::swap(&mut params, &mut mixed);

        // --- evaluation --------------------------------------------------
        let (test_loss, test_acc) = if cfg.eval_every > 0
            && (k % cfg.eval_every == 0 || k + 1 == cfg.rounds)
        {
            let mean = mean_params(&params);
            let (l, acc) = trainer.eval(&mean)?;
            (Some(l), Some(acc))
        } else {
            (None, None)
        };

        records.push(RoundRecord {
            round: k,
            train_loss,
            test_loss,
            test_acc,
        });
    }

    Ok(TrainReport {
        final_params_mean: mean_params(&params),
        records,
    })
}

/// Element-wise mean of all silos' parameters.
pub fn mean_params(params: &[Params]) -> Params {
    let n = params.len();
    let len = params[0].len();
    let mut out = vec![0.0f32; len];
    for p in params {
        super::consensus::axpy(1.0 / n as f32, p, &mut out);
    }
    out
}

// ---------------------------------------------------------------------------
// Closed-form test trainer
// ---------------------------------------------------------------------------

/// A quadratic “model”: silo i minimizes `½‖w − c_i‖²` with noisy gradients.
/// The global optimum of the average objective is `mean(c_i)`, so the
/// orchestration (local steps + doubly-stochastic mixing) is verifiable in
/// closed form. Accuracy is reported as `1 / (1 + ‖w − mean(c)‖)`.
pub struct QuadraticTrainer {
    pub centers: Vec<Params>,
    pub lr: f32,
    pub noise: f32,
    dim: usize,
}

impl QuadraticTrainer {
    pub fn new(n_silos: usize, dim: usize, seed: u64) -> QuadraticTrainer {
        let mut rng = Rng::new(seed);
        // Shared signal + per-silo heterogeneity: local optima genuinely
        // differ (non-iid) but a common component exists, so the training
        // loss visibly decreases from the zero initialization.
        let common: Vec<f32> = (0..dim).map(|_| rng.normal() as f32 * 5.0).collect();
        let centers = (0..n_silos)
            .map(|_| {
                common
                    .iter()
                    .map(|&c| c + rng.normal() as f32)
                    .collect()
            })
            .collect();
        QuadraticTrainer {
            centers,
            lr: 0.2,
            noise: 0.05,
            dim,
        }
    }

    pub fn optimum(&self) -> Params {
        mean_params(&self.centers)
    }
}

impl LocalTrainer for QuadraticTrainer {
    fn param_count(&self) -> usize {
        self.dim
    }

    fn init(&mut self, _silo: usize, _seed: u64) -> Result<Params> {
        Ok(vec![0.0; self.dim])
    }

    fn step(&mut self, silo: usize, params: &mut Params, rng: &mut Rng) -> Result<f32> {
        let c = &self.centers[silo];
        let mut loss = 0.0f32;
        for (w, &ci) in params.iter_mut().zip(c) {
            let g = (*w - ci) + self.noise * rng.normal() as f32;
            loss += 0.5 * (*w - ci) * (*w - ci);
            *w -= self.lr * g;
        }
        Ok(loss / self.dim as f32)
    }

    fn eval(&mut self, params: &Params) -> Result<(f32, f32)> {
        let opt = self.optimum();
        let dist: f32 = params
            .iter()
            .zip(&opt)
            .map(|(&w, &o)| (w - o) * (w - o))
            .sum::<f32>()
            .sqrt();
        Ok((dist, 1.0 / (1.0 + dist)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::delay::DelayModel;
    use crate::netsim::underlay::Underlay;
    use crate::topology::{design, design_with_underlay, OverlayKind};

    fn gaia_model() -> (Underlay, DelayModel) {
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        (net, dm)
    }

    fn run_kind(kind: OverlayKind, rounds: usize, s: usize) -> (TrainReport, QuadraticTrainer) {
        let (net, dm) = gaia_model();
        let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
        let mut trainer = QuadraticTrainer::new(11, 8, 3);
        let cfg = DpasgdConfig {
            rounds,
            s,
            eval_every: 5,
            ..Default::default()
        };
        let report = run(&mut trainer, &overlay, &cfg).unwrap();
        (report, trainer)
    }

    #[test]
    fn converges_to_global_optimum_on_ring() {
        let (report, trainer) = run_kind(OverlayKind::Ring, 200, 1);
        let opt = trainer.optimum();
        let dist: f32 = report
            .final_params_mean
            .iter()
            .zip(&opt)
            .map(|(&w, &o)| (w - o) * (w - o))
            .sum::<f32>()
            .sqrt();
        assert!(dist < 0.5, "mean model {dist} from optimum");
    }

    #[test]
    fn converges_on_star_and_mst_too() {
        for kind in [OverlayKind::Star, OverlayKind::Mst] {
            let (report, trainer) = run_kind(kind, 200, 1);
            let opt = trainer.optimum();
            let dist: f32 = report
                .final_params_mean
                .iter()
                .zip(&opt)
                .map(|(&w, &o)| (w - o) * (w - o))
                .sum::<f32>()
                .sqrt();
            assert!(dist < 0.6, "{kind:?}: {dist}");
        }
    }

    #[test]
    fn converges_with_matcha_dynamic_topology() {
        let (report, trainer) = run_kind(OverlayKind::Matcha, 250, 1);
        let opt = trainer.optimum();
        let dist: f32 = report
            .final_params_mean
            .iter()
            .zip(&opt)
            .map(|(&w, &o)| (w - o) * (w - o))
            .sum::<f32>()
            .sqrt();
        assert!(dist < 0.8, "matcha: {dist}");
    }

    #[test]
    fn train_loss_decreases() {
        let (report, _) = run_kind(OverlayKind::Ring, 100, 1);
        let first = report.records[2].train_loss;
        let last = report.final_train_loss();
        assert!(last < 0.3 * first, "loss {first} → {last}");
    }

    #[test]
    fn more_local_steps_fewer_rounds_needed() {
        let (r1, _) = run_kind(OverlayKind::Ring, 60, 1);
        let (r5, _) = run_kind(OverlayKind::Ring, 60, 5);
        // With 5 local steps per round the model at round 10 must be better.
        let at = |r: &TrainReport, k: usize| r.records[k].train_loss;
        assert!(at(&r5, 10) < at(&r1, 10));
    }

    #[test]
    fn eval_cadence_respected() {
        let (report, _) = run_kind(OverlayKind::Ring, 21, 1);
        for rec in &report.records {
            let should_eval = rec.round % 5 == 0 || rec.round == 20;
            assert_eq!(rec.test_acc.is_some(), should_eval, "round {}", rec.round);
        }
    }

    #[test]
    fn rounds_to_accuracy_detects_threshold() {
        let (report, _) = run_kind(OverlayKind::Ring, 200, 1);
        let hit = report.rounds_to_accuracy(0.5);
        assert!(hit.is_some());
        assert!(hit.unwrap() > 0);
    }

    #[test]
    fn ring_half_weights_also_converge() {
        let (net, dm) = gaia_model();
        let overlay = design(OverlayKind::Ring, &dm, 0.5).unwrap();
        let mut trainer = QuadraticTrainer::new(11, 8, 3);
        let cfg = DpasgdConfig {
            rounds: 300,
            ring_half_weights: true,
            eval_every: 10,
            ..Default::default()
        };
        let report = run(&mut trainer, &overlay, &cfg).unwrap();
        let opt = trainer.optimum();
        let dist: f32 = report
            .final_params_mean
            .iter()
            .zip(&opt)
            .map(|(&w, &o)| (w - o) * (w - o))
            .sum::<f32>()
            .sqrt();
        assert!(dist < 0.6, "ring-half: {dist}");
        let _ = net;
    }
}
