//! Synthetic non-iid federated datasets.
//!
//! Stand-in for the paper's LEAF / iNaturalist data (no network access in
//! this environment — DESIGN.md §3). The generator reproduces the two
//! statistical properties the paper's experiments depend on:
//!
//! 1. **Size skew** — silo dataset sizes follow a log-normal (the paper
//!    associates "a random number of writers/roles/accounts following a
//!    lognormal distribution with mean 5 and std 1.5", App. G.2; Table 4
//!    shows up to 50× size ratios).
//! 2. **Label skew** — per-silo class distributions are Dirichlet(α) draws
//!    (the standard non-iid FL partition), giving the high pairwise
//!    Jensen–Shannon divergences of the paper's Fig. 25.
//!
//! Features are drawn from class-conditional Gaussians around well-separated
//! class means, so the global problem is learnable and the local optima
//! genuinely differ across silos.

use crate::util::rng::Rng;
use crate::util::stats::js_divergence;

/// One silo's local dataset (dense features + integer labels).
#[derive(Clone, Debug)]
pub struct LocalData {
    pub x: Vec<f32>, // row-major [n_samples × dim]
    pub y: Vec<i32>,
    pub dim: usize,
}

impl LocalData {
    pub fn len(&self) -> usize {
        self.y.len()
    }
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }
}

/// A federated dataset: one [`LocalData`] per silo + shared test set.
#[derive(Clone, Debug)]
pub struct FedDataset {
    pub silos: Vec<LocalData>,
    pub test: LocalData,
    pub num_classes: usize,
    pub dim: usize,
}

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct DataConfig {
    pub num_silos: usize,
    pub dim: usize,
    pub num_classes: usize,
    /// Dirichlet concentration: small → heavy label skew.
    pub alpha: f64,
    /// log-normal (μ, σ) of silo sample counts.
    pub size_mu: f64,
    pub size_sigma: f64,
    /// class-mean separation (in units of the noise σ=1).
    pub separation: f64,
    pub test_samples: usize,
    pub seed: u64,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            num_silos: 11,
            dim: 64,
            num_classes: 10,
            alpha: 0.5,
            size_mu: 5.0,
            size_sigma: 0.8,
            separation: 3.0,
            test_samples: 2000,
            seed: 7,
        }
    }
}

impl FedDataset {
    /// Generate a federated dataset deterministically from the config.
    pub fn synthesize(cfg: &DataConfig) -> FedDataset {
        let mut rng = Rng::new(cfg.seed);
        // class means on a scaled random orthant pattern
        let means: Vec<Vec<f64>> = (0..cfg.num_classes)
            .map(|_| {
                (0..cfg.dim)
                    .map(|_| rng.normal() * cfg.separation / (cfg.dim as f64).sqrt().max(1.0))
                    .collect()
            })
            .collect();

        let sample = |rng: &mut Rng, class: usize| -> Vec<f32> {
            means[class]
                .iter()
                .map(|&m| (m + rng.normal() / (cfg.dim as f64).sqrt()) as f32)
                .collect()
        };

        let mut silos = Vec::with_capacity(cfg.num_silos);
        for s in 0..cfg.num_silos {
            let mut srng = rng.fork(s as u64 + 1);
            let n = srng.lognormal(cfg.size_mu, cfg.size_sigma).round().max(8.0) as usize;
            let label_dist = srng.dirichlet(cfg.alpha, cfg.num_classes);
            // cumulative for sampling
            let mut cum = vec![0.0f64; cfg.num_classes];
            let mut acc = 0.0;
            for (c, &p) in label_dist.iter().enumerate() {
                acc += p;
                cum[c] = acc;
            }
            let mut x = Vec::with_capacity(n * cfg.dim);
            let mut y = Vec::with_capacity(n);
            for _ in 0..n {
                let u = srng.f64();
                let class = cum.iter().position(|&c| u <= c).unwrap_or(cfg.num_classes - 1);
                x.extend(sample(&mut srng, class));
                y.push(class as i32);
            }
            silos.push(LocalData {
                x,
                y,
                dim: cfg.dim,
            });
        }

        // iid test set
        let mut trng = rng.fork(0xdead);
        let mut x = Vec::with_capacity(cfg.test_samples * cfg.dim);
        let mut y = Vec::with_capacity(cfg.test_samples);
        for _ in 0..cfg.test_samples {
            let class = trng.usize(cfg.num_classes);
            x.extend(sample(&mut trng, class));
            y.push(class as i32);
        }
        FedDataset {
            silos,
            test: LocalData {
                x,
                y,
                dim: cfg.dim,
            },
            num_classes: cfg.num_classes,
            dim: cfg.dim,
        }
    }

    /// Label distribution of silo `s` (for JS-divergence diagnostics).
    pub fn label_distribution(&self, s: usize) -> Vec<f64> {
        let mut counts = vec![0.0f64; self.num_classes];
        for &y in &self.silos[s].y {
            counts[y as usize] += 1.0;
        }
        let total: f64 = counts.iter().sum();
        counts.iter_mut().for_each(|c| *c /= total.max(1.0));
        counts
    }

    /// Mean pairwise Jensen–Shannon divergence across silo label
    /// distributions (the paper's Fig. 25 non-iid-ness metric).
    pub fn mean_pairwise_js(&self) -> f64 {
        let dists: Vec<Vec<f64>> = (0..self.silos.len())
            .map(|s| self.label_distribution(s))
            .collect();
        let n = dists.len();
        let mut total = 0.0;
        let mut count = 0;
        for i in 0..n {
            for j in i + 1..n {
                total += js_divergence(&dists[i], &dists[j]);
                count += 1;
            }
        }
        total / count.max(1) as f64
    }

    /// Draw a mini-batch (with replacement) from silo `s`.
    pub fn batch(&self, s: usize, m: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        let local = &self.silos[s];
        let mut x = Vec::with_capacity(m * self.dim);
        let mut y = Vec::with_capacity(m);
        for _ in 0..m {
            let i = rng.usize(local.len());
            x.extend_from_slice(local.row(i));
            y.push(local.y[i]);
        }
        (x, y)
    }

    /// Per-silo sample counts (Table 4/5-style statistics).
    pub fn sizes(&self) -> Vec<usize> {
        self.silos.iter().map(|s| s.len()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> DataConfig {
        DataConfig {
            num_silos: 8,
            dim: 16,
            num_classes: 5,
            test_samples: 200,
            ..DataConfig::default()
        }
    }

    #[test]
    fn deterministic() {
        let a = FedDataset::synthesize(&small_cfg());
        let b = FedDataset::synthesize(&small_cfg());
        assert_eq!(a.sizes(), b.sizes());
        assert_eq!(a.silos[0].y, b.silos[0].y);
        assert_eq!(a.silos[0].x, b.silos[0].x);
    }

    #[test]
    fn shapes_consistent() {
        let d = FedDataset::synthesize(&small_cfg());
        assert_eq!(d.silos.len(), 8);
        for s in &d.silos {
            assert_eq!(s.x.len(), s.y.len() * s.dim);
            assert!(s.y.iter().all(|&y| (y as usize) < d.num_classes));
        }
        assert_eq!(d.test.len(), 200);
    }

    #[test]
    fn size_skew_present() {
        let cfg = DataConfig {
            num_silos: 40,
            size_sigma: 1.5,
            ..small_cfg()
        };
        let d = FedDataset::synthesize(&cfg);
        let sizes = d.sizes();
        let max = *sizes.iter().max().unwrap() as f64;
        let min = *sizes.iter().min().unwrap() as f64;
        assert!(max / min > 3.0, "sizes not skewed: {sizes:?}");
    }

    #[test]
    fn label_skew_scales_with_alpha() {
        let skewed = FedDataset::synthesize(&DataConfig {
            alpha: 0.1,
            seed: 3,
            ..small_cfg()
        });
        let uniform = FedDataset::synthesize(&DataConfig {
            alpha: 100.0,
            seed: 3,
            ..small_cfg()
        });
        assert!(
            skewed.mean_pairwise_js() > 3.0 * uniform.mean_pairwise_js(),
            "js skewed={} uniform={}",
            skewed.mean_pairwise_js(),
            uniform.mean_pairwise_js()
        );
    }

    #[test]
    fn batches_draw_from_local_data() {
        let d = FedDataset::synthesize(&small_cfg());
        let mut rng = Rng::new(5);
        let (x, y) = d.batch(2, 32, &mut rng);
        assert_eq!(x.len(), 32 * d.dim);
        assert_eq!(y.len(), 32);
    }

    #[test]
    fn classes_separable_by_nearest_mean() {
        // sanity: a nearest-class-mean classifier on the test set should
        // beat chance comfortably given separation=3.
        let d = FedDataset::synthesize(&small_cfg());
        // estimate class means from all silo data
        let mut means = vec![vec![0.0f64; d.dim]; d.num_classes];
        let mut counts = vec![0usize; d.num_classes];
        for s in &d.silos {
            for i in 0..s.len() {
                let c = s.y[i] as usize;
                counts[c] += 1;
                for (m, &v) in means[c].iter_mut().zip(s.row(i)) {
                    *m += v as f64;
                }
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            if c > 0 {
                m.iter_mut().for_each(|v| *v /= c as f64);
            }
        }
        let mut correct = 0;
        for i in 0..d.test.len() {
            let row = d.test.row(i);
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for (c, m) in means.iter().enumerate() {
                let dist: f64 = m
                    .iter()
                    .zip(row)
                    .map(|(&a, &b)| (a - b as f64) * (a - b as f64))
                    .sum();
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            if best == d.test.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.test.len() as f64;
        assert!(acc > 0.5, "nearest-mean accuracy only {acc}");
    }
}
