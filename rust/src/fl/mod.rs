//! Decentralized periodic-averaging SGD (DPASGD, Eq. 2) and its substrates.
//!
//! * [`workloads`] — the Table-2 model-size / computation-time catalogue.
//! * [`consensus`] — local-degree-rule consensus matrices + the mixing hot
//!   loop (chunked AXPY over flat parameter buffers).
//! * [`data`] — synthetic non-iid federated datasets (Dirichlet label skew,
//!   log-normal size skew — the LEAF/iNaturalist stand-in, DESIGN.md §3).
//! * [`dpasgd`] — the training orchestrator: s local steps → neighbour
//!   exchange → consensus mixing, generic over the [`dpasgd::LocalTrainer`]
//!   compute backend (XLA/PJRT in production, closed-form in tests).
//! * [`trainsim`] — the wall-clock time-to-accuracy engine: DPASGD rounds
//!   interleaved with the Eq.-(4) recurrence under a dynamic-network
//!   scenario, with optional adaptive re-design that swaps the topology
//!   *and* the consensus matrix mid-training. Under the identity scenario
//!   with re-design disabled it degenerates to [`dpasgd::run`] bit-for-bit.

pub mod workloads;
pub mod consensus;
pub mod data;
pub mod dpasgd;
pub mod trainsim;
