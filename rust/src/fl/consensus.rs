//! Consensus matrices for DPASGD (Eq. 2).
//!
//! The default is the paper's *local-degree rule* (App. G.3, Eq. 22-23):
//!
//! ```text
//! A[i][j] = 1 / (1 + max(|N_i⁻|, |N_j⁻|))   for (i,j) ∈ E_o
//! A[i][i] = 1 − Σ_j A[i][j]
//! ```
//!
//! which is symmetric and doubly stochastic on undirected overlays and can
//! be computed with only neighbour-degree exchange. For directed rings the
//! paper (App. H.4) notes the spectrally-optimal matrix has all non-zero
//! entries = 1/2 — provided as [`ConsensusMatrix::ring_half`]. The mixing
//! step itself (`w_i ← Σ_j A_ij w_j`) is the L3 hot loop: implemented as
//! chunked AXPY over flat parameter buffers, benchmarked in §Perf.

use crate::graph::DiGraph;

/// Sparse row-stochastic consensus matrix: `rows[i]` lists `(j, A_ij)`
/// including the diagonal entry.
#[derive(Clone, Debug)]
pub struct ConsensusMatrix {
    pub n: usize,
    pub rows: Vec<Vec<(usize, f32)>>,
}

impl ConsensusMatrix {
    /// Local-degree rule over a communication digraph. Degrees are
    /// *in-degrees* (the models a silo has to aggregate), matching Eq. 22.
    pub fn local_degree(g: &DiGraph) -> ConsensusMatrix {
        let n = g.n();
        let mut rows = Vec::with_capacity(n);
        for i in 0..n {
            let deg_i = g.in_degree(i);
            let mut row = Vec::with_capacity(deg_i + 1);
            let mut off_diag_sum = 0.0f32;
            for &(j, _) in g.in_neighbors(i) {
                let deg_j = g.in_degree(j);
                let w = 1.0f32 / (1.0 + deg_i.max(deg_j) as f32);
                row.push((j, w));
                off_diag_sum += w;
            }
            row.push((i, 1.0 - off_diag_sum));
            rows.push(row);
        }
        ConsensusMatrix { n, rows }
    }

    /// Ring-optimal matrix: ½ self + ½ predecessor (App. H.4: "For the RING,
    /// the optimal consensus matrix has all the non-zero entries equal to
    /// 1/2"). `g` must be a directed ring (in-degree 1 everywhere).
    pub fn ring_half(g: &DiGraph) -> ConsensusMatrix {
        let n = g.n();
        let mut rows = Vec::with_capacity(n);
        for i in 0..n {
            assert_eq!(g.in_degree(i), 1, "ring_half needs a directed ring");
            let j = g.in_neighbors(i)[0].0;
            rows.push(vec![(j, 0.5f32), (i, 0.5f32)]);
        }
        ConsensusMatrix { n, rows }
    }

    /// Row sums (should all be 1 — row stochastic).
    pub fn row_sums(&self) -> Vec<f32> {
        self.rows
            .iter()
            .map(|r| r.iter().map(|&(_, w)| w).sum())
            .collect()
    }

    /// Column sums (1 on undirected overlays — doubly stochastic).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut cols = vec![0.0f32; self.n];
        for r in &self.rows {
            for &(j, w) in r {
                cols[j] += w;
            }
        }
        cols
    }

    /// Is the matrix symmetric (A_ij == A_ji)?
    pub fn is_symmetric(&self, tol: f32) -> bool {
        for (i, r) in self.rows.iter().enumerate() {
            for &(j, w) in r {
                let w_ji = self.rows[j]
                    .iter()
                    .find(|&&(k, _)| k == i)
                    .map(|&(_, w)| w)
                    .unwrap_or(0.0);
                if (w - w_ji).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Mix step for silo `i`: `out = Σ_j A_ij · params[j]`.
    ///
    /// `get` maps silo id → parameter slice (all of equal length). The inner
    /// loop is a chunked multiply-accumulate the compiler auto-vectorizes;
    /// see `benches/consensus.rs`.
    pub fn mix_into(&self, i: usize, get: &dyn Fn(usize) -> *const f32, len: usize, out: &mut [f32]) {
        assert_eq!(out.len(), len);
        out.iter_mut().for_each(|x| *x = 0.0);
        for &(j, w) in &self.rows[i] {
            // SAFETY: caller guarantees `get(j)` points at `len` valid f32s
            // that do not alias `out` (distinct buffers per silo).
            let src = unsafe { std::slice::from_raw_parts(get(j), len) };
            axpy(w, src, out);
        }
    }

    /// Safe convenience mix over a dense parameter table.
    pub fn mix_row(&self, i: usize, params: &[Vec<f32>]) -> Vec<f32> {
        let len = params[0].len();
        let mut out = vec![0.0f32; len];
        for &(j, w) in &self.rows[i] {
            axpy(w, &params[j], &mut out);
        }
        out
    }

    /// Apply the full matrix: new_params[i] = Σ_j A_ij params[j].
    pub fn apply(&self, params: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut out: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        self.apply_into(params, &mut out);
        out
    }

    /// Allocation-free apply into caller-owned buffers (the DPASGD loop
    /// ping-pongs two buffer sets). Rows are mixed in parallel across a
    /// small scoped thread pool when the work is large enough — the op is
    /// memory-bound, so a few threads reach socket bandwidth (§Perf).
    pub fn apply_into(&self, params: &[Vec<f32>], out: &mut [Vec<f32>]) {
        assert_eq!(params.len(), self.n);
        assert_eq!(out.len(), self.n);
        let len = params[0].len();
        let work = self.n * len;
        let threads = if work < 1 << 20 {
            1
        } else {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1)
                .min(8)
        };
        if threads == 1 {
            for (i, o) in out.iter_mut().enumerate() {
                o.iter_mut().for_each(|x| *x = 0.0);
                for &(j, w) in &self.rows[i] {
                    axpy(w, &params[j], o);
                }
            }
            return;
        }
        let rows = &self.rows;
        std::thread::scope(|scope| {
            let chunk = self.n.div_ceil(threads);
            for (c, out_chunk) in out.chunks_mut(chunk).enumerate() {
                scope.spawn(move || {
                    for (k, o) in out_chunk.iter_mut().enumerate() {
                        let i = c * chunk + k;
                        o.iter_mut().for_each(|x| *x = 0.0);
                        for &(j, w) in &rows[i] {
                            axpy(w, &params[j], o);
                        }
                    }
                });
            }
        });
    }
}

/// `out += a * x`, written so LLVM vectorizes it (no bounds checks in the
/// hot loop, 8-wide unroll).
#[inline]
pub fn axpy(a: f32, x: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), out.len());
    let chunks = x.len() / 8;
    let (xh, xt) = x.split_at(chunks * 8);
    let (oh, ot) = out.split_at_mut(chunks * 8);
    for (xc, oc) in xh.chunks_exact(8).zip(oh.chunks_exact_mut(8)) {
        for k in 0..8 {
            oc[k] += a * xc[k];
        }
    }
    for (xi, oi) in xt.iter().zip(ot.iter_mut()) {
        *oi += a * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::UnGraph;
    use crate::util::prop::{check, Gen};

    fn ring_digraph(n: usize) -> DiGraph {
        let mut g = DiGraph::new(n);
        for i in 0..n {
            g.add_edge(i, (i + 1) % n, 0.0);
        }
        g
    }

    fn path_undirected(n: usize) -> DiGraph {
        let mut g = UnGraph::new(n);
        for i in 0..n - 1 {
            g.add_edge(i, i + 1, 1.0);
        }
        g.to_digraph()
    }

    #[test]
    fn local_degree_row_stochastic() {
        let g = path_undirected(5);
        let a = ConsensusMatrix::local_degree(&g);
        for s in a.row_sums() {
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn local_degree_doubly_stochastic_and_symmetric_on_undirected() {
        let g = path_undirected(7);
        let a = ConsensusMatrix::local_degree(&g);
        for s in a.col_sums() {
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(a.is_symmetric(1e-7));
    }

    #[test]
    fn local_degree_known_values_on_path3() {
        // path 0-1-2: in-degrees 1,2,1.
        // A[0][1] = 1/(1+max(1,2)) = 1/3; A[0][0] = 2/3.
        // A[1][0] = A[1][2] = 1/3; A[1][1] = 1/3.
        let g = path_undirected(3);
        let a = ConsensusMatrix::local_degree(&g);
        let w01 = a.rows[0].iter().find(|&&(j, _)| j == 1).unwrap().1;
        assert!((w01 - 1.0 / 3.0).abs() < 1e-6);
        let w11 = a.rows[1].iter().find(|&&(j, _)| j == 1).unwrap().1;
        assert!((w11 - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn ring_half_mixes_evenly() {
        let g = ring_digraph(4);
        let a = ConsensusMatrix::ring_half(&g);
        for s in a.row_sums() {
            assert!((s - 1.0).abs() < 1e-7);
        }
        // column sums also 1 (each node is predecessor of exactly one)
        for s in a.col_sums() {
            assert!((s - 1.0).abs() < 1e-7);
        }
    }

    #[test]
    fn mix_preserves_global_mean_when_doubly_stochastic() {
        let g = path_undirected(5);
        let a = ConsensusMatrix::local_degree(&g);
        let params: Vec<Vec<f32>> = (0..5)
            .map(|i| vec![i as f32, 2.0 * i as f32, -1.0])
            .collect();
        let mean_before: f32 = params.iter().map(|p| p[0]).sum::<f32>() / 5.0;
        let mixed = a.apply(&params);
        let mean_after: f32 = mixed.iter().map(|p| p[0]).sum::<f32>() / 5.0;
        assert!((mean_before - mean_after).abs() < 1e-5);
    }

    #[test]
    fn repeated_mixing_converges_to_consensus() {
        let g = path_undirected(6);
        let a = ConsensusMatrix::local_degree(&g);
        let mut params: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32]).collect();
        for _ in 0..300 {
            params = a.apply(&params);
        }
        let target = (0..6).map(|i| i as f32).sum::<f32>() / 6.0;
        for p in &params {
            assert!((p[0] - target).abs() < 1e-3, "p={} target={target}", p[0]);
        }
    }

    #[test]
    fn axpy_matches_naive() {
        let x: Vec<f32> = (0..103).map(|i| i as f32 * 0.5).collect();
        let mut out = vec![1.0f32; 103];
        let mut expect = out.clone();
        axpy(0.25, &x, &mut out);
        for (e, xi) in expect.iter_mut().zip(&x) {
            *e += 0.25 * xi;
        }
        assert_eq!(out, expect);
    }

    #[test]
    fn prop_local_degree_stochastic_on_random_graphs() {
        check("local-degree rule stochastic", 50, |gen: &mut Gen| {
            let (n, edges) = gen.connected_graph(2, 25);
            let mut un = UnGraph::new(n);
            for &(a, b) in &edges {
                if !un.has_edge(a, b) {
                    un.add_edge(a, b, 1.0);
                }
            }
            let a = ConsensusMatrix::local_degree(&un.to_digraph());
            for s in a.row_sums() {
                assert!((s - 1.0).abs() < 1e-5);
            }
            for s in a.col_sums() {
                assert!((s - 1.0).abs() < 1e-5);
            }
            assert!(a.is_symmetric(1e-6));
            // all weights non-negative (needed for convergence)
            for r in &a.rows {
                for &(_, w) in r {
                    assert!(w >= -1e-7, "negative weight {w}");
                }
            }
        });
    }
}
