//! The Table-2 workload catalogue: model sizes and per-step computation
//! times that drive every timing experiment.
//!
//! | dataset          | model            | params  | size (Mbit) | T_c (ms) |
//! |------------------|------------------|---------|-------------|----------|
//! | Shakespeare      | Stacked-GRU      | 840 k   | 3.23        | 389.6    |
//! | FEMNIST          | 2-layer CNN      | 1 207 k | 4.62        | 4.6      |
//! | Sentiment140     | GloVe + LSTM     | 4 810 k | 18.38       | 9.8      |
//! | iNaturalist      | ResNet-18        | 11 217 k| 42.88       | 25.4     |
//! | Full-iNaturalist | ResNet-50        | —       | 161.06      | 946.7    |
//!
//! Timing experiments need only `(M, T_c)`; the *training* experiments run
//! our JAX/Pallas models on synthetic non-iid data shaped like each dataset
//! (see DESIGN.md §3 for the substitution rationale).

use anyhow::Result;

/// A training workload: model size + computation time + dataset shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Workload {
    pub name: &'static str,
    /// model update size in bits (Table 2 "Model Size").
    pub model_bits: f64,
    /// time of one local mini-batch gradient step, ms (Table 2, Tesla P100).
    pub tc_ms: f64,
    /// batch size used by the paper.
    pub batch_size: usize,
    /// number of parameters (thousands) — documentation/reporting only.
    pub params_k: f64,
}

impl Workload {
    pub const fn shakespeare() -> Workload {
        Workload {
            name: "shakespeare",
            model_bits: 3.23e6,
            tc_ms: 389.6,
            batch_size: 512,
            params_k: 840.0,
        }
    }
    pub const fn femnist() -> Workload {
        Workload {
            name: "femnist",
            model_bits: 4.62e6,
            tc_ms: 4.6,
            batch_size: 128,
            params_k: 1207.0,
        }
    }
    pub const fn sent140() -> Workload {
        Workload {
            name: "sent140",
            model_bits: 18.38e6,
            tc_ms: 9.8,
            batch_size: 512,
            params_k: 4810.0,
        }
    }
    pub const fn inaturalist() -> Workload {
        Workload {
            name: "inaturalist",
            model_bits: 42.88e6,
            tc_ms: 25.4,
            batch_size: 16,
            params_k: 11217.0,
        }
    }
    pub const fn full_inaturalist() -> Workload {
        Workload {
            name: "full-inaturalist",
            model_bits: 161.06e6,
            tc_ms: 946.7,
            batch_size: 96,
            params_k: 25557.0,
        }
    }

    pub fn all() -> Vec<Workload> {
        vec![
            Workload::shakespeare(),
            Workload::femnist(),
            Workload::sent140(),
            Workload::inaturalist(),
            Workload::full_inaturalist(),
        ]
    }

    /// Resolve a Table-2 workload name — a thin delegate into the
    /// [`crate::spec::Resolve`] registry (pinned error format, suggestions).
    pub fn by_name(name: &str) -> Result<Workload> {
        <Workload as crate::spec::Resolve>::resolve(name)
    }

    /// Model size in megabits (for reporting).
    pub fn model_mbits(&self) -> f64 {
        self.model_bits / 1e6
    }
}

impl crate::spec::Resolve for Workload {
    const KIND: &'static str = "workload";

    fn names() -> Vec<&'static str> {
        Workload::all().iter().map(|w| w.name).collect()
    }

    fn grammar() -> String {
        Self::names().join("|")
    }

    fn parse_spec(input: &str) -> Result<Workload, crate::spec::ResolveError> {
        use crate::spec::{Resolve, ResolveError};
        for w in Workload::all() {
            if w.name == input {
                return Ok(w);
            }
        }
        Err(ResolveError::new(Self::KIND, input, "unknown workload")
            .expected(Self::grammar())
            .suggest(input, &Self::names()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalogue_matches_table2() {
        let w = Workload::inaturalist();
        assert!((w.model_mbits() - 42.88).abs() < 1e-9);
        assert!((w.tc_ms - 25.4).abs() < 1e-9);
        assert_eq!(w.batch_size, 16);
        assert!((Workload::shakespeare().tc_ms - 389.6).abs() < 1e-9);
        assert!((Workload::full_inaturalist().model_mbits() - 161.06).abs() < 1e-9);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Workload::by_name("femnist").unwrap(), Workload::femnist());
        assert!(Workload::by_name("mnist").is_err());
    }

    #[test]
    fn all_unique_names() {
        let all = Workload::all();
        let mut names: Vec<_> = all.iter().map(|w| w.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), all.len());
    }
}
