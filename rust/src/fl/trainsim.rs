//! The wall-clock time-to-accuracy engine: DPASGD interleaved with the
//! Eq.-(4) recurrence, round by round.
//!
//! The paper's core evidence (Fig. 2/3) is that per-round convergence is
//! weakly topology-sensitive, so *throughput* decides time-to-accuracy.
//! `fig2.rs` used to demonstrate that on a static network by training first
//! and reconstructing wall-clock after the fact; this engine fuses the two
//! loops so the question survives contact with a *dynamic* network:
//!
//! * every round performs the DPASGD local + mixing phases **and** one
//!   [`recurrence step`](crate::maxplus::recurrence::step) of the max-plus
//!   timeline over the *same* round communication graph, so each evaluated
//!   (loss, accuracy) point is stamped with the simulated wall-clock of the
//!   round that produced it;
//! * the round's delay digraph comes from the [`Scenario`]'s per-round
//!   [`RoundState`](crate::netsim::scenario::RoundState) — drift,
//!   congestion, stragglers, churn all bend the timeline under the training
//!   run;
//! * a [`ThroughputMonitor`] (the same one
//!   [`run_adaptive`](crate::topology::adaptive::run_adaptive) uses) can
//!   re-design the overlay mid-training from the currently measured
//!   network; the re-design swaps the communication graph **and the
//!   consensus matrix** — which the simulation-only adaptive loop cannot
//!   express — so adaptivity's effect on *learning*, not just throughput,
//!   is observable.
//!
//! Degenerate cases are exact, not approximate: under `scenario:identity`
//! with `threshold = ∞` the (round, loss) sequence is bit-identical to
//! [`dpasgd::run`] on the designed overlay, and the timeline is
//! bit-identical to [`Timeline::simulate`](crate::maxplus::recurrence::Timeline::simulate)
//! (pinned by `tests/train.rs`). The engine is deterministic for any
//! `--jobs`: all randomness flows from the caller's seed through the usual
//! forked streams.

use super::consensus::ConsensusMatrix;
use super::dpasgd::{self, silo_stream_tag, LocalTrainer, Params, RoundRecord, TrainReport};
use crate::netsim::delay::{DelayModel, OverlayDelayCsr};
use crate::netsim::scenario::{RoundState, Scenario};
use crate::netsim::timeline::DynamicTimeline;
use crate::netsim::underlay::Underlay;
use crate::topology::adaptive::{recurrence_tau_ms, ThroughputMonitor};
use crate::topology::{design_with_underlay, OverlayKind};
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

/// Knobs of one coupled training-and-timeline run.
#[derive(Clone, Debug)]
pub struct TrainSimConfig {
    /// Communication rounds to train.
    pub rounds: usize,
    /// Local steps per round (the paper's `s`). Must match the delay
    /// model's `s` for the timeline to time what the trainer computes.
    pub s: usize,
    /// Seed for the trainer streams, the scenario process, and MATCHA's
    /// round sampling (one seed, forked — the whole run replays from it).
    pub seed: u64,
    /// Evaluate the mean model every `eval_every` rounds (0 = never;
    /// the final round is always evaluated when non-zero).
    pub eval_every: usize,
    /// Use the ring-optimal ½ consensus matrix on directed rings.
    pub ring_half_weights: bool,
    /// MATCHA communication budget forwarded to the designers.
    pub c_b: f64,
    /// Monitor window (rounds) for the realized cycle-time estimate.
    pub window: usize,
    /// Re-design when the window mean exceeds `threshold × designed τ`;
    /// `INFINITY` disables re-design (the static baseline).
    pub threshold: f64,
    /// Fig.-2 compatibility: time the STAR with the non-pipelined FedAvg
    /// closed form (`τ_STAR × k`) instead of the pipelined recurrence.
    /// Only valid under the identity scenario with re-design disabled.
    pub star_closed_form: bool,
}

impl Default for TrainSimConfig {
    fn default() -> TrainSimConfig {
        TrainSimConfig {
            rounds: 100,
            s: 1,
            seed: 17,
            eval_every: 10,
            ring_half_weights: false,
            c_b: 0.5,
            window: 20,
            threshold: f64::INFINITY,
            star_closed_form: false,
        }
    }
}

impl TrainSimConfig {
    /// The static baseline: identical run, re-design disabled.
    pub fn static_baseline(&self) -> TrainSimConfig {
        TrainSimConfig {
            threshold: f64::INFINITY,
            ..self.clone()
        }
    }
}

/// One evaluated point of the loss curve, stamped with simulated time.
#[derive(Clone, Copy, Debug)]
pub struct TrainPoint {
    pub round: usize,
    /// Simulated wall-clock (ms) at which the round completed.
    pub sim_ms: f64,
    pub loss: f32,
    pub acc: f32,
}

/// A completed coupled run: the algorithmic view, the temporal view, and
/// the re-design trace, all from one pass.
#[derive(Clone, Debug)]
pub struct TrainSimReport {
    pub kind: OverlayKind,
    /// Per-round training records (same shape as [`dpasgd::run`]'s).
    pub train: TrainReport,
    /// Simulated wall-clock (ms) at which round k completed; `[0] = 0`.
    pub completion_ms: Vec<f64>,
    /// Rounds (1-based) at which the monitor re-designed the overlay.
    pub redesign_rounds: Vec<usize>,
    /// Monitor baseline after the initial design and each re-design; the
    /// first entry is the initial design's promised cycle time λ*.
    pub designed_tau_ms: Vec<f64>,
}

impl TrainSimReport {
    /// Simulated time for the whole horizon (ms).
    pub fn total_ms(&self) -> f64 {
        *self.completion_ms.last().expect("round 0 always present")
    }

    /// The initial design's promised cycle time λ* (ms).
    pub fn lambda_star_ms(&self) -> f64 {
        self.designed_tau_ms[0]
    }

    /// Simulated time (ms) to the first *evaluated* accuracy ≥ `target`.
    pub fn time_to_accuracy_ms(&self, target: f32) -> Option<f64> {
        self.train
            .rounds_to_accuracy(target)
            .map(|k| self.completion_ms[k + 1])
    }

    /// The evaluated loss-curve knots, each stamped with the wall-clock of
    /// the round that produced it.
    pub fn eval_points(&self) -> Vec<TrainPoint> {
        self.train
            .records
            .iter()
            .filter_map(|r| {
                Some(TrainPoint {
                    round: r.round,
                    sim_ms: self.completion_ms[r.round + 1],
                    loss: r.test_loss?,
                    acc: r.test_acc?,
                })
            })
            .collect()
    }
}

/// Run `cfg.rounds` rounds of DPASGD on `kind`'s overlay while simulating
/// the same rounds' wall-clock under `scenario`, re-designing (topology and
/// consensus matrix both) when the monitor trips.
pub fn run(
    trainer: &mut dyn LocalTrainer,
    kind: OverlayKind,
    dm: &DelayModel,
    net: &Underlay,
    scenario: &Scenario,
    cfg: &TrainSimConfig,
) -> Result<TrainSimReport> {
    let n = dm.n;
    ensure!(cfg.rounds > 0, "train: need at least one round");
    let star_closed = cfg.star_closed_form && kind == OverlayKind::Star;
    ensure!(
        !star_closed || (scenario.is_identity() && cfg.threshold.is_infinite()),
        "star_closed_form is a Fig.-2 compatibility mode: it requires the \
         identity scenario and threshold = ∞ (the closed form cannot absorb \
         perturbations or re-designs)"
    );

    let mut overlay = design_with_underlay(kind, dm, net, cfg.c_b)?;
    // What the timeline will realize: the closed-form FedAvg round for the
    // compatibility mode, the recurrence cycle mean otherwise.
    let tau0 = if star_closed {
        overlay.cycle_time_ms(dm)
    } else {
        recurrence_tau_ms(&overlay, dm)
    };
    let mut monitor = ThroughputMonitor::new(cfg.window, cfg.threshold, n, tau0);
    let mut designed_tau_ms = vec![tau0];
    let mut redesign_rounds = Vec::new();

    // --- training state (identical layout to dpasgd::run) ---------------
    let mut rng = Rng::new(cfg.seed);
    let w0 = trainer.init(0, cfg.seed)?;
    let p_len = w0.len();
    let mut params: Vec<Params> = vec![w0; n];
    let mut mixed: Vec<Params> = vec![vec![0.0; p_len]; n];
    let mut records = Vec::with_capacity(cfg.rounds);
    // Consensus matrix cache for static overlays: rebuilt only when a
    // re-design swaps the overlay (MATCHA rebuilds per sampled round).
    let mut consensus: Option<ConsensusMatrix> = None;

    // --- temporal state --------------------------------------------------
    let mut proc = scenario.process(n, cfg.seed);
    let mut tl = DynamicTimeline::with_capacity(n, cfg.rounds);
    let mut st = RoundState::unperturbed(n, 0);
    // Reusable CSR delay digraph for static overlays: the scenario rewrites
    // its weights in place each round, so the timeline half of the engine
    // allocates nothing per round (PR 5). Rebuilt only on re-design;
    // MATCHA keeps the materializing path (its arc set changes per round).
    // The `step_csr` calls below row-partition large cells across the
    // intra-cell pool (PR 10); the trajectory is bit-identical for any
    // worker count, so training curves never depend on threading.
    let mut ov_csr: Option<OverlayDelayCsr> = if star_closed {
        None
    } else {
        overlay.static_graph().map(|g| dm.delay_csr(g))
    };
    // Closed-form star completion series (star_closed only).
    let mut star_completion: Vec<f64> = Vec::new();
    if star_closed {
        star_completion = (0..=cfg.rounds).map(|k| tau0 * k as f64).collect();
    }

    for k in 0..cfg.rounds {
        proc.advance_into(&mut st);

        // --- local phase: s mini-batch steps per silo --------------------
        let mut loss_sum = 0.0f32;
        for (i, p) in params.iter_mut().enumerate() {
            let mut srng = rng.fork(silo_stream_tag(k, i));
            for _ in 0..cfg.s {
                loss_sum += trainer.step(i, p, &mut srng)?;
            }
        }
        let train_loss = loss_sum / (n * cfg.s) as f32;

        // --- communication phase: mix over this round's graph, and feed
        //     the exact same graph to the timeline ------------------------
        let g_round = match overlay.static_graph() {
            Some(_) => None,
            None => Some(overlay.round_graph(k, cfg.seed)),
        };
        {
            let a: &ConsensusMatrix = match (&g_round, overlay.static_graph()) {
                (Some(g), _) => {
                    consensus = Some(dpasgd::consensus_for(g, cfg.ring_half_weights));
                    consensus.as_ref().expect("just built")
                }
                (None, Some(g)) => {
                    if consensus.is_none() {
                        consensus = Some(dpasgd::consensus_for(g, cfg.ring_half_weights));
                    }
                    consensus.as_ref().expect("cached or just built")
                }
                (None, None) => unreachable!("overlay is static or random"),
            };
            a.apply_into(&params, &mut mixed);
        }
        std::mem::swap(&mut params, &mut mixed);

        // --- timeline step + monitor -------------------------------------
        if !star_closed {
            let prev = tl.last_completion_ms();
            let done = match &mut ov_csr {
                Some(ov) => {
                    st.reweight(dm, ov);
                    tl.step_csr(&ov.csr)
                }
                None => {
                    let g = g_round.as_ref().expect("sampled above");
                    tl.step(&st.delay_digraph(dm, g))
                }
            };
            if let Some(mean) = monitor.observe(done - prev) {
                // Re-measure the network as it is *now*, re-design, and
                // rebuild the consensus matrix and the reusable CSR — the
                // next round trains on the new topology.
                let measured = st.perturbed_model(dm);
                overlay = design_with_underlay(kind, &measured, net, cfg.c_b)?;
                consensus = None;
                ov_csr = overlay.static_graph().map(|g| dm.delay_csr(g));
                let new_tau = recurrence_tau_ms(&overlay, &measured);
                designed_tau_ms.push(monitor.rearm(new_tau, mean));
                redesign_rounds.push(k + 1);
            }
        }

        // --- evaluation (dpasgd cadence), stamped by eval_points() -------
        let (test_loss, test_acc) = if cfg.eval_every > 0
            && (k % cfg.eval_every == 0 || k + 1 == cfg.rounds)
        {
            let mean = dpasgd::mean_params(&params);
            let (l, acc) = trainer.eval(&mean)?;
            (Some(l), Some(acc))
        } else {
            (None, None)
        };
        records.push(RoundRecord {
            round: k,
            train_loss,
            test_loss,
            test_acc,
        });
    }

    Ok(TrainSimReport {
        kind,
        train: TrainReport {
            final_params_mean: dpasgd::mean_params(&params),
            records,
        },
        completion_ms: if star_closed {
            star_completion
        } else {
            tl.into_completion_ms()
        },
        redesign_rounds,
        designed_tau_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::dpasgd::QuadraticTrainer;
    use crate::fl::workloads::Workload;

    fn gaia() -> (Underlay, DelayModel) {
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        (net, dm)
    }

    #[test]
    fn losses_decrease_and_stamps_are_monotone_for_every_kind() {
        let (net, dm) = gaia();
        let sc = Scenario::by_name("scenario:drift:0.2").unwrap();
        for kind in OverlayKind::all() {
            let mut tr = QuadraticTrainer::new(dm.n, 8, 3);
            let cfg = TrainSimConfig {
                rounds: 60,
                eval_every: 5,
                ..Default::default()
            };
            let rep = run(&mut tr, kind, &dm, &net, &sc, &cfg).unwrap();
            assert_eq!(rep.completion_ms.len(), 61, "{kind:?}");
            assert!(
                rep.completion_ms.windows(2).all(|w| w[1] >= w[0]),
                "{kind:?}: stamps not monotone"
            );
            let first = rep.train.records[2].train_loss;
            let last = rep.train.final_train_loss();
            assert!(last < 0.5 * first, "{kind:?}: loss {first} → {last}");
            let pts = rep.eval_points();
            assert!(!pts.is_empty());
            for p in &pts {
                assert_eq!(p.sim_ms, rep.completion_ms[p.round + 1]);
            }
        }
    }

    #[test]
    fn time_to_accuracy_orders_by_throughput_on_slow_access() {
        // The paper's claim inside one engine call: same per-round
        // convergence machinery, RING reaches the target in less simulated
        // time than the STAR on a slow-access network.
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 100e6, 1e9);
        let sc = Scenario::identity();
        let mut times = Vec::new();
        for kind in [OverlayKind::Star, OverlayKind::Ring] {
            let mut tr = QuadraticTrainer::new(dm.n, 8, 3);
            let cfg = TrainSimConfig {
                rounds: 150,
                eval_every: 5,
                ..Default::default()
            };
            let rep = run(&mut tr, kind, &dm, &net, &sc, &cfg).unwrap();
            times.push(rep.time_to_accuracy_ms(0.45).expect("target reached"));
        }
        assert!(
            times[1] < 0.7 * times[0],
            "ring {} ms !< star {} ms",
            times[1],
            times[0]
        );
    }

    #[test]
    fn adaptive_redesign_fires_and_speeds_up_training_time() {
        // Under a 10× straggler the armed engine must re-design and finish
        // the horizon sooner in simulated time than its static baseline —
        // while both arms train (losses fall) through the swap.
        let (net, dm) = gaia();
        let sc = Scenario::by_name("scenario:straggler:3:x10").unwrap();
        let armed = TrainSimConfig {
            rounds: 200,
            eval_every: 10,
            threshold: 1.3,
            ..Default::default()
        };
        let mut tr_a = QuadraticTrainer::new(dm.n, 8, 3);
        let a = run(&mut tr_a, OverlayKind::Mst, &dm, &net, &sc, &armed).unwrap();
        let mut tr_s = QuadraticTrainer::new(dm.n, 8, 3);
        let s = run(
            &mut tr_s,
            OverlayKind::Mst,
            &dm,
            &net,
            &sc,
            &armed.static_baseline(),
        )
        .unwrap();
        assert!(!a.redesign_rounds.is_empty(), "monitor must trip");
        assert!(s.redesign_rounds.is_empty());
        assert!(
            a.total_ms() < 0.9 * s.total_ms(),
            "adaptive {} !< static {}",
            a.total_ms(),
            s.total_ms()
        );
        for rep in [&a, &s] {
            let first = rep.train.records[2].train_loss;
            assert!(rep.train.final_train_loss() < 0.5 * first);
        }
        // consensus swapped mid-run, yet the mean model still converges
        let opt = tr_a.optimum();
        let dist: f32 = a
            .train
            .final_params_mean
            .iter()
            .zip(&opt)
            .map(|(&w, &o)| (w - o) * (w - o))
            .sum::<f32>()
            .sqrt();
        assert!(dist < 0.8, "adaptive run diverged: {dist}");
    }

    #[test]
    fn zero_rounds_is_a_clean_error() {
        let (net, dm) = gaia();
        let mut tr = QuadraticTrainer::new(dm.n, 4, 1);
        let cfg = TrainSimConfig {
            rounds: 0,
            ..Default::default()
        };
        let r = run(&mut tr, OverlayKind::Ring, &dm, &net, &Scenario::identity(), &cfg);
        assert!(r.is_err(), "rounds = 0 must error, not panic downstream");
    }

    #[test]
    fn star_closed_form_requires_identity_and_static() {
        let (net, dm) = gaia();
        let sc = Scenario::by_name("scenario:drift:0.3").unwrap();
        let mut tr = QuadraticTrainer::new(dm.n, 4, 1);
        let cfg = TrainSimConfig {
            rounds: 10,
            star_closed_form: true,
            ..Default::default()
        };
        assert!(run(&mut tr, OverlayKind::Star, &dm, &net, &sc, &cfg).is_err());
        // non-star kinds ignore the flag entirely
        let mut tr2 = QuadraticTrainer::new(dm.n, 4, 1);
        assert!(run(&mut tr2, OverlayKind::Ring, &dm, &net, &sc, &cfg).is_ok());
    }

    #[test]
    fn star_closed_form_is_the_arithmetic_progression() {
        let (net, dm) = gaia();
        let mut tr = QuadraticTrainer::new(dm.n, 4, 1);
        let cfg = TrainSimConfig {
            rounds: 25,
            star_closed_form: true,
            ..Default::default()
        };
        let rep = run(&mut tr, OverlayKind::Star, &dm, &net, &Scenario::identity(), &cfg)
            .unwrap();
        let tau = rep.lambda_star_ms();
        for (k, c) in rep.completion_ms.iter().enumerate() {
            assert_eq!(c.to_bits(), (tau * k as f64).to_bits(), "k={k}");
        }
    }
}
