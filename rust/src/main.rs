//! `fedtopo` — leader entrypoint + experiment CLI.
//!
//! Every table and figure of the paper has a subcommand that regenerates it;
//! `fedtopo help` lists them. See README.md for the quickstart.

use anyhow::Result;
use fedtopo::coordinator::config::{ExpConfig, SessionConfig};
use fedtopo::coordinator::experiments as exp;
use fedtopo::fl::workloads::Workload;
use fedtopo::netsim::underlay::Underlay;
use fedtopo::topology::{design_with_underlay, OverlayKind};
use fedtopo::util::cli::{flag, opt, Args, OptSpec};
use fedtopo::util::table::Table;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".to_string());
    let rest = argv.get(1..).unwrap_or(&[]).to_vec();
    if let Err(e) = dispatch(&cmd, &rest) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn specs_with(extra: &[OptSpec]) -> Vec<OptSpec> {
    let mut s = ExpConfig::common_opts();
    s.extend(extra.iter().cloned());
    s
}

fn dispatch(cmd: &str, rest: &[String]) -> Result<()> {
    match cmd {
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        "workloads" | "table2" => {
            // accepts (and applies) the common options so `--jobs` works on
            // every subcommand; table2 itself has no knobs
            let args = parse(cmd, rest, &specs_with(&[]))?;
            let _ = ExpConfig::from_args(&args)?;
            let mut t = Table::new(
                "Table 2: workload catalogue",
                &["Dataset", "Batch", "Params (k)", "Model size (Mbit)", "T_c (ms)"],
            );
            for w in Workload::all() {
                t.row(vec![
                    w.name.to_string(),
                    w.batch_size.to_string(),
                    format!("{:.0}", w.params_k),
                    format!("{:.2}", w.model_mbits()),
                    format!("{:.1}", w.tc_ms),
                ]);
            }
            t.print();
            Ok(())
        }
        "table3" | "table6" | "table7" | "table9" | "cycle-table" => {
            let extra = [flag("train", "add proxy training-speedup columns")];
            let args = parse(cmd, rest, &specs_with(&extra))?;
            let mut cfg = ExpConfig::from_args(&args)?;
            match cmd {
                "table6" => cfg.s = 5,
                "table7" => cfg.s = 10,
                "table9" => {
                    cfg.workload = Workload::full_inaturalist();
                    cfg.access_bps = 1e9;
                }
                _ => {}
            }
            let t = exp::cycle_table::run(
                &cfg.workload,
                cfg.s,
                cfg.access_bps,
                cfg.core_bps,
                cfg.c_b,
                Underlay::builtin_names(),
                args.flag("train"),
            )?;
            t.print();
            Ok(())
        }
        "fig2" => {
            let extra = [
                opt("rounds", "communication rounds to train", Some("100")),
                opt("lr", "SGD learning rate", Some("0.1")),
                flag("proxy", "force the quadratic proxy trainer"),
            ];
            let args = parse(cmd, rest, &specs_with(&extra))?;
            let cfg = ExpConfig::from_args(&args)?;
            let f2 = exp::fig2::Fig2Config {
                network: if rest.iter().any(|a| a.contains("network")) {
                    cfg.network
                } else {
                    "aws-na".to_string() // paper's Fig-2 underlay
                },
                workload: cfg.workload,
                access_bps: if rest.iter().any(|a| a.contains("access")) {
                    cfg.access_bps
                } else {
                    100e6 // paper's Fig-2 access capacity
                },
                core_bps: cfg.core_bps,
                rounds: args.usize_or("rounds", 100).map_err(anyhow::Error::msg)?,
                s: cfg.s,
                c_b: cfg.c_b,
                seed: cfg.seed,
                lr: args.f64_or("lr", 0.1).map_err(anyhow::Error::msg)? as f32,
                force_proxy: args.flag("proxy"),
            };
            let reports = exp::fig2::run_all(&f2)?;
            let (a, b) = exp::fig2::render(&reports, f2.rounds);
            a.print();
            b.print();
            let mut t = Table::new(
                "Cycle time + time-to-final-round",
                &["Overlay", "cycle time (ms)", "time for all rounds (s)"],
            );
            for r in &reports {
                t.row(vec![
                    r.overlay.clone(),
                    format!("{:.0}", r.cycle_time_ms),
                    format!("{:.1}", r.wallclock_ms.last().unwrap() / 1e3),
                ]);
            }
            t.print();
            Ok(())
        }
        "fig3a" | "fig3b" => {
            let args = parse(cmd, rest, &specs_with(&[]))?;
            let mut cfg = ExpConfig::from_args(&args)?;
            if !rest.iter().any(|a| a.contains("network")) {
                cfg.network = "geant".to_string(); // paper's Fig-3 underlay
            }
            exp::fig3::run(
                &cfg.network,
                &cfg.workload,
                cfg.s,
                cfg.core_bps,
                cfg.c_b,
                cmd == "fig3b",
            )?
            .print();
            Ok(())
        }
        "fig4" => {
            let args = parse(cmd, rest, &specs_with(&[]))?;
            let mut cfg = ExpConfig::from_args(&args)?;
            if !rest.iter().any(|a| a.contains("network")) {
                cfg.network = "exodus".to_string(); // paper's Fig-4 underlay
            }
            if !rest.iter().any(|a| a.contains("access")) {
                cfg.access_bps = 1e9; // paper: all links 1 Gbps
            }
            exp::fig4::run(&cfg.network, &cfg.workload, cfg.access_bps, cfg.core_bps, cfg.c_b)?
                .print();
            Ok(())
        }
        "table10" => {
            let args = parse(cmd, rest, &specs_with(&[]))?;
            let mut cfg = ExpConfig::from_args(&args)?;
            if !rest.iter().any(|a| a.contains("network")) {
                cfg.network = "aws-na".to_string();
            }
            exp::table10::run(&cfg.network, &cfg.workload, cfg.s, cfg.core_bps)?.print();
            Ok(())
        }
        "scale" => {
            let extra = [
                opt("family", "synthetic family: waxman|ba|geo|grid", Some("waxman")),
                opt("sizes", "comma-separated silo counts", Some("50,100,200,500")),
                opt(
                    "networks",
                    "comma-separated underlay specs (overrides --family/--sizes; \
                     e.g. synth:ba:2000,gaia)",
                    None,
                ),
                opt(
                    "overlays",
                    "comma-separated overlay kinds, or 'all' (at 100k silos \
                     the O(N²)-scan designers are impractical — use e.g. \
                     star,matcha)",
                    Some("all"),
                ),
                opt(
                    "backends",
                    "comma-separated communication backends (scalar|grpc|rdma, \
                     modifiers :chunk<bytes>[k|M|G]/:over<ms>/:pipe<depth>); \
                     one row per network x backend",
                    Some("backend:scalar"),
                ),
                flag(
                    "json",
                    "emit the machine-readable report (deterministic fields \
                     only — byte-identical for any --jobs)",
                ),
            ];
            let args = parse(cmd, rest, &specs_with(&extra))?;
            let cfg = ExpConfig::from_args(&args)?;
            let overlays = args.str_or("overlays", "all");
            let kinds: Vec<OverlayKind> = if overlays == "all" {
                OverlayKind::all().to_vec()
            } else {
                split_csv(&overlays)
                    .iter()
                    .map(|n| OverlayKind::by_name(n))
                    .collect::<Result<_>>()?
            };
            let sizes: Vec<usize> = args
                .str_or("sizes", "50,100,200,500")
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("--sizes: bad count '{s}'"))
                })
                .collect::<Result<_>>()?;
            let family = match args.str("networks") {
                Some(_) => "custom".to_string(),
                None => args.str_or("family", "waxman"),
            };
            let specs = match args.str("networks") {
                Some(nets) => split_csv(&nets),
                None => sizes
                    .iter()
                    .map(|n| format!("synth:{family}:{n}:seed{}", cfg.seed))
                    .collect(),
            };
            let rows = exp::scale::sweep_rows_specs_kinds_backends(
                specs,
                kinds,
                split_csv(&args.str_or("backends", "backend:scalar")),
                &cfg.workload,
                cfg.s,
                cfg.access_bps,
                cfg.core_bps,
                cfg.c_b,
                cfg.seed,
            )?;
            if args.flag("json") {
                println!(
                    "{}",
                    exp::scale::to_json(
                        &family,
                        &cfg.workload,
                        cfg.s,
                        cfg.access_bps,
                        cfg.core_bps,
                        cfg.c_b,
                        cfg.seed,
                        &rows,
                    )
                );
            } else {
                exp::scale::render(
                    &family,
                    &cfg.workload,
                    cfg.s,
                    cfg.access_bps,
                    cfg.c_b,
                    cfg.seed,
                    &rows,
                )
                .print();
            }
            Ok(())
        }
        "train" => {
            let extra = [
                opt("rounds", "communication rounds to train", Some("60")),
                opt(
                    "eval-every",
                    "evaluate the mean model every k rounds (the final round always)",
                    Some("5"),
                ),
                opt("target", "accuracy target for time-to-accuracy", Some("0.5")),
                opt("dim", "proxy-model dimension", Some("16")),
                opt(
                    "overlays",
                    "comma-separated overlay kinds, or 'all'",
                    Some("all"),
                ),
                opt(
                    "scenarios",
                    "comma-separated scenario specs (each itself '+'-composable)",
                    Some("scenario:identity"),
                ),
                opt("seeds", "comma-separated base seeds (default: --seed)", None),
                opt(
                    "networks",
                    "comma-separated underlays (default: --network)",
                    None,
                ),
                opt(
                    "workloads",
                    "comma-separated Table-2 workloads (default: --workload)",
                    None,
                ),
                opt(
                    "backends",
                    "comma-separated communication backends \
                     (scalar|grpc|rdma[:chunk…/:over…/:pipe…]; a grid axis)",
                    Some("backend:scalar"),
                ),
                opt("window", "adaptive monitor window, rounds", Some("20")),
                opt(
                    "threshold",
                    "re-design when realized/designed cycle time exceeds this (inf = static)",
                    Some("inf"),
                ),
                flag(
                    "json",
                    "emit the machine-readable report (simulated quantities only \
                     — byte-identical for any --jobs)",
                ),
            ];
            let args = parse(cmd, rest, &specs_with(&extra))?;
            let cfg = ExpConfig::from_args(&args)?;
            let overlays = args.str_or("overlays", "all");
            let kinds = if overlays == "all" {
                OverlayKind::all().to_vec()
            } else {
                split_csv(&overlays)
                    .iter()
                    .map(|n| OverlayKind::by_name(n))
                    .collect::<Result<_>>()?
            };
            let seeds: Vec<u64> = match args.str("seeds") {
                None => vec![cfg.seed],
                Some(s) => split_csv(&s)
                    .iter()
                    .map(|v| {
                        v.parse::<u64>()
                            .map_err(|_| anyhow::anyhow!("--seeds: bad seed '{v}'"))
                    })
                    .collect::<Result<_>>()?,
            };
            let workloads = match args.str("workloads") {
                None => vec![cfg.workload.clone()],
                Some(s) => split_csv(&s)
                    .iter()
                    .map(|n| Workload::by_name(n))
                    .collect::<Result<_>>()?,
            };
            let tcfg = exp::train::TrainConfig {
                networks: args
                    .str("networks")
                    .map(|s| split_csv(&s))
                    .unwrap_or_else(|| vec![cfg.network.clone()]),
                workloads,
                backends: split_csv(&args.str_or("backends", "backend:scalar")),
                kinds,
                scenarios: split_csv(&args.str_or("scenarios", "scenario:identity")),
                seeds,
                s: cfg.s,
                access_bps: cfg.access_bps,
                core_bps: cfg.core_bps,
                c_b: cfg.c_b,
                rounds: args.usize_or("rounds", 60).map_err(anyhow::Error::msg)?,
                eval_every: args.usize_or("eval-every", 5).map_err(anyhow::Error::msg)?,
                window: args.usize_or("window", 20).map_err(anyhow::Error::msg)?,
                threshold: args
                    .f64_or("threshold", f64::INFINITY)
                    .map_err(anyhow::Error::msg)?,
                target_acc: args.f64_or("target", 0.5).map_err(anyhow::Error::msg)? as f32,
                dim: args.usize_or("dim", 16).map_err(anyhow::Error::msg)?,
            };
            let rows = exp::train::run(&tcfg)?;
            if args.flag("json") {
                println!("{}", exp::train::to_json(&tcfg, &rows));
            } else {
                exp::train::to_table(&tcfg, &rows).print();
            }
            Ok(())
        }
        "robustness" => {
            let extra = [
                opt(
                    "scenario",
                    "dynamic-network spec, e.g. scenario:straggler:3:x10 (see netsim::scenario)",
                    Some("scenario:straggler:3:x10"),
                ),
                opt("rounds", "training rounds R (time-to-round-R)", Some("200")),
                opt("window", "monitor window, rounds", Some("20")),
                opt(
                    "threshold",
                    "re-design when realized/designed cycle time exceeds this",
                    Some("1.3"),
                ),
                opt("overlay", "one overlay kind, or 'all'", Some("all")),
                opt(
                    "backends",
                    "comma-separated communication backends \
                     (scalar|grpc|rdma[:chunk…/:over…/:pipe…]; a grid axis)",
                    Some("backend:scalar"),
                ),
                opt(
                    "actions",
                    "adaptive actions to race: design | design,reroute \
                     (re-route re-solves underlay paths, overlay fixed)",
                    Some("design"),
                ),
                flag("table", "also print the human-readable table"),
            ];
            let args = parse(cmd, rest, &specs_with(&extra))?;
            let cfg = ExpConfig::from_args(&args)?;
            let overlay = args.str_or("overlay", "all");
            let kinds = if overlay == "all" {
                OverlayKind::all().to_vec()
            } else {
                vec![OverlayKind::by_name(&overlay)?]
            };
            let mut reroute = false;
            for a in split_csv(&args.str_or("actions", "design")) {
                match a.as_str() {
                    "design" => {}
                    "reroute" => reroute = true,
                    other => anyhow::bail!(
                        "--actions: unknown action '{other}' (expected design|reroute)"
                    ),
                }
            }
            let rcfg = exp::robustness::RobustnessConfig {
                network: cfg.network,
                workload: cfg.workload,
                s: cfg.s,
                access_bps: cfg.access_bps,
                core_bps: cfg.core_bps,
                c_b: cfg.c_b,
                scenario: args.str_or("scenario", "scenario:straggler:3:x10"),
                rounds: args.usize_or("rounds", 200).map_err(anyhow::Error::msg)?,
                window: args.usize_or("window", 20).map_err(anyhow::Error::msg)?,
                threshold: args.f64_or("threshold", 1.3).map_err(anyhow::Error::msg)?,
                seed: cfg.seed,
                kinds,
                backends: split_csv(&args.str_or("backends", "backend:scalar")),
                reroute,
            };
            let rows = exp::robustness::run(&rcfg)?;
            println!("{}", exp::robustness::to_json(&rcfg, &rows));
            if args.flag("table") {
                exp::robustness::to_table(&rcfg, &rows).print();
            }
            Ok(())
        }
        "bandwidth-dist" => {
            let args = parse(cmd, rest, &specs_with(&[]))?;
            let mut cfg = ExpConfig::from_args(&args)?;
            if !rest.iter().any(|a| a.contains("network")) {
                cfg.network = "geant".to_string();
            }
            exp::bandwidth::run(&cfg.network, cfg.core_bps)?.print();
            Ok(())
        }
        "enrich" => {
            // the paper's Sect.-5 future work: throughput-neutral link adds
            let extra = [
                opt("overlay", "base overlay: ring|mst|delta-mbst", Some("ring")),
                opt("slack", "relative cycle-time budget", Some("0.05")),
            ];
            let args = parse(cmd, rest, &specs_with(&extra))?;
            let cfg = ExpConfig::from_args(&args)?;
            let net = cfg.underlay()?;
            let dm = cfg.delay_model(&net);
            let kind = OverlayKind::by_name(&args.str_or("overlay", "ring"))?;
            let slack = args.f64_or("slack", 0.05).map_err(anyhow::Error::msg)?;
            let base = design_with_underlay(kind, &dm, &net, cfg.c_b)?;
            let g = base
                .static_graph()
                .ok_or_else(|| anyhow::anyhow!("enrich needs a static overlay"))?;
            let e = fedtopo::topology::enrich::enrich(g, &dm, slack);
            println!(
                "{} on {}: τ {:.1} → {:.1} ms (+{} links), SLEM {:.4} → {:.4}",
                kind.name(),
                cfg.network,
                e.base_cycle_ms,
                e.cycle_ms,
                e.added.len(),
                fedtopo::topology::enrich::slem(g),
                fedtopo::topology::enrich::slem(&e.graph),
            );
            for (i, j) in &e.added {
                println!("  + {} <-> {}", net.sites[*i].name, net.sites[*j].name);
            }
            Ok(())
        }
        "design" => {
            let extra = [
                opt("overlay", "star|mst|delta-mbst|ring|matcha|matcha+", Some("ring")),
                flag("gml", "dump the underlay as GML"),
            ];
            let args = parse(cmd, rest, &specs_with(&extra))?;
            let cfg = ExpConfig::from_args(&args)?;
            let net = cfg.underlay()?;
            if args.flag("gml") {
                print!("{}", net.to_gml());
                return Ok(());
            }
            let dm = cfg.delay_model(&net);
            let kind = OverlayKind::by_name(&args.str_or("overlay", "ring"))?;
            let overlay = design_with_underlay(kind, &dm, &net, cfg.c_b)?;
            println!(
                "{} on {} ({} silos): cycle time {:.1} ms",
                kind.name(),
                cfg.network,
                net.n_silos(),
                overlay.cycle_time_ms(&dm)
            );
            if let Some(g) = overlay.static_graph() {
                for (u, v, _) in g.edges() {
                    println!(
                        "  {} -> {}  (d_o = {:.1} ms)",
                        net.sites[u].name,
                        net.sites[v].name,
                        dm.d_o(u, v, g.out_degree(u).max(1), g.in_degree(v).max(1)),
                    );
                }
            } else {
                println!("  (random MATCHA process; sample with --seed)");
            }
            Ok(())
        }
        "serve" => {
            let mut specs = vec![
                opt(
                    "addr",
                    "listen address, host:port (port 0 = ephemeral; the \
                     bound address is announced on the first stdout line)",
                    Some("127.0.0.1:7878"),
                ),
                opt(
                    "cache",
                    "design-cache capacity, entries (0 disables; responses \
                     are byte-identical for any value)",
                    Some("64"),
                ),
            ];
            specs.extend(SessionConfig::opts());
            let args = parse(cmd, rest, &specs)?;
            SessionConfig::from_args(&args)?.install();
            let addr = args.str_or("addr", "127.0.0.1:7878");
            let cache = args.usize_or("cache", 64).map_err(anyhow::Error::msg)?;
            fedtopo::coordinator::serve::serve(&addr, cache)
        }
        other => {
            anyhow::bail!("unknown subcommand '{other}'\n\n{}", help_text());
        }
    }
}

fn parse(cmd: &str, rest: &[String], specs: &[OptSpec]) -> Result<Args> {
    Args::parse(cmd, rest, specs).map_err(anyhow::Error::msg)
}

/// Split a comma-separated CLI list, trimming whitespace around items.
fn split_csv(s: &str) -> Vec<String> {
    s.split(',').map(|p| p.trim().to_string()).collect()
}

fn help_text() -> String {
    // name lists render from the spec registry — help can never drift from
    // what the resolvers accept
    let networks = fedtopo::spec::names_line::<Underlay>();
    let overlays = fedtopo::spec::names_line::<OverlayKind>();
    let workloads = fedtopo::spec::names_line::<Workload>();
    let scenarios = fedtopo::spec::names_line::<fedtopo::netsim::scenario::Scenario>();
    let backends = fedtopo::spec::names_line::<fedtopo::netsim::backend::BackendProfile>();
    format!(
        "fedtopo — throughput-optimal topology design for cross-silo FL (NeurIPS'20 reproduction)

usage: fedtopo <command> [options]

experiment commands (one per paper table/figure):
  table2            workload catalogue (Table 2)
  table3            cycle times, 10 Gbps access, s=1 (Table 3)
  table6 / table7   same with s=5 / s=10 (Tables 6-7)
  table9            Full-iNaturalist, 1 Gbps access (Table 9)
  table10           RING vs MATCHA across C_b (Table 10)
  fig2              convergence vs rounds & wall-clock (Figure 2)
  fig3a / fig3b     access-capacity sweeps on Géant (Figure 3)
  fig4              local-steps sweep on Exodus (Figure 4)
  bandwidth-dist    available-bandwidth distribution (App. G Fig. 7)
  scale             designer τ + Karp/Howard solver time vs N on synthetic
                    underlays (--family waxman|ba|geo|grid, --sizes 50,...,
                    or explicit --networks synth:ba:2000,gaia — tiered
                    routing holds 100000 silos; --overlays star,matcha to
                    skip the O(N²)-scan designers at that scale; --json for
                    the deterministic machine-readable report)
  robustness        static vs adaptive designers under dynamic scenarios
                    (--scenario scenario:straggler:3:x10 | drift:0.3 |
                    congestion:50:x4 | churn:p0.01 | silo-churn:p0.05,
                    '+'-composable); --actions design,reroute races a
                    re-route arm (underlay paths re-solved, overlay fixed)
                    against re-design; emits JSON, --table for a table
  serve             resident coordinator daemon: newline-delimited JSON over
                    TCP (design / simulate / robustness / cycle-time /
                    measure / capabilities / ...), request batching on the
                    --jobs pool, a drift-invalidated design cache, streamed
                    round events — responses byte-identical to the one-shot
                    CLI (see coordinator::serve docs for the protocol)
  train             wall-clock time-to-accuracy: DPASGD coupled to the
                    dynamic timeline over a (networks x workloads x overlays
                    x scenarios x seeds) grid; paired seeds across overlays
                    (common random numbers), adaptive re-design via
                    --threshold (inf = static); --json for the deterministic
                    machine-readable report (simulated times only)

tools:
  design            design one overlay and print its edges / cycle time
  enrich            add throughput-neutral links to an overlay (Sect.-5
                    future work): better mixing at ~zero cycle-time cost
  cycle-table       table3 with custom --workload/--s/--access/--core
  workloads         alias for table2

common options: --network --workload --s --access --core --cb --seed --jobs
                --route-cache
(--network: {networks}, plus synth specs: synth:waxman:500:seed7)
(--workload: {workloads})
(overlay kinds: {overlays})
(scenario families: {scenarios})
(--backends on scale/train/robustness: {backends}; modifiers
 :chunk<bytes>[k|M|G] :over<ms> :pipe<depth>, e.g. backend:grpc:chunk1M)
(--jobs N parallelizes sweeps; resolution CLI > FEDTOPO_JOBS > auto, and
 output is bit-identical for any value)
(--route-cache N sets the tiered-routing row-cache capacity; resolution
 CLI > FEDTOPO_ROUTE_CACHE > 128, and output is bit-identical for any value)
(`fedtopo <cmd> --help` lists per-command options)
"
    )
}

fn print_help() {
    println!("{}", help_text());
}
