//! Throughput-neutral link enrichment — the paper's stated future work
//! (Sect. 5: "enriching the topologies found by our algorithms with
//! additional links that improve connectivity without decreasing the
//! throughput").
//!
//! Given a designed overlay with cycle time τ₀, greedily add candidate arcs
//! (best spectral gain first) whose addition keeps the *exact* cycle time —
//! recomputed via Karp with the updated degrees, since adding an arc raises
//! |N⁻|/|N⁺| shares on its endpoints — within `(1 + slack)·τ₀`. More links
//! → better consensus mixing per round (smaller spectral gap) at zero
//! throughput cost.

use crate::fl::consensus::ConsensusMatrix;
use crate::graph::DiGraph;
use crate::netsim::delay::DelayModel;

/// Result of an enrichment pass.
#[derive(Clone, Debug)]
pub struct Enriched {
    pub graph: DiGraph,
    pub base_cycle_ms: f64,
    pub cycle_ms: f64,
    pub added: Vec<(usize, usize)>,
}

/// Greedily add symmetric arc pairs to `base` without raising the cycle
/// time by more than `slack` (relative). Candidates are all non-edges,
/// tried in ascending d_c order (cheap links first).
pub fn enrich(base: &DiGraph, dm: &DelayModel, slack: f64) -> Enriched {
    let n = base.n();
    let base_tau = dm.cycle_time_ms(base);
    let budget = base_tau * (1.0 + slack);

    let mut cands: Vec<(f64, usize, usize)> = Vec::new();
    for i in 0..n {
        for j in i + 1..n {
            if !base.has_edge(i, j) && !base.has_edge(j, i) {
                cands.push((dm.edge_cap_undirected_weight(i, j), i, j));
            }
        }
    }
    cands.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then((a.1, a.2).cmp(&(b.1, b.2))));

    let mut g = base.clone();
    let mut added = Vec::new();
    let mut tau = base_tau;
    for (_, i, j) in cands {
        let mut trial = g.clone();
        trial.add_edge(i, j, 0.0);
        trial.add_edge(j, i, 0.0);
        let t = dm.cycle_time_ms(&trial);
        if t <= budget {
            g = trial;
            tau = t;
            added.push((i, j));
        }
    }
    Enriched {
        graph: g,
        base_cycle_ms: base_tau,
        cycle_ms: tau,
        added,
    }
}

/// Second-largest eigenvalue modulus (SLEM) of the local-degree consensus
/// matrix — the mixing-speed proxy ([62]; smaller = faster consensus).
/// Power iteration on the mean-deflated operator.
pub fn slem(g: &DiGraph) -> f64 {
    let n = g.n();
    if n < 2 {
        return 0.0;
    }
    let a = ConsensusMatrix::local_degree(g);
    // x orthogonal to 1-vector; iterate x ← A x, deflating the mean.
    // Random start — any structured start risks being an exact non-dominant
    // eigenvector (e.g. the alternating vector on even cycles).
    let mut rng = crate::util::rng::Rng::new(0x51E3);
    let mut x: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
    let mut lambda = 0.0f64;
    for _ in 0..300 {
        // deflate
        let mean: f32 = x.iter().sum::<f32>() / n as f32;
        x.iter_mut().for_each(|v| *v -= mean);
        let norm: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        if norm < 1e-20 {
            return 0.0;
        }
        x.iter_mut().for_each(|v| *v /= norm);
        // multiply
        let mut y = vec![0.0f32; n];
        for (i, yi) in y.iter_mut().enumerate() {
            for &(j, w) in &a.rows[i] {
                *yi += w * x[j];
            }
        }
        lambda = y.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
        x = y;
    }
    lambda
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;
    use crate::topology::{design, OverlayKind};

    fn setup(access: f64) -> (DelayModel, DiGraph) {
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, access, 1e9);
        let ring = design(OverlayKind::Ring, &dm, 0.5).unwrap();
        (dm, ring.static_graph().unwrap().clone())
    }

    #[test]
    fn enrichment_never_exceeds_budget() {
        let (dm, ring) = setup(10e9);
        let e = enrich(&ring, &dm, 0.05);
        assert!(e.cycle_ms <= 1.05 * e.base_cycle_ms + 1e-9);
        assert!(e.graph.m() >= ring.m());
        assert!(e.graph.is_strongly_connected());
    }

    #[test]
    fn enrichment_adds_links_when_slack_allows() {
        // On fast access the ring has headroom: enrichment should find at
        // least one extra link within 10% slack.
        let (dm, ring) = setup(100e9);
        let e = enrich(&ring, &dm, 0.10);
        assert!(
            !e.added.is_empty(),
            "expected extra links, τ {} → {}",
            e.base_cycle_ms,
            e.cycle_ms
        );
    }

    #[test]
    fn enrichment_improves_mixing() {
        let (dm, ring) = setup(100e9);
        let e = enrich(&ring, &dm, 0.10);
        if !e.added.is_empty() {
            let before = slem(&ring);
            let after = slem(&e.graph);
            assert!(
                after < before + 1e-9,
                "SLEM should not worsen: {before} → {after}"
            );
        }
    }

    #[test]
    fn zero_slack_on_tight_ring_adds_little_or_nothing() {
        // At slow access every extra link splits the uplink → raises τ;
        // with zero slack the enrichment must refuse.
        let (dm, ring) = setup(100e6);
        let e = enrich(&ring, &dm, 0.0);
        assert!(e.cycle_ms <= e.base_cycle_ms + 1e-9);
        assert!(e.added.is_empty(), "added {:?}", e.added);
    }

    #[test]
    fn slem_sane_on_known_graphs() {
        // complete graph mixes in one step → SLEM ≈ 0 under uniform weights;
        // ring mixes slowly → SLEM close to 1.
        let mut ring = DiGraph::new(8);
        for i in 0..8 {
            ring.add_edge(i, (i + 1) % 8, 0.0);
            ring.add_edge((i + 1) % 8, i, 0.0);
        }
        let s_ring = slem(&ring);
        assert!(s_ring > 0.5 && s_ring <= 1.0 + 1e-9, "{s_ring}");
        let mut complete = DiGraph::new(8);
        for i in 0..8 {
            for j in 0..8 {
                if i != j {
                    complete.add_edge(i, j, 0.0);
                }
            }
        }
        let s_k = slem(&complete);
        assert!(s_k < s_ring, "complete {s_k} vs ring {s_ring}");
    }
}
