//! MST overlay — Prop. 3.1.
//!
//! On edge-capacitated networks with an undirected overlay requirement, the
//! MCT solution is a minimum weight spanning tree of the symmetrized
//! connectivity graph G_c^(u) with weights
//! `d_c^(u)(i,j) = (d_c(i,j) + d_c(j,i)) / 2`. Tree overlays only have
//! 2-circuits, so the cycle time is the maximum edge weight (Lemma E.2) and
//! the MST — which is also a minimum *bottleneck* spanning tree — minimizes
//! it (cut property).
//!
//! PR 5: the designer runs [`implicit_prim`] on the *implicit* complete
//! connectivity graph (weight callback, O(N) memory) instead of
//! materializing the Θ(N²)-edge G_c^(u). Selection order and tie-breaks
//! are identical to Prim over [`connectivity_undirected`] — the dense
//! path, retained as the equivalence oracle (`tests/csr_equiv.rs` pins the
//! trees bit-identical).

use crate::graph::csr::implicit_prim;
use crate::graph::{DiGraph, UnGraph};
use crate::netsim::delay::DelayModel;

/// The G_c^(u) of Prop. 3.1 over a complete connectivity graph —
/// **materialized**. Dense oracle / small-n analysis only; the designer
/// itself never builds this.
pub fn connectivity_undirected(dm: &DelayModel) -> UnGraph {
    UnGraph::complete_with(dm.n, |i, j| dm.edge_cap_undirected_weight(i, j))
}

/// Design the MST overlay (undirected tree → symmetric digraph).
pub fn design(dm: &DelayModel) -> DiGraph {
    design_tree(dm).to_digraph()
}

/// The undirected tree itself (used by Algorithm 1 and tests). Implicit-Kₙ
/// Prim: O(N) memory, O(N²) weight evaluations.
pub fn design_tree(dm: &DelayModel) -> UnGraph {
    let mut tree = UnGraph::new(dm.n);
    for (u, v, w) in implicit_prim(dm.n, |i, j| dm.edge_cap_undirected_weight(i, j)) {
        tree.add_edge(u, v, w);
    }
    tree
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;

    fn dm(name: &str, access: f64) -> DelayModel {
        let net = Underlay::builtin(name).unwrap();
        DelayModel::new(&net, &Workload::inaturalist(), 1, access, 1e9)
    }

    #[test]
    fn implicit_design_matches_dense_prim_bitwise() {
        use crate::graph::mst::prim;
        for name in ["gaia", "geant"] {
            let m = dm(name, 10e9);
            let implicit = design_tree(&m);
            let dense = prim(&connectivity_undirected(&m)).unwrap();
            assert_eq!(implicit.m(), dense.m(), "{name}");
            for (a, b) in implicit.edges().iter().zip(dense.edges()) {
                assert_eq!((a.0, a.1), (b.0, b.1), "{name}");
                assert_eq!(a.2.to_bits(), b.2.to_bits(), "{name}");
            }
        }
    }

    #[test]
    fn tree_shape() {
        let m = dm("gaia", 10e9);
        let g = design(&m);
        assert_eq!(g.m(), 2 * 10); // tree on 11 nodes, both directions
        assert!(g.is_strongly_connected());
    }

    #[test]
    fn prop31_optimality_vs_random_trees() {
        // The MST's cycle time must not exceed any other spanning tree's,
        // when the network is edge-capacitated (access ≫ core).
        let m = dm("gaia", 100e9);
        assert!(m.is_edge_capacitated());
        let mst_tau = m.cycle_time_ms(&design(&m));
        let gc = connectivity_undirected(&m);
        let mut rng = crate::util::rng::Rng::new(99);
        for _ in 0..30 {
            // random spanning tree via randomized Kruskal
            let mut order: Vec<usize> = (0..gc.m()).collect();
            rng.shuffle(&mut order);
            let mut parent: Vec<usize> = (0..gc.n()).collect();
            fn find(p: &mut Vec<usize>, x: usize) -> usize {
                if p[x] != x {
                    let r = find(p, p[x]);
                    p[x] = r;
                }
                p[x]
            }
            let mut tree = UnGraph::new(gc.n());
            for &ei in &order {
                let (a, b, w) = gc.edge(ei);
                let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
                if ra != rb {
                    parent[ra] = rb;
                    tree.add_edge(a, b, w);
                }
            }
            let tau = m.cycle_time_ms(&tree.to_digraph());
            assert!(
                mst_tau <= tau + 1e-6,
                "random tree beat MST: {tau} < {mst_tau}"
            );
        }
    }

    #[test]
    fn tree_cycle_time_close_to_bottleneck() {
        // Lemma E.2: on a tree the only circuits are 2-circuits (and the
        // compute self-loops), so τ = max(bottleneck d_o mean, s·T_c). With
        // degree-dependent access sharing the realized τ can only exceed
        // the designer's edge-capacitated weight.
        let m = dm("geant", 10e9);
        let tree = design_tree(&m);
        let tau = m.cycle_time_ms(&tree.to_digraph());
        assert!(tau + 1e-9 >= tree.bottleneck());
    }

    #[test]
    fn mst_beats_star_on_every_builtin() {
        for name in Underlay::builtin_names() {
            let m = dm(name, 10e9);
            let mst_tau = m.cycle_time_ms(&design(&m));
            let star_tau = m.cycle_time_ms(&super::super::star::design(&m));
            assert!(
                mst_tau <= star_tau + 1e-6,
                "{name}: mst {mst_tau} vs star {star_tau}"
            );
        }
    }
}
