//! RING overlay — Christofides' algorithm (Props. 3.3 / 3.6).
//!
//! A directed Hamiltonian ring splits every silo's uplink and downlink zero
//! ways (degree 1 in and out), so in the node-capacitated regime it is up to
//! 2N× faster than the STAR (App. B). Christofides gives a 1.5-approximation
//! of the optimal tour, hence a 3N-approximation of MCT on Euclidean
//! connectivity graphs (edge-capacitated: Prop. 3.3; node-capacitated with
//! the Prop.-3.6 weights `d'(i,j) = s·T_c(i)+l(i,j)+M/min(C_UP,C_DN,A)`).
//!
//! Pipeline: MST → odd-degree vertices → min-weight perfect matching
//! (greedy — the standard practical stand-in for Blossom; the 1.5 factor
//! degrades to 2 in the worst case, which Prop.-3.3's 2N·1.5 bound absorbs)
//! → Eulerian circuit (Hierholzer on the multigraph) → shortcut to a
//! Hamiltonian cycle → optional 2-opt polish → orient the ring in the
//! direction with the smaller exact cycle time.
//!
//! PR 5: the MST phase runs [`implicit_prim`] on the implicit Kₙ (O(N)
//! memory, no materialized complete graph) and the matching phase runs the
//! pair-list-free [`nn_greedy_matching`] — both bit-identical to the dense
//! constructions ([`greedy_matching_sorted`] stays as the matching oracle;
//! `tests/csr_equiv.rs` pins whole designed rings).

use crate::graph::csr::{implicit_prim, nn_greedy_matching};
use crate::graph::{DiGraph, UnGraph};
use crate::netsim::delay::DelayModel;

/// Symmetrized Prop.-3.6 tour weights.
fn tour_weight(dm: &DelayModel, i: usize, j: usize) -> f64 {
    0.5 * (dm.ring_weight(i, j) + dm.ring_weight(j, i))
}

/// Greedy minimum-weight perfect matching on `odd` (even length) under `w`
/// via the materialized O(f²) pair list — the **dense oracle** for
/// [`nn_greedy_matching`], which the designer now uses.
pub fn greedy_matching_sorted(
    odd: &[usize],
    w: &dyn Fn(usize, usize) -> f64,
) -> Vec<(usize, usize)> {
    let mut pairs: Vec<(f64, usize, usize)> = Vec::new();
    for (a, &i) in odd.iter().enumerate() {
        for &j in &odd[a + 1..] {
            pairs.push((w(i, j), i, j));
        }
    }
    pairs.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap().then((x.1, x.2).cmp(&(y.1, y.2))));
    let mut used = std::collections::HashSet::new();
    let mut matching = Vec::new();
    for (_, i, j) in pairs {
        if !used.contains(&i) && !used.contains(&j) {
            used.insert(i);
            used.insert(j);
            matching.push((i, j));
        }
    }
    matching
}

/// Hierholzer's algorithm for an Eulerian circuit on a connected multigraph
/// given as adjacency lists of (neighbor, edge-id).
fn eulerian_circuit(n: usize, edges: &[(usize, usize)]) -> Vec<usize> {
    let mut adj: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
    for (id, &(u, v)) in edges.iter().enumerate() {
        adj[u].push((v, id));
        adj[v].push((u, id));
    }
    let mut used = vec![false; edges.len()];
    let mut ptr = vec![0usize; n];
    let mut stack = vec![0usize];
    let mut circuit = Vec::with_capacity(edges.len() + 1);
    while let Some(&v) = stack.last() {
        let mut advanced = false;
        while ptr[v] < adj[v].len() {
            let (to, id) = adj[v][ptr[v]];
            ptr[v] += 1;
            if !used[id] {
                used[id] = true;
                stack.push(to);
                advanced = true;
                break;
            }
        }
        if !advanced {
            circuit.push(v);
            stack.pop();
        }
    }
    circuit.reverse();
    circuit
}

/// Christofides tour over the complete graph on `n` nodes with weights `w`.
/// Returns the Hamiltonian cycle as a node sequence (first node repeated at
/// the end is *not* included).
pub fn christofides_tour(n: usize, w: &dyn Fn(usize, usize) -> f64) -> Vec<usize> {
    assert!(n >= 3, "a ring needs at least 3 nodes");
    // MST over the *implicit* complete graph — O(n) memory (PR 5); the
    // selection sequence equals dense Prim on `complete_with` bit for bit.
    let mut tree = UnGraph::new(n);
    for (u, v, wt) in implicit_prim(n, |i, j| w(i, j)) {
        tree.add_edge(u, v, wt);
    }

    // Odd-degree vertices + greedy matching (pair-list-free form).
    let odd: Vec<usize> = (0..n).filter(|&v| tree.degree(v) % 2 == 1).collect();
    debug_assert!(odd.len() % 2 == 0, "handshake lemma");
    let matching = nn_greedy_matching(&odd, |i, j| w(i, j));

    // Multigraph = MST ∪ matching → Eulerian circuit → shortcut.
    let mut multi: Vec<(usize, usize)> = tree.edges().iter().map(|&(u, v, _)| (u, v)).collect();
    multi.extend(matching);
    let circuit = eulerian_circuit(n, &multi);
    let mut seen = vec![false; n];
    let mut tour = Vec::with_capacity(n);
    for &v in &circuit {
        if !seen[v] {
            seen[v] = true;
            tour.push(v);
        }
    }
    debug_assert_eq!(tour.len(), n, "shortcut must visit all nodes");
    tour
}

/// 2-opt improvement: repeatedly reverse tour segments while the total
/// symmetric weight decreases. O(n²) per sweep, a few sweeps in practice.
pub fn two_opt(tour: &mut Vec<usize>, w: &dyn Fn(usize, usize) -> f64) {
    let n = tour.len();
    if n < 4 {
        return;
    }
    let mut improved = true;
    let mut sweeps = 0;
    while improved && sweeps < 30 {
        improved = false;
        sweeps += 1;
        for a in 0..n - 1 {
            for b in a + 2..n {
                // edges (tour[a], tour[a+1]) and (tour[b], tour[(b+1)%n])
                let (i, inext) = (tour[a], tour[a + 1]);
                let (j, jnext) = (tour[b], tour[(b + 1) % n]);
                if i == jnext {
                    continue;
                }
                let before = w(i, inext) + w(j, jnext);
                let after = w(i, j) + w(inext, jnext);
                if after + 1e-12 < before {
                    tour[a + 1..=b].reverse();
                    improved = true;
                }
            }
        }
    }
}

/// Total symmetric tour weight (for tests / diagnostics).
pub fn tour_cost(tour: &[usize], w: &dyn Fn(usize, usize) -> f64) -> f64 {
    let n = tour.len();
    (0..n).map(|k| w(tour[k], tour[(k + 1) % n])).sum()
}

/// Design the directed RING overlay. `polish` enables a 2-opt pass on top
/// of plain Christofides (off for paper fidelity; the ablation bench
/// measures its effect).
pub fn design(dm: &DelayModel, polish: bool) -> DiGraph {
    let n = dm.n;
    if n == 2 {
        let mut g = DiGraph::new(2);
        g.add_edge(0, 1, 0.0);
        g.add_edge(1, 0, 0.0);
        return g;
    }
    let w = |i: usize, j: usize| tour_weight(dm, i, j);
    let mut tour = christofides_tour(n, &w);
    if polish {
        two_opt(&mut tour, &w);
    }
    // Orient in the direction with the smaller exact cycle time (d' is
    // asymmetric when computation times differ).
    let build = |seq: &[usize]| {
        let mut g = DiGraph::new(n);
        for k in 0..n {
            g.add_edge(seq[k], seq[(k + 1) % n], 0.0);
        }
        g
    };
    let fwd = build(&tour);
    let mut rev_seq = tour.clone();
    rev_seq.reverse();
    let rev = build(&rev_seq);
    if dm.cycle_time_ms(&fwd) <= dm.cycle_time_ms(&rev) {
        fwd
    } else {
        rev
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;
    use crate::util::prop::{check, Gen};

    fn dm(name: &str, access: f64) -> DelayModel {
        let net = Underlay::builtin(name).unwrap();
        DelayModel::new(&net, &Workload::inaturalist(), 1, access, 1e9)
    }

    #[test]
    fn nn_matching_matches_sorted_oracle_on_designer_weights() {
        // The pair-list-free matching must reproduce the dense sorted
        // greedy exactly on real tour weights (ties included).
        for name in ["gaia", "geant", "ebone"] {
            let m = dm(name, 10e9);
            let w = |i: usize, j: usize| tour_weight(&m, i, j);
            let mut tree = UnGraph::new(m.n);
            for (u, v, wt) in implicit_prim(m.n, |i, j| w(i, j)) {
                tree.add_edge(u, v, wt);
            }
            let odd: Vec<usize> = (0..m.n).filter(|&v| tree.degree(v) % 2 == 1).collect();
            let fast = nn_greedy_matching(&odd, |i, j| w(i, j));
            let slow = greedy_matching_sorted(&odd, &w);
            assert_eq!(fast, slow, "{name}");
        }
    }

    #[test]
    fn ring_shape() {
        let m = dm("gaia", 10e9);
        let g = design(&m, false);
        assert!(g.is_strongly_connected());
        for i in 0..m.n {
            assert_eq!(g.out_degree(i), 1);
            assert_eq!(g.in_degree(i), 1);
        }
    }

    #[test]
    fn eulerian_circuit_covers_all_edges() {
        // square with a diagonal doubled to keep degrees even
        let edges = vec![(0, 1), (1, 2), (2, 3), (3, 0), (0, 2), (0, 2)];
        let circ = eulerian_circuit(4, &edges);
        assert_eq!(circ.len(), edges.len() + 1);
        assert_eq!(circ.first(), circ.last());
    }

    #[test]
    fn christofides_on_euclidean_grid_within_bound() {
        // 3×3 grid of points, Euclidean distances: optimal tour is 8 for
        // unit spacing... (actually 8 + √2 − ... just check the 1.5/2 bound
        // versus a brute-force optimum on 8 points).
        let pts: Vec<(f64, f64)> = (0..8)
            .map(|k| ((k % 4) as f64, (k / 4) as f64))
            .collect();
        let w = |i: usize, j: usize| {
            let (dx, dy) = (pts[i].0 - pts[j].0, pts[i].1 - pts[j].1);
            (dx * dx + dy * dy).sqrt()
        };
        let tour = christofides_tour(8, &w);
        let cost = tour_cost(&tour, &w);
        // brute force optimum
        let mut perm: Vec<usize> = (1..8).collect();
        let mut best = f64::INFINITY;
        fn rec(
            perm: &mut Vec<usize>,
            k: usize,
            w: &dyn Fn(usize, usize) -> f64,
            best: &mut f64,
        ) {
            if k == perm.len() {
                let mut seq = vec![0usize];
                seq.extend(perm.iter());
                let mut c = 0.0;
                for i in 0..seq.len() {
                    c += w(seq[i], seq[(i + 1) % seq.len()]);
                }
                if c < *best {
                    *best = c;
                }
                return;
            }
            for i in k..perm.len() {
                perm.swap(k, i);
                rec(perm, k + 1, w, best);
                perm.swap(k, i);
            }
        }
        rec(&mut perm, 0, &w, &mut best);
        assert!(
            cost <= 2.0 * best + 1e-9,
            "christofides {cost} vs optimal {best}"
        );
    }

    #[test]
    fn two_opt_never_worsens() {
        check("2-opt monotone", 30, |g: &mut Gen| {
            let n = g.usize(4, 15);
            let pts: Vec<(f64, f64)> =
                (0..n).map(|_| (g.f64(0.0, 100.0), g.f64(0.0, 100.0))).collect();
            let w = |i: usize, j: usize| {
                let (dx, dy) = (pts[i].0 - pts[j].0, pts[i].1 - pts[j].1);
                (dx * dx + dy * dy).sqrt()
            };
            let mut tour: Vec<usize> = (0..n).collect();
            g.rng.shuffle(&mut tour);
            let before = tour_cost(&tour, &w);
            two_opt(&mut tour, &w);
            let after = tour_cost(&tour, &w);
            assert!(after <= before + 1e-9);
            // still a permutation
            let mut sorted = tour.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n).collect::<Vec<_>>());
        });
    }

    #[test]
    fn ring_dominates_in_slow_access_regime() {
        // Fig. 3a: below ~6 Gbps access the RING has the best throughput.
        let m = dm("geant", 100e6);
        let ring_tau = m.cycle_time_ms(&design(&m, false));
        let star_tau = m.cycle_time_ms(&super::super::star::design(&m));
        let mst_tau = m.cycle_time_ms(&super::super::mst::design(&m));
        assert!(ring_tau < star_tau, "ring {ring_tau} < star {star_tau}");
        assert!(ring_tau <= mst_tau + 1e-6, "ring {ring_tau} ≤ mst {mst_tau}");
    }

    #[test]
    fn appendix_b_ring_asymptote() {
        // Slow homogeneous access: τ_RING → M/C (App. B).
        let net = Underlay::builtin("gaia").unwrap();
        let wl = Workload::inaturalist();
        let m = DelayModel::new(&net, &wl, 1, 10e6, 1e9); // very slow access
        let tau = m.cycle_time_ms(&design(&m, false));
        let asym = wl.model_bits / 10e6 * 1e3; // M/C in ms = 4288
        assert!(
            (tau - asym).abs() < 0.15 * asym,
            "τ={tau} vs M/C={asym}"
        );
    }

    #[test]
    fn polish_helps_or_ties() {
        for name in ["gaia", "aws-na"] {
            let m = dm(name, 10e9);
            let plain = m.cycle_time_ms(&design(&m, false));
            let polished = m.cycle_time_ms(&design(&m, true));
            assert!(polished <= plain + 1e-6, "{name}");
        }
    }

    #[test]
    fn two_node_ring() {
        let net = Underlay::builtin("gaia").unwrap();
        let wl = Workload::femnist();
        let full = DelayModel::new(&net, &wl, 1, 1e9, 1e9);
        // restrict to 2 silos by constructing a tiny model
        let m = DelayModel::with_parts(
            1,
            wl.model_bits,
            vec![wl.tc_ms; 2],
            vec![1e9; 2],
            vec![1e9; 2],
            crate::netsim::routing::Routes::from_dense(
                &[vec![0.0, 10.0], vec![10.0, 0.0]],
                &[vec![f64::INFINITY, 1e9], vec![1e9, f64::INFINITY]],
                &[vec![0, 1], vec![1, 0]],
                Vec::new(),
            ),
        );
        let g = design(&m, false);
        assert!(g.is_strongly_connected());
        assert_eq!(g.m(), 2);
        let _ = full; // silence
    }
}
