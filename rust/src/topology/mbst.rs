//! δ-MBST overlay — Algorithm 1 (Prop. 3.5).
//!
//! On node-capacitated networks, access-link sharing makes a node's delay
//! grow with its overlay degree, so MCT (restricted to undirected overlays)
//! reduces to degree-bounded minimum-bottleneck spanning trees (δ-MBST),
//! which is NP-hard (Prop. 3.4). Algorithm 1 combines:
//!
//! 1. the symmetrized node-capacitated weights `d_c^(u)` (lines 1-4);
//! 2. the 2-MBST 3-approximation of Andersen & Ras: Hamiltonian path in the
//!    cube of an MST (lines 6-9);
//! 3. δ-PRIM trees for δ = 3..N as further candidates (lines 10-12);
//! 4. the candidate with the smallest *exact* cycle time wins (line 13).
//!
//! Overall guarantee: 6-approximation when G_c is Euclidean and
//! `C_UP(i) ≤ min(C_DN(j)/N, A(i',j'))` (Prop. 3.5).

use crate::graph::csr::{implicit_delta_prim, implicit_prim};
use crate::graph::hamiltonian::ham_path_any;
use crate::graph::{DiGraph, UnGraph};
use crate::netsim::delay::DelayModel;

/// The node-capacitated G_c^(u) (Algorithm 1, lines 1-4) — **materialized**.
/// Dense oracle / small-n analysis only (PR 5): the designer runs the
/// implicit-Kₙ variants below and never builds the Θ(N²) edge list.
pub fn connectivity_undirected(dm: &DelayModel) -> UnGraph {
    UnGraph::complete_with(dm.n, |i, j| dm.node_cap_undirected_weight(i, j))
}

/// Rebuild an [`UnGraph`] tree from implicit-Prim edge triples.
fn tree_from(n: usize, edges: Vec<(usize, usize, f64)>) -> UnGraph {
    let mut t = UnGraph::new(n);
    for (u, v, w) in edges {
        t.add_edge(u, v, w);
    }
    t
}

/// All candidate overlays considered by Algorithm 1 (exposed for the
/// ablation bench): the Hamiltonian-path 2-BST plus δ-PRIM for δ = 3..N.
/// All candidates are grown on the *implicit* complete graph (weight
/// callback, O(N) memory) with selection order bit-identical to the dense
/// constructions over [`connectivity_undirected`] (`tests/csr_equiv.rs`).
pub fn candidates(dm: &DelayModel) -> Vec<(String, UnGraph)> {
    let n = dm.n;
    let mut out = Vec::new();

    // 2-MBST approximation: Hamiltonian path in the cube of the MST.
    let tree = tree_from(
        n,
        implicit_prim(n, |i, j| dm.node_cap_undirected_weight(i, j)),
    );
    let path_nodes = ham_path_any(&tree);
    let mut path = UnGraph::new(n);
    for w in path_nodes.windows(2) {
        // Same operand order the materialized G_c^(u) stored: w(min, max).
        let (a, b) = (w[0].min(w[1]), w[0].max(w[1]));
        path.add_edge(w[0], w[1], dm.node_cap_undirected_weight(a, b));
    }
    out.push(("ham-path(2-BST)".to_string(), path));

    // δ-PRIM candidates.
    for delta in 3..=n.max(3) {
        let cand = implicit_delta_prim(n, delta, |i, j| dm.node_cap_undirected_weight(i, j));
        if let Some(es) = cand {
            out.push((format!("{delta}-prim"), tree_from(n, es)));
            // δ-PRIM with δ ≥ max MST degree equals the MST; stop early.
            if delta >= tree.max_degree() {
                break;
            }
        }
    }
    out
}

/// Design the δ-MBST overlay: best candidate by exact cycle time (line 13).
pub fn design(dm: &DelayModel) -> DiGraph {
    let (_, best) = design_named(dm);
    best.to_digraph()
}

/// Like [`design`] but also reports which candidate won.
pub fn design_named(dm: &DelayModel) -> (String, UnGraph) {
    let mut best: Option<(String, UnGraph, f64)> = None;
    for (name, cand) in candidates(dm) {
        let tau = dm.cycle_time_ms(&cand.to_digraph());
        match &best {
            None => best = Some((name, cand, tau)),
            Some((_, _, t)) if tau < *t => best = Some((name, cand, tau)),
            _ => {}
        }
    }
    let (name, g, _) = best.expect("at least the ham-path candidate exists");
    (name, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;

    fn dm(name: &str, access: f64) -> DelayModel {
        let net = Underlay::builtin(name).unwrap();
        DelayModel::new(&net, &Workload::inaturalist(), 1, access, 1e9)
    }

    #[test]
    fn implicit_candidates_match_dense_algorithm1_bitwise() {
        // The dense oracle: Algorithm 1 exactly as pre-PR-5, over the
        // materialized G_c^(u).
        use crate::graph::hamiltonian::ham_path_any;
        use crate::graph::mst::{delta_prim, prim};
        for name in ["gaia", "geant"] {
            let m = dm(name, 100e6);
            let gcu = connectivity_undirected(&m);
            let n = gcu.n();
            let mut dense: Vec<(String, UnGraph)> = Vec::new();
            let tree = prim(&gcu).unwrap();
            let path_nodes = ham_path_any(&tree);
            let mut path = UnGraph::new(n);
            for w in path_nodes.windows(2) {
                path.add_edge(w[0], w[1], gcu.weight(w[0], w[1]).unwrap());
            }
            dense.push(("ham-path(2-BST)".to_string(), path));
            for delta in 3..=n.max(3) {
                if let Some(t) = delta_prim(&gcu, delta) {
                    dense.push((format!("{delta}-prim"), t));
                    if delta >= tree.max_degree() {
                        break;
                    }
                }
            }
            let implicit = candidates(&m);
            assert_eq!(implicit.len(), dense.len(), "{name}");
            for ((ni, gi), (nd, gd)) in implicit.iter().zip(&dense) {
                assert_eq!(ni, nd, "{name}");
                assert_eq!(gi.m(), gd.m(), "{name}/{ni}");
                for (a, b) in gi.edges().iter().zip(gd.edges()) {
                    assert_eq!((a.0, a.1), (b.0, b.1), "{name}/{ni}");
                    assert_eq!(a.2.to_bits(), b.2.to_bits(), "{name}/{ni}");
                }
            }
        }
    }

    #[test]
    fn result_is_spanning_tree_or_path() {
        let m = dm("gaia", 100e6);
        let (_, g) = design_named(&m);
        assert!(g.is_connected());
        assert_eq!(g.m(), m.n - 1);
    }

    #[test]
    fn slow_access_prefers_low_degree() {
        // In the node-capacitated regime, high-degree trees pay degree × M/C
        // on their bottleneck edge, so the winner should have small degree.
        let m = dm("geant", 100e6);
        let (name, g) = design_named(&m);
        assert!(
            g.max_degree() <= 4,
            "winner {name} has degree {}",
            g.max_degree()
        );
    }

    #[test]
    fn fast_access_matches_mst() {
        // Table 3 note: "In this particular setting, δ-MBST selects the same
        // overlay as MST" — with 10 Gbps access the degree penalty vanishes
        // and cycle times coincide (the trees may differ by ties).
        for name in ["gaia", "aws-na"] {
            let m = dm(name, 10e9);
            let mbst_tau = m.cycle_time_ms(&design(&m));
            let mst_tau = m.cycle_time_ms(&super::super::mst::design(&m));
            assert!(
                (mbst_tau - mst_tau).abs() <= 0.15 * mst_tau,
                "{name}: δ-MBST {mbst_tau} vs MST {mst_tau}"
            );
        }
    }

    #[test]
    fn beats_or_ties_plain_mst_when_node_capacitated() {
        for name in ["gaia", "geant"] {
            let m = dm(name, 100e6);
            let mbst_tau = m.cycle_time_ms(&design(&m));
            let mst_tau = m.cycle_time_ms(&super::super::mst::design(&m));
            assert!(
                mbst_tau <= mst_tau + 1e-6,
                "{name}: δ-MBST {mbst_tau} should ≤ MST {mst_tau}"
            );
        }
    }

    #[test]
    fn candidates_all_spanning() {
        let m = dm("gaia", 1e9);
        for (name, c) in candidates(&m) {
            assert!(c.is_connected(), "{name} disconnected");
            assert_eq!(c.m(), m.n - 1, "{name} not a tree/path");
        }
    }
}
