//! STAR overlay — the classic server-client baseline.
//!
//! Every silo exchanges with a central hub that performs the aggregation
//! (FedAvg's orchestrator as a special case of DPASGD where the hub's loss
//! is constant). The hub is "the node with the highest load centrality"
//! (Table 3 description) measured on the underlay-routed latency metric —
//! on complete synthetic underlays (where betweenness is degenerate) we fall
//! back to the 1-median: the silo minimizing the worst round-trip delay,
//! which is the throughput-optimal hub placement for a star.

use crate::graph::centrality::betweenness;
use crate::graph::{DiGraph, UnGraph};
use crate::netsim::delay::DelayModel;

/// Largest network on which the hub runs the Brandes betweenness pass
/// (O(V·E log V) on the complete routed-latency graph — ~V³ log V). Beyond
/// it the O(V²) minimax fallback is both the only affordable choice and the
/// throughput-relevant one.
const BETWEENNESS_MAX_N: usize = 200;

/// Pick the hub: highest betweenness on the latency graph; ties / degenerate
/// all-zero betweenness (complete graphs) fall back to minimax round-trip.
/// Synthetic underlays past `BETWEENNESS_MAX_N` silos go straight to the
/// minimax rule (Brandes on a complete 1000-node graph would dominate the
/// whole design).
pub fn choose_hub(dm: &DelayModel) -> usize {
    let n = dm.n;
    if n <= BETWEENNESS_MAX_N {
        let lat = UnGraph::complete_with(n, |i, j| {
            (0.5 * (dm.routes.lat_ms(i, j) + dm.routes.lat_ms(j, i))).max(1e-9)
        });
        let bc = betweenness(&lat);
        let max_bc = bc.iter().cloned().fold(0.0f64, f64::max);
        if max_bc > 1e-9 {
            let mut best = 0;
            for i in 1..n {
                if bc[i] > bc[best] + 1e-12 {
                    best = i;
                }
            }
            return best;
        }
    }
    // Degenerate (complete underlay): minimax star delay. On the landmark
    // routing tier the candidate set shrinks from all N silos to the ~N/64
    // region landmarks (already chosen as geographic medoids) — the O(N²)
    // scan becomes O(R·N), which is what keeps the 100 000-silo star design
    // affordable; below the tier gate the exhaustive scan is unchanged.
    let candidates: Vec<usize> = match dm.routes.landmark_nodes() {
        Some(lms) => lms.iter().map(|&l| l as usize).collect(),
        None => (0..n).collect(),
    };
    let mut best = candidates[0];
    let mut best_cost = f64::INFINITY;
    for &hub in &candidates {
        let worst = (0..n)
            .filter(|&i| i != hub)
            .map(|i| dm.d_c(i, hub) + dm.d_c(hub, i))
            .fold(0.0f64, f64::max);
        if worst < best_cost {
            best_cost = worst;
            best = hub;
        }
    }
    best
}

/// Build the STAR digraph: arcs i→hub and hub→i for every silo i.
pub fn design(dm: &DelayModel) -> DiGraph {
    let hub = choose_hub(dm);
    let mut g = DiGraph::new(dm.n);
    for i in 0..dm.n {
        if i != hub {
            g.add_edge(i, hub, 0.0);
            g.add_edge(hub, i, 0.0);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;

    #[test]
    fn star_shape() {
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let g = design(&dm);
        let hub = choose_hub(&dm);
        assert!(g.is_strongly_connected());
        assert_eq!(g.out_degree(hub), 10);
        assert_eq!(g.in_degree(hub), 10);
        for i in 0..11 {
            if i != hub {
                assert_eq!(g.out_degree(i), 1);
                assert_eq!(g.in_degree(i), 1);
            }
        }
    }

    #[test]
    fn hub_is_reasonably_central_on_gaia() {
        // Gaia spans four continents; the minimax hub should be a
        // US/EU site, never Sydney (8) or São Paulo (10).
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let hub = choose_hub(&dm);
        assert!(hub != 8 && hub != 10, "hub={hub}");
    }

    #[test]
    fn hub_uses_betweenness_on_sparse_underlay() {
        let net = Underlay::builtin("geant").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let hub = choose_hub(&dm);
        assert!(hub < 40);
        // star over Géant must still be strong
        let g = design(&dm);
        assert!(g.is_strongly_connected());
    }

    #[test]
    fn star_cycle_time_grows_with_n_on_slow_access() {
        // Appendix B: τ_STAR ≈ 2N·M/C in the slow homogeneous regime.
        let net = Underlay::builtin("gaia").unwrap();
        let wl = Workload::inaturalist();
        let dm = DelayModel::new(&net, &wl, 1, 100e6, 1e9);
        let g = design(&dm);
        let tau = dm.cycle_time_ms(&g);
        let asymptote = 2.0 * 11.0 * wl.model_bits / 100e6 * 1e3 / 2.0;
        // each 2-cycle mean is ≈ N·M/C (hub down N-share + up N-share halved)
        assert!(tau > 0.5 * asymptote, "τ={tau} asym={asymptote}");
    }
}
