//! Adaptive topology re-design under dynamic network scenarios.
//!
//! The paper designs an overlay once, from a static measurement of the
//! network. When the network *changes* — silos straggle, bandwidth drifts,
//! the core congests — the designed overlay keeps its structure but loses
//! its optimality, and reacting to the observed state is where real
//! speedups live (SmartFLow; MATCHA's adaptive budgets). This module closes
//! the loop:
//!
//! 1. **design** an overlay of any [`OverlayKind`] from the base model;
//! 2. **simulate** the Eq.-(4) recurrence round by round under a
//!    [`Scenario`], tracking the realized per-round cycle time over a
//!    sliding window;
//! 3. **re-design** with the *currently measured* network (the scenario's
//!    [`RoundState::perturbed_model`]) whenever the window mean exceeds
//!    `threshold ×` the cycle time the current design promised, then keep
//!    monitoring against the new design's promise.
//!
//! An infinite threshold never re-designs, so [`run_adaptive`] with
//! `threshold = f64::INFINITY` **is** the static baseline — both arms share
//! the same recurrence kernel ([`crate::maxplus::recurrence::step`]) and the
//! same scenario stream, so the comparison isolates exactly the re-design
//! decision (pinned bit-for-bit by `tests/dynamic.rs`).
//!
//! All overlay kinds run through the same recurrence (the STAR is simulated
//! pipelined like every other digraph, not with the non-pipelined FedAvg
//! closed form) so static-vs-adaptive numbers are comparable across kinds.
//! MATCHA re-samples its matchings every round in both arms; its designer
//! ignores the delay model, so re-design only refreshes the monitor's
//! baseline — adaptivity helps the *topology-aware* designers, and the
//! `fedtopo robustness` report shows exactly that.
//!
//! Re-design is not the only possible reaction. [`AdaptiveAction::Reroute`]
//! keeps the overlay fixed and re-solves the *underlay* routes instead
//! (SmartFLow reacts at this layer), so `fedtopo robustness --actions
//! design,reroute` can report which layer's reaction wins per scenario.

use super::{design_with_underlay, Overlay, OverlayKind};
use crate::netsim::delay::{DelayModel, OverlayDelayCsr};
use crate::netsim::routing::{BwModel, Routes};
use crate::netsim::scenario::{RoundState, Scenario};
use crate::netsim::timeline::DynamicTimeline;
use crate::netsim::underlay::Underlay;
use anyhow::Result;

/// What the loop does when the monitor fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdaptiveAction {
    /// Re-run the overlay designer on the currently measured network: the
    /// topology changes, the underlay routes stay. The default, and the
    /// paper-aligned reaction (the designers are the contribution).
    Redesign,
    /// Keep the overlay fixed and recompute the underlay routes on the
    /// currently measured network (SmartFLow-style): latency-shortest paths
    /// are re-solved and adopted, priced at the *base* link capacities so
    /// the scenario's per-round multipliers are not double-counted. The
    /// builtin scenarios perturb delays spatially uniformly and never touch
    /// link latencies, so the re-solved paths coincide with the originals
    /// and the re-route arm tracks the static trajectory bit for bit — an
    /// honest negative result the robustness report makes visible; the
    /// monitor re-arms on the measured rate, so the no-op fires do not
    /// thrash.
    Reroute,
}

/// Knobs of the monitor / re-design loop.
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    /// Sliding-window length (rounds) for the realized cycle-time estimate.
    pub window: usize,
    /// Re-design when `window mean > threshold × designed τ`. `INFINITY`
    /// disables re-design (the static baseline).
    pub threshold: f64,
    /// MATCHA communication budget forwarded to the designers.
    pub c_b: f64,
    /// Seed for the scenario stream and MATCHA round sampling.
    pub seed: u64,
    /// Reaction taken when the monitor fires (re-design by default).
    pub action: AdaptiveAction,
}

impl Default for AdaptiveConfig {
    fn default() -> AdaptiveConfig {
        AdaptiveConfig {
            window: 20,
            threshold: 1.3,
            c_b: 0.5,
            seed: 7,
            action: AdaptiveAction::Redesign,
        }
    }
}

impl AdaptiveConfig {
    /// The static baseline: identical loop, re-design disabled.
    pub fn static_baseline(&self) -> AdaptiveConfig {
        AdaptiveConfig {
            threshold: f64::INFINITY,
            ..self.clone()
        }
    }
}

/// Trajectory of one (designer, scenario) run.
#[derive(Clone, Debug)]
pub struct AdaptiveRun {
    pub kind: OverlayKind,
    /// Wall-clock (ms) at which round k completed at every silo; `[0] = 0`.
    pub completion_ms: Vec<f64>,
    /// Rounds (1-based, = completed-round index) at which re-design fired.
    pub redesign_rounds: Vec<usize>,
    /// Monitor baseline after the initial design and each re-design: the new
    /// design's promised cycle time, or the observed rate when a re-design
    /// turned out futile (could not change the promise).
    pub designed_tau_ms: Vec<f64>,
}

impl AdaptiveRun {
    /// Time-to-round-R (ms) for the full horizon: when the slowest silo
    /// finished the last simulated round. Per-round times are in
    /// [`AdaptiveRun::completion_ms`].
    pub fn total_ms(&self) -> f64 {
        *self.completion_ms.last().expect("round 0 always present")
    }
}

/// Cycle time the recurrence will realize for this overlay on `dm`: the
/// Eq.-(5) max cycle mean for static digraphs, the seeded Monte-Carlo
/// average for the MATCHA processes. Shared with the training engine
/// ([`crate::fl::trainsim`]), whose monitor must promise exactly what the
/// adaptive loop's does.
pub fn recurrence_tau_ms(overlay: &Overlay, dm: &DelayModel) -> f64 {
    match overlay.static_graph() {
        Some(g) => dm.cycle_time_ms(g),
        None => overlay.cycle_time_ms(dm),
    }
}

/// The monitor half of the adaptive loop, factored out so the simulation
/// loop ([`run_adaptive`]) and the training engine
/// ([`crate::fl::trainsim::run`]) make *identical* re-design decisions when
/// fed the same per-round durations.
///
/// The recurrence needs ~n rounds (one trip around the longest critical
/// circuit) to shed its cold-start transient, during which `max_i t_i(k)`
/// grows by worst-case *local* arc sums that can exceed the asymptotic
/// cycle mean. Sampling the window through that transient would fire
/// spurious re-designs on large rings even under the identity scenario —
/// so the monitor holds off for a warm-up after the start and after every
/// re-design (which begins a fresh transient).
#[derive(Clone, Debug)]
pub struct ThroughputMonitor {
    window_len: usize,
    threshold: f64,
    warmup: usize,
    cooldown: usize,
    /// Fixed ring buffer over the last `window_len` samples. While filling,
    /// plain pushes; once full, the oldest sample (at `head`) is overwritten
    /// in place — O(1) per round, vs the O(window) `Vec::remove(0)` memmove
    /// this replaced (PR 6), inside the zero-alloc warm loop.
    window: Vec<f64>,
    /// Index of the *oldest* sample once the ring is full (next overwrite
    /// target). 0 while filling.
    head: usize,
    designed_tau: f64,
}

impl ThroughputMonitor {
    /// Arm a monitor against `designed_tau` (the current design's promised
    /// cycle time) for an `n`-silo recurrence.
    pub fn new(window: usize, threshold: f64, n: usize, designed_tau: f64) -> ThroughputMonitor {
        let window_len = window.max(1);
        let warmup = window_len.max(n);
        ThroughputMonitor {
            window_len,
            threshold,
            warmup,
            cooldown: warmup,
            // Sized once: the ring never holds more than window_len samples,
            // so the monitor is allocation-free after construction (the
            // PR-5 zero-alloc contract, gated by benches/memory.rs).
            window: Vec::with_capacity(window_len),
            head: 0,
            designed_tau,
        }
    }

    /// The baseline the monitor currently compares against.
    pub fn designed_tau(&self) -> f64 {
        self.designed_tau
    }

    /// Feed one realized per-round duration (ms). Returns the window mean
    /// when the re-design condition `mean > threshold × designed τ` fired;
    /// the caller must then re-design and [`ThroughputMonitor::rearm`].
    ///
    /// The mean is summed oldest → newest over the logical window — the
    /// exact order the pre-ring `Vec` held the samples in — so the f64
    /// accumulation, and with it every adaptive trace, is bit-identical to
    /// the `Vec::remove(0)` implementation it replaced (pinned by the
    /// naive-reference test below and cross-engine by `tests/train.rs` /
    /// `tests/dynamic.rs`).
    pub fn observe(&mut self, dt: f64) -> Option<f64> {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return None;
        }
        if self.window.len() < self.window_len {
            self.window.push(dt);
        } else {
            self.window[self.head] = dt;
            self.head += 1;
            if self.head == self.window_len {
                self.head = 0;
            }
        }
        if self.window.len() == self.window_len {
            let mut sum = 0.0;
            for k in 0..self.window_len {
                let mut idx = self.head + k;
                if idx >= self.window_len {
                    idx -= self.window_len;
                }
                sum += self.window[idx];
            }
            let mean = sum / self.window_len as f64;
            if mean > self.threshold * self.designed_tau {
                return Some(mean);
            }
        }
        None
    }

    /// Adopt a re-design's promise and restart the warm-up. A re-design
    /// that cannot change the promise is futile — the degradation is not
    /// topology-addressable (e.g. memoryless churn, whose measured model is
    /// the base model) — so the baseline ratchets to the observed rate
    /// instead, re-arming on *further* degradation rather than thrashing
    /// through an identical designer run every window. Returns the adopted
    /// baseline.
    pub fn rearm(&mut self, new_tau: f64, observed_mean: f64) -> f64 {
        self.designed_tau =
            if (new_tau - self.designed_tau).abs() <= 1e-9 * self.designed_tau.abs().max(1.0) {
                observed_mean / self.threshold
            } else {
                new_tau
            };
        self.window.clear();
        self.head = 0;
        self.cooldown = self.warmup;
        self.designed_tau
    }
}

/// Run `rounds` rounds of `kind` on `net` under `scenario`, re-designing
/// whenever the monitored throughput degrades past the threshold.
pub fn run_adaptive(
    kind: OverlayKind,
    dm: &DelayModel,
    net: &Underlay,
    scenario: &Scenario,
    rounds: usize,
    cfg: &AdaptiveConfig,
) -> Result<AdaptiveRun> {
    let mut overlay = design_with_underlay(kind, dm, net, cfg.c_b)?;
    let mut monitor =
        ThroughputMonitor::new(cfg.window, cfg.threshold, dm.n, recurrence_tau_ms(&overlay, dm));
    let mut designed_tau_ms = vec![monitor.designed_tau()];
    let mut redesign_rounds = Vec::new();

    let mut proc = scenario.process(dm.n, cfg.seed);
    let mut tl = DynamicTimeline::with_capacity(dm.n, rounds);
    let mut st = RoundState::unperturbed(dm.n, 0);
    // Static overlays keep one reusable CSR digraph whose weights the
    // scenario rewrites in place — zero allocation per round (PR 5; the
    // weights are fully overwritten each round, so the structure only
    // needs rebuilding on re-design). MATCHA's arc set changes every
    // round, so the random branch keeps the materializing path. `step_csr`
    // row-partitions large cells across the intra-cell pool (PR 10) —
    // bit-identical for any worker count, and gated off below
    // INTRACELL_MIN_FOLDS so small runs stay on the sequential oracle.
    let mut ov_csr: Option<OverlayDelayCsr> = overlay.static_graph().map(|g| dm.delay_csr(g));
    // The working model: `dm` until a re-route adopts re-solved routes.
    // Redesign never populates this, so the default arm stays on `dm` and
    // its trajectory is untouched.
    let mut routed: Option<DelayModel> = None;

    for k in 0..rounds {
        proc.advance_into(&mut st);
        let prev = tl.last_completion_ms();
        let model = routed.as_ref().unwrap_or(dm);
        let done = match &mut ov_csr {
            Some(ov) => {
                st.reweight(model, ov);
                tl.step_csr(&ov.csr)
            }
            None => {
                let g = overlay.round_graph(k, cfg.seed);
                tl.step(&st.delay_digraph(model, &g))
            }
        };

        if let Some(mean) = monitor.observe(done - prev) {
            match cfg.action {
                AdaptiveAction::Redesign => {
                    // Re-measure the network as it is *now* and re-design.
                    let measured = st.perturbed_model(dm);
                    overlay = design_with_underlay(kind, &measured, net, cfg.c_b)?;
                    ov_csr = overlay.static_graph().map(|g| dm.delay_csr(g));
                    let new_tau = recurrence_tau_ms(&overlay, &measured);
                    designed_tau_ms.push(monitor.rearm(new_tau, mean));
                }
                AdaptiveAction::Reroute => {
                    // Overlay stays; re-solve the underlay routes and adopt
                    // them, priced at the base capacities (the scenario's
                    // multipliers are applied per round on top). The new
                    // promise is what the unchanged overlay delivers on the
                    // re-routed, currently measured network.
                    let mut model = routed.take().unwrap_or_else(|| dm.clone());
                    let caps = model.routes.link_caps_bps().to_vec();
                    model.routes =
                        Routes::compute_with_capacities(net, &caps, BwModel::MinCapacity);
                    ov_csr = overlay.static_graph().map(|g| model.delay_csr(g));
                    let measured = st.perturbed_model(&model);
                    let new_tau = recurrence_tau_ms(&overlay, &measured);
                    routed = Some(model);
                    designed_tau_ms.push(monitor.rearm(new_tau, mean));
                }
            }
            redesign_rounds.push(k + 1);
        }
    }

    Ok(AdaptiveRun {
        kind,
        completion_ms: tl.into_completion_ms(),
        redesign_rounds,
        designed_tau_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;

    fn gaia() -> (Underlay, DelayModel) {
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        (net, dm)
    }

    #[test]
    fn monitor_warms_up_fires_and_ratchets() {
        // window 3, n 2 → warm-up 3; threshold 2 over a promise of 10.
        let mut m = ThroughputMonitor::new(3, 2.0, 2, 10.0);
        for _ in 0..3 {
            assert_eq!(m.observe(100.0), None, "warm-up must swallow samples");
        }
        assert_eq!(m.observe(30.0), None); // window filling
        assert_eq!(m.observe(30.0), None);
        let mean = m.observe(30.0).expect("mean 30 > 2 × 10 must fire");
        assert!((mean - 30.0).abs() < 1e-12);
        // futile re-design (same promise): ratchet to mean / threshold …
        let adopted = m.rearm(10.0, mean);
        assert!((adopted - 15.0).abs() < 1e-12);
        // … and a fresh warm-up follows
        assert_eq!(m.observe(1000.0), None);
        // a real re-design adopts the new promise
        let mut m2 = ThroughputMonitor::new(1, 1.5, 1, 10.0);
        assert_eq!(m2.observe(50.0), None); // warm-up (= window = 1)
        let mean = m2.observe(50.0).expect("50 > 1.5 × 10");
        assert_eq!(m2.rearm(20.0, mean), 20.0);
        assert_eq!(m2.designed_tau(), 20.0);
    }

    #[test]
    fn ring_window_matches_naive_vec_reference_bitwise() {
        // The pre-PR-6 monitor, verbatim: push + Vec::remove(0) eviction,
        // mean summed over the vec in chronological order. The ring buffer
        // must reproduce its observe/rearm stream bit for bit — including
        // warm evictions, firings, and post-rearm refills.
        struct NaiveMonitor {
            window_len: usize,
            threshold: f64,
            warmup: usize,
            cooldown: usize,
            window: Vec<f64>,
            designed_tau: f64,
        }
        impl NaiveMonitor {
            fn new(window: usize, threshold: f64, n: usize, designed_tau: f64) -> NaiveMonitor {
                let window_len = window.max(1);
                let warmup = window_len.max(n);
                NaiveMonitor {
                    window_len,
                    threshold,
                    warmup,
                    cooldown: warmup,
                    window: Vec::with_capacity(window_len + 1),
                    designed_tau,
                }
            }
            fn observe(&mut self, dt: f64) -> Option<f64> {
                if self.cooldown > 0 {
                    self.cooldown -= 1;
                    return None;
                }
                self.window.push(dt);
                if self.window.len() > self.window_len {
                    self.window.remove(0);
                }
                if self.window.len() == self.window_len {
                    let mean = self.window.iter().sum::<f64>() / self.window_len as f64;
                    if mean > self.threshold * self.designed_tau {
                        return Some(mean);
                    }
                }
                None
            }
            fn rearm(&mut self, new_tau: f64, observed_mean: f64) -> f64 {
                self.designed_tau = if (new_tau - self.designed_tau).abs()
                    <= 1e-9 * self.designed_tau.abs().max(1.0)
                {
                    observed_mean / self.threshold
                } else {
                    new_tau
                };
                self.window.clear();
                self.cooldown = self.warmup;
                self.designed_tau
            }
        }

        let mut rng = crate::util::rng::Rng::new(99);
        for (window, n, threshold) in [(1usize, 1usize, 1.2f64), (3, 2, 1.5), (7, 20, 1.1)] {
            let mut ring = ThroughputMonitor::new(window, threshold, n, 10.0);
            let mut naive = NaiveMonitor::new(window, threshold, n, 10.0);
            let mut fired = 0usize;
            for step in 0..500 {
                // jittery durations that drift upward, so the monitor fires
                // repeatedly and both eviction paths stay warm between fires
                let dt = 8.0 + 0.05 * step as f64 + 6.0 * rng.f64();
                let a = ring.observe(dt);
                let b = naive.observe(dt);
                assert_eq!(
                    a.map(f64::to_bits),
                    b.map(f64::to_bits),
                    "w={window} n={n}: observe diverged at step {step}"
                );
                if let (Some(mean), Some(_)) = (a, b) {
                    fired += 1;
                    // alternate futile re-designs (ratchet path) with real
                    // ones (adopt path)
                    let new_tau = if fired % 2 == 0 {
                        ring.designed_tau()
                    } else {
                        ring.designed_tau() * 1.5
                    };
                    let x = ring.rearm(new_tau, mean);
                    let y = naive.rearm(new_tau, mean);
                    assert_eq!(x.to_bits(), y.to_bits(), "rearm diverged");
                }
                assert_eq!(ring.designed_tau().to_bits(), naive.designed_tau.to_bits());
            }
            assert!(fired >= 2, "w={window}: test must exercise rearm ({fired})");
        }
    }

    #[test]
    fn identity_scenario_tracks_designed_tau() {
        let (net, dm) = gaia();
        let run = run_adaptive(
            OverlayKind::Mst,
            &dm,
            &net,
            &Scenario::identity(),
            120,
            &AdaptiveConfig::default(),
        )
        .unwrap();
        assert!(run.redesign_rounds.is_empty(), "identity must not re-design");
        assert_eq!(run.completion_ms.len(), 121);
        let slope = (run.completion_ms[120] - run.completion_ms[60]) / 60.0;
        let tau = run.designed_tau_ms[0];
        assert!((slope - tau).abs() < 0.05 * tau, "slope {slope} vs τ {tau}");
    }

    #[test]
    fn infinite_threshold_never_redesigns_under_stress() {
        let (net, dm) = gaia();
        let sc = Scenario::by_name("scenario:straggler:3:x10").unwrap();
        let cfg = AdaptiveConfig::default().static_baseline();
        for kind in [OverlayKind::Mst, OverlayKind::Ring, OverlayKind::Star] {
            let run = run_adaptive(kind, &dm, &net, &sc, 80, &cfg).unwrap();
            assert!(run.redesign_rounds.is_empty(), "{kind:?}");
            assert_eq!(run.designed_tau_ms.len(), 1);
        }
    }

    #[test]
    fn completion_times_monotone_for_every_kind() {
        let (net, dm) = gaia();
        let sc = Scenario::by_name("scenario:drift:0.3+churn:p0.05").unwrap();
        for kind in OverlayKind::all() {
            let run =
                run_adaptive(kind, &dm, &net, &sc, 60, &AdaptiveConfig::default()).unwrap();
            assert!(
                run.completion_ms.windows(2).all(|w| w[1] >= w[0]),
                "{kind:?} not monotone"
            );
            assert!(run.total_ms().is_finite() && run.total_ms() > 0.0);
        }
    }

    #[test]
    fn futile_redesigns_do_not_thrash_under_churn() {
        // Memoryless churn is not topology-addressable: the measured model
        // is the base model, so a re-design changes nothing. The baseline
        // ratchet must keep the monitor from firing every single window.
        let (net, dm) = gaia();
        let sc = Scenario::by_name("scenario:churn:p0.3:x5").unwrap();
        let run = run_adaptive(
            OverlayKind::Mst,
            &dm,
            &net,
            &sc,
            300,
            &AdaptiveConfig::default(),
        )
        .unwrap();
        // Structural cap: every trip costs warm-up (20) + window refill
        // (20) rounds, so at most 7 trips fit in 300 rounds; without the
        // cooldown + ratchet a churn-inflated rolling mean would fire at
        // nearly every round (~hundreds of futile designer runs).
        assert!(
            run.redesign_rounds.len() <= 7,
            "{} re-designs in 300 rounds — monitor is thrashing",
            run.redesign_rounds.len()
        );
    }

    #[test]
    fn reroute_is_a_noop_under_spatially_uniform_perturbations() {
        // The builtin scenarios scale delays uniformly in space and leave
        // link latencies alone, so re-solving the latency-shortest routes
        // reproduces the original routes exactly: the re-route arm must
        // track the static trajectory bit for bit even though the monitor
        // fires. This is the documented negative result the robustness
        // report surfaces when both actions are requested.
        let (net, dm) = gaia();
        let sc = Scenario::by_name("scenario:straggler:3:x10").unwrap();
        let cfg = AdaptiveConfig {
            action: AdaptiveAction::Reroute,
            ..AdaptiveConfig::default()
        };
        let rr = run_adaptive(OverlayKind::Mst, &dm, &net, &sc, 200, &cfg).unwrap();
        let stat =
            run_adaptive(OverlayKind::Mst, &dm, &net, &sc, 200, &cfg.static_baseline()).unwrap();
        assert!(
            !rr.redesign_rounds.is_empty(),
            "the monitor must still fire on a 10× straggler"
        );
        assert_eq!(rr.completion_ms.len(), stat.completion_ms.len());
        for k in 0..rr.completion_ms.len() {
            assert_eq!(
                rr.completion_ms[k].to_bits(),
                stat.completion_ms[k].to_bits(),
                "re-route diverged from static at round {k}"
            );
        }
        // After the first fire the monitor promises the measured rate, not
        // the stale base-design τ — that is what keeps it from thrashing.
        assert!(rr.designed_tau_ms.len() > 1);
        assert!(rr.designed_tau_ms[1] > rr.designed_tau_ms[0]);
    }

    #[test]
    fn straggler_triggers_redesign_and_helps_mst() {
        let (net, dm) = gaia();
        let sc = Scenario::by_name("scenario:straggler:3:x10").unwrap();
        let cfg = AdaptiveConfig::default();
        let adaptive = run_adaptive(OverlayKind::Mst, &dm, &net, &sc, 200, &cfg).unwrap();
        let stat =
            run_adaptive(OverlayKind::Mst, &dm, &net, &sc, 200, &cfg.static_baseline())
                .unwrap();
        assert!(
            !adaptive.redesign_rounds.is_empty(),
            "monitor must trip on a 10× straggler"
        );
        assert!(
            adaptive.total_ms() < 0.9 * stat.total_ms(),
            "adaptive {} should beat static {}",
            adaptive.total_ms(),
            stat.total_ms()
        );
    }
}
