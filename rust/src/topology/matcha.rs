//! MATCHA / MATCHA⁺ baseline (Wang et al. 2019).
//!
//! MATCHA decomposes a base topology into matchings (our Misra–Gries edge
//! coloring, ≤ Δ+1 classes) and activates each matching independently with
//! probability `C_b` every round; activated pairs exchange models
//! bidirectionally. MATCHA starts from the *connectivity graph* (complete
//! between silos); MATCHA⁺ from the *underlay* (App. G.3).
//!
//! Fairness fix from the paper (App. G.3): to isolate the effect of the
//! number of local steps s, rounds where *no* matching activates are
//! resampled, so every round has at least one active matching.
//!
//! The cycle time of this random process is estimated by simulating the
//! exact Eq.-(4) recurrence over a long sampled round sequence (the paper:
//! "As MATCHA and MATCHA⁺ select random overlays at each iteration, we
//! compute their average cycle time"). Appendix B's closed form
//! `τ ≳ (M/C)·C_b·max_degree(G_u)` is a test oracle in the slow-access
//! regime.

use crate::graph::matching::matching_decomposition;
use crate::graph::{DiGraph, UnGraph};
use crate::netsim::delay::DelayModel;
use crate::util::parallel::par_map_indexed;
use crate::util::rng::{derive_seed, Rng};

/// The MATCHA random-overlay process.
#[derive(Clone, Debug)]
pub struct MatchaOverlay {
    n: usize,
    /// The matching decomposition — explicit pair lists, or the implicit
    /// circle-method factorization of K_n (PR 5: O(1) storage instead of
    /// Θ(n²) materialized pairs; a 20 000-silo K_n decomposition is ~2·10⁸
    /// pairs, which is exactly the memory wall the scale acceptance hits).
    matchings: Matchings,
    /// per-round activation probability of each matching (uniform C_b, as
    /// in the paper's experiments — App. B assumes the same).
    pub c_b: f64,
}

/// Storage of the matching decomposition.
#[derive(Clone, Debug)]
enum Matchings {
    /// Explicit pair lists (Misra–Gries colorings of arbitrary graphs, and
    /// small cliques — the historical, bit-pinned route).
    Explicit(Vec<Vec<(usize, usize)>>),
    /// The round-robin circle factorization of K_n, pairs generated on
    /// demand by [`circle_pairs`] — same pairs, same order, no storage.
    Circle { n: usize },
}

impl Matchings {
    fn len(&self) -> usize {
        match self {
            Matchings::Explicit(v) => v.len(),
            Matchings::Circle { n } => {
                if *n < 2 {
                    0
                } else if n % 2 == 0 {
                    n - 1
                } else {
                    *n
                }
            }
        }
    }

    /// Visit matching `r`'s pairs in canonical order.
    fn for_each_pair(&self, r: usize, mut f: impl FnMut(usize, usize)) {
        match self {
            Matchings::Explicit(v) => {
                for &(i, j) in &v[r] {
                    f(i, j);
                }
            }
            Matchings::Circle { n } => circle_pairs(*n, r, f),
        }
    }
}

impl MatchaOverlay {
    /// Largest complete graph still decomposed via Misra–Gries (exactly the
    /// builtin-network regime); bigger cliques use the closed-form circle
    /// method, whose O(n²) cost is what keeps 1000-silo MATCHA tractable.
    const CIRCLE_METHOD_MIN_N: usize = 101;

    /// Smallest clique at which the Monte-Carlo estimator switches from
    /// exact per-round iteration (every active pair folded — ~C_b·n²/2
    /// work per round, the PR-7 time wall) to the budgeted sampled-pairs
    /// estimator. Only the implicit circle factorization qualifies:
    /// explicit matchings never reach this size. Below the gate the
    /// estimate is byte-identical to the historical exact path.
    const SAMPLED_MIN_N: usize = 8192;

    /// Per-round pair-fold budget of the sampled estimator (~2M folds),
    /// split evenly across the round's active matchings. A matching whose
    /// share covers all its pairs is iterated exactly instead of sampled.
    const SAMPLED_PAIR_BUDGET: usize = 1 << 21;

    /// MATCHA over the complete connectivity graph.
    ///
    /// Small n (every builtin network) keeps the historical Misra–Gries
    /// route bit-for-bit; past `Self::CIRCLE_METHOD_MIN_N` silos K_n is
    /// 1-factorized directly with the round-robin *circle method* (n − 1
    /// perfect matchings for even n, n near-perfect for odd n) — optimal in
    /// matching count and O(n²) instead of Misra–Gries' fan/path recoloring
    /// over n²/2 edges.
    pub fn over_complete(n: usize, c_b: f64) -> MatchaOverlay {
        if n >= Self::CIRCLE_METHOD_MIN_N {
            assert!((0.0..=1.0).contains(&c_b), "C_b ∈ [0,1]");
            return MatchaOverlay {
                n,
                matchings: Matchings::Circle { n },
                c_b,
            };
        }
        let mut g = UnGraph::new(n);
        for i in 0..n {
            for j in i + 1..n {
                g.add_edge(i, j, 1.0);
            }
        }
        MatchaOverlay::over_graph(&g, c_b)
    }

    /// Test oracle: the circle factorization **materialized** as explicit
    /// pair lists. Bit-identical process to [`MatchaOverlay::over_complete`]
    /// past the circle threshold (same pairs, same order, same RNG stream);
    /// exists so the implicit representation has a dense path to be pinned
    /// against (`tests/csr_equiv.rs`).
    pub fn over_complete_circle_explicit(n: usize, c_b: f64) -> MatchaOverlay {
        assert!((0.0..=1.0).contains(&c_b), "C_b ∈ [0,1]");
        MatchaOverlay {
            n,
            matchings: Matchings::Explicit(circle_factorization(n)),
            c_b,
        }
    }

    /// MATCHA⁺ over an arbitrary base graph (the underlay core).
    pub fn over_graph(base: &UnGraph, c_b: f64) -> MatchaOverlay {
        assert!((0.0..=1.0).contains(&c_b), "C_b ∈ [0,1]");
        let classes = matching_decomposition(base);
        let matchings: Vec<Vec<(usize, usize)>> = classes
            .into_iter()
            .map(|cls| {
                cls.into_iter()
                    .map(|e| {
                        let (u, v, _) = base.edge(e);
                        (u, v)
                    })
                    .collect()
            })
            .collect();
        MatchaOverlay {
            n: base.n(),
            matchings: Matchings::Explicit(matchings),
            c_b,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn num_matchings(&self) -> usize {
        self.matchings.len()
    }

    /// Matching `r`'s silo pairs, materialized (tests / diagnostics).
    pub fn matching_pairs(&self, r: usize) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        self.matchings.for_each_pair(r, |i, j| v.push((i, j)));
        v
    }

    /// Sample one round's activated communication digraph (bidirectional
    /// arcs for every pair of every activated matching). Guarantees ≥ 1
    /// activated matching via resampling (the App.-G.3 fairness fix).
    pub fn sample_round(&self, rng: &mut Rng) -> DiGraph {
        let mut g = DiGraph::new(self.n);
        let nm = self.matchings.len();
        loop {
            let mut any = false;
            for r in 0..nm {
                if rng.bool(self.c_b) {
                    any = true;
                    self.matchings.for_each_pair(r, |i, j| {
                        g.add_edge(i, j, 0.0);
                        g.add_edge(j, i, 0.0);
                    });
                }
            }
            if any || nm == 0 {
                return g;
            }
            g = DiGraph::new(self.n);
        }
    }

    /// Number of independent Monte-Carlo batches the round budget is split
    /// into. A pure function of (n, rounds) — **never** of the worker
    /// count — so the estimate is identical for any `--jobs`. Each batch
    /// must stay long enough (≥ ~4n rounds) for its slope estimator to
    /// shed the max-plus cold-start transient.
    fn mc_batches(n: usize, rounds: usize) -> usize {
        (rounds / (4 * n.max(1)).max(20)).clamp(1, 16)
    }

    /// Average cycle time via the exact time-varying recurrence, estimated
    /// over independent sample batches: the round budget is split into
    /// `Self::mc_batches` chains, chain `b` seeded `derive_seed(seed, b)`
    /// (the per-item rule — no RNG is shared across batches), each chain
    /// simulated with `Self::batch_slope_ms`, and the batch slopes
    /// averaged by an **ordered reduction** (summed in batch order). The
    /// batches run on the [`crate::util::parallel`] pool; by construction
    /// the result is bit-identical to running them sequentially
    /// (`tests/parallel.rs` pins this on gaia).
    pub fn average_cycle_time_ms(&self, dm: &DelayModel, rounds: usize, seed: u64) -> f64 {
        assert!(rounds >= 10);
        let batches = Self::mc_batches(self.n, rounds);
        // Split the budget exactly: the first `rounds % batches` batches
        // take one extra round, so no part of the budget is dropped. The
        // split depends only on (n, rounds) — never on the worker count.
        let per_batch = rounds / batches;
        let rem = rounds % batches;
        let idx: Vec<usize> = (0..batches).collect();
        let slopes = par_map_indexed(&idx, |_, &b| {
            self.batch_slope_ms(
                dm,
                per_batch + usize::from(b < rem),
                derive_seed(seed, b as u64),
            )
        });
        slopes.iter().sum::<f64>() / batches as f64
    }

    /// One batch of the estimator: exact per-round iteration below
    /// [`Self::SAMPLED_MIN_N`], budgeted pair sampling above it.
    fn batch_slope_ms(&self, dm: &DelayModel, rounds: usize, seed: u64) -> f64 {
        self.batch_slope_ms_with(dm, rounds, seed, None)
    }

    /// Dispatch between the exact and sampled batch estimators.
    /// `force_budget` pins a sampling budget regardless of the size gate —
    /// the test hook that lets small models exercise the sampled path
    /// against the exact one.
    fn batch_slope_ms_with(
        &self,
        dm: &DelayModel,
        rounds: usize,
        seed: u64,
        force_budget: Option<usize>,
    ) -> f64 {
        let circle = matches!(self.matchings, Matchings::Circle { .. });
        if circle && (self.n >= Self::SAMPLED_MIN_N || force_budget.is_some()) {
            let budget = force_budget.unwrap_or(Self::SAMPLED_PAIR_BUDGET);
            self.batch_slope_ms_sampled(dm, rounds, seed, budget)
        } else {
            self.batch_slope_ms_exact(dm, rounds, seed)
        }
    }

    /// Exact batch: simulate
    /// `t_i(k+1) = max_j (t_j(k) + d_k(j,i))` over `rounds` sampled rounds
    /// and return the asymptotic slope (second half of the trajectory).
    ///
    /// PR 5: the round graph is never materialized — the activation coins
    /// (drawn in exactly [`MatchaOverlay::sample_round`]'s stream order,
    /// resample loop included), the node degrees, and the Eq.-(3) arc folds
    /// all run straight off the matching decomposition, so a round costs
    /// O(active-pairs) arithmetic and **zero** graph allocation. The max
    /// fold commutes, so the slopes equal the historical
    /// build-a-`DiGraph`-then-`arc_delays` path bit for bit (pinned by
    /// `tests/csr_equiv.rs` via the explicit-circle oracle).
    fn batch_slope_ms_exact(&self, dm: &DelayModel, rounds: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let n = self.n;
        let nm = self.matchings.len();
        let mut t = vec![0.0f64; n];
        let mut t_mid = vec![0.0f64; n];
        let mut next = vec![0.0f64; n];
        let mut deg = vec![0u32; n];
        let mut active: Vec<usize> = Vec::with_capacity(nm);
        let half = rounds / 2;
        for k in 0..rounds {
            // Activation coins — the exact sample_round stream, fairness
            // resampling included.
            loop {
                active.clear();
                for r in 0..nm {
                    if rng.bool(self.c_b) {
                        active.push(r);
                    }
                }
                if !active.is_empty() || nm == 0 {
                    break;
                }
            }
            // Round-graph degrees: one in- and one out-arc per pair touch.
            deg.fill(0);
            for &r in &active {
                self.matchings.for_each_pair(r, |i, j| {
                    deg[i] += 1;
                    deg[j] += 1;
                });
            }
            // Eq.-(4) fold with Eq.-(3) delays, both arcs of every pair.
            for i in 0..n {
                next[i] = t[i] + dm.compute_ms(i);
            }
            for &r in &active {
                self.matchings.for_each_pair(r, |i, j| {
                    let d_ij = dm.d_o(i, j, deg[i].max(1) as usize, deg[j].max(1) as usize);
                    let cand = t[i] + d_ij;
                    if cand > next[j] {
                        next[j] = cand;
                    }
                    let d_ji = dm.d_o(j, i, deg[j].max(1) as usize, deg[i].max(1) as usize);
                    let cand = t[j] + d_ji;
                    if cand > next[i] {
                        next[i] = cand;
                    }
                });
            }
            std::mem::swap(&mut t, &mut next);
            if k + 1 == half {
                t_mid.copy_from_slice(&t);
            }
        }
        let m_end = t.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let m_mid = t_mid.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        (m_end - m_mid) / (rounds - half) as f64
    }

    /// Sampled batch (PR 7): same recurrence, but each active matching
    /// folds only `budget / |active|` of its pairs, drawn uniformly with
    /// replacement (RNG stream: activation coins first — identical to the
    /// exact path — then the round's sample indices), so a round costs
    /// O(budget) instead of ~C_b·n²/2. Degrees of the *full* activated
    /// graph are closed-form for the circle factorization (even n: every
    /// active matching is perfect, deg ≡ |active|; odd n: matching r byes
    /// node r, so deg[i] = |active| − [i ∈ active]), keeping the Eq.-(3)
    /// congestion terms exact — only the set of folded max-plus candidates
    /// is subsampled, which can only *under*-estimate each node's max.
    /// The pinned band (`sampled_estimator_within_pinned_band`) bounds the
    /// resulting slope within [0.3×, 1.1×] of the exact estimate. A
    /// matching whose share covers all pairs is iterated exactly, so a
    /// generous budget degrades gracefully into the exact fold.
    fn batch_slope_ms_sampled(
        &self,
        dm: &DelayModel,
        rounds: usize,
        seed: u64,
        budget: usize,
    ) -> f64 {
        let mut rng = Rng::new(seed);
        let n = self.n;
        let nm = self.matchings.len();
        let even = n % 2 == 0;
        let ppm = circle_pairs_per_matching(n);
        let mut t = vec![0.0f64; n];
        let mut t_mid = vec![0.0f64; n];
        let mut next = vec![0.0f64; n];
        let mut active: Vec<usize> = Vec::with_capacity(nm);
        let half = rounds / 2;
        for k in 0..rounds {
            loop {
                active.clear();
                for r in 0..nm {
                    if rng.bool(self.c_b) {
                        active.push(r);
                    }
                }
                if !active.is_empty() || nm == 0 {
                    break;
                }
            }
            let al = active.len() as u32;
            // `active` is ascending by construction; odd-n byes are looked
            // up by binary search (matching r's bye is node r).
            let deg = |v: usize| -> usize {
                let d = if even {
                    al
                } else if v < nm && active.binary_search(&v).is_ok() {
                    al - 1
                } else {
                    al
                };
                d.max(1) as usize
            };
            for i in 0..n {
                next[i] = t[i] + dm.compute_ms(i);
            }
            let share = (budget / active.len().max(1)).clamp(1, ppm);
            for &r in &active {
                let mut fold = |i: usize, j: usize| {
                    let (di, dj) = (deg(i), deg(j));
                    let d_ij = dm.d_o(i, j, di, dj);
                    let cand = t[i] + d_ij;
                    if cand > next[j] {
                        next[j] = cand;
                    }
                    let d_ji = dm.d_o(j, i, dj, di);
                    let cand = t[j] + d_ji;
                    if cand > next[i] {
                        next[i] = cand;
                    }
                };
                if share >= ppm {
                    for idx in 0..ppm {
                        let (i, j) = circle_pair_at(n, r, idx);
                        fold(i, j);
                    }
                } else {
                    for _ in 0..share {
                        let (i, j) = circle_pair_at(n, r, rng.usize(ppm));
                        fold(i, j);
                    }
                }
            }
            std::mem::swap(&mut t, &mut next);
            if k + 1 == half {
                t_mid.copy_from_slice(&t);
            }
        }
        let m_end = t.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let m_mid = t_mid.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        (m_end - m_mid) / (rounds - half) as f64
    }

    /// Expected max degree of the activated graph ≈ C_b · #matchings
    /// touching the max-degree node (App.-B estimate; diagnostics).
    pub fn expected_max_degree(&self) -> f64 {
        // max over nodes of (number of matchings containing the node) × C_b
        let mut per_node = vec![0usize; self.n];
        for r in 0..self.matchings.len() {
            self.matchings.for_each_pair(r, |i, j| {
                per_node[i] += 1;
                per_node[j] += 1;
            });
        }
        per_node.iter().map(|&c| c as f64 * self.c_b).fold(0.0, f64::max)
    }
}

/// One matching of the round-robin 1-factorization of K_n, generated pair
/// by pair (the implicit form [`Matchings::Circle`] iterates). For even n:
/// fix node n−1, rotate the rest — n−1 perfect matchings covering every
/// edge once. For odd n: run the even scheme on n+1 nodes and drop the
/// phantom's pair (n matchings, one bye per round — matching r's bye is
/// node r). Classic tournament-scheduling construction.
fn circle_pairs(n: usize, r: usize, mut f: impl FnMut(usize, usize)) {
    let even = n % 2 == 0;
    let m = if even { n } else { n + 1 }; // pad odd n with a phantom
    // fixed pivot m−1 plays the rotating slot r; for odd n the pivot IS
    // the phantom, so its pair is the round's bye.
    if even {
        let (a, b) = (m - 1, r);
        f(a.min(b), a.max(b));
    }
    for i in 1..m / 2 {
        let x = (r + i) % (m - 1);
        let y = (r + m - 1 - i) % (m - 1);
        f(x.min(y), x.max(y));
    }
}

/// Pairs per circle matching: n/2 for even n (perfect matchings), (n−1)/2
/// for odd n (one bye per round).
fn circle_pairs_per_matching(n: usize) -> usize {
    if n < 2 {
        0
    } else if n % 2 == 0 {
        n / 2
    } else {
        (n - 1) / 2
    }
}

/// Random access into matching `r`'s pair list: `circle_pair_at(n, r, idx)`
/// is pair number `idx` of the sequence [`circle_pairs`] emits — the pivot
/// pair first for even n, then the rotation pairs — in O(1), which is what
/// lets the sampled estimator draw uniform pairs without materializing the
/// matching (`circle_pair_at_matches_iterator` pins the equivalence).
fn circle_pair_at(n: usize, r: usize, idx: usize) -> (usize, usize) {
    let even = n % 2 == 0;
    let m = if even { n } else { n + 1 };
    let i = if even {
        if idx == 0 {
            let (a, b) = (m - 1, r);
            return (a.min(b), a.max(b));
        }
        idx
    } else {
        idx + 1
    };
    let x = (r + i) % (m - 1);
    let y = (r + m - 1 - i) % (m - 1);
    (x.min(y), x.max(y))
}

/// The full factorization, materialized ([`circle_pairs`] per round) — the
/// explicit oracle behind [`MatchaOverlay::over_complete_circle_explicit`]
/// and the partition tests.
fn circle_factorization(n: usize) -> Vec<Vec<(usize, usize)>> {
    if n < 2 {
        return Vec::new();
    }
    let rounds = if n % 2 == 0 { n - 1 } else { n };
    (0..rounds)
        .map(|r| {
            let mut pairs = Vec::with_capacity(n / 2);
            circle_pairs(n, r, |a, b| pairs.push((a, b)));
            pairs
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;

    #[test]
    fn matchings_partition_complete_graph() {
        let m = MatchaOverlay::over_complete(6, 0.5);
        // K6 is 5-edge-colorable; Misra–Gries uses ≤ 6
        assert!(m.num_matchings() <= 6);
        let total: usize = (0..m.num_matchings())
            .map(|r| m.matching_pairs(r).len())
            .sum();
        assert_eq!(total, 15);
    }

    #[test]
    fn implicit_circle_matches_explicit_oracle_bitwise() {
        // Same pairs in the same order, same sampled rounds, same Monte-
        // Carlo estimate — the implicit representation is pure storage.
        for n_big in [101usize, 150] {
            let imp = MatchaOverlay::over_complete(n_big, 0.5);
            let exp = MatchaOverlay::over_complete_circle_explicit(n_big, 0.5);
            assert_eq!(imp.num_matchings(), exp.num_matchings());
            for r in 0..imp.num_matchings() {
                assert_eq!(imp.matching_pairs(r), exp.matching_pairs(r), "n={n_big} r={r}");
            }
            let mut ra = Rng::new(3);
            let mut rb = Rng::new(3);
            let ga = imp.sample_round(&mut ra);
            let gb = exp.sample_round(&mut rb);
            assert_eq!(ga.edges(), gb.edges(), "n={n_big}");
        }
        // the estimator itself, on a matching-size model (the builtins are
        // all below the circle threshold, so use a 150-silo synthetic)
        let net = Underlay::by_name("synth:waxman:150:seed7").unwrap();
        let dm150 = DelayModel::new(&net, &Workload::inaturalist(), 1, 1e9, 1e9);
        let a = MatchaOverlay::over_complete(150, 0.5).average_cycle_time_ms(&dm150, 200, 7);
        let b = MatchaOverlay::over_complete_circle_explicit(150, 0.5)
            .average_cycle_time_ms(&dm150, 200, 7);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn circle_factorization_partitions_large_cliques() {
        for n in [101usize, 102, 257] {
            let classes = circle_factorization(n);
            assert_eq!(classes.len(), if n % 2 == 0 { n - 1 } else { n });
            let mut seen = std::collections::HashSet::new();
            for cls in &classes {
                let mut touched = vec![false; n];
                for &(i, j) in cls {
                    assert!(i < j && j < n, "bad pair ({i},{j})");
                    assert!(!touched[i] && !touched[j], "n={n}: not a matching");
                    touched[i] = true;
                    touched[j] = true;
                    assert!(seen.insert((i, j)), "n={n}: edge ({i},{j}) repeated");
                }
            }
            assert_eq!(seen.len(), n * (n - 1) / 2, "n={n}: K_n not covered");
        }
        // over_complete routes big n through the circle method
        let m = MatchaOverlay::over_complete(150, 0.5);
        assert_eq!(m.num_matchings(), 149);
    }

    #[test]
    fn sample_round_always_nonempty() {
        let m = MatchaOverlay::over_complete(5, 0.05); // tiny C_b
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let g = m.sample_round(&mut rng);
            assert!(g.m() > 0, "fairness fix guarantees ≥1 matching");
        }
    }

    #[test]
    fn sampled_graph_is_valid_matching_union() {
        let net = Underlay::builtin("geant").unwrap();
        let m = MatchaOverlay::over_graph(&net.core, 0.5);
        let mut rng = Rng::new(2);
        let g = m.sample_round(&mut rng);
        // symmetric
        for (u, v, _) in g.edges() {
            assert!(g.has_edge(v, u));
        }
        // degree bounded by #matchings
        for i in 0..g.n() {
            assert!(g.out_degree(i) <= m.num_matchings());
        }
    }

    #[test]
    fn cycle_time_decreases_with_cb_down_to_a_point() {
        // Lower C_b → fewer active matchings → lower congestion per round.
        let net = Underlay::builtin("geant").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 100e6, 1e9);
        let hi = MatchaOverlay::over_graph(&net.core, 0.9).average_cycle_time_ms(&dm, 400, 7);
        let lo = MatchaOverlay::over_graph(&net.core, 0.3).average_cycle_time_ms(&dm, 400, 7);
        assert!(lo < hi, "lo={lo} hi={hi}");
    }

    #[test]
    fn appendix_b_asymptote_slow_access() {
        // τ_MATCHA+ ≳ (M/C)·C_b·max_degree(G_u) for slow homogeneous access.
        let net = Underlay::builtin("geant").unwrap();
        let wl = Workload::inaturalist();
        let dm = DelayModel::new(&net, &wl, 1, 10e6, 1e9); // 10 Mbps access
        let c_b = 0.5;
        let m = MatchaOverlay::over_graph(&net.core, c_b);
        let tau = m.average_cycle_time_ms(&dm, 600, 3);
        let mc = wl.model_bits / 10e6 * 1e3; // M/C ms
        let bound = mc * c_b * net.core.max_degree() as f64;
        assert!(
            tau > 0.6 * bound,
            "τ={tau} should be ≳ C_b·Δ·M/C = {bound}"
        );
    }

    #[test]
    fn matcha_over_complete_slower_than_matcha_plus_on_sparse_underlay() {
        // Table 3 Géant: MATCHA 452 vs MATCHA+ 106 — coloring the complete
        // connectivity graph forces ≈N matchings and high expected degree.
        let net = Underlay::builtin("geant").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let plain = MatchaOverlay::over_complete(net.n_silos(), 0.5)
            .average_cycle_time_ms(&dm, 300, 5);
        let plus =
            MatchaOverlay::over_graph(&net.core, 0.5).average_cycle_time_ms(&dm, 300, 5);
        assert!(plus < plain, "matcha+ {plus} < matcha {plain}");
    }

    #[test]
    fn expected_max_degree_reasonable() {
        let net = Underlay::builtin("geant").unwrap();
        let m = MatchaOverlay::over_graph(&net.core, 0.5);
        let d = m.expected_max_degree();
        assert!(d > 0.0 && d <= net.core.max_degree() as f64);
    }

    #[test]
    fn mc_batch_split_long_enough_to_clear_transients() {
        for n in [5usize, 11, 40, 87, 100] {
            let b = MatchaOverlay::mc_batches(n, 2000);
            assert!((1..=16).contains(&b), "n={n}: {b} batches");
            // every batch clears the ~n-round cold-start transient
            assert!(2000 / b >= (4 * n).max(20), "n={n}: {} rounds/batch", 2000 / b);
        }
        // a budget smaller than one healthy batch stays a single chain
        assert_eq!(MatchaOverlay::mc_batches(1000, 200), 1);
    }

    #[test]
    fn circle_pair_at_matches_iterator() {
        for n in [101usize, 102, 150, 257] {
            let ppm = circle_pairs_per_matching(n);
            let rounds = if n % 2 == 0 { n - 1 } else { n };
            for r in [0, 1, rounds / 2, rounds - 1] {
                let mut seq = Vec::with_capacity(ppm);
                circle_pairs(n, r, |a, b| seq.push((a, b)));
                assert_eq!(seq.len(), ppm, "n={n} r={r}");
                for (idx, &p) in seq.iter().enumerate() {
                    assert_eq!(circle_pair_at(n, r, idx), p, "n={n} r={r} idx={idx}");
                }
            }
        }
    }

    #[test]
    fn closed_form_degrees_match_touch_counts() {
        // The sampled estimator's degree formula (even n: |active|
        // everywhere; odd n: minus one on each active matching's bye node)
        // against degrees counted by iterating every pair.
        for n in [102usize, 101, 257] {
            let nm = if n % 2 == 0 { n - 1 } else { n };
            let active: Vec<usize> = (0..nm).filter(|r| r % 3 == 0).collect();
            let mut touch = vec![0usize; n];
            for &r in &active {
                circle_pairs(n, r, |i, j| {
                    touch[i] += 1;
                    touch[j] += 1;
                });
            }
            let al = active.len();
            let even = n % 2 == 0;
            for v in 0..n {
                let closed = if even || active.binary_search(&v).is_err() {
                    al
                } else {
                    al - 1
                };
                assert_eq!(touch[v], closed, "n={n} v={v}");
            }
        }
    }

    #[test]
    fn sampled_estimator_within_pinned_band() {
        // The sampled path can only drop max-plus candidates, so it
        // under-estimates; the band pins it within [0.3×, 1.1×] of exact on
        // a 150-silo model where the budget covers ~1/3 of each matching.
        let net = Underlay::by_name("synth:waxman:150:seed7").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 1e9, 1e9);
        let m = MatchaOverlay::over_complete(150, 0.5);
        let exact = m.batch_slope_ms_with(&dm, 400, 7, None);
        let sampled = m.batch_slope_ms_with(&dm, 400, 7, Some(2000));
        assert!(exact > 0.0 && sampled > 0.0, "exact={exact} sampled={sampled}");
        assert!(
            sampled >= 0.3 * exact && sampled <= 1.1 * exact,
            "sampled={sampled} outside pinned band of exact={exact}"
        );
    }

    #[test]
    fn sampled_estimator_deterministic_and_exact_when_budget_covers() {
        let net = Underlay::by_name("synth:waxman:150:seed7").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 1e9, 1e9);
        let m = MatchaOverlay::over_complete(150, 0.5);
        let a = m.batch_slope_ms_with(&dm, 200, 11, Some(2000));
        let b = m.batch_slope_ms_with(&dm, 200, 11, Some(2000));
        assert_eq!(a.to_bits(), b.to_bits());
        // a budget covering every pair of every matching degrades into the
        // exact fold — bit-identical, coins stream untouched by sampling
        let cover = 149 * circle_pairs_per_matching(150);
        let c = m.batch_slope_ms_with(&dm, 200, 11, Some(cover));
        let e = m.batch_slope_ms_with(&dm, 200, 11, None);
        assert_eq!(c.to_bits(), e.to_bits());
    }

    #[test]
    fn deterministic_given_seed() {
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 1e9, 1e9);
        let m = MatchaOverlay::over_complete(11, 0.5);
        let a = m.average_cycle_time_ms(&dm, 200, 42);
        let b = m.average_cycle_time_ms(&dm, 200, 42);
        assert_eq!(a, b);
    }
}
