//! Overlay topology designers — the paper's contribution (Table 1).
//!
//! | designer  | guarantee                    | network regime            |
//! |-----------|------------------------------|---------------------------|
//! | [`star`]  | baseline (server-client)     | —                         |
//! | [`mst`]   | optimal (Prop. 3.1)          | edge-capacitated, undirected |
//! | [`mbst`]  | 6-approx (Alg. 1, Prop. 3.5) | node-capacitated, undirected |
//! | [`ring`]  | 3N-approx (Props. 3.3/3.6)   | any Euclidean             |
//! | [`matcha`]| baseline (Wang et al. 2019)  | —                         |
//!
//! All designers consume a [`DelayModel`] (the measurable inputs of the MCT
//! problem: latencies, available bandwidths, capacities, computation times)
//! and emit an [`Overlay`] whose cycle time is evaluated with the exact
//! Eq.-(3)/Eq.-(5) machinery. When the network is *dynamic* (a
//! `netsim::scenario` perturbation), [`adaptive`] wraps any designer in a
//! monitor/re-design loop that reacts to realized throughput degradation.

pub mod star;
pub mod mst;
pub mod mbst;
pub mod ring;
pub mod matcha;
pub mod enrich;
pub mod adaptive;

use crate::graph::DiGraph;
use crate::netsim::delay::DelayModel;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// The overlay families of Table 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OverlayKind {
    /// Server-client: all silos exchange with a central hub.
    Star,
    /// Minimum spanning tree of G_c^(u) (Prop. 3.1).
    Mst,
    /// Degree-bounded minimum bottleneck tree via Algorithm 1 (Prop. 3.5).
    DeltaMbst,
    /// Directed ring from Christofides' algorithm (Props. 3.3 / 3.6).
    Ring,
    /// MATCHA over the connectivity graph (complete).
    Matcha,
    /// MATCHA⁺ over the underlay graph.
    MatchaPlus,
}

impl OverlayKind {
    pub fn all() -> [OverlayKind; 6] {
        [
            OverlayKind::Star,
            OverlayKind::Matcha,
            OverlayKind::MatchaPlus,
            OverlayKind::Mst,
            OverlayKind::DeltaMbst,
            OverlayKind::Ring,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            OverlayKind::Star => "star",
            OverlayKind::Mst => "mst",
            OverlayKind::DeltaMbst => "delta-mbst",
            OverlayKind::Ring => "ring",
            OverlayKind::Matcha => "matcha",
            OverlayKind::MatchaPlus => "matcha+",
        }
    }

    /// Resolve an overlay-kind name — a thin delegate into the
    /// [`crate::spec::Resolve`] registry (pinned error format, suggestions).
    pub fn by_name(name: &str) -> Result<OverlayKind> {
        <OverlayKind as crate::spec::Resolve>::resolve(name)
    }
}

impl crate::spec::Resolve for OverlayKind {
    const KIND: &'static str = "overlay";

    fn names() -> Vec<&'static str> {
        OverlayKind::all().iter().map(|k| k.name()).collect()
    }

    fn aliases() -> Vec<&'static str> {
        vec!["mbst", "matcha-plus"]
    }

    fn grammar() -> String {
        "star|mst|delta-mbst|ring|matcha|matcha+ (aliases: mbst, matcha-plus)".to_string()
    }

    fn parse_spec(input: &str) -> Result<OverlayKind, crate::spec::ResolveError> {
        use crate::spec::{Resolve, ResolveError};
        Ok(match input {
            "star" => OverlayKind::Star,
            "mst" => OverlayKind::Mst,
            "delta-mbst" | "mbst" => OverlayKind::DeltaMbst,
            "ring" => OverlayKind::Ring,
            "matcha" => OverlayKind::Matcha,
            "matcha+" | "matcha-plus" => OverlayKind::MatchaPlus,
            other => {
                let mut candidates = Self::names();
                candidates.extend(Self::aliases());
                return Err(ResolveError::new(Self::KIND, input, "unknown overlay kind")
                    .expected(Self::grammar())
                    .suggest(other, &candidates));
            }
        })
    }
}

/// A designed overlay: either a static digraph or MATCHA's random process.
#[derive(Clone, Debug)]
pub enum Overlay {
    Static {
        kind: OverlayKind,
        graph: DiGraph,
    },
    Random {
        kind: OverlayKind,
        matcha: matcha::MatchaOverlay,
    },
}

impl Overlay {
    pub fn kind(&self) -> OverlayKind {
        match self {
            Overlay::Static { kind, .. } => *kind,
            Overlay::Random { kind, .. } => *kind,
        }
    }

    pub fn n(&self) -> usize {
        match self {
            Overlay::Static { graph, .. } => graph.n(),
            Overlay::Random { matcha, .. } => matcha.n(),
        }
    }

    /// Cycle time in ms.
    ///
    /// * STAR — the non-pipelined FedAvg round (hub gathers all, then
    ///   broadcasts): `s·T_c + max_i up_i + max_i dn_i`, App. B's model.
    /// * other static overlays — exact max cycle mean (Eq. 5) via the
    ///   size-dispatched Karp/Howard solver.
    /// * MATCHA — Monte-Carlo average over the round process (seeded; the
    ///   paper: "we compute their average cycle time", footnote 6). The
    ///   sampled-round budget keeps the paper's 2000 rounds on every
    ///   builtin network (n ≤ 100) and scales it down ∝ 1/n on big
    ///   synthetic underlays, where each round costs Θ(n²) arc work and the
    ///   slope estimator converges in far fewer rounds anyway. The floor is
    ///   200 rounds up to 4096 silos — every pre-PR-5 budget, bit-for-bit —
    ///   and 24 rounds beyond, where a K_n round graph mixes in O(1) rounds
    ///   and each round is ~C_b·n²/2 pair folds (at 20 000 silos: ~10⁸ per
    ///   round; the lower floor is what keeps the scale acceptance
    ///   tractable at sizes the dense layout could never reach anyway).
    ///   The budget is split into independent per-seeded batches reduced
    ///   in order (PR 3), so the estimate is bit-identical for any
    ///   `--jobs`.
    pub fn cycle_time_ms(&self, dm: &DelayModel) -> f64 {
        match self {
            Overlay::Static {
                kind: OverlayKind::Star,
                graph,
            } => dm.star_cycle_time_ms(star_hub(graph)),
            Overlay::Static { graph, .. } => dm.cycle_time_ms(graph),
            Overlay::Random { matcha, .. } => {
                let n = matcha.n().max(1);
                let floor = if n <= 4096 { 200 } else { 24 };
                let rounds = (200_000 / n).clamp(floor, 2000);
                matcha.average_cycle_time_ms(dm, rounds, 0xC1C1E)
            }
        }
    }

    /// Simulated wall-clock (ms) at which each round 0..=rounds completes:
    /// the Algorithm-3 reconstruction, specialised per overlay family.
    pub fn wallclock_ms(&self, dm: &DelayModel, rounds: usize, seed: u64) -> Vec<f64> {
        match self {
            Overlay::Static {
                kind: OverlayKind::Star,
                graph,
            } => {
                // non-pipelined rounds: exact arithmetic progression
                let tau = dm.star_cycle_time_ms(star_hub(graph));
                (0..=rounds).map(|k| tau * k as f64).collect()
            }
            Overlay::Static { graph, .. } => {
                crate::netsim::timeline::round_completion_ms(dm, graph, rounds)
            }
            Overlay::Random { .. } => {
                // replay the exact per-round sampled graphs through the
                // time-varying recurrence
                let n = self.n();
                let mut t = vec![0.0f64; n];
                let mut out = Vec::with_capacity(rounds + 1);
                out.push(0.0);
                for k in 0..rounds {
                    let g = self.round_graph(k, seed);
                    let mut next: Vec<f64> =
                        (0..n).map(|i| t[i] + dm.compute_ms(i)).collect();
                    for (j, i, d) in dm.arc_delays(&g) {
                        let cand = t[j] + d;
                        if cand > next[i] {
                            next[i] = cand;
                        }
                    }
                    t = next;
                    out.push(t.iter().cloned().fold(f64::NEG_INFINITY, f64::max));
                }
                out
            }
        }
    }

    /// The communication digraph used in round `k` (static overlays return
    /// their graph; MATCHA samples matchings with a per-round seed).
    pub fn round_graph(&self, k: usize, seed: u64) -> DiGraph {
        match self {
            Overlay::Static { graph, .. } => graph.clone(),
            Overlay::Random { matcha, .. } => {
                let mut rng = Rng::new(seed ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                matcha.sample_round(&mut rng)
            }
        }
    }

    /// For static overlays, expose the digraph.
    pub fn static_graph(&self) -> Option<&DiGraph> {
        match self {
            Overlay::Static { graph, .. } => Some(graph),
            Overlay::Random { .. } => None,
        }
    }
}

/// Design an overlay of the requested kind for this delay model.
/// All designers are deterministic; `c_b` is MATCHA's communication budget.
pub fn design(kind: OverlayKind, dm: &DelayModel, c_b: f64) -> Result<Overlay> {
    Ok(match kind {
        OverlayKind::Star => Overlay::Static {
            kind,
            graph: star::design(dm),
        },
        OverlayKind::Mst => Overlay::Static {
            kind,
            graph: mst::design(dm),
        },
        OverlayKind::DeltaMbst => Overlay::Static {
            kind,
            graph: mbst::design(dm),
        },
        OverlayKind::Ring => Overlay::Static {
            kind,
            graph: ring::design(dm, false),
        },
        OverlayKind::Matcha => Overlay::Random {
            kind,
            matcha: matcha::MatchaOverlay::over_complete(dm.n, c_b),
        },
        OverlayKind::MatchaPlus => {
            bail!("MATCHA+ needs the underlay graph; use design_with_underlay()")
        }
    })
}

/// Hub of a star digraph: the node with the largest out-degree.
pub(crate) fn star_hub(g: &DiGraph) -> usize {
    (0..g.n()).max_by_key(|&i| g.out_degree(i)).unwrap_or(0)
}

/// Like [`design`] but with underlay access (required by MATCHA⁺, which
/// colors the *underlay* topology; harmless for the others).
pub fn design_with_underlay(
    kind: OverlayKind,
    dm: &DelayModel,
    underlay: &crate::netsim::underlay::Underlay,
    c_b: f64,
) -> Result<Overlay> {
    match kind {
        OverlayKind::MatchaPlus => Ok(Overlay::Random {
            kind,
            matcha: matcha::MatchaOverlay::over_graph(&underlay.core, c_b),
        }),
        other => design(other, dm, c_b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;

    #[test]
    fn kind_names_roundtrip() {
        for k in OverlayKind::all() {
            assert_eq!(OverlayKind::by_name(k.name()).unwrap(), k);
        }
        assert!(OverlayKind::by_name("torus").is_err());
    }

    #[test]
    fn design_all_static_kinds_on_gaia() {
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        for kind in [
            OverlayKind::Star,
            OverlayKind::Mst,
            OverlayKind::DeltaMbst,
            OverlayKind::Ring,
        ] {
            let ov = design(kind, &dm, 0.5).unwrap();
            let g = ov.static_graph().unwrap();
            assert!(g.is_strongly_connected(), "{kind:?} must be strong");
            assert!(ov.cycle_time_ms(&dm) > 0.0);
        }
    }

    #[test]
    fn matcha_plus_requires_underlay() {
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        assert!(design(OverlayKind::MatchaPlus, &dm, 0.5).is_err());
        let ov = design_with_underlay(OverlayKind::MatchaPlus, &dm, &net, 0.5).unwrap();
        assert_eq!(ov.kind(), OverlayKind::MatchaPlus);
    }

    #[test]
    fn table3_ordering_holds_on_big_sparse_networks() {
        // The paper's headline: on Exodus/Ebone with 10 Gbps access, the
        // RING and the trees beat MATCHA(+) which beats the STAR.
        for name in ["exodus", "ebone"] {
            let net = Underlay::builtin(name).unwrap();
            let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
            let tau = |k| {
                design_with_underlay(k, &dm, &net, 0.5)
                    .unwrap()
                    .cycle_time_ms(&dm)
            };
            let star = tau(OverlayKind::Star);
            let ring = tau(OverlayKind::Ring);
            let mst = tau(OverlayKind::Mst);
            let matcha_p = tau(OverlayKind::MatchaPlus);
            assert!(ring < star, "{name}: ring {ring} < star {star}");
            assert!(mst < star, "{name}: mst {mst} < star {star}");
            assert!(matcha_p < star, "{name}: matcha+ {matcha_p} < star {star}");
            // the paper itself has MATCHA+/MST edging out the RING on some
            // networks (Géant, Table 3) — require parity, not dominance
            assert!(
                ring < 1.15 * matcha_p,
                "{name}: ring {ring} ≲ matcha+ {matcha_p}"
            );
            // and the big-network headline: near-order-of-magnitude speedup
            assert!(
                star / ring > 5.0,
                "{name}: star/ring speedup {}",
                star / ring
            );
        }
    }
}
