//! Karp's maximum-cycle-mean algorithm (Karp 1978) + critical circuit.
//!
//! For a digraph with n nodes the maximum mean weight over all circuits is
//!
//! `λ* = max_v min_{0 ≤ k < n} ( D_n(v) − D_k(v) ) / (n − k)`
//!
//! where `D_k(v)` is the maximum weight of a k-arc walk ending at `v`
//! (max-plus matrix power applied to the all-zero vector). O(V·E) time,
//! O(V²) space — instantaneous for ≤100-silo overlays, and fast enough to
//! sit inside MATCHA's Monte-Carlo loop and Algorithm 1's candidate scan.

use super::DelayDigraph;

/// Maximum cycle mean of `g`, or `None` if `g` is acyclic.
pub fn max_cycle_mean(g: &DelayDigraph) -> Option<f64> {
    max_cycle_mean_with_cycle(g).map(|(l, _)| l)
}

/// Maximum cycle mean plus one *critical circuit* achieving it (as a node
/// sequence `[v_0, v_1, …, v_0]`).
pub fn max_cycle_mean_with_cycle(g: &DelayDigraph) -> Option<(f64, Vec<usize>)> {
    let n = g.n;
    if n == 0 || g.arcs.is_empty() {
        return None;
    }
    const NEG: f64 = f64::NEG_INFINITY;

    // D[k][v] = max weight of a k-arc walk ending at v, from any start
    // (standard trick: virtual source connected to all nodes with weight 0,
    // implemented by initializing D[0][*] = 0).
    let mut d = vec![vec![NEG; n]; n + 1];
    let mut parent = vec![vec![usize::MAX; n]; n + 1];
    for v in 0..n {
        d[0][v] = 0.0;
    }
    for k in 1..=n {
        for &(u, v, w) in &g.arcs {
            if d[k - 1][u] > NEG {
                let cand = d[k - 1][u] + w;
                if cand > d[k][v] {
                    d[k][v] = cand;
                    parent[k][v] = u;
                }
            }
        }
    }

    // λ* = max_v min_k (D_n(v) − D_k(v)) / (n − k)
    let mut best: Option<(f64, usize)> = None; // (λ, argmax v)
    for v in 0..n {
        if d[n][v] == NEG {
            continue; // no n-arc walk ends at v
        }
        let mut min_over_k = f64::INFINITY;
        for k in 0..n {
            if d[k][v] > NEG {
                let mean = (d[n][v] - d[k][v]) / (n - k) as f64;
                if mean < min_over_k {
                    min_over_k = mean;
                }
            }
        }
        match best {
            None => best = Some((min_over_k, v)),
            Some((l, _)) if min_over_k > l => best = Some((min_over_k, v)),
            _ => {}
        }
    }
    let (lambda, v_star) = best?;

    // Extract a critical circuit: walk parents back from (n, v*); any node
    // repetition on this maximal-weight walk closes a circuit of mean λ*.
    let mut walk = vec![v_star];
    let mut cur = v_star;
    let mut k = n;
    while k > 0 && parent[k][cur] != usize::MAX {
        cur = parent[k][cur];
        walk.push(cur);
        k -= 1;
    }
    walk.reverse(); // chronological order
    // find a repeated node
    let mut first_seen = std::collections::HashMap::new();
    let mut cycle = Vec::new();
    for (idx, &node) in walk.iter().enumerate() {
        if let Some(&prev) = first_seen.get(&node) {
            cycle = walk[prev..=idx].to_vec();
            break;
        }
        first_seen.insert(node, idx);
    }
    if cycle.is_empty() {
        // The max-mean walk had no repetition (can happen when λ is achieved
        // by a short cycle not on this particular walk); fall back to the
        // λ-value alone with a degenerate marker.
        cycle = vec![v_star];
    }
    Some((lambda, cycle))
}

/// *Minimum* cycle mean — not used by the paper's objective (which maximizes
/// over circuits) but handy for validation and exposed for completeness.
pub fn min_cycle_mean(g: &DelayDigraph) -> Option<f64> {
    let neg = DelayDigraph {
        n: g.n,
        arcs: g.arcs.iter().map(|&(u, v, w)| (u, v, -w)).collect(),
    };
    // max_cycle_mean rejects negative delays only via DelayDigraph::arc,
    // which we bypassed on purpose here.
    max_cycle_mean(&neg).map(|l| -l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn ring(delays: &[f64]) -> DelayDigraph {
        let n = delays.len();
        let mut g = DelayDigraph::new(n);
        for i in 0..n {
            g.arc(i, (i + 1) % n, delays[i]);
        }
        g
    }

    #[test]
    fn single_ring_mean() {
        let g = ring(&[1.0, 3.0, 3.0, 1.0]);
        assert!((g.cycle_time() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn self_loop_is_a_cycle() {
        let mut g = DelayDigraph::new(2);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 1.0);
        g.arc(0, 0, 5.0); // slow local computation dominates
        assert!((g.cycle_time() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn two_cycles_max_wins() {
        // cycle A: 0→1→0 mean 2; cycle B: 2→3→2 mean 4
        let mut g = DelayDigraph::new(4);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 3.0);
        g.arc(2, 3, 4.0);
        g.arc(3, 2, 4.0);
        g.arc(1, 2, 0.0); // connect them (arbitrary direction)
        assert!((g.cycle_time() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn acyclic_returns_none() {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 1.0);
        assert!(max_cycle_mean(&g).is_none());
    }

    #[test]
    fn paper_appendix_c_three_node_example() {
        // Fig. 5a: undirected overlay {(1,2),(2,3)} has τ = 3;
        // the directed ring 1→2→3→1 has τ = 8/3.
        // Delays: d(1,2)=d(2,1)=1, d(2,3)=d(3,2)=3, d(3,1)=d(1,3)=4.
        let mut undirected = DelayDigraph::new(3);
        for (a, b, w) in [(0, 1, 1.0), (1, 0, 1.0), (1, 2, 3.0), (2, 1, 3.0)] {
            undirected.arc(a, b, w);
        }
        assert!((undirected.cycle_time() - 3.0).abs() < 1e-9);

        let mut directed = DelayDigraph::new(3);
        directed.arc(0, 1, 1.0);
        directed.arc(1, 2, 3.0);
        directed.arc(2, 0, 4.0);
        assert!((directed.cycle_time() - 8.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn paper_appendix_c_family_example() {
        // Fig. 5b with n = 5: undirected overlay τ = n; directed ring
        // τ = (4n − 2)/(n + 1) < 4.
        let n = 5usize;
        // Underlay: path 1-2-…-n with delays 1, plus node n+1 attached to n
        // with delay n, and the "closing" link n+1 → 1 with delay
        // n + (n-1)·1 (the long way back), per the figure's construction.
        // Undirected tree = the path + pendant: critical edge delay n.
        let mut undirected = DelayDigraph::new(n + 1);
        for i in 0..n - 1 {
            undirected.arc(i, i + 1, 1.0);
            undirected.arc(i + 1, i, 1.0);
        }
        undirected.arc(n - 1, n, n as f64);
        undirected.arc(n, n - 1, n as f64);
        assert!((undirected.cycle_time() - n as f64).abs() < 1e-9);

        let mut ringg = DelayDigraph::new(n + 1);
        for i in 0..n - 1 {
            ringg.arc(i, i + 1, 1.0);
        }
        ringg.arc(n - 1, n, n as f64);
        ringg.arc(n, 0, n as f64 + (n as f64 - 1.0));
        let tau = ringg.cycle_time();
        let expect = (4.0 * n as f64 - 2.0) / (n as f64 + 1.0);
        assert!((tau - expect).abs() < 1e-9, "τ={tau} expect={expect}");
        assert!(tau < 4.0);
    }

    #[test]
    fn critical_cycle_mean_matches_lambda() {
        let mut g = DelayDigraph::new(5);
        g.arc(0, 1, 2.0);
        g.arc(1, 2, 2.0);
        g.arc(2, 0, 5.0); // cycle mean 3
        g.arc(2, 3, 1.0);
        g.arc(3, 4, 1.0);
        g.arc(4, 2, 1.0); // cycle mean 1
        let (lambda, cyc) = max_cycle_mean_with_cycle(&g).unwrap();
        assert!((lambda - 3.0).abs() < 1e-9);
        if cyc.len() > 1 {
            assert_eq!(cyc.first(), cyc.last());
            // verify the extracted circuit really has mean λ
            let mut w = 0.0;
            for pair in cyc.windows(2) {
                w += g
                    .arcs
                    .iter()
                    .filter(|&&(u, v, _)| u == pair[0] && v == pair[1])
                    .map(|&(_, _, d)| d)
                    .fold(f64::NEG_INFINITY, f64::max);
            }
            let mean = w / (cyc.len() - 1) as f64;
            assert!((mean - lambda).abs() < 1e-9, "cycle {cyc:?} mean {mean}");
        }
    }

    #[test]
    fn min_cycle_mean_sanity() {
        let mut g = DelayDigraph::new(4);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 1.0); // mean 1
        g.arc(2, 3, 4.0);
        g.arc(3, 2, 4.0); // mean 4
        g.arc(1, 2, 2.0);
        assert!((min_cycle_mean(&g).unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn prop_karp_vs_bruteforce_on_small_digraphs() {
        check("karp equals brute-force cycle mean", 60, |gen: &mut Gen| {
            let n = gen.usize(2, 7);
            let mut g = DelayDigraph::new(n);
            for u in 0..n {
                for v in 0..n {
                    if u != v && gen.bool(0.5) {
                        g.arc(u, v, gen.f64(0.0, 10.0));
                    }
                }
            }
            // ensure at least one cycle: a ring over all nodes
            for i in 0..n {
                if !g.arcs.iter().any(|&(a, b, _)| a == i && b == (i + 1) % n) {
                    g.arc(i, (i + 1) % n, gen.f64(0.0, 10.0));
                }
            }
            let karp = max_cycle_mean(&g).unwrap();
            let brute = brute_force_max_mean(&g);
            assert!(
                (karp - brute).abs() < 1e-6,
                "karp={karp} brute={brute} arcs={:?}",
                g.arcs
            );
        });
    }

    /// Enumerate all elementary circuits by DFS (n ≤ 7 in the test).
    fn brute_force_max_mean(g: &DelayDigraph) -> f64 {
        let n = g.n;
        let mut adj = vec![Vec::new(); n];
        for &(u, v, w) in &g.arcs {
            adj[u].push((v, w));
        }
        let mut best = f64::NEG_INFINITY;
        fn dfs(
            start: usize,
            cur: usize,
            weight: f64,
            len: usize,
            visited: &mut Vec<bool>,
            adj: &Vec<Vec<(usize, f64)>>,
            best: &mut f64,
        ) {
            for &(nxt, w) in &adj[cur] {
                if nxt == start {
                    let mean = (weight + w) / (len + 1) as f64;
                    if mean > *best {
                        *best = mean;
                    }
                } else if nxt > start && !visited[nxt] {
                    visited[nxt] = true;
                    dfs(start, nxt, weight + w, len + 1, visited, adj, best);
                    visited[nxt] = false;
                }
            }
        }
        for s in 0..n {
            let mut visited = vec![false; n];
            visited[s] = true;
            dfs(s, s, 0.0, 0, &mut visited, &adj, &mut best);
        }
        best
    }
}
