//! Exact event-time simulation of Eq. (4) — the paper's Algorithm 3.
//!
//! `t_i(k+1) = max_{j ∈ N_i⁺ ∪ {i}} ( t_j(k) + d_o(j, i) )`
//!
//! The simulator reconstructs the wall-clock timeline of a training run on a
//! given overlay: `t_i(k)` is when silo i starts its k-th computation phase.
//! The paper's key theorem is that `t_i(k) ≈ τ·k` with bounded error, τ the
//! max cycle mean — cross-checked against Karp in the tests below and used
//! to map loss-vs-round curves into loss-vs-time curves (Fig. 2 bottom row).
//!
//! ## Storage & kernels (PR 5)
//!
//! [`Timeline`] holds the whole `(rounds+1) × n` event matrix in **one**
//! flat allocation (`Timeline::row` / `Timeline::at` index it), and the
//! shared kernel comes in three forms:
//!
//! * [`step`] — legacy allocating form over a nested in-adjacency view;
//!   retained as the dense equivalence oracle.
//! * [`step_into`] — the same fold writing into a caller-provided buffer.
//! * [`step_csr_into`] — the flat form over a [`CsrDelayDigraph`]: the
//!   zero-allocation path every per-round simulator
//!   ([`Timeline::simulate_reweighted`], `netsim::timeline::DynamicTimeline`,
//!   `topology::adaptive`, `fl::trainsim`) now drives.
//!
//! All three produce bit-identical results on identical weights: the fold
//! is a pure `max` over `prev[j] + d` candidates, and IEEE max is exactly
//! commutative on the finite delays the model emits.
//!
//! Sentinel contract: "no candidate yet" is **`f64::NEG_INFINITY`**
//! everywhere (a silo with no in-arcs at all falls back to `prev[i]` so
//! event times stay monotone). `f64::MIN` is *not* a fold identity — it
//! silently clamps legitimate values below ≈ −1.8e308 and, worse, reads as
//! "a real candidate existed"; PR 5 unified the one stray `f64::MIN` fold
//! (`cycle_time_estimate`) onto `NEG_INFINITY`, pinned by the isolated-silo
//! regression test below.
//!
//! ## Row-partitioned intra-cell kernels (PR 10)
//!
//! [`step_csr_chunked_into`] / [`step_csr_batched_chunked_into`] split the
//! in-adjacency CSR into contiguous destination-row chunks
//! ([`CsrDelayDigraph::row_chunk`]) and fold each chunk on an intra-cell
//! pool worker. Bit-identity with the sequential kernels is *structural*:
//! every chunk boundary is a row boundary, so a destination's fold never
//! crosses a worker, and every worker runs the **same** per-row fold
//! ([`fold_row`] / [`fold_row_batched`] — shared with the sequential
//! kernels) in the same arc order with the same `>` comparison. The
//! [`step_csr_auto_into`] / [`step_csr_batched_auto_into`] dispatchers add
//! a size gate ([`INTRACELL_MIN_FOLDS`] on arcs × lanes) so small rounds
//! never pay synchronization overhead; below the gate they *are* the
//! sequential kernels, which survive unchanged as the oracles. The chunked
//! path allocates nothing per call (the resident pool and on-the-fly chunk
//! bounds need no per-part buffers), keeping the `benches/memory.rs`
//! zero-alloc warm-round contract.

use super::csr::{BatchedCsrWeights, CsrDelayDigraph};
use super::DelayDigraph;
use crate::util::parallel;

/// One synchronous step of Eq. (4) over an in-adjacency view (`inn[i]` =
/// `[(j, d_o(j,i))]`, as produced by [`DelayDigraph::in_arcs`]).
///
/// Self-loops `d_o(i,i)` may or may not be explicit arcs; the DelayDigraph
/// convention is that callers add them explicitly (the delay model always
/// does). If a silo has no in-arcs at all it would stall — guard with a
/// `prev[i]` fallback so event times stay monotone.
///
/// Allocating legacy form — the dense oracle. Hot paths use [`step_into`]
/// or [`step_csr_into`] instead.
pub fn step(prev: &[f64], inn: &[Vec<(usize, f64)>]) -> Vec<f64> {
    let mut next = vec![f64::NEG_INFINITY; inn.len()];
    step_into(prev, inn, &mut next);
    next
}

/// [`step`] into a caller-provided buffer (`next.len() == inn.len()`).
pub fn step_into(prev: &[f64], inn: &[Vec<(usize, f64)>], next: &mut [f64]) {
    let n = inn.len();
    assert_eq!(prev.len(), n);
    assert_eq!(next.len(), n);
    for i in 0..n {
        let mut best = f64::NEG_INFINITY;
        for &(j, d) in &inn[i] {
            let cand = prev[j] + d;
            if cand > best {
                best = cand;
            }
        }
        next[i] = if best == f64::NEG_INFINITY { prev[i] } else { best };
    }
}

/// The one per-destination fold both the sequential and the row-partitioned
/// CSR kernels run: max over `prev[j] + d` across silo `i`'s in-arcs in CSR
/// order, `NEG_INFINITY ⇒ prev[i]` fallback. Sharing this body is what
/// makes chunked-vs-sequential bit-identity structural rather than a
/// maintenance invariant.
#[inline(always)]
fn fold_row(prev: &[f64], g: &CsrDelayDigraph, i: usize) -> f64 {
    let (srcs, ws) = g.in_arcs_of(i);
    let mut best = f64::NEG_INFINITY;
    for (&j, &d) in srcs.iter().zip(ws) {
        let cand = prev[j as usize] + d;
        if cand > best {
            best = cand;
        }
    }
    if best == f64::NEG_INFINITY {
        prev[i]
    } else {
        best
    }
}

/// The batched per-destination fold (all `S` lanes of silo `i` into `out`),
/// shared by [`step_csr_batched_into`] and the row-partitioned variant for
/// the same structural-bit-identity reason as [`fold_row`].
#[inline(always)]
fn fold_row_batched(
    prev: &[f64],
    g: &CsrDelayDigraph,
    w: &BatchedCsrWeights,
    i: usize,
    out: &mut [f64],
) {
    let s = w.lanes();
    out.fill(f64::NEG_INFINITY);
    for k in g.in_arc_range(i) {
        let j = g.arc_src(k);
        let pj = &prev[j * s..(j + 1) * s];
        let ws = w.arc_lanes(k);
        for l in 0..s {
            let cand = pj[l] + ws[l];
            if cand > out[l] {
                out[l] = cand;
            }
        }
    }
    let pi = &prev[i * s..(i + 1) * s];
    for l in 0..s {
        if out[l] == f64::NEG_INFINITY {
            out[l] = pi[l];
        }
    }
}

/// A `*mut f64` that crosses the intra-cell dispatch. Safety is by the
/// row-chunk contract: [`CsrDelayDigraph::row_chunk`] ranges are disjoint
/// and each worker writes only its own rows, so no element is aliased.
#[derive(Clone, Copy)]
struct RowsPtr(*mut f64);
unsafe impl Send for RowsPtr {}
unsafe impl Sync for RowsPtr {}

/// The flat-kernel form of [`step`]: fold round `k+1` from `prev` over a
/// [`CsrDelayDigraph`] into `next`, with **zero** heap allocation. Same
/// fold, same sentinel, same `prev[i]` fallback — bit-identical to [`step`]
/// whenever the arc weights are bit-identical (pinned in tests and by
/// `tests/csr_equiv.rs`). This sequential form is the oracle for the
/// row-partitioned [`step_csr_chunked_into`].
pub fn step_csr_into(prev: &[f64], g: &CsrDelayDigraph, next: &mut [f64]) {
    let n = g.n();
    assert_eq!(prev.len(), n);
    assert_eq!(next.len(), n);
    for i in 0..n {
        next[i] = fold_row(prev, g, i);
    }
}

/// Row-partitioned [`step_csr_into`]: destination rows split into `parts`
/// contiguous chunks ([`CsrDelayDigraph::row_chunk`]), each folded on an
/// intra-cell worker with the identical [`fold_row`] body. Bit-identical to
/// the sequential kernel for **any** `parts` and any worker count — a
/// destination's fold never crosses a chunk (pinned in `tests/csr_equiv.rs`).
/// Zero heap allocation per call once the resident pool is warm.
pub fn step_csr_chunked_into(prev: &[f64], g: &CsrDelayDigraph, next: &mut [f64], parts: usize) {
    let n = g.n();
    assert_eq!(prev.len(), n);
    assert_eq!(next.len(), n);
    if parts <= 1 {
        step_csr_into(prev, g, next);
        return;
    }
    let out = RowsPtr(next.as_mut_ptr());
    parallel::run_intracell(parts, |p| {
        for i in g.row_chunk(p, parts) {
            // SAFETY: row_chunk ranges are disjoint across parts and each
            // part is claimed exactly once, so writes never alias.
            unsafe { *out.0.add(i) = fold_row(prev, g, i) };
        }
    });
}

/// Auto-dispatching [`step_csr_into`]: the row-partitioned kernel when the
/// resolved intra-cell worker count exceeds one **and** the fold count
/// (arcs) clears [`INTRACELL_MIN_FOLDS`]; the sequential oracle otherwise.
/// A perf switch, never a semantics switch — output is bit-identical either
/// way.
pub fn step_csr_auto_into(prev: &[f64], g: &CsrDelayDigraph, next: &mut [f64]) {
    let parts = parallel::intracell_jobs();
    if parts <= 1 || g.arcs() < INTRACELL_MIN_FOLDS {
        step_csr_into(prev, g, next);
    } else {
        step_csr_chunked_into(prev, g, next, parts);
    }
}

/// Minimum fold count (arcs × lanes) before the auto dispatchers engage the
/// row-partitioned kernels. Below this, one round's fold is ~tens of
/// microseconds — cheaper than waking the pool — so small-N rounds (every
/// real-topology cell: gaia, geant, aws, exodus, ebone) stay on the
/// sequential path and the intra-cell machinery is exercised only where it
/// pays (six-figure synthetic silos, wide lane batches).
pub const INTRACELL_MIN_FOLDS: usize = 1 << 15;

/// The batched SoA form of [`step_csr_into`] (PR 6): advance `S` weight
/// lanes of one shared structure in a single pass. State is lane-fastest
/// like the weights — silo `i`'s lanes are `prev[i*S..(i+1)*S]` — so the
/// inner loop (lanes of one arc) reads three contiguous blocks (`prev`
/// row of the source, weight block of the arc, accumulator row of the
/// destination) with unit stride: auto-vectorizable, one pass over the
/// weights per round.
///
/// Bit-identity with the per-cell kernel is structural, not accidental:
/// for every lane `l` the fold visits the same arcs in the same global CSR
/// order, computes the same `prev[j*S+l] + w[k*S+l]` candidates, compares
/// with the same `>`, and applies the same `NEG_INFINITY ⇒ prev` fallback
/// per lane — exactly [`step_csr_into`] run on lane `l` alone (pinned in
/// the tests below and in `tests/csr_equiv.rs`). Zero heap allocation.
pub fn step_csr_batched_into(
    prev: &[f64],
    g: &CsrDelayDigraph,
    w: &BatchedCsrWeights,
    next: &mut [f64],
) {
    let n = g.n();
    let s = w.lanes();
    assert_eq!(w.arcs(), g.arcs(), "weights built for another structure");
    assert_eq!(prev.len(), n * s);
    assert_eq!(next.len(), n * s);
    for i in 0..n {
        fold_row_batched(prev, g, w, i, &mut next[i * s..(i + 1) * s]);
    }
}

/// Row-partitioned [`step_csr_batched_into`]: the batched counterpart of
/// [`step_csr_chunked_into`] — same chunk geometry (a destination's `S`
/// lanes live in one contiguous state block, so row-boundary chunks keep
/// every lane of a destination on one worker), same shared
/// [`fold_row_batched`] body, bit-identical for any `parts`/worker count.
pub fn step_csr_batched_chunked_into(
    prev: &[f64],
    g: &CsrDelayDigraph,
    w: &BatchedCsrWeights,
    next: &mut [f64],
    parts: usize,
) {
    let n = g.n();
    let s = w.lanes();
    assert_eq!(w.arcs(), g.arcs(), "weights built for another structure");
    assert_eq!(prev.len(), n * s);
    assert_eq!(next.len(), n * s);
    if parts <= 1 {
        step_csr_batched_into(prev, g, w, next);
        return;
    }
    let out = RowsPtr(next.as_mut_ptr());
    parallel::run_intracell(parts, |p| {
        for i in g.row_chunk(p, parts) {
            // SAFETY: disjoint row ranges × lane-contiguous state blocks ⇒
            // `[i*s, (i+1)*s)` is written by exactly one worker.
            let row = unsafe { std::slice::from_raw_parts_mut(out.0.add(i * s), s) };
            fold_row_batched(prev, g, w, i, row);
        }
    });
}

/// Auto-dispatching [`step_csr_batched_into`] — the batched analogue of
/// [`step_csr_auto_into`], gating on arcs × lanes.
pub fn step_csr_batched_auto_into(
    prev: &[f64],
    g: &CsrDelayDigraph,
    w: &BatchedCsrWeights,
    next: &mut [f64],
) {
    let parts = parallel::intracell_jobs();
    if parts <= 1 || g.arcs().saturating_mul(w.lanes()) < INTRACELL_MIN_FOLDS {
        step_csr_batched_into(prev, g, w, next);
    } else {
        step_csr_batched_chunked_into(prev, g, w, next, parts);
    }
}

/// The full event-time matrix `t_i(k)`, `k = 0..=rounds`, stored flat
/// (row-major by round) in a single allocation.
#[derive(Clone, Debug)]
pub struct Timeline {
    n: usize,
    t: Vec<f64>,
}

impl Timeline {
    /// Simulate `rounds` rounds from `t_i(0) = 0`.
    pub fn simulate(g: &DelayDigraph, rounds: usize) -> Timeline {
        let inn = g.in_arcs();
        let n = g.n;
        assert!(n > 0, "empty digraph");
        let mut t = vec![0.0f64; (rounds + 1) * n];
        for k in 0..rounds {
            let (head, tail) = t.split_at_mut((k + 1) * n);
            step_into(&head[k * n..], &inn, &mut tail[..n]);
        }
        Timeline { n, t }
    }

    /// Time-varying Eq. (4): the delay digraph is re-sampled every round
    /// (`digraph_at(k)` supplies round k's digraph), which is how scenario
    /// perturbations — drift, congestion, stragglers, churn — and MATCHA's
    /// random matchings enter the wall-clock reconstruction.
    ///
    /// With a constant digraph this is bit-for-bit identical to
    /// [`Timeline::simulate`] (same [`step`] kernel, same fold order).
    /// This is the **dense oracle** form: it materializes a digraph + its
    /// nested in-adjacency per round. The production path is
    /// [`Timeline::simulate_reweighted`].
    pub fn simulate_dynamic(
        n: usize,
        rounds: usize,
        mut digraph_at: impl FnMut(usize) -> DelayDigraph,
    ) -> Timeline {
        assert!(n > 0, "empty digraph");
        let mut t = vec![0.0f64; (rounds + 1) * n];
        for k in 0..rounds {
            let g = digraph_at(k);
            assert_eq!(g.n, n, "round {k}: digraph changed size");
            let (head, tail) = t.split_at_mut((k + 1) * n);
            step_into(&head[k * n..], &g.in_arcs(), &mut tail[..n]);
        }
        Timeline { n, t }
    }

    /// The zero-allocation time-varying form: one reusable
    /// [`CsrDelayDigraph`] whose weights `reweight(k, g)` mutates in place
    /// before each round's [`step_csr_into`]. After the single upfront
    /// event-matrix allocation, the loop performs **no** heap allocation —
    /// `benches/memory.rs` gates this with a counting allocator.
    ///
    /// Fed weights bit-identical to what `digraph_at` would build,
    /// the trajectory equals [`Timeline::simulate_dynamic`]'s bit for bit.
    ///
    /// Steps through [`step_csr_auto_into`], so large cells row-partition
    /// across the intra-cell pool — a perf switch only; the trajectory is
    /// bit-identical for any worker count.
    pub fn simulate_reweighted(
        g: &mut CsrDelayDigraph,
        rounds: usize,
        mut reweight: impl FnMut(usize, &mut CsrDelayDigraph),
    ) -> Timeline {
        let n = g.n();
        assert!(n > 0, "empty digraph");
        let mut t = vec![0.0f64; (rounds + 1) * n];
        for k in 0..rounds {
            reweight(k, &mut *g);
            let (head, tail) = t.split_at_mut((k + 1) * n);
            step_csr_auto_into(&head[k * n..], &*g, &mut tail[..n]);
        }
        Timeline { n, t }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn rounds(&self) -> usize {
        self.t.len() / self.n - 1
    }

    /// Event times of round `k` as a contiguous slice (`t_i(k)` at `[i]`).
    #[inline]
    pub fn row(&self, k: usize) -> &[f64] {
        &self.t[k * self.n..(k + 1) * self.n]
    }

    /// `t_i(k)`.
    #[inline]
    pub fn at(&self, k: usize, i: usize) -> f64 {
        self.t[k * self.n + i]
    }

    /// Empirical cycle time: slope of `max_i t_i(k)` over the last half of
    /// the horizon (skipping the transient, as the theory prescribes).
    pub fn cycle_time_estimate(&self) -> f64 {
        let k_end = self.rounds();
        assert!(k_end >= 2, "need ≥2 rounds to estimate a slope");
        let k_mid = k_end / 2;
        (self.round_completion(k_end) - self.round_completion(k_mid)) / (k_end - k_mid) as f64
    }

    /// Completion time of round k (when the slowest silo starts round k).
    pub fn round_completion(&self, k: usize) -> f64 {
        self.row(k).iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// `S` event-time matrices advancing in lockstep over one shared structure
/// — the batched counterpart of [`Timeline`] (PR 6). Storage is one flat
/// allocation, round-major then silo then lane:
/// `t[(k * n + i) * lanes + l]`, matching the lane-fastest state layout
/// [`step_csr_batched_into`] consumes, so each round steps directly from
/// the previous round's slice with no copying.
#[derive(Clone, Debug)]
pub struct BatchedTimeline {
    n: usize,
    lanes: usize,
    t: Vec<f64>,
}

impl BatchedTimeline {
    /// The batched form of [`Timeline::simulate_reweighted`]: simulate
    /// `rounds` rounds from `t_i(0) = 0` in every lane, calling
    /// `reweight(k, w)` to rewrite all lanes' weights before each round's
    /// [`step_csr_batched_into`]. After the single upfront event-matrix
    /// allocation the loop allocates nothing (gated, alongside the
    /// per-cell path, in `benches/memory.rs`).
    pub fn simulate_reweighted(
        g: &CsrDelayDigraph,
        w: &mut BatchedCsrWeights,
        rounds: usize,
        mut reweight: impl FnMut(usize, &mut BatchedCsrWeights),
    ) -> BatchedTimeline {
        let n = g.n();
        let s = w.lanes();
        assert!(n > 0, "empty digraph");
        let stride = n * s;
        let mut t = vec![0.0f64; (rounds + 1) * stride];
        for k in 0..rounds {
            reweight(k, &mut *w);
            let (head, tail) = t.split_at_mut((k + 1) * stride);
            step_csr_batched_auto_into(&head[k * stride..], g, &*w, &mut tail[..stride]);
        }
        BatchedTimeline { n, lanes: s, t }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn rounds(&self) -> usize {
        self.t.len() / (self.n * self.lanes) - 1
    }

    /// `t_i(k)` in lane `l`.
    #[inline]
    pub fn at(&self, k: usize, i: usize, l: usize) -> f64 {
        self.t[(k * self.n + i) * self.lanes + l]
    }

    /// Extract lane `l` as a standalone [`Timeline`] (bit-copy; the lane's
    /// trajectory is bit-identical to the per-cell simulation fed the same
    /// weight stream).
    pub fn lane_timeline(&self, l: usize) -> Timeline {
        assert!(l < self.lanes, "lane {l} out of {}", self.lanes);
        let rounds = self.rounds();
        let mut t = Vec::with_capacity((rounds + 1) * self.n);
        for k in 0..=rounds {
            for i in 0..self.n {
                t.push(self.at(k, i, l));
            }
        }
        Timeline { n: self.n, t }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn with_self_loops(mut g: DelayDigraph, comp: f64) -> DelayDigraph {
        for i in 0..g.n {
            g.arc(i, i, comp);
        }
        g
    }

    #[test]
    fn ring_timeline_linear_growth() {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 3.0);
        g.arc(2, 0, 4.0);
        let g = with_self_loops(g, 0.5);
        let tl = Timeline::simulate(&g, 300);
        let est = tl.cycle_time_estimate();
        let tau = g.cycle_time();
        assert!((est - tau).abs() < 1e-6, "est={est} τ={tau}");
    }

    #[test]
    fn star_timeline_matches_closed_form() {
        // Hub 0 with two leaves; symmetric delays D. One round = leaf→hub →
        // hub→leaf, so per Eq. (5) the 2-cycle (0,i,0) has mean D.
        let mut g = DelayDigraph::new(3);
        for i in 1..3 {
            g.arc(0, i, 2.0);
            g.arc(i, 0, 2.0);
        }
        let g = with_self_loops(g, 0.0);
        let tau = g.cycle_time();
        assert!((tau - 2.0).abs() < 1e-9);
        let tl = Timeline::simulate(&g, 200);
        assert!((tl.cycle_time_estimate() - tau).abs() < 1e-6);
    }

    #[test]
    fn bounded_deviation_from_linear() {
        // |t_i(k) − τ·k| stays bounded (max-plus asymptotics, Sect. 2.3).
        let mut g = DelayDigraph::new(4);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 2.0);
        g.arc(2, 3, 1.5);
        g.arc(3, 0, 2.5);
        g.arc(1, 0, 0.7);
        let g = with_self_loops(g, 0.3);
        let tau = g.cycle_time();
        let tl = Timeline::simulate(&g, 500);
        let mut max_dev: f64 = 0.0;
        for k in 0..=500 {
            for i in 0..4 {
                max_dev = max_dev.max((tl.at(k, i) - tau * k as f64).abs());
            }
        }
        // bound is graph-dependent; for this tiny graph the transient is
        // small — assert it does not grow with k by checking late window
        let mut late_dev: f64 = 0.0;
        for k in 400..=500 {
            for i in 0..4 {
                late_dev = late_dev.max((tl.at(k, i) - tau * k as f64).abs());
            }
        }
        assert!(late_dev <= max_dev + 1e-9);
        assert!(late_dev < 10.0 * tau, "late_dev={late_dev} τ={tau}");
    }

    #[test]
    fn monotone_nondecreasing_times() {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 1.0);
        g.arc(1, 2, 1.0);
        g.arc(2, 1, 1.0);
        let g = with_self_loops(g, 0.2);
        let tl = Timeline::simulate(&g, 50);
        for k in 0..50 {
            for i in 0..3 {
                assert!(tl.at(k + 1, i) >= tl.at(k, i));
            }
        }
    }

    #[test]
    fn isolated_self_loop_silo_regression() {
        // PR-5 sentinel satellite: a silo whose only in-arc is its own
        // self-loop must advance exactly d_ii per round, and the slope
        // estimator / completion folds must track whichever silo is
        // slowest — with NEG_INFINITY (not f64::MIN) as the fold identity.
        let mut g = DelayDigraph::new(3);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 1.0);
        g.arc(0, 0, 0.2);
        g.arc(1, 1, 0.2);
        g.arc(2, 2, 7.5); // isolated: self-loop only
        let tl = Timeline::simulate(&g, 40);
        for k in 0..=40 {
            assert_eq!(tl.at(k, 2).to_bits(), (7.5 * k as f64).to_bits(), "k={k}");
        }
        // the isolated silo is the slowest: completions follow it exactly
        assert_eq!(tl.round_completion(40).to_bits(), (7.5 * 40.0f64).to_bits());
        assert!((tl.cycle_time_estimate() - 7.5).abs() < 1e-12);
        // a silo with no in-arcs at all stalls at its fallback (prev[i])
        let mut h = DelayDigraph::new(2);
        h.arc(0, 0, 1.0); // silo 1 has no arcs whatsoever
        let th = Timeline::simulate(&h, 10);
        for k in 0..=10 {
            assert_eq!(th.at(k, 1), 0.0, "k={k}");
        }
        assert!(th.round_completion(10).is_finite());
    }

    #[test]
    fn simulate_dynamic_constant_digraph_is_bit_identical() {
        let mut g = DelayDigraph::new(5);
        for i in 0..5 {
            g.arc(i, (i + 1) % 5, 1.0 + i as f64);
        }
        g.arc(2, 0, 0.7);
        let g = with_self_loops(g, 0.4);
        let stat = Timeline::simulate(&g, 120);
        let dyn_ = Timeline::simulate_dynamic(5, 120, |_| g.clone());
        assert_eq!(stat.rounds(), dyn_.rounds());
        for k in 0..=120 {
            for i in 0..5 {
                assert_eq!(
                    stat.at(k, i).to_bits(),
                    dyn_.at(k, i).to_bits(),
                    "k={k} i={i}"
                );
            }
        }
    }

    #[test]
    fn simulate_reweighted_identity_is_bit_identical_to_simulate() {
        let mut g = DelayDigraph::new(6);
        for i in 0..6 {
            g.arc(i, (i + 1) % 6, 0.5 + i as f64);
        }
        g.arc(3, 1, 0.9);
        let g = with_self_loops(g, 0.25);
        let stat = Timeline::simulate(&g, 90);
        let mut csr = CsrDelayDigraph::from_delay_digraph(&g);
        let flat = Timeline::simulate_reweighted(&mut csr, 90, |_, _| {});
        for k in 0..=90 {
            for i in 0..6 {
                assert_eq!(stat.at(k, i).to_bits(), flat.at(k, i).to_bits());
            }
        }
    }

    #[test]
    fn step_csr_matches_step_on_random_digraphs() {
        check("step_csr == step", 30, |gen: &mut Gen| {
            let n = gen.usize(2, 12);
            let mut g = DelayDigraph::new(n);
            for i in 0..n {
                g.arc(i, (i + 1) % n, gen.f64(0.1, 5.0));
                g.arc(i, i, gen.f64(0.0, 1.0));
            }
            for _ in 0..n {
                let u = gen.rng.usize(n);
                let v = gen.rng.usize(n);
                if u != v {
                    g.arc(u, v, gen.f64(0.1, 5.0));
                }
            }
            let prev: Vec<f64> = (0..n).map(|_| gen.f64(0.0, 100.0)).collect();
            let dense = step(&prev, &g.in_arcs());
            let csr = CsrDelayDigraph::from_delay_digraph(&g);
            let mut flat = vec![0.0f64; n];
            step_csr_into(&prev, &csr, &mut flat);
            for i in 0..n {
                assert_eq!(dense[i].to_bits(), flat[i].to_bits(), "i={i}");
            }
        });
    }

    #[test]
    fn simulate_dynamic_alternating_digraphs_slope_between_taus() {
        // Alternate a fast and a slow ring: the realized slope must sit
        // between the two static cycle times (and times stay monotone).
        let build = |d: f64| {
            let mut g = DelayDigraph::new(4);
            for i in 0..4 {
                g.arc(i, (i + 1) % 4, d);
            }
            with_self_loops(g, 0.1)
        };
        let fast = build(1.0);
        let slow = build(3.0);
        let (tau_f, tau_s) = (fast.cycle_time(), slow.cycle_time());
        let tl = Timeline::simulate_dynamic(4, 400, |k| {
            if k % 2 == 0 {
                fast.clone()
            } else {
                slow.clone()
            }
        });
        for k in 0..400 {
            for i in 0..4 {
                assert!(tl.at(k + 1, i) >= tl.at(k, i));
            }
        }
        let est = tl.cycle_time_estimate();
        assert!(
            est >= tau_f - 1e-9 && est <= tau_s + 1e-9,
            "est={est} not in [{tau_f}, {tau_s}]"
        );
    }

    #[test]
    fn batched_step_matches_per_cell_step_per_lane() {
        // Structural bit-identity: with diverged per-lane weights, every
        // lane of the batched kernel equals step_csr_into run on a CSR
        // whose weights are that lane's.
        check("step_csr_batched == step_csr per lane", 25, |gen: &mut Gen| {
            let n = gen.usize(2, 10);
            let lanes = gen.usize(1, 6);
            let mut g = DelayDigraph::new(n);
            for i in 0..n {
                g.arc(i, (i + 1) % n, gen.f64(0.1, 5.0));
                g.arc(i, i, gen.f64(0.0, 1.0));
            }
            for _ in 0..n {
                let u = gen.rng.usize(n);
                let v = gen.rng.usize(n);
                if u != v {
                    g.arc(u, v, gen.f64(0.1, 5.0));
                }
            }
            let csr = CsrDelayDigraph::from_delay_digraph(&g);
            let mut bw = BatchedCsrWeights::broadcast(&csr, lanes);
            // diverge the lanes with arbitrary (finite) rescales
            let scales: Vec<f64> = (0..lanes).map(|_| gen.f64(0.2, 3.0)).collect();
            bw.for_each_arc_lanes_mut(&csr, |_, _, ws| {
                for (l, w) in ws.iter_mut().enumerate() {
                    *w *= scales[l];
                }
            });
            let prev_b: Vec<f64> = (0..n * lanes).map(|_| gen.f64(0.0, 100.0)).collect();
            let mut next_b = vec![0.0f64; n * lanes];
            step_csr_batched_into(&prev_b, &csr, &bw, &mut next_b);
            for l in 0..lanes {
                // lane l's dedicated per-cell CSR
                let mut lane_csr = csr.clone();
                lane_csr.for_each_arc_mut(|dst, _, w| {
                    let _ = dst;
                    *w = 0.0; // overwritten below in arc order
                });
                let mut k = 0usize;
                lane_csr.for_each_arc_mut(|_, _, w| {
                    *w = bw.arc_lanes(k)[l];
                    k += 1;
                });
                let prev: Vec<f64> = (0..n).map(|i| prev_b[i * lanes + l]).collect();
                let mut next = vec![0.0f64; n];
                step_csr_into(&prev, &lane_csr, &mut next);
                for i in 0..n {
                    assert_eq!(
                        next[i].to_bits(),
                        next_b[i * lanes + l].to_bits(),
                        "lane {l} silo {i}"
                    );
                }
            }
        });
    }

    #[test]
    fn batched_no_in_arc_fallback_is_per_lane() {
        // Silo 1 has no arcs at all: each lane must fall back to its own
        // prev value, not a cross-lane one.
        let mut h = DelayDigraph::new(2);
        h.arc(0, 0, 1.0);
        let csr = CsrDelayDigraph::from_delay_digraph(&h);
        let bw = BatchedCsrWeights::broadcast(&csr, 3);
        let prev = vec![0.0, 0.0, 0.0, 10.0, 20.0, 30.0];
        let mut next = vec![0.0f64; 6];
        step_csr_batched_into(&prev, &csr, &bw, &mut next);
        assert_eq!(&next[3..], &[10.0, 20.0, 30.0], "fallback must be per lane");
        assert_eq!(&next[..3], &[1.0, 1.0, 1.0]);
    }

    #[test]
    fn batched_timeline_lanes_match_simulate_reweighted() {
        // Constant weights, 4 identical lanes: every lane's extracted
        // Timeline equals the per-cell simulate_reweighted bit for bit.
        let mut g = DelayDigraph::new(5);
        for i in 0..5 {
            g.arc(i, (i + 1) % 5, 1.0 + i as f64);
        }
        g.arc(2, 0, 0.7);
        let g = with_self_loops(g, 0.4);
        let csr = CsrDelayDigraph::from_delay_digraph(&g);
        let mut ref_csr = csr.clone();
        let reference = Timeline::simulate_reweighted(&mut ref_csr, 70, |_, _| {});
        let mut bw = BatchedCsrWeights::broadcast(&csr, 4);
        let bt = BatchedTimeline::simulate_reweighted(&csr, &mut bw, 70, |_, _| {});
        assert_eq!(bt.rounds(), 70);
        assert_eq!((bt.n(), bt.lanes()), (5, 4));
        for l in 0..4 {
            let tl = bt.lane_timeline(l);
            for k in 0..=70 {
                for i in 0..5 {
                    assert_eq!(
                        tl.at(k, i).to_bits(),
                        reference.at(k, i).to_bits(),
                        "lane {l} t[{k}][{i}]"
                    );
                    assert_eq!(bt.at(k, i, l).to_bits(), reference.at(k, i).to_bits());
                }
            }
        }
    }

    fn random_digraph(gen: &mut Gen, n: usize) -> DelayDigraph {
        let mut g = DelayDigraph::new(n);
        for i in 0..n {
            g.arc(i, (i + 1) % n, gen.f64(0.1, 5.0));
            g.arc(i, i, gen.f64(0.0, 1.0));
        }
        for _ in 0..2 * n {
            let u = gen.rng.usize(n);
            let v = gen.rng.usize(n);
            if u != v {
                g.arc(u, v, gen.f64(0.1, 5.0));
            }
        }
        g
    }

    #[test]
    fn chunked_step_matches_sequential_for_any_parts_and_workers() {
        let _guard = parallel::jobs_test_guard();
        check("step_csr_chunked == step_csr", 15, |gen: &mut Gen| {
            let n = gen.usize(2, 40);
            let g = random_digraph(gen, n);
            let csr = CsrDelayDigraph::from_delay_digraph(&g);
            let prev: Vec<f64> = (0..n).map(|_| gen.f64(0.0, 100.0)).collect();
            let mut seq = vec![0.0f64; n];
            step_csr_into(&prev, &csr, &mut seq);
            for workers in [1usize, 2, 7] {
                parallel::set_intracell(workers);
                for parts in [1usize, 2, 3, 7, 16, 64] {
                    let mut par = vec![f64::NAN; n];
                    step_csr_chunked_into(&prev, &csr, &mut par, parts);
                    for i in 0..n {
                        assert_eq!(
                            seq[i].to_bits(),
                            par[i].to_bits(),
                            "workers={workers} parts={parts} i={i}"
                        );
                    }
                }
            }
            parallel::set_intracell(0);
        });
    }

    #[test]
    fn chunked_batched_step_matches_sequential_per_lane() {
        let _guard = parallel::jobs_test_guard();
        check("batched chunked == batched", 10, |gen: &mut Gen| {
            let n = gen.usize(2, 24);
            let lanes = gen.usize(1, 8);
            let g = random_digraph(gen, n);
            let csr = CsrDelayDigraph::from_delay_digraph(&g);
            let mut bw = BatchedCsrWeights::broadcast(&csr, lanes);
            let scales: Vec<f64> = (0..lanes).map(|_| gen.f64(0.2, 3.0)).collect();
            bw.for_each_arc_lanes_mut(&csr, |_, _, ws| {
                for (l, w) in ws.iter_mut().enumerate() {
                    *w *= scales[l];
                }
            });
            let prev: Vec<f64> = (0..n * lanes).map(|_| gen.f64(0.0, 100.0)).collect();
            let mut seq = vec![0.0f64; n * lanes];
            step_csr_batched_into(&prev, &csr, &bw, &mut seq);
            parallel::set_intracell(3);
            for parts in [2usize, 5, 16] {
                let mut par = vec![f64::NAN; n * lanes];
                step_csr_batched_chunked_into(&prev, &csr, &bw, &mut par, parts);
                for x in 0..n * lanes {
                    assert_eq!(seq[x].to_bits(), par[x].to_bits(), "parts={parts} x={x}");
                }
            }
            parallel::set_intracell(0);
        });
    }

    #[test]
    fn chunked_step_handles_isolated_and_self_loop_only_silos() {
        // Boundary rows with zero in-arcs and self-loop-only rows: the
        // fallback must come from the worker that owns the row.
        let _guard = parallel::jobs_test_guard();
        let mut g = DelayDigraph::new(6);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 1.0);
        g.arc(2, 2, 7.5); // self-loop only
        g.arc(4, 5, 2.0); // silo 3 has no arcs at all
        let csr = CsrDelayDigraph::from_delay_digraph(&g);
        let prev = vec![5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        let mut seq = vec![0.0f64; 6];
        step_csr_into(&prev, &csr, &mut seq);
        assert_eq!(seq[2], 7.0 + 7.5);
        assert_eq!(seq[3], 8.0, "no-in-arc fallback");
        parallel::set_intracell(4);
        for parts in 1..=8 {
            let mut par = vec![f64::NAN; 6];
            step_csr_chunked_into(&prev, &csr, &mut par, parts);
            for i in 0..6 {
                assert_eq!(seq[i].to_bits(), par[i].to_bits(), "parts={parts} i={i}");
            }
        }
        parallel::set_intracell(0);
    }

    #[test]
    fn auto_dispatch_is_bit_identical_across_the_gate() {
        // Both sides of the size gate produce the sequential kernel's bytes:
        // a small graph (gated to sequential) and a forced-parallel setting.
        let _guard = parallel::jobs_test_guard();
        let mut gen = Gen::new(0xA11C, 32);
        let n = 32;
        let g = random_digraph(&mut gen, n);
        let csr = CsrDelayDigraph::from_delay_digraph(&g);
        let prev: Vec<f64> = (0..n).map(|_| gen.f64(0.0, 50.0)).collect();
        let mut seq = vec![0.0f64; n];
        step_csr_into(&prev, &csr, &mut seq);
        for workers in [0usize, 1, 2, 7] {
            parallel::set_intracell(workers);
            let mut auto = vec![f64::NAN; n];
            step_csr_auto_into(&prev, &csr, &mut auto);
            for i in 0..n {
                assert_eq!(seq[i].to_bits(), auto[i].to_bits(), "workers={workers}");
            }
        }
        parallel::set_intracell(0);
        assert!(csr.arcs() < INTRACELL_MIN_FOLDS, "gate must cover the small case");
    }

    #[test]
    fn prop_recurrence_slope_equals_karp_on_random_strong_digraphs() {
        check("recurrence slope = karp λ", 40, |gen: &mut Gen| {
            let n = gen.usize(2, 10);
            let mut g = DelayDigraph::new(n);
            // random ring guarantees strong connectivity
            for i in 0..n {
                g.arc(i, (i + 1) % n, gen.f64(0.1, 5.0));
            }
            for _ in 0..n {
                let u = gen.rng.usize(n);
                let v = gen.rng.usize(n);
                if u != v {
                    g.arc(u, v, gen.f64(0.1, 5.0));
                }
            }
            for i in 0..n {
                g.arc(i, i, gen.f64(0.0, 1.0));
            }
            let tau = g.cycle_time();
            let tl = Timeline::simulate(&g, 400);
            let est = tl.cycle_time_estimate();
            // The slope estimator carries an O(1/K) phase error from the
            // critical circuit's periodic regime; 1% is ample at K = 400.
            assert!(
                (est - tau).abs() < 1e-2 * tau.max(1.0),
                "est={est} τ={tau} n={n}"
            );
        });
    }
}
