//! Exact event-time simulation of Eq. (4) — the paper's Algorithm 3.
//!
//! `t_i(k+1) = max_{j ∈ N_i⁺ ∪ {i}} ( t_j(k) + d_o(j, i) )`
//!
//! The simulator reconstructs the wall-clock timeline of a training run on a
//! given overlay: `t_i(k)` is when silo i starts its k-th computation phase.
//! The paper's key theorem is that `t_i(k) ≈ τ·k` with bounded error, τ the
//! max cycle mean — cross-checked against Karp in the tests below and used
//! to map loss-vs-round curves into loss-vs-time curves (Fig. 2 bottom row).

use super::DelayDigraph;

/// One synchronous step of Eq. (4) over an in-adjacency view (`inn[i]` =
/// `[(j, d_o(j,i))]`, as produced by [`DelayDigraph::in_arcs`]).
///
/// Self-loops `d_o(i,i)` may or may not be explicit arcs; the DelayDigraph
/// convention is that callers add them explicitly (the delay model always
/// does). If a silo has no in-arcs at all it would stall — guard with a
/// `prev[i]` fallback so event times stay monotone.
///
/// This is the single shared kernel behind [`Timeline::simulate`],
/// [`Timeline::simulate_dynamic`] and the adaptive re-design loop
/// (`topology::adaptive`), so their trajectories agree bit-for-bit whenever
/// they are fed the same per-round digraphs.
pub fn step(prev: &[f64], inn: &[Vec<(usize, f64)>]) -> Vec<f64> {
    let n = inn.len();
    let mut next = vec![f64::NEG_INFINITY; n];
    for i in 0..n {
        for &(j, d) in &inn[i] {
            let cand = prev[j] + d;
            if cand > next[i] {
                next[i] = cand;
            }
        }
        if next[i] == f64::NEG_INFINITY {
            next[i] = prev[i];
        }
    }
    next
}

/// The full event-time matrix: `t[k][i]`.
#[derive(Clone, Debug)]
pub struct Timeline {
    pub t: Vec<Vec<f64>>,
}

impl Timeline {
    /// Simulate `rounds` rounds from `t_i(0) = 0`.
    pub fn simulate(g: &DelayDigraph, rounds: usize) -> Timeline {
        let inn = g.in_arcs();
        let n = g.n;
        let mut t = Vec::with_capacity(rounds + 1);
        t.push(vec![0.0f64; n]);
        for k in 0..rounds {
            let next = step(&t[k], &inn);
            t.push(next);
        }
        Timeline { t }
    }

    /// Time-varying Eq. (4): the delay digraph is re-sampled every round
    /// (`digraph_at(k)` supplies round k's digraph), which is how scenario
    /// perturbations — drift, congestion, stragglers, churn — and MATCHA's
    /// random matchings enter the wall-clock reconstruction.
    ///
    /// With a constant digraph this is bit-for-bit identical to
    /// [`Timeline::simulate`] (same [`step`] kernel, same fold order).
    pub fn simulate_dynamic(
        n: usize,
        rounds: usize,
        mut digraph_at: impl FnMut(usize) -> DelayDigraph,
    ) -> Timeline {
        let mut t = Vec::with_capacity(rounds + 1);
        t.push(vec![0.0f64; n]);
        for k in 0..rounds {
            let g = digraph_at(k);
            assert_eq!(g.n, n, "round {k}: digraph changed size");
            let next = step(&t[k], &g.in_arcs());
            t.push(next);
        }
        Timeline { t }
    }

    pub fn rounds(&self) -> usize {
        self.t.len() - 1
    }

    /// Empirical cycle time: slope of `max_i t_i(k)` over the last half of
    /// the horizon (skipping the transient, as the theory prescribes).
    pub fn cycle_time_estimate(&self) -> f64 {
        let k_end = self.rounds();
        assert!(k_end >= 2, "need ≥2 rounds to estimate a slope");
        let k_mid = k_end / 2;
        let m_end = self.t[k_end].iter().cloned().fold(f64::MIN, f64::max);
        let m_mid = self.t[k_mid].iter().cloned().fold(f64::MIN, f64::max);
        (m_end - m_mid) / (k_end - k_mid) as f64
    }

    /// Completion time of round k (when the slowest silo starts round k).
    pub fn round_completion(&self, k: usize) -> f64 {
        self.t[k].iter().cloned().fold(f64::MIN, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn with_self_loops(mut g: DelayDigraph, comp: f64) -> DelayDigraph {
        for i in 0..g.n {
            g.arc(i, i, comp);
        }
        g
    }

    #[test]
    fn ring_timeline_linear_growth() {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 3.0);
        g.arc(2, 0, 4.0);
        let g = with_self_loops(g, 0.5);
        let tl = Timeline::simulate(&g, 300);
        let est = tl.cycle_time_estimate();
        let tau = g.cycle_time();
        assert!((est - tau).abs() < 1e-6, "est={est} τ={tau}");
    }

    #[test]
    fn star_timeline_matches_closed_form() {
        // Hub 0 with two leaves; symmetric delays D. One round = leaf→hub →
        // hub→leaf, so per Eq. (5) the 2-cycle (0,i,0) has mean D.
        let mut g = DelayDigraph::new(3);
        for i in 1..3 {
            g.arc(0, i, 2.0);
            g.arc(i, 0, 2.0);
        }
        let g = with_self_loops(g, 0.0);
        let tau = g.cycle_time();
        assert!((tau - 2.0).abs() < 1e-9);
        let tl = Timeline::simulate(&g, 200);
        assert!((tl.cycle_time_estimate() - tau).abs() < 1e-6);
    }

    #[test]
    fn bounded_deviation_from_linear() {
        // |t_i(k) − τ·k| stays bounded (max-plus asymptotics, Sect. 2.3).
        let mut g = DelayDigraph::new(4);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 2.0);
        g.arc(2, 3, 1.5);
        g.arc(3, 0, 2.5);
        g.arc(1, 0, 0.7);
        let g = with_self_loops(g, 0.3);
        let tau = g.cycle_time();
        let tl = Timeline::simulate(&g, 500);
        let mut max_dev: f64 = 0.0;
        for k in 0..=500 {
            for i in 0..4 {
                max_dev = max_dev.max((tl.t[k][i] - tau * k as f64).abs());
            }
        }
        // bound is graph-dependent; for this tiny graph the transient is
        // small — assert it does not grow with k by checking late window
        let mut late_dev: f64 = 0.0;
        for k in 400..=500 {
            for i in 0..4 {
                late_dev = late_dev.max((tl.t[k][i] - tau * k as f64).abs());
            }
        }
        assert!(late_dev <= max_dev + 1e-9);
        assert!(late_dev < 10.0 * tau, "late_dev={late_dev} τ={tau}");
    }

    #[test]
    fn monotone_nondecreasing_times() {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 1.0);
        g.arc(1, 2, 1.0);
        g.arc(2, 1, 1.0);
        let g = with_self_loops(g, 0.2);
        let tl = Timeline::simulate(&g, 50);
        for k in 0..50 {
            for i in 0..3 {
                assert!(tl.t[k + 1][i] >= tl.t[k][i]);
            }
        }
    }

    #[test]
    fn simulate_dynamic_constant_digraph_is_bit_identical() {
        let mut g = DelayDigraph::new(5);
        for i in 0..5 {
            g.arc(i, (i + 1) % 5, 1.0 + i as f64);
        }
        g.arc(2, 0, 0.7);
        let g = with_self_loops(g, 0.4);
        let stat = Timeline::simulate(&g, 120);
        let dyn_ = Timeline::simulate_dynamic(5, 120, |_| g.clone());
        assert_eq!(stat.t.len(), dyn_.t.len());
        for k in 0..=120 {
            for i in 0..5 {
                assert_eq!(
                    stat.t[k][i].to_bits(),
                    dyn_.t[k][i].to_bits(),
                    "k={k} i={i}"
                );
            }
        }
    }

    #[test]
    fn simulate_dynamic_alternating_digraphs_slope_between_taus() {
        // Alternate a fast and a slow ring: the realized slope must sit
        // between the two static cycle times (and times stay monotone).
        let build = |d: f64| {
            let mut g = DelayDigraph::new(4);
            for i in 0..4 {
                g.arc(i, (i + 1) % 4, d);
            }
            with_self_loops(g, 0.1)
        };
        let fast = build(1.0);
        let slow = build(3.0);
        let (tau_f, tau_s) = (fast.cycle_time(), slow.cycle_time());
        let tl = Timeline::simulate_dynamic(4, 400, |k| {
            if k % 2 == 0 {
                fast.clone()
            } else {
                slow.clone()
            }
        });
        for k in 0..400 {
            for i in 0..4 {
                assert!(tl.t[k + 1][i] >= tl.t[k][i]);
            }
        }
        let est = tl.cycle_time_estimate();
        assert!(
            est >= tau_f - 1e-9 && est <= tau_s + 1e-9,
            "est={est} not in [{tau_f}, {tau_s}]"
        );
    }

    #[test]
    fn prop_recurrence_slope_equals_karp_on_random_strong_digraphs() {
        check("recurrence slope = karp λ", 40, |gen: &mut Gen| {
            let n = gen.usize(2, 10);
            let mut g = DelayDigraph::new(n);
            // random ring guarantees strong connectivity
            for i in 0..n {
                g.arc(i, (i + 1) % n, gen.f64(0.1, 5.0));
            }
            for _ in 0..n {
                let u = gen.rng.usize(n);
                let v = gen.rng.usize(n);
                if u != v {
                    g.arc(u, v, gen.f64(0.1, 5.0));
                }
            }
            for i in 0..n {
                g.arc(i, i, gen.f64(0.0, 1.0));
            }
            let tau = g.cycle_time();
            let tl = Timeline::simulate(&g, 400);
            let est = tl.cycle_time_estimate();
            // The slope estimator carries an O(1/K) phase error from the
            // critical circuit's periodic regime; 1% is ample at K = 400.
            assert!(
                (est - tau).abs() < 1e-2 * tau.max(1.0),
                "est={est} τ={tau} n={n}"
            );
        });
    }
}
