//! Exact event-time simulation of Eq. (4) — the paper's Algorithm 3.
//!
//! `t_i(k+1) = max_{j ∈ N_i⁺ ∪ {i}} ( t_j(k) + d_o(j, i) )`
//!
//! The simulator reconstructs the wall-clock timeline of a training run on a
//! given overlay: `t_i(k)` is when silo i starts its k-th computation phase.
//! The paper's key theorem is that `t_i(k) ≈ τ·k` with bounded error, τ the
//! max cycle mean — cross-checked against Karp in the tests below and used
//! to map loss-vs-round curves into loss-vs-time curves (Fig. 2 bottom row).

use super::DelayDigraph;

/// The full event-time matrix: `t[k][i]`.
#[derive(Clone, Debug)]
pub struct Timeline {
    pub t: Vec<Vec<f64>>,
}

impl Timeline {
    /// Simulate `rounds` rounds from `t_i(0) = 0`.
    pub fn simulate(g: &DelayDigraph, rounds: usize) -> Timeline {
        let inn = g.in_arcs();
        let n = g.n;
        let mut t = Vec::with_capacity(rounds + 1);
        t.push(vec![0.0f64; n]);
        for k in 0..rounds {
            let prev = &t[k];
            let mut next = vec![f64::NEG_INFINITY; n];
            for i in 0..n {
                // Self-loop d_o(i,i) may or may not be an explicit arc; the
                // DelayDigraph convention is that callers add it explicitly
                // (the delay model always does). If absent, a silo with no
                // inputs would stall — guard with max(prev) fallback.
                for &(j, d) in &inn[i] {
                    let cand = prev[j] + d;
                    if cand > next[i] {
                        next[i] = cand;
                    }
                }
                if next[i] == f64::NEG_INFINITY {
                    next[i] = prev[i];
                }
            }
            t.push(next);
        }
        Timeline { t }
    }

    pub fn rounds(&self) -> usize {
        self.t.len() - 1
    }

    /// Empirical cycle time: slope of `max_i t_i(k)` over the last half of
    /// the horizon (skipping the transient, as the theory prescribes).
    pub fn cycle_time_estimate(&self) -> f64 {
        let k_end = self.rounds();
        assert!(k_end >= 2, "need ≥2 rounds to estimate a slope");
        let k_mid = k_end / 2;
        let m_end = self.t[k_end].iter().cloned().fold(f64::MIN, f64::max);
        let m_mid = self.t[k_mid].iter().cloned().fold(f64::MIN, f64::max);
        (m_end - m_mid) / (k_end - k_mid) as f64
    }

    /// Completion time of round k (when the slowest silo starts round k).
    pub fn round_completion(&self, k: usize) -> f64 {
        self.t[k].iter().cloned().fold(f64::MIN, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn with_self_loops(mut g: DelayDigraph, comp: f64) -> DelayDigraph {
        for i in 0..g.n {
            g.arc(i, i, comp);
        }
        g
    }

    #[test]
    fn ring_timeline_linear_growth() {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 3.0);
        g.arc(2, 0, 4.0);
        let g = with_self_loops(g, 0.5);
        let tl = Timeline::simulate(&g, 300);
        let est = tl.cycle_time_estimate();
        let tau = g.cycle_time();
        assert!((est - tau).abs() < 1e-6, "est={est} τ={tau}");
    }

    #[test]
    fn star_timeline_matches_closed_form() {
        // Hub 0 with two leaves; symmetric delays D. One round = leaf→hub →
        // hub→leaf, so per Eq. (5) the 2-cycle (0,i,0) has mean D.
        let mut g = DelayDigraph::new(3);
        for i in 1..3 {
            g.arc(0, i, 2.0);
            g.arc(i, 0, 2.0);
        }
        let g = with_self_loops(g, 0.0);
        let tau = g.cycle_time();
        assert!((tau - 2.0).abs() < 1e-9);
        let tl = Timeline::simulate(&g, 200);
        assert!((tl.cycle_time_estimate() - tau).abs() < 1e-6);
    }

    #[test]
    fn bounded_deviation_from_linear() {
        // |t_i(k) − τ·k| stays bounded (max-plus asymptotics, Sect. 2.3).
        let mut g = DelayDigraph::new(4);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 2.0);
        g.arc(2, 3, 1.5);
        g.arc(3, 0, 2.5);
        g.arc(1, 0, 0.7);
        let g = with_self_loops(g, 0.3);
        let tau = g.cycle_time();
        let tl = Timeline::simulate(&g, 500);
        let mut max_dev: f64 = 0.0;
        for k in 0..=500 {
            for i in 0..4 {
                max_dev = max_dev.max((tl.t[k][i] - tau * k as f64).abs());
            }
        }
        // bound is graph-dependent; for this tiny graph the transient is
        // small — assert it does not grow with k by checking late window
        let mut late_dev: f64 = 0.0;
        for k in 400..=500 {
            for i in 0..4 {
                late_dev = late_dev.max((tl.t[k][i] - tau * k as f64).abs());
            }
        }
        assert!(late_dev <= max_dev + 1e-9);
        assert!(late_dev < 10.0 * tau, "late_dev={late_dev} τ={tau}");
    }

    #[test]
    fn monotone_nondecreasing_times() {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 1.0);
        g.arc(1, 2, 1.0);
        g.arc(2, 1, 1.0);
        let g = with_self_loops(g, 0.2);
        let tl = Timeline::simulate(&g, 50);
        for k in 0..50 {
            for i in 0..3 {
                assert!(tl.t[k + 1][i] >= tl.t[k][i]);
            }
        }
    }

    #[test]
    fn prop_recurrence_slope_equals_karp_on_random_strong_digraphs() {
        check("recurrence slope = karp λ", 40, |gen: &mut Gen| {
            let n = gen.usize(2, 10);
            let mut g = DelayDigraph::new(n);
            // random ring guarantees strong connectivity
            for i in 0..n {
                g.arc(i, (i + 1) % n, gen.f64(0.1, 5.0));
            }
            for _ in 0..n {
                let u = gen.rng.usize(n);
                let v = gen.rng.usize(n);
                if u != v {
                    g.arc(u, v, gen.f64(0.1, 5.0));
                }
            }
            for i in 0..n {
                g.arc(i, i, gen.f64(0.0, 1.0));
            }
            let tau = g.cycle_time();
            let tl = Timeline::simulate(&g, 400);
            let est = tl.cycle_time_estimate();
            // The slope estimator carries an O(1/K) phase error from the
            // critical circuit's periodic regime; 1% is ample at K = 400.
            assert!(
                (est - tau).abs() < 1e-2 * tau.max(1.0),
                "est={est} τ={tau} n={n}"
            );
        });
    }
}
