//! Howard's policy-iteration maximum-cycle-mean solver (Cochet-Terrasson,
//! Cohen, Gaubert, McGettrick & Quadrat 1998) over a sparse adjacency-list
//! representation.
//!
//! ## Why a second solver
//!
//! Karp's algorithm ([`super::karp`]) is exactly O(V·E) time and O(V²)
//! space: it fills the full `D_k(v)` walk table. That is instantaneous for
//! the 11–87-silo networks of Table 3 but becomes the bottleneck once the
//! cycle-time engine sits inside Monte-Carlo loops or scenario sweeps over
//! synthetic underlays with 500–2000 silos.
//!
//! Howard's method iterates over *policies* (one out-arc per node). Each
//! iteration costs O(V + E) — value determination on the policy's
//! functional graph plus one improvement sweep — and the number of
//! iterations is small in practice (typically < 10, independent of V on the
//! delay digraphs we solve; no polynomial bound is known, which is why a
//! safety cap falls back to Karp). Memory is O(V + E): no dense tables.
//!
//! | solver | time            | space  | regime                        |
//! |--------|-----------------|--------|-------------------------------|
//! | Karp   | Θ(V·E)          | Θ(V²)  | exact, small graphs           |
//! | Howard | O(k·(V+E)), k≪V | Θ(V+E) | large sparse delay digraphs   |
//!
//! [`super::cycle_time_with`] dispatches between the two on graph size; the
//! property tests below pin Howard to Karp within 1e-9 on random strongly
//! connected digraphs.

use super::DelayDigraph;

/// Sparse adjacency-list view of a [`DelayDigraph`]: out-arcs per node plus
/// the in-source lists needed to prune acyclic tails. This is the O(V+E)
/// representation Howard iterates over (Karp scans the raw arc list).
pub struct SparseDigraph {
    pub n: usize,
    /// `out[u] = [(v, w), ...]` in insertion order (parallel arcs allowed).
    pub out: Vec<Vec<(usize, f64)>>,
    /// `inn[v] = [u, ...]` — one entry per arc, mirrors `out`.
    pub inn: Vec<Vec<usize>>,
}

impl SparseDigraph {
    pub fn from_delay(g: &DelayDigraph) -> SparseDigraph {
        let mut out = vec![Vec::new(); g.n];
        let mut inn = vec![Vec::new(); g.n];
        for &(u, v, w) in &g.arcs {
            out[u].push((v, w));
            inn[v].push(u);
        }
        SparseDigraph { n: g.n, out, inn }
    }

    /// Nodes that can lie on (or lead into) a circuit: iteratively strip
    /// nodes with no surviving out-arc. Returns the `alive` mask, or `None`
    /// when the graph is acyclic (everything stripped).
    fn alive_mask(&self) -> Option<Vec<bool>> {
        let mut alive = vec![true; self.n];
        let mut outdeg: Vec<usize> = self.out.iter().map(|a| a.len()).collect();
        let mut queue: Vec<usize> = (0..self.n).filter(|&u| outdeg[u] == 0).collect();
        while let Some(v) = queue.pop() {
            if !alive[v] {
                continue;
            }
            alive[v] = false;
            for &u in &self.inn[v] {
                if alive[u] {
                    outdeg[u] -= 1;
                    if outdeg[u] == 0 {
                        queue.push(u);
                    }
                }
            }
        }
        if alive.iter().any(|&a| a) {
            Some(alive)
        } else {
            None
        }
    }
}

/// Maximum cycle mean of `g` via Howard's policy iteration, or `None` if
/// `g` is acyclic. Agrees with [`super::karp::max_cycle_mean`] to float
/// round-off (the dispatch layer canonicalizes both to the extracted
/// critical circuit's mean).
pub fn max_cycle_mean(g: &DelayDigraph) -> Option<f64> {
    max_cycle_mean_with_cycle(g).map(|(l, _)| l)
}

/// Maximum cycle mean plus one critical circuit achieving it, as a node
/// sequence `[v_0, v_1, …, v_0]` (same contract as Karp's).
pub fn max_cycle_mean_with_cycle(g: &DelayDigraph) -> Option<(f64, Vec<usize>)> {
    let n = g.n;
    if n == 0 || g.arcs.is_empty() {
        return None;
    }
    let sp = SparseDigraph::from_delay(g);
    let alive = sp.alive_mask()?;

    // Strict-improvement guard: smaller than any meaningful delay gap,
    // large enough to stop float ping-pong between equal policies.
    let scale = g
        .arcs
        .iter()
        .map(|&(_, _, w)| w.abs())
        .fold(1.0f64, f64::max);
    let eps = 1e-12 * scale;

    // Initial policy: heaviest out-arc into the alive set (ties: lowest
    // target index — deterministic across runs).
    let mut pi_v = vec![usize::MAX; n];
    let mut pi_w = vec![f64::NEG_INFINITY; n];
    for u in 0..n {
        if !alive[u] {
            continue;
        }
        for &(v, w) in &sp.out[u] {
            if !alive[v] {
                continue;
            }
            if w > pi_w[u] || (w == pi_w[u] && v < pi_v[u]) {
                pi_v[u] = v;
                pi_w[u] = w;
            }
        }
        debug_assert!(pi_v[u] != usize::MAX, "alive node must keep an out-arc");
    }

    let mut eta = vec![f64::NEG_INFINITY; n];
    let mut bias = vec![0.0f64; n];
    let max_iters = 4 * n + 64;
    let mut converged = false;
    for _ in 0..max_iters {
        value_determination(&sp, &alive, &pi_v, &pi_w, &mut eta, &mut bias);
        if !improve_policy(&sp, &alive, &mut pi_v, &mut pi_w, &eta, &bias, eps) {
            converged = true;
            break;
        }
    }
    if !converged {
        // Extremely defensive: Howard converges in a handful of iterations
        // on every graph family we generate, but its worst case is open —
        // guarantee correctness by falling back to the exact solver.
        return super::karp::max_cycle_mean_with_cycle(g);
    }

    // λ* = max chain value; critical circuit = the final policy's cycle in
    // the argmax component.
    let mut u0 = usize::MAX;
    for u in 0..n {
        if alive[u] && (u0 == usize::MAX || eta[u] > eta[u0]) {
            u0 = u;
        }
    }
    let lambda = eta[u0];
    let mut seen = vec![false; n];
    let mut cur = u0;
    while !seen[cur] {
        seen[cur] = true;
        cur = pi_v[cur];
    }
    // `cur` is on the policy cycle; walk it once around.
    let mut cycle = vec![cur];
    let mut x = pi_v[cur];
    while x != cur {
        cycle.push(x);
        x = pi_v[x];
    }
    cycle.push(cur);
    Some((lambda, cycle))
}

/// Multichain value determination: per-node chain value η (its policy
/// cycle's mean) and bias v with `v(u) = w(u,π(u)) − η(u) + v(π(u))`,
/// anchored at `v = 0` on each cycle's lowest-index node.
fn value_determination(
    sp: &SparseDigraph,
    alive: &[bool],
    pi_v: &[usize],
    pi_w: &[f64],
    eta: &mut [f64],
    bias: &mut [f64],
) {
    let n = sp.n;
    // 0 = unvisited, 1 = on the current path, 2 = resolved.
    let mut mark = vec![0u8; n];
    let mut path: Vec<usize> = Vec::new();
    for start in 0..n {
        if !alive[start] || mark[start] != 0 {
            continue;
        }
        path.clear();
        let mut u = start;
        while mark[u] == 0 {
            mark[u] = 1;
            path.push(u);
            u = pi_v[u];
        }
        if mark[u] == 1 {
            // New cycle: the path suffix starting at `u`.
            let pos = path.iter().position(|&x| x == u).expect("u is on path");
            let cycle = &path[pos..];
            let len = cycle.len();
            let e: f64 = cycle.iter().map(|&x| pi_w[x]).sum::<f64>() / len as f64;
            // Anchor the bias at the lowest-index cycle node (determinism).
            let rpos = (0..len).min_by_key(|&k| cycle[k]).expect("non-empty");
            for &x in cycle {
                eta[x] = e;
            }
            bias[cycle[rpos]] = 0.0;
            for k in (1..len).rev() {
                let x = cycle[(rpos + k) % len];
                bias[x] = pi_w[x] - e + bias[pi_v[x]];
            }
            for &x in cycle {
                mark[x] = 2;
            }
            // Resolve the pre-cycle tail back-to-front.
            for &x in path[..pos].iter().rev() {
                eta[x] = eta[pi_v[x]];
                bias[x] = pi_w[x] - eta[x] + bias[pi_v[x]];
                mark[x] = 2;
            }
        } else {
            // Hit an already-resolved component: propagate its values.
            for &x in path.iter().rev() {
                eta[x] = eta[pi_v[x]];
                bias[x] = pi_w[x] - eta[x] + bias[pi_v[x]];
                mark[x] = 2;
            }
        }
    }
}

/// One improvement sweep. Stage 1 raises chain values (switch to an arc
/// whose head reaches a better cycle); only when no chain improves does
/// stage 2 raise biases within a chain class. Returns whether the policy
/// changed.
#[allow(clippy::too_many_arguments)]
fn improve_policy(
    sp: &SparseDigraph,
    alive: &[bool],
    pi_v: &mut [usize],
    pi_w: &mut [f64],
    eta: &[f64],
    bias: &[f64],
    eps: f64,
) -> bool {
    let n = sp.n;
    let mut changed = false;
    for u in 0..n {
        if !alive[u] {
            continue;
        }
        let mut best_eta = f64::NEG_INFINITY;
        let mut best_key = f64::NEG_INFINITY;
        let mut best_arc = (usize::MAX, 0.0f64);
        for &(v, w) in &sp.out[u] {
            if !alive[v] {
                continue;
            }
            let key = w + bias[v];
            if eta[v] > best_eta || (eta[v] == best_eta && key > best_key) {
                best_eta = eta[v];
                best_key = key;
                best_arc = (v, w);
            }
        }
        if best_eta > eta[u] + eps {
            pi_v[u] = best_arc.0;
            pi_w[u] = best_arc.1;
            changed = true;
        }
    }
    if changed {
        return true;
    }
    for u in 0..n {
        if !alive[u] {
            continue;
        }
        let mut best_val = f64::NEG_INFINITY;
        let mut best_arc = (usize::MAX, 0.0f64);
        for &(v, w) in &sp.out[u] {
            if !alive[v] || (eta[v] - eta[u]).abs() > eps {
                continue;
            }
            let val = w - eta[u] + bias[v];
            if val > best_val {
                best_val = val;
                best_arc = (v, w);
            }
        }
        if best_arc.0 != usize::MAX && best_val > bias[u] + eps {
            pi_v[u] = best_arc.0;
            pi_w[u] = best_arc.1;
            changed = true;
        }
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maxplus::karp;
    use crate::util::prop::{check, Gen};

    fn ring(delays: &[f64]) -> DelayDigraph {
        let n = delays.len();
        let mut g = DelayDigraph::new(n);
        for i in 0..n {
            g.arc(i, (i + 1) % n, delays[i]);
        }
        g
    }

    #[test]
    fn single_ring_mean() {
        let g = ring(&[1.0, 3.0, 3.0, 1.0]);
        assert!((max_cycle_mean(&g).unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn self_loop_dominates() {
        let mut g = DelayDigraph::new(2);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 1.0);
        g.arc(0, 0, 5.0);
        let (l, cyc) = max_cycle_mean_with_cycle(&g).unwrap();
        assert!((l - 5.0).abs() < 1e-9);
        assert_eq!(cyc, vec![0, 0]);
    }

    #[test]
    fn two_cycles_max_wins() {
        let mut g = DelayDigraph::new(4);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 3.0);
        g.arc(2, 3, 4.0);
        g.arc(3, 2, 4.0);
        g.arc(1, 2, 0.0);
        assert!((max_cycle_mean(&g).unwrap() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn acyclic_returns_none() {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 1.0);
        assert!(max_cycle_mean(&g).is_none());
    }

    #[test]
    fn acyclic_tail_into_cycle_is_pruned_not_lost() {
        // 0 → 1 → 2 ⇄ 3: nodes 0,1 lead into the cycle but lie on none.
        let mut g = DelayDigraph::new(4);
        g.arc(0, 1, 100.0);
        g.arc(1, 2, 100.0);
        g.arc(2, 3, 2.0);
        g.arc(3, 2, 4.0);
        let (l, cyc) = max_cycle_mean_with_cycle(&g).unwrap();
        assert!((l - 3.0).abs() < 1e-9);
        assert_eq!(cyc.len(), 3);
        assert_eq!(cyc.first(), cyc.last());
    }

    #[test]
    fn paper_appendix_c_three_node_example() {
        let mut undirected = DelayDigraph::new(3);
        for (a, b, w) in [(0, 1, 1.0), (1, 0, 1.0), (1, 2, 3.0), (2, 1, 3.0)] {
            undirected.arc(a, b, w);
        }
        assert!((max_cycle_mean(&undirected).unwrap() - 3.0).abs() < 1e-9);

        let mut directed = DelayDigraph::new(3);
        directed.arc(0, 1, 1.0);
        directed.arc(1, 2, 3.0);
        directed.arc(2, 0, 4.0);
        assert!((max_cycle_mean(&directed).unwrap() - 8.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn critical_cycle_mean_equals_lambda() {
        let mut g = DelayDigraph::new(5);
        g.arc(0, 1, 2.0);
        g.arc(1, 2, 2.0);
        g.arc(2, 0, 5.0);
        g.arc(2, 3, 1.0);
        g.arc(3, 4, 1.0);
        g.arc(4, 2, 1.0);
        let (lambda, cyc) = max_cycle_mean_with_cycle(&g).unwrap();
        assert!((lambda - 3.0).abs() < 1e-9);
        assert_eq!(cyc.first(), cyc.last());
        let mean = cycle_mean_of(&g, &cyc);
        assert!((mean - lambda).abs() < 1e-9);
    }

    fn cycle_mean_of(g: &DelayDigraph, cyc: &[usize]) -> f64 {
        let mut w = 0.0;
        for pair in cyc.windows(2) {
            w += g
                .arcs
                .iter()
                .filter(|&&(u, v, _)| u == pair[0] && v == pair[1])
                .map(|&(_, _, d)| d)
                .fold(f64::NEG_INFINITY, f64::max);
        }
        w / (cyc.len() - 1) as f64
    }

    /// The ISSUE's pinned property: on random strongly connected digraphs
    /// (≤ 60 nodes) Howard matches Karp within 1e-9, and the returned
    /// critical circuit's mean equals λ*.
    #[test]
    fn prop_howard_matches_karp_on_strong_digraphs() {
        check("howard equals karp", 80, |gen: &mut Gen| {
            let n = gen.usize(2, 61);
            let mut g = DelayDigraph::new(n);
            // Ring over all nodes ⇒ strongly connected…
            for i in 0..n {
                g.arc(i, (i + 1) % n, gen.f64(0.0, 10.0));
            }
            // …plus random chords and the occasional self-loop.
            for u in 0..n {
                for v in 0..n {
                    if u != v && gen.bool(0.15) {
                        g.arc(u, v, gen.f64(0.0, 10.0));
                    }
                }
                if gen.bool(0.1) {
                    g.arc(u, u, gen.f64(0.0, 10.0));
                }
            }
            let karp = karp::max_cycle_mean(&g).unwrap();
            let (howard, cyc) = max_cycle_mean_with_cycle(&g).unwrap();
            assert!(
                (karp - howard).abs() < 1e-9,
                "karp={karp} howard={howard} n={n}"
            );
            assert_eq!(cyc.first(), cyc.last(), "circuit must close");
            let mean = cycle_mean_of(&g, &cyc);
            assert!(
                (mean - howard).abs() < 1e-9,
                "critical circuit mean {mean} vs λ* {howard}"
            );
        });
    }

    #[test]
    fn prop_howard_matches_karp_with_dangling_tails() {
        // Graphs that are NOT strongly connected: a strong core plus
        // acyclic in/out tails — exercises the pruning path.
        check("howard equals karp (tails)", 40, |gen: &mut Gen| {
            let core = gen.usize(2, 20);
            let tail = gen.usize(1, 10);
            let n = core + tail;
            let mut g = DelayDigraph::new(n);
            for i in 0..core {
                g.arc(i, (i + 1) % core, gen.f64(0.0, 10.0));
            }
            for t in core..n {
                if gen.bool(0.5) {
                    // in-tail: feeds the core, on no cycle, stays alive
                    g.arc(t, gen.rng.usize(core), gen.f64(0.0, 10.0));
                } else {
                    // out-tail: fed by the core, no out-arc — pruned
                    g.arc(gen.rng.usize(core), t, gen.f64(0.0, 10.0));
                }
            }
            let karp = karp::max_cycle_mean(&g).unwrap();
            let howard = max_cycle_mean(&g).unwrap();
            assert!((karp - howard).abs() < 1e-9, "karp={karp} howard={howard}");
        });
    }

    #[test]
    fn large_sparse_ring_with_chords() {
        // Above the dispatch threshold: a 500-node delay-digraph shape
        // (ring + self-loops), the exact workload Howard exists for.
        let n = 500;
        let mut g = DelayDigraph::new(n);
        let mut rng = crate::util::rng::Rng::new(0x5CA1E);
        for i in 0..n {
            g.arc(i, (i + 1) % n, 50.0 + 200.0 * rng.f64());
            g.arc(i, i, 25.4);
        }
        let karp = karp::max_cycle_mean(&g).unwrap();
        let howard = max_cycle_mean(&g).unwrap();
        assert!((karp - howard).abs() < 1e-9, "karp={karp} howard={howard}");
    }
}
