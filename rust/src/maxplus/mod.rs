//! Linear systems in the (max, +) algebra — the paper's analytic engine.
//!
//! A synchronous FL round obeys the recurrence (Eq. 4)
//! `t_i(k+1) = max_{j ∈ N_i⁺ ∪ {i}} ( t_j(k) + d_o(j, i) )`,
//! i.e. `t(k+1) = A ⊗ t(k)` where `A` is the overlay's delay matrix in the
//! max-plus semiring. For a strongly connected overlay the asymptotic growth
//! rate `τ = lim t_i(k)/k` — the *cycle time*, inverse of throughput — is the
//! max-plus spectral radius: the **maximum cycle mean** of the delay digraph
//! (Eq. 5).
//!
//! Two exact solvers compute it:
//!
//! * [`karp`] — Karp 1978: Θ(V·E) time, Θ(V²) space. Unbeatable at
//!   Table-3 scale (≤ 87 silos).
//! * [`howard`] — Howard policy iteration over a sparse adjacency list:
//!   O(V+E) per iteration, a handful of iterations in practice, O(V+E)
//!   space. The solver for 500–2000-silo synthetic underlays.
//!
//! [`cycle_time_with`] dispatches between them: Karp below
//! [`HOWARD_MIN_N`] nodes, Howard at or above it. Both routes return λ*
//! **and** a critical circuit, and both are canonicalized to the circuit's
//! mean (summed in a fixed rotation), so the two solvers return
//! bit-identical cycle times whenever they certify the same circuit.
//!
//! * [`algebra`] — max-plus scalars/matrices, ⊗ product, powers.
//! * [`csr`] — [`csr::CsrDelayDigraph`]: the delay digraph in flat
//!   in-adjacency CSR form, arc weights mutable in place — the reusable
//!   per-round structure behind the PR-5 zero-allocation stepping.
//! * [`recurrence`] — exact event-time simulation of Eq. (4) (the paper's
//!   Algorithm 3); cross-checks the solvers in tests and powers the
//!   wall-clock reconstruction for Fig. 2. Its time-varying forms
//!   ([`recurrence::Timeline::simulate_dynamic`] — the dense oracle — and
//!   [`recurrence::Timeline::simulate_reweighted`] — the flat production
//!   path) re-sample the delay digraph per round: the substrate of the
//!   `netsim::scenario` dynamic workloads and the `topology::adaptive`
//!   re-design loop.

pub mod algebra;
pub mod csr;
pub mod howard;
pub mod karp;
pub mod recurrence;

use std::collections::HashMap;

/// Smallest node count at which the dispatcher prefers Howard over Karp.
/// Below this, Karp's dense tables fit in cache and its constant factor
/// wins; above it, Karp's Θ(V·E) walk table dominates the profile.
pub const HOWARD_MIN_N: usize = 128;

/// Which maximum-cycle-mean solver to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CycleSolver {
    /// Size-based dispatch: Karp for `n <` [`HOWARD_MIN_N`], else Howard.
    Auto,
    /// Force Karp (exact O(V·E) reference).
    Karp,
    /// Force Howard (sparse policy iteration).
    Howard,
}

/// Delay digraph of an overlay: node count plus arcs `(j, i, d_o(j,i))`,
/// including the implicit self-loops `d_o(i,i) = s·T_c(i)` of the model.
/// This is the exact input of Eq. (5).
#[derive(Clone, Debug)]
pub struct DelayDigraph {
    pub n: usize,
    /// arcs (src, dst, delay) — self-loops allowed.
    pub arcs: Vec<(usize, usize, f64)>,
}

impl DelayDigraph {
    pub fn new(n: usize) -> DelayDigraph {
        DelayDigraph { n, arcs: Vec::new() }
    }

    pub fn arc(&mut self, j: usize, i: usize, d: f64) {
        assert!(j < self.n && i < self.n);
        assert!(d >= 0.0, "negative delay");
        self.arcs.push((j, i, d));
    }

    /// In-adjacency view used by the recurrence: `in_arcs[i] = [(j, d)]`.
    pub fn in_arcs(&self) -> Vec<Vec<(usize, f64)>> {
        let mut inn = vec![Vec::new(); self.n];
        for &(j, i, d) in &self.arcs {
            inn[i].push((j, d));
        }
        inn
    }

    /// The cycle time τ (Eq. 5): maximum cycle mean via the size-dispatched
    /// solver (Karp under [`HOWARD_MIN_N`] nodes, Howard above).
    pub fn cycle_time(&self) -> f64 {
        cycle_time_with(self, CycleSolver::Auto).expect("overlay must contain a circuit")
    }

    /// Cycle time plus a critical circuit (`[v_0, …, v_0]`).
    pub fn cycle_time_with_cycle(&self) -> Option<(f64, Vec<usize>)> {
        max_cycle_mean_with_cycle(self, CycleSolver::Auto)
    }
}

/// Maximum cycle mean through the chosen solver, or `None` for acyclic
/// graphs.
pub fn cycle_time_with(g: &DelayDigraph, solver: CycleSolver) -> Option<f64> {
    max_cycle_mean_with_cycle(g, solver).map(|(l, _)| l)
}

/// Maximum cycle mean + critical circuit through the chosen solver.
///
/// Whatever solver runs, the returned λ* is *canonicalized*: when the
/// extracted circuit certifies (its mean reproduces the solver's λ* within
/// float tolerance — it always does for both solvers barring pathological
/// round-off), λ* is recomputed as the circuit's mean with a fixed summation
/// order. Karp and Howard therefore return bit-identical values whenever
/// they certify the same critical circuit, which the cross-validation suite
/// in `tests/integration.rs` pins for every builtin network × overlay kind.
pub fn max_cycle_mean_with_cycle(
    g: &DelayDigraph,
    solver: CycleSolver,
) -> Option<(f64, Vec<usize>)> {
    let use_howard = match solver {
        CycleSolver::Karp => false,
        CycleSolver::Howard => true,
        CycleSolver::Auto => g.n >= HOWARD_MIN_N,
    };
    let (lambda, cycle) = if use_howard {
        howard::max_cycle_mean_with_cycle(g)?
    } else {
        karp::max_cycle_mean_with_cycle(g)?
    };
    Some(canonicalize(g, lambda, cycle))
}

/// Rotate the circuit to start at its lowest node index and recompute its
/// mean in that fixed order; keep the solver's raw λ* if the circuit fails
/// to certify (degenerate extraction).
fn canonicalize(g: &DelayDigraph, lambda: f64, cycle: Vec<usize>) -> (f64, Vec<usize>) {
    if cycle.len() < 2 || cycle.first() != cycle.last() {
        return (lambda, cycle);
    }
    let body = &cycle[..cycle.len() - 1];
    let pivot = (0..body.len())
        .min_by_key(|&k| body[k])
        .expect("non-empty circuit");
    let mut rotated: Vec<usize> = Vec::with_capacity(cycle.len());
    rotated.extend_from_slice(&body[pivot..]);
    rotated.extend_from_slice(&body[..pivot]);
    rotated.push(rotated[0]);

    // Max parallel-arc weight per circuit hop, one pass over the arc list.
    let mut want: HashMap<(usize, usize), f64> = rotated
        .windows(2)
        .map(|p| ((p[0], p[1]), f64::NEG_INFINITY))
        .collect();
    for &(u, v, w) in &g.arcs {
        if let Some(best) = want.get_mut(&(u, v)) {
            if w > *best {
                *best = w;
            }
        }
    }
    let mut sum = 0.0f64;
    for p in rotated.windows(2) {
        let w = want[&(p[0], p[1])];
        if w == f64::NEG_INFINITY {
            return (lambda, cycle); // not an actual circuit of g
        }
        sum += w;
    }
    let mean = sum / (rotated.len() - 1) as f64;
    if (mean - lambda).abs() <= 1e-6 * lambda.abs().max(1.0) {
        (mean, rotated)
    } else {
        (lambda, cycle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_strong(n: usize, seed: u64) -> DelayDigraph {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut g = DelayDigraph::new(n);
        for i in 0..n {
            g.arc(i, (i + 1) % n, 10.0 + 90.0 * rng.f64());
            g.arc(i, i, 25.4);
        }
        for _ in 0..2 * n {
            let u = rng.usize(n);
            let v = rng.usize(n);
            if u != v {
                g.arc(u, v, 10.0 + 90.0 * rng.f64());
            }
        }
        g
    }

    #[test]
    fn dispatch_small_graphs_agree_bitwise() {
        for seed in 0..10 {
            let g = random_strong(40, seed);
            let karp = cycle_time_with(&g, CycleSolver::Karp).unwrap();
            let howard = cycle_time_with(&g, CycleSolver::Howard).unwrap();
            let auto = cycle_time_with(&g, CycleSolver::Auto).unwrap();
            assert_eq!(karp.to_bits(), howard.to_bits(), "seed {seed}");
            assert_eq!(auto.to_bits(), karp.to_bits(), "auto routes to karp");
        }
    }

    #[test]
    fn dispatch_large_graphs_agree_bitwise() {
        let g = random_strong(HOWARD_MIN_N + 72, 99);
        let karp = cycle_time_with(&g, CycleSolver::Karp).unwrap();
        let howard = cycle_time_with(&g, CycleSolver::Howard).unwrap();
        let auto = cycle_time_with(&g, CycleSolver::Auto).unwrap();
        assert_eq!(karp.to_bits(), howard.to_bits());
        assert_eq!(auto.to_bits(), howard.to_bits(), "auto routes to howard");
    }

    #[test]
    fn canonical_cycle_is_rotated_to_min_index() {
        let mut g = DelayDigraph::new(4);
        g.arc(2, 3, 4.0);
        g.arc(3, 2, 4.0);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 1.0);
        g.arc(1, 2, 0.0);
        let (l, cyc) = max_cycle_mean_with_cycle(&g, CycleSolver::Auto).unwrap();
        assert!((l - 4.0).abs() < 1e-9);
        assert_eq!(cyc, vec![2, 3, 2]);
    }

    #[test]
    fn both_solvers_none_on_acyclic() {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 1.0);
        assert!(cycle_time_with(&g, CycleSolver::Karp).is_none());
        assert!(cycle_time_with(&g, CycleSolver::Howard).is_none());
    }

    #[test]
    fn cycle_time_with_cycle_certifies() {
        let g = random_strong(60, 5);
        let (l, cyc) = g.cycle_time_with_cycle().unwrap();
        assert_eq!(cyc.first(), cyc.last());
        // recompute the mean independently
        let mut sum = 0.0;
        for p in cyc.windows(2) {
            let w = g
                .arcs
                .iter()
                .filter(|&&(u, v, _)| (u, v) == (p[0], p[1]))
                .map(|&(_, _, w)| w)
                .fold(f64::NEG_INFINITY, f64::max);
            sum += w;
        }
        assert!((sum / (cyc.len() - 1) as f64 - l).abs() < 1e-9);
    }
}
