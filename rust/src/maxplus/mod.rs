//! Linear systems in the (max, +) algebra — the paper's analytic engine.
//!
//! A synchronous FL round obeys the recurrence (Eq. 4)
//! `t_i(k+1) = max_{j ∈ N_i⁺ ∪ {i}} ( t_j(k) + d_o(j, i) )`,
//! i.e. `t(k+1) = A ⊗ t(k)` where `A` is the overlay's delay matrix in the
//! max-plus semiring. For a strongly connected overlay the asymptotic growth
//! rate `τ = lim t_i(k)/k` — the *cycle time*, inverse of throughput — is the
//! max-plus spectral radius: the **maximum cycle mean** of the delay digraph
//! (Eq. 5), computable exactly with Karp's algorithm.
//!
//! * [`algebra`] — max-plus scalars/matrices, ⊗ product, powers.
//! * [`karp`] — O(V·E) maximum cycle mean + critical-circuit extraction.
//! * [`recurrence`] — exact event-time simulation of Eq. (4) (the paper's
//!   Algorithm 3); cross-checks Karp in tests and powers the wall-clock
//!   reconstruction for Fig. 2.

pub mod algebra;
pub mod karp;
pub mod recurrence;

/// Delay digraph of an overlay: node count plus arcs `(j, i, d_o(j,i))`,
/// including the implicit self-loops `d_o(i,i) = s·T_c(i)` of the model.
/// This is the exact input of Eq. (5).
#[derive(Clone, Debug)]
pub struct DelayDigraph {
    pub n: usize,
    /// arcs (src, dst, delay) — self-loops allowed.
    pub arcs: Vec<(usize, usize, f64)>,
}

impl DelayDigraph {
    pub fn new(n: usize) -> DelayDigraph {
        DelayDigraph { n, arcs: Vec::new() }
    }

    pub fn arc(&mut self, j: usize, i: usize, d: f64) {
        assert!(j < self.n && i < self.n);
        assert!(d >= 0.0, "negative delay");
        self.arcs.push((j, i, d));
    }

    /// In-adjacency view used by the recurrence: `in_arcs[i] = [(j, d)]`.
    pub fn in_arcs(&self) -> Vec<Vec<(usize, f64)>> {
        let mut inn = vec![Vec::new(); self.n];
        for &(j, i, d) in &self.arcs {
            inn[i].push((j, d));
        }
        inn
    }

    /// The cycle time τ (Eq. 5) via Karp's maximum cycle mean.
    pub fn cycle_time(&self) -> f64 {
        karp::max_cycle_mean(self).expect("overlay must contain a circuit")
    }
}
