//! CSR delay digraphs — the reusable, mutate-in-place form of
//! [`DelayDigraph`] behind the zero-allocation round stepping of PR 5.
//!
//! The arc-list [`DelayDigraph`] is the right shape for one-shot Eq.-(5)
//! solves, but the dynamic simulators (`Timeline::simulate_dynamic`,
//! `topology::adaptive`, `fl::trainsim`) used to rebuild it — plus a nested
//! `in_arcs()` `Vec<Vec<_>>` — every single round, so a 2 000-silo
//! 10 000-round run performed tens of millions of short-lived allocations.
//! [`CsrDelayDigraph`] stores the same arcs once, grouped by *destination*
//! (the recurrence folds over in-neighbourhoods), in three flat arrays; a
//! scenario perturbation then only **rewrites the weight array in place**
//! (`maxplus::recurrence::step_csr_into` reads it with zero allocation).
//!
//! Structure and weights are separated on purpose: an overlay's arc set is
//! fixed between re-designs, while its delays change every round. Only a
//! re-design rebuilds the structure.
//!
//! PR 6 pushes the separation one step further: a sweep grid runs many
//! cells over the *same* structure (same underlay × designer × model; only
//! scenarios/seeds differ), so [`BatchedCsrWeights`] stores `S` independent
//! weight lanes over one shared [`CsrDelayDigraph`] and
//! [`crate::maxplus::recurrence::step_csr_batched_into`] advances all `S`
//! cells per pass.

use super::DelayDigraph;

/// A delay digraph in in-adjacency CSR form: the arcs into silo `i` are
/// `src[off[i]..off[i+1]]` with weights `w[...]` (self-loops appear as
/// `src == dst`). Within each destination, arcs keep the order of the
/// source [`DelayDigraph`]'s arc list, so conversions are stable.
#[derive(Clone, Debug)]
pub struct CsrDelayDigraph {
    n: usize,
    off: Vec<usize>,
    src: Vec<u32>,
    w: Vec<f64>,
}

impl CsrDelayDigraph {
    /// Flatten a [`DelayDigraph`] (stable counting sort by destination).
    pub fn from_delay_digraph(g: &DelayDigraph) -> CsrDelayDigraph {
        let n = g.n;
        let mut counts = vec![0usize; n + 1];
        for &(_, dst, _) in &g.arcs {
            counts[dst + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let off = counts.clone();
        let mut cursor = counts;
        let m = g.arcs.len();
        let mut src = vec![0u32; m];
        let mut w = vec![0.0f64; m];
        for &(s, dst, d) in &g.arcs {
            let k = cursor[dst];
            cursor[dst] += 1;
            src[k] = s as u32;
            w[k] = d;
        }
        CsrDelayDigraph { n, off, src, w }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Total arc count (self-loops included).
    pub fn arcs(&self) -> usize {
        self.src.len()
    }

    /// In-arcs of silo `i` as parallel `(sources, weights)` slices.
    #[inline]
    pub fn in_arcs_of(&self, i: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.off[i], self.off[i + 1]);
        (&self.src[a..b], &self.w[a..b])
    }

    /// The global CSR arc-index range of silo `i`'s in-arcs — for kernels
    /// that index *parallel* per-arc arrays (the [`BatchedCsrWeights`]
    /// lanes) instead of this structure's own weights.
    #[inline]
    pub fn in_arc_range(&self, i: usize) -> std::ops::Range<usize> {
        self.off[i]..self.off[i + 1]
    }

    /// Source silo of arc `k` (global CSR order).
    #[inline]
    pub fn arc_src(&self, k: usize) -> usize {
        self.src[k] as usize
    }

    /// Destination-row range of chunk `part` of `parts` for a row-partitioned
    /// kernel pass: contiguous, disjoint, covering `0..n` in order, with
    /// boundaries chosen by *arc count* (each chunk targets `≈ arcs/parts`
    /// arcs) so worker loads balance even when in-degrees are skewed. Some
    /// chunks may be empty when `parts > n`.
    ///
    /// Bit-identity with the sequential kernel is structural: every boundary
    /// is a row boundary, so a destination's fold never crosses a chunk and
    /// each worker folds its rows in the identical arc order with the
    /// identical `>` comparison. Computed on the fly from the offset array
    /// (two binary searches, no allocation) — round states need no per-part
    /// buffers.
    #[inline]
    pub fn row_chunk(&self, part: usize, parts: usize) -> std::ops::Range<usize> {
        debug_assert!(part < parts, "part {part} out of {parts}");
        let arcs = self.src.len();
        // smallest row whose offset reaches the arc target k·arcs/parts;
        // partition_point on the monotone `off` keeps boundaries consistent
        // between neighbouring parts (chunk ends where the next begins).
        let bound = |k: usize| {
            let target = k * arcs / parts;
            self.off[..=self.n].partition_point(|&o| o < target).min(self.n)
        };
        let lo = if part == 0 { 0 } else { bound(part) };
        let hi = if part + 1 == parts { self.n } else { bound(part + 1) };
        lo..hi.max(lo)
    }

    /// Visit every arc as `(dst, src, &mut weight)` — the in-place reweight
    /// hook scenario perturbations use (no allocation, no restructuring).
    #[inline]
    pub fn for_each_arc_mut(&mut self, mut f: impl FnMut(usize, usize, &mut f64)) {
        for dst in 0..self.n {
            let (a, b) = (self.off[dst], self.off[dst + 1]);
            for k in a..b {
                f(dst, self.src[k] as usize, &mut self.w[k]);
            }
        }
    }

    /// Expand back to the arc-list form (arcs ordered by destination). The
    /// λ* solvers take [`DelayDigraph`]; use this for one-shot solves on a
    /// perturbed structure — not in per-round loops.
    pub fn to_delay_digraph(&self) -> DelayDigraph {
        let mut g = DelayDigraph::new(self.n);
        for dst in 0..self.n {
            let (srcs, ws) = self.in_arcs_of(dst);
            for (&s, &d) in srcs.iter().zip(ws) {
                g.arc(s as usize, dst, d);
            }
        }
        g
    }
}

/// `S` weight lanes over one shared [`CsrDelayDigraph`] structure — the
/// storage half of the PR-6 batched SoA stepping path.
///
/// **Layout: arc-major, lane-fastest.** Lane `l` of arc `k` lives at
/// `w[k * lanes + l]`, i.e. `[arc0_lane0.., arc0_laneS, arc1_lane0.., …]`.
/// This is the cache-blocking choice: each arc's `S` lanes form one
/// contiguous, cache-line-dense block, so the batched kernel's inner loop
/// (over lanes of a fixed arc) is a unit-stride, auto-vectorizable fold,
/// and consecutive arcs of the same destination reuse the destination's
/// accumulator block. Lane-major (`w[l * arcs + k]`) would instead stride
/// the per-arc fold by the arc count and touch `S` distant cache lines per
/// arc.
///
/// The structure (arc set, `n`, offsets) stays in the shared
/// [`CsrDelayDigraph`]; only weights live here. Each lane is semantically
/// one per-cell `CsrDelayDigraph` weight array — a lane-parameterized
/// reweight (`netsim::scenario::BatchedRoundState::reweight`) writes lane
/// `l` with the exact float expressions the per-cell path writes, so lane
/// equality with the per-cell path is structural (pinned in
/// `tests/csr_equiv.rs`).
#[derive(Clone, Debug)]
pub struct BatchedCsrWeights {
    lanes: usize,
    w: Vec<f64>,
}

impl BatchedCsrWeights {
    /// `lanes` copies of `g`'s current weights (each lane starts as the
    /// shared structure's weight array; reweights then diverge them).
    pub fn broadcast(g: &CsrDelayDigraph, lanes: usize) -> BatchedCsrWeights {
        assert!(lanes > 0, "need at least one weight lane");
        let mut w = Vec::with_capacity(g.w.len() * lanes);
        for &base in &g.w {
            for _ in 0..lanes {
                w.push(base);
            }
        }
        BatchedCsrWeights { lanes, w }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Total arc count (must equal the shared structure's).
    pub fn arcs(&self) -> usize {
        self.w.len() / self.lanes
    }

    /// All lanes of arc `k`, contiguous.
    #[inline]
    pub fn arc_lanes(&self, k: usize) -> &[f64] {
        &self.w[k * self.lanes..(k + 1) * self.lanes]
    }

    /// All lanes of arc `k`, mutable.
    #[inline]
    pub fn arc_lanes_mut(&mut self, k: usize) -> &mut [f64] {
        let s = self.lanes;
        &mut self.w[k * s..(k + 1) * s]
    }

    /// Visit every arc of `g` as `(dst, src, &mut lanes)` in global CSR arc
    /// order — the batched counterpart of
    /// [`CsrDelayDigraph::for_each_arc_mut`] (same order, same zero
    /// allocation; the lane slice replaces the single weight).
    #[inline]
    pub fn for_each_arc_lanes_mut(
        &mut self,
        g: &CsrDelayDigraph,
        mut f: impl FnMut(usize, usize, &mut [f64]),
    ) {
        assert_eq!(self.arcs(), g.arcs(), "weights built for another structure");
        let s = self.lanes;
        for dst in 0..g.n {
            let (a, b) = (g.off[dst], g.off[dst + 1]);
            for k in a..b {
                f(dst, g.src[k] as usize, &mut self.w[k * s..(k + 1) * s]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DelayDigraph {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 0, 0.5);
        g.arc(1, 1, 0.6);
        g.arc(2, 2, 0.7);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 2.0);
        g.arc(2, 0, 3.0);
        g.arc(0, 2, 4.0);
        g
    }

    #[test]
    fn csr_groups_by_destination_preserving_order() {
        let c = CsrDelayDigraph::from_delay_digraph(&sample());
        assert_eq!(c.n(), 3);
        assert_eq!(c.arcs(), 7);
        let (s0, w0) = c.in_arcs_of(0);
        assert_eq!(s0, &[0, 2]);
        assert_eq!(w0, &[0.5, 3.0]);
        let (s2, w2) = c.in_arcs_of(2);
        assert_eq!(s2, &[2, 1, 0]);
        assert_eq!(w2, &[0.7, 2.0, 4.0]);
    }

    #[test]
    fn arc_range_accessors_agree_with_in_arcs_of() {
        let c = CsrDelayDigraph::from_delay_digraph(&sample());
        for i in 0..c.n() {
            let (srcs, _) = c.in_arcs_of(i);
            let range = c.in_arc_range(i);
            assert_eq!(range.len(), srcs.len(), "i={i}");
            for (pos, k) in range.enumerate() {
                assert_eq!(c.arc_src(k), srcs[pos] as usize, "i={i} k={k}");
            }
        }
    }

    #[test]
    fn row_chunks_are_contiguous_disjoint_and_covering() {
        // skewed in-degrees: silo 2 holds 3 of the 7 arcs
        let c = CsrDelayDigraph::from_delay_digraph(&sample());
        for parts in [1usize, 2, 3, 4, 7, 16] {
            let mut next = 0usize;
            let mut total_arcs = 0usize;
            for p in 0..parts {
                let r = c.row_chunk(p, parts);
                assert_eq!(r.start, next, "parts={parts} p={p}: chunks must abut");
                assert!(r.end >= r.start);
                next = r.end;
                for i in r {
                    total_arcs += c.in_arc_range(i).len();
                }
            }
            assert_eq!(next, c.n(), "parts={parts}: chunks must cover 0..n");
            assert_eq!(total_arcs, c.arcs(), "parts={parts}: every arc exactly once");
        }
    }

    #[test]
    fn row_chunks_balance_by_arc_count_not_row_count() {
        // one hub destination with 64 in-arcs plus 63 arc-free rows: arc-
        // count boundaries put the hub alone-ish rather than splitting rows
        let mut g = DelayDigraph::new(64);
        for s in 0..64 {
            g.arc(s, 0, 1.0 + s as f64);
        }
        let c = CsrDelayDigraph::from_delay_digraph(&g);
        let r0 = c.row_chunk(0, 4);
        assert!(r0.contains(&0), "hub row lands in exactly one chunk");
        let mut owners = 0;
        for p in 0..4 {
            if c.row_chunk(p, 4).contains(&0) {
                owners += 1;
            }
        }
        assert_eq!(owners, 1, "a destination's fold never crosses a chunk");
    }

    #[test]
    fn row_chunks_tolerate_more_parts_than_rows_and_empty_graphs() {
        let c = CsrDelayDigraph::from_delay_digraph(&sample());
        let mut covered = Vec::new();
        for p in 0..10 {
            covered.extend(c.row_chunk(p, 10));
        }
        assert_eq!(covered, vec![0, 1, 2]);
        let empty = CsrDelayDigraph::from_delay_digraph(&DelayDigraph::new(5));
        let mut covered = Vec::new();
        for p in 0..3 {
            covered.extend(empty.row_chunk(p, 3));
        }
        assert_eq!(covered, vec![0, 1, 2, 3, 4], "arc-free rows still covered");
    }

    #[test]
    fn batched_weights_broadcast_and_reweight_per_lane() {
        let c = CsrDelayDigraph::from_delay_digraph(&sample());
        let mut bw = BatchedCsrWeights::broadcast(&c, 3);
        assert_eq!(bw.lanes(), 3);
        assert_eq!(bw.arcs(), c.arcs());
        // broadcast: every lane starts as the structure's weight
        for i in 0..c.n() {
            let (_, ws) = c.in_arcs_of(i);
            for (pos, k) in c.in_arc_range(i).enumerate() {
                for l in 0..3 {
                    assert_eq!(bw.arc_lanes(k)[l].to_bits(), ws[pos].to_bits());
                }
            }
        }
        // per-lane reweight visits arcs in the same order as the per-cell
        // visitor, and lanes stay independent
        let mut order_batched = Vec::new();
        bw.for_each_arc_lanes_mut(&c, |dst, src, lanes| {
            order_batched.push((dst, src));
            for (l, w) in lanes.iter_mut().enumerate() {
                *w = (dst * 100 + src * 10 + l) as f64;
            }
        });
        let mut c2 = c.clone();
        let mut order_cell = Vec::new();
        c2.for_each_arc_mut(|dst, src, _| order_cell.push((dst, src)));
        assert_eq!(order_batched, order_cell, "arc visit order must match");
        for k in 0..c.arcs() {
            let lanes = bw.arc_lanes(k);
            assert_eq!(lanes[1] - lanes[0], 1.0);
            assert_eq!(lanes[2] - lanes[1], 1.0);
        }
    }

    #[test]
    fn reweight_in_place_and_round_trip() {
        let g = sample();
        let mut c = CsrDelayDigraph::from_delay_digraph(&g);
        c.for_each_arc_mut(|dst, src, w| {
            if dst == src {
                *w *= 2.0;
            }
        });
        let back = c.to_delay_digraph();
        assert_eq!(back.n, 3);
        assert_eq!(back.arcs.len(), 7);
        for &(s, d, w) in &back.arcs {
            let orig = g
                .arcs
                .iter()
                .find(|&&(a, b, _)| (a, b) == (s, d))
                .map(|&(_, _, w)| w)
                .unwrap();
            if s == d {
                assert_eq!(w, 2.0 * orig);
            } else {
                assert_eq!(w, orig);
            }
        }
    }
}
