//! CSR delay digraphs — the reusable, mutate-in-place form of
//! [`DelayDigraph`] behind the zero-allocation round stepping of PR 5.
//!
//! The arc-list [`DelayDigraph`] is the right shape for one-shot Eq.-(5)
//! solves, but the dynamic simulators (`Timeline::simulate_dynamic`,
//! `topology::adaptive`, `fl::trainsim`) used to rebuild it — plus a nested
//! `in_arcs()` `Vec<Vec<_>>` — every single round, so a 2 000-silo
//! 10 000-round run performed tens of millions of short-lived allocations.
//! [`CsrDelayDigraph`] stores the same arcs once, grouped by *destination*
//! (the recurrence folds over in-neighbourhoods), in three flat arrays; a
//! scenario perturbation then only **rewrites the weight array in place**
//! (`maxplus::recurrence::step_csr_into` reads it with zero allocation).
//!
//! Structure and weights are separated on purpose: an overlay's arc set is
//! fixed between re-designs, while its delays change every round. Only a
//! re-design rebuilds the structure.

use super::DelayDigraph;

/// A delay digraph in in-adjacency CSR form: the arcs into silo `i` are
/// `src[off[i]..off[i+1]]` with weights `w[...]` (self-loops appear as
/// `src == dst`). Within each destination, arcs keep the order of the
/// source [`DelayDigraph`]'s arc list, so conversions are stable.
#[derive(Clone, Debug)]
pub struct CsrDelayDigraph {
    n: usize,
    off: Vec<usize>,
    src: Vec<u32>,
    w: Vec<f64>,
}

impl CsrDelayDigraph {
    /// Flatten a [`DelayDigraph`] (stable counting sort by destination).
    pub fn from_delay_digraph(g: &DelayDigraph) -> CsrDelayDigraph {
        let n = g.n;
        let mut counts = vec![0usize; n + 1];
        for &(_, dst, _) in &g.arcs {
            counts[dst + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let off = counts.clone();
        let mut cursor = counts;
        let m = g.arcs.len();
        let mut src = vec![0u32; m];
        let mut w = vec![0.0f64; m];
        for &(s, dst, d) in &g.arcs {
            let k = cursor[dst];
            cursor[dst] += 1;
            src[k] = s as u32;
            w[k] = d;
        }
        CsrDelayDigraph { n, off, src, w }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Total arc count (self-loops included).
    pub fn arcs(&self) -> usize {
        self.src.len()
    }

    /// In-arcs of silo `i` as parallel `(sources, weights)` slices.
    #[inline]
    pub fn in_arcs_of(&self, i: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.off[i], self.off[i + 1]);
        (&self.src[a..b], &self.w[a..b])
    }

    /// Visit every arc as `(dst, src, &mut weight)` — the in-place reweight
    /// hook scenario perturbations use (no allocation, no restructuring).
    #[inline]
    pub fn for_each_arc_mut(&mut self, mut f: impl FnMut(usize, usize, &mut f64)) {
        for dst in 0..self.n {
            let (a, b) = (self.off[dst], self.off[dst + 1]);
            for k in a..b {
                f(dst, self.src[k] as usize, &mut self.w[k]);
            }
        }
    }

    /// Expand back to the arc-list form (arcs ordered by destination). The
    /// λ* solvers take [`DelayDigraph`]; use this for one-shot solves on a
    /// perturbed structure — not in per-round loops.
    pub fn to_delay_digraph(&self) -> DelayDigraph {
        let mut g = DelayDigraph::new(self.n);
        for dst in 0..self.n {
            let (srcs, ws) = self.in_arcs_of(dst);
            for (&s, &d) in srcs.iter().zip(ws) {
                g.arc(s as usize, dst, d);
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DelayDigraph {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 0, 0.5);
        g.arc(1, 1, 0.6);
        g.arc(2, 2, 0.7);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 2.0);
        g.arc(2, 0, 3.0);
        g.arc(0, 2, 4.0);
        g
    }

    #[test]
    fn csr_groups_by_destination_preserving_order() {
        let c = CsrDelayDigraph::from_delay_digraph(&sample());
        assert_eq!(c.n(), 3);
        assert_eq!(c.arcs(), 7);
        let (s0, w0) = c.in_arcs_of(0);
        assert_eq!(s0, &[0, 2]);
        assert_eq!(w0, &[0.5, 3.0]);
        let (s2, w2) = c.in_arcs_of(2);
        assert_eq!(s2, &[2, 1, 0]);
        assert_eq!(w2, &[0.7, 2.0, 4.0]);
    }

    #[test]
    fn reweight_in_place_and_round_trip() {
        let g = sample();
        let mut c = CsrDelayDigraph::from_delay_digraph(&g);
        c.for_each_arc_mut(|dst, src, w| {
            if dst == src {
                *w *= 2.0;
            }
        });
        let back = c.to_delay_digraph();
        assert_eq!(back.n, 3);
        assert_eq!(back.arcs.len(), 7);
        for &(s, d, w) in &back.arcs {
            let orig = g
                .arcs
                .iter()
                .find(|&&(a, b, _)| (a, b) == (s, d))
                .map(|&(_, _, w)| w)
                .unwrap();
            if s == d {
                assert_eq!(w, 2.0 * orig);
            } else {
                assert_eq!(w, orig);
            }
        }
    }
}
