//! Max-plus scalars and matrices.
//!
//! The semiring (ℝ ∪ {−∞}, max, +): `a ⊕ b = max(a,b)`, `a ⊗ b = a + b`,
//! zero element ε = −∞, unit e = 0. A synchronous round is the linear map
//! `t(k+1) = A ⊗ t(k)` with `A[i][j] = d_o(j, i)` (ε where no arc). Used in
//! tests to tie Eq. (4) to matrix powers and to verify that the cycle time
//! is the max-plus spectral radius.

/// ε, the additive identity of the semiring.
pub const EPS: f64 = f64::NEG_INFINITY;

/// Dense max-plus matrix (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct MpMat {
    pub n: usize,
    pub a: Vec<f64>,
}

impl MpMat {
    /// All-ε matrix.
    pub fn eps(n: usize) -> MpMat {
        MpMat {
            n,
            a: vec![EPS; n * n],
        }
    }

    /// Max-plus identity: 0 on the diagonal, ε elsewhere.
    pub fn identity(n: usize) -> MpMat {
        let mut m = MpMat::eps(n);
        for i in 0..n {
            m.set(i, i, 0.0);
        }
        m
    }

    /// Build from a delay digraph: `A[i][j] = d(j → i)`.
    pub fn from_delays(g: &super::DelayDigraph) -> MpMat {
        let mut m = MpMat::eps(g.n);
        for &(j, i, d) in &g.arcs {
            let cur = m.get(i, j);
            m.set(i, j, cur.max(d));
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    /// Max-plus matrix product `self ⊗ rhs`.
    pub fn otimes(&self, rhs: &MpMat) -> MpMat {
        assert_eq!(self.n, rhs.n);
        let n = self.n;
        let mut out = MpMat::eps(n);
        for i in 0..n {
            for k in 0..n {
                let aik = self.get(i, k);
                if aik == EPS {
                    continue;
                }
                for j in 0..n {
                    let b = rhs.get(k, j);
                    if b == EPS {
                        continue;
                    }
                    let v = aik + b;
                    if v > out.get(i, j) {
                        out.set(i, j, v);
                    }
                }
            }
        }
        out
    }

    /// Max-plus matrix–vector product `self ⊗ x`.
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let n = self.n;
        let mut out = vec![EPS; n];
        for i in 0..n {
            for j in 0..n {
                let a = self.get(i, j);
                if a == EPS || x[j] == EPS {
                    continue;
                }
                let v = a + x[j];
                if v > out[i] {
                    out[i] = v;
                }
            }
        }
        out
    }

    /// k-th max-plus power by repeated squaring.
    pub fn pow(&self, mut k: usize) -> MpMat {
        let mut result = MpMat::identity(self.n);
        let mut base = self.clone();
        while k > 0 {
            if k & 1 == 1 {
                result = result.otimes(&base);
            }
            base = base.otimes(&base);
            k >>= 1;
        }
        result
    }

    /// Spectral radius via the power-iteration growth rate: for an
    /// irreducible matrix, `max_i (A^{⊗(K+1)} x)_i − (A^{⊗K} x)_i → λ`.
    /// Exposed as an *independent* estimator to cross-check Karp.
    pub fn spectral_radius_estimate(&self, iters: usize) -> f64 {
        // The per-step increment oscillates with the critical circuit's
        // period, so measure the *slope* over the second half of the run:
        // λ ≈ (max x(K) − max x(K/2)) / (K − K/2).
        let mut x = vec![0.0; self.n];
        let half = (iters / 2).max(1);
        let mut mid_max = 0.0f64;
        let mut cur_max = 0.0f64;
        for k in 1..=iters {
            x = self.apply(&x);
            cur_max = x.iter().cloned().fold(EPS, f64::max);
            if k == half {
                mid_max = cur_max;
            }
        }
        (cur_max - mid_max) / (iters - half) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maxplus::DelayDigraph;

    fn ring3() -> DelayDigraph {
        let mut g = DelayDigraph::new(3);
        g.arc(0, 1, 1.0);
        g.arc(1, 2, 3.0);
        g.arc(2, 0, 4.0);
        g
    }

    #[test]
    fn identity_is_neutral() {
        let a = MpMat::from_delays(&ring3());
        let i = MpMat::identity(3);
        assert_eq!(a.otimes(&i), a);
        assert_eq!(i.otimes(&a), a);
    }

    #[test]
    fn apply_matches_recurrence_step() {
        let g = ring3();
        let a = MpMat::from_delays(&g);
        let t0 = vec![0.0, 0.0, 0.0];
        let t1 = a.apply(&t0);
        // t1[i] = max_j (d(j,i)): node1 gets d(0,1)=1, node2 d(1,2)=3, node0 d(2,0)=4
        assert_eq!(t1, vec![4.0, 1.0, 3.0]);
    }

    #[test]
    fn pow_consistent_with_repeated_otimes() {
        let a = MpMat::from_delays(&ring3());
        let mut manual = MpMat::identity(3);
        for _ in 0..5 {
            manual = manual.otimes(&a);
        }
        assert_eq!(a.pow(5), manual);
    }

    #[test]
    fn power_iteration_converges_to_cycle_time() {
        let g = ring3();
        let a = MpMat::from_delays(&g);
        let lambda = a.spectral_radius_estimate(300);
        let tau = g.cycle_time(); // 8/3 via Karp
        assert!(
            (lambda - tau).abs() < 0.05,
            "power-iter {lambda} vs karp {tau}"
        );
    }

    #[test]
    fn self_loops_enter_diagonal() {
        let mut g = DelayDigraph::new(2);
        g.arc(0, 0, 7.0);
        g.arc(0, 1, 1.0);
        g.arc(1, 0, 1.0);
        let a = MpMat::from_delays(&g);
        assert_eq!(a.get(0, 0), 7.0);
        assert_eq!(a.get(1, 0), 1.0);
        assert_eq!(a.get(0, 1), 1.0);
        assert_eq!(a.get(1, 1), EPS);
    }

    #[test]
    fn parallel_arcs_keep_max() {
        let mut g = DelayDigraph::new(2);
        g.arc(0, 1, 1.0);
        g.arc(0, 1, 5.0);
        g.arc(1, 0, 1.0);
        let a = MpMat::from_delays(&g);
        assert_eq!(a.get(1, 0), 5.0);
    }
}
