//! Deterministic pseudo-random number generation.
//!
//! Every stochastic component of the framework (data partitioning, MATCHA
//! matching activation, synthetic topology generation, property tests) draws
//! from an explicitly seeded [`Rng`], so experiments reproduce bit-for-bit
//! across runs and machines.
//!
//! The generator is xoshiro256++ (Blackman & Vigna) seeded through
//! SplitMix64, the de-facto standard small PRNG: 256-bit state, period
//! 2^256 − 1, passes BigCrush, and is sub-nanosecond per draw.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// The per-item seeding rule (PR 3): a sweep cell or Monte-Carlo batch at
/// position `index` under base seed `base` draws its stream from
/// `Rng::new(derive_seed(base, index))` — never from an RNG shared across
/// items — so results are independent of scheduling and thread count.
/// SplitMix64 finalizer over the (base, index) pair.
pub fn derive_seed(base: u64, index: u64) -> u64 {
    let mut z = base
        .wrapping_add(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(index.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-silo / per-test streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Debiased via rejection sampling.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "usize(0) is meaningless");
        let n = n as u64;
        // Lemire's method with rejection on the biased zone.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.usize(hi - lo)
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached spare deliberately omitted —
    /// branch-free reproducibility matters more than the extra ~30ns).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal draw with parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; used by [`Rng::dirichlet`].
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(α·1) sample of dimension `k` (label-skew partitioner).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = v.iter().sum();
        if sum <= 0.0 {
            // Degenerate draw (α extremely small): put all mass on one class.
            let j = self.usize(k);
            v.iter_mut().for_each(|x| *x = 0.0);
            v[j] = 1.0;
            return v;
        }
        v.iter_mut().for_each(|x| *x /= sum);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.usize(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn usize_uniform_and_in_range() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.usize(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(13);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let v = r.dirichlet(alpha, 8);
            assert_eq!(v.len(), 8);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_behaviour() {
        // Small alpha → spiky; large alpha → flat.
        let mut r = Rng::new(17);
        let spiky: f64 = (0..200)
            .map(|_| {
                r.dirichlet(0.05, 10)
                    .iter()
                    .cloned()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 200.0;
        let flat: f64 = (0..200)
            .map(|_| {
                r.dirichlet(50.0, 10)
                    .iter()
                    .cloned()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 200.0;
        assert!(spiky > 0.6, "spiky={spiky}");
        assert!(flat < 0.3, "flat={flat}");
        assert!(spiky > 2.0 * flat, "spiky={spiky} flat={flat}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(29);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derive_seed_deterministic_and_spread() {
        assert_eq!(derive_seed(7, 0), derive_seed(7, 0));
        assert_ne!(derive_seed(7, 0), derive_seed(7, 1));
        assert_ne!(derive_seed(7, 0), derive_seed(8, 0));
        // neighbouring cells get well-separated streams
        let mut a = Rng::new(derive_seed(7, 3));
        let mut b = Rng::new(derive_seed(7, 4));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(31);
        for _ in 0..1000 {
            assert!(r.lognormal(5.0, 1.5) > 0.0);
        }
    }
}
