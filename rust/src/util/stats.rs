//! Small statistics helpers shared by the bench harness, MATCHA Monte-Carlo
//! cycle-time estimation, and experiment reporting.

/// Summary statistics of a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Percentile of an already-sorted sample with linear interpolation.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Welford online mean/variance accumulator (used where samples stream in
/// and we don't want to buffer, e.g. the MATCHA round sampler).
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn n(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    /// Half-width of the 95% CI of the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            f64::INFINITY
        } else {
            1.96 * self.std() / (self.n as f64).sqrt()
        }
    }
}

/// Jensen–Shannon divergence between two discrete distributions (used by the
/// data partitioner diagnostics, mirroring the paper's Fig. 25).
pub fn js_divergence(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    let kl = |a: &[f64], b: &[f64]| -> f64 {
        a.iter()
            .zip(b)
            .filter(|(&x, _)| x > 0.0)
            .map(|(&x, &y)| x * (x / y).log2())
            .sum()
    };
    let m: Vec<f64> = p.iter().zip(q).map(|(&a, &b)| 0.5 * (a + b)).collect();
    0.5 * kl(p, &m) + 0.5 * kl(q, &m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::default();
        xs.iter().for_each(|&x| w.push(x));
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-12);
    }

    #[test]
    fn js_divergence_properties() {
        let p = [0.5, 0.5, 0.0];
        let q = [0.0, 0.5, 0.5];
        let d = js_divergence(&p, &q);
        assert!(d > 0.0 && d <= 1.0);
        assert!((js_divergence(&p, &p)).abs() < 1e-12);
        // Symmetry
        assert!((js_divergence(&p, &q) - js_divergence(&q, &p)).abs() < 1e-12);
        // Disjoint supports → exactly 1 bit
        let a = [1.0, 0.0];
        let b = [0.0, 1.0];
        assert!((js_divergence(&a, &b) - 1.0).abs() < 1e-12);
    }
}
