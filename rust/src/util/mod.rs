//! Zero-dependency substrates.
//!
//! The deployment image vendors only the `xla` crate and its build chain, so
//! everything an ordinary framework would pull from crates.io (PRNG, JSON,
//! CLI parsing, statistics, bench harness, property testing) is implemented
//! here from scratch. Each submodule is small, documented, and unit-tested.

pub mod rng;
pub mod grid;
pub mod json;
pub mod cli;
pub mod stats;
pub mod log;
pub mod bench;
pub mod parallel;
pub mod prop;
pub mod table;
