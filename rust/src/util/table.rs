//! ASCII table rendering for the experiment harness.
//!
//! Every `fedtopo <table|fig>` subcommand prints rows in the same layout as
//! the paper's tables so results can be compared side by side.

/// A simple column-aligned table with a title and optional footnote.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: &str) {
        self.notes.push(s.to_string());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let sep: String = width
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    // Right-align numeric-looking cells, left-align text.
                    let numeric = c
                        .chars()
                        .all(|ch| ch.is_ascii_digit() || ".-+e×x%()".contains(ch));
                    if numeric && !c.is_empty() {
                        format!(" {:>w$} ", c, w = width[i])
                    } else {
                        format!(" {:<w$} ", c, w = width[i])
                    }
                })
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `d` decimals, trimming to integer display when exact.
pub fn fnum(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["Network", "STAR", "RING"]);
        t.row(vec!["Gaia".into(), "391".into(), "118".into()]);
        t.row(vec!["AWS North America".into(), "288".into(), "81".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("Gaia"));
        // header and row lines have same display length
        let lines: Vec<&str> = s.lines().filter(|l| l.contains('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = Table::new("bad", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(fnum(9.0, 1), "9.0");
    }
}
