//! Leveled stderr logging (the image has no `env_logger`).
//!
//! Level is controlled by `FEDTOPO_LOG` (error|warn|info|debug|trace,
//! default info) or programmatically via [`set_level`]. Messages carry a
//! monotonic timestamp relative to process start so long experiment runs
//! are easy to read.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn start() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

fn level_from_env() -> Level {
    match std::env::var("FEDTOPO_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let l = level_from_env();
        LEVEL.store(l as u8, Ordering::Relaxed);
        l
    } else {
        // Safe: only stored from the enum above.
        match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if l <= level() {
        let t = start().elapsed().as_secs_f64();
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_and_get() {
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }
}
