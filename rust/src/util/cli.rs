//! Tiny declarative CLI argument parser (the image has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! typed accessors with defaults, and generated `--help` text. Unknown flags
//! are an error so typos fail loudly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative option spec used for parsing and `--help` rendering.
///
/// `help` is an owned `String` (not `&'static str`) so option help can be
/// rendered from the [`crate::spec`] registry at runtime — name lists in
/// `--help` can then never drift from what the parsers accept.
#[derive(Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: String,
    /// Whether the option takes a value (`--key v`) or is a bare flag.
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
pub struct Args {
    cmd: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    specs: Vec<OptSpec>,
}

impl Args {
    /// Parse `argv` (without the program/subcommand prefix) against `specs`.
    pub fn parse(cmd: &str, argv: &[String], specs: &[OptSpec]) -> Result<Args, String> {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let known = |n: &str| specs.iter().find(|s| s.name == n);

        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(usage(cmd, specs));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = known(&name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", usage(cmd, specs)))?;
                if spec.takes_value {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    values.insert(name, val);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    flags.push(name);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args {
            cmd: cmd.to_string(),
            values,
            flags,
            positional,
            specs: specs.to_vec(),
        })
    }

    fn default_of(&self, name: &str) -> Option<&'static str> {
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default)
    }

    pub fn str(&self, name: &str) -> Option<String> {
        self.values
            .get(name)
            .cloned()
            .or_else(|| self.default_of(name).map(|s| s.to_string()))
    }

    pub fn str_or(&self, name: &str, fallback: &str) -> String {
        self.str(name).unwrap_or_else(|| fallback.to_string())
    }

    pub fn f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.str(name) {
            None => Ok(None),
            Some(s) => parse_f64_human(&s)
                .map(Some)
                .ok_or_else(|| format!("--{name}: cannot parse '{s}' as a number")),
        }
    }

    pub fn f64_or(&self, name: &str, fallback: f64) -> Result<f64, String> {
        Ok(self.f64(name)?.unwrap_or(fallback))
    }

    pub fn usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.str(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse '{s}' as an integer")),
        }
    }

    pub fn usize_or(&self, name: &str, fallback: usize) -> Result<usize, String> {
        Ok(self.usize(name)?.unwrap_or(fallback))
    }

    pub fn u64_or(&self, name: &str, fallback: u64) -> Result<u64, String> {
        match self.str(name) {
            None => Ok(fallback),
            Some(s) => s
                .parse::<u64>()
                .map_err(|_| format!("--{name}: cannot parse '{s}' as an integer")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn cmd(&self) -> &str {
        &self.cmd
    }
}

/// Parse human-friendly numbers: `100e6`, `1.5`, `10G`, `100M`, `250k`.
pub fn parse_f64_human(s: &str) -> Option<f64> {
    let s = s.trim();
    if let Ok(v) = s.parse::<f64>() {
        return Some(v);
    }
    let (num, suffix) = s.split_at(s.len().saturating_sub(1));
    let mult = match suffix {
        "k" | "K" => 1e3,
        "M" => 1e6,
        "G" => 1e9,
        "T" => 1e12,
        _ => return None,
    };
    num.trim().parse::<f64>().ok().map(|v| v * mult)
}

/// Render `--help` for a subcommand.
pub fn usage(cmd: &str, specs: &[OptSpec]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "usage: fedtopo {cmd} [options]");
    if !specs.is_empty() {
        let _ = writeln!(out, "\noptions:");
        let width = specs.iter().map(|s| s.name.len()).max().unwrap_or(0) + 10;
        for s in specs {
            let left = if s.takes_value {
                format!("--{} <v>", s.name)
            } else {
                format!("--{}", s.name)
            };
            let default = s
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(out, "  {left:width$}{}{default}", s.help);
        }
    }
    out
}

/// Convenience macro-free spec builder. Accepts `&str` literals and
/// registry-rendered `String`s alike (hence not `const`: help text may be
/// computed from [`crate::spec`]).
pub fn opt(name: &'static str, help: impl Into<String>, default: Option<&'static str>) -> OptSpec {
    OptSpec {
        name,
        help: help.into(),
        takes_value: true,
        default,
    }
}

pub fn flag(name: &'static str, help: impl Into<String>) -> OptSpec {
    OptSpec {
        name,
        help: help.into(),
        takes_value: false,
        default: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    fn specs() -> Vec<OptSpec> {
        vec![
            opt("network", "underlay name", Some("gaia")),
            opt("access", "access capacity bps", Some("10e9")),
            opt("s", "local steps", Some("1")),
            flag("verbose", "chatty output"),
        ]
    }

    #[test]
    fn parses_key_value_forms() {
        let a = Args::parse(
            "t",
            &argv(&["--network", "geant", "--access=100M", "--verbose"]),
            &specs(),
        )
        .unwrap();
        assert_eq!(a.str("network").unwrap(), "geant");
        assert_eq!(a.f64("access").unwrap(), Some(100e6));
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("s", 9).unwrap(), 1); // default applies
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse("t", &argv(&[]), &specs()).unwrap();
        assert_eq!(a.str("network").unwrap(), "gaia");
        assert_eq!(a.f64_or("access", 0.0).unwrap(), 10e9);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(Args::parse("t", &argv(&["--nope"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse("t", &argv(&["--network"]), &specs()).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = Args::parse("t", &argv(&["pos1", "--s", "5", "pos2"]), &specs()).unwrap();
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
        assert_eq!(a.usize("s").unwrap(), Some(5));
    }

    #[test]
    fn human_numbers() {
        assert_eq!(parse_f64_human("10G"), Some(10e9));
        assert_eq!(parse_f64_human("100M"), Some(100e6));
        assert_eq!(parse_f64_human("1.5"), Some(1.5));
        assert_eq!(parse_f64_human("3e8"), Some(3e8));
        assert_eq!(parse_f64_human("abc"), None);
    }

    #[test]
    fn help_renders() {
        let u = usage("table3", &specs());
        assert!(u.contains("--network"));
        assert!(u.contains("[default: gaia]"));
    }
}
