//! Flat row-major matrices — the storage contract of the PR-5 refactor.
//!
//! Everything per-pair in the simulator (routed latencies, available
//! bandwidths, hop counts) used to live in `Vec<Vec<T>>`: N heap headers, N
//! separate allocations, and a pointer chase per access. [`Grid`] stores the
//! same N×N payload in **one** flat allocation indexed `(row, col)`, which
//! is what lets `Routes` hold 20 000-silo underlays (see
//! [`crate::netsim::routing`]) — the dense nested layout dies of allocator
//! overhead long before the payload itself stops fitting.

use std::ops::{Index, IndexMut};

/// A dense rows×cols matrix in one flat allocation, indexed `g[(r, c)]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Grid<T> {
    cols: usize,
    v: Vec<T>,
}

impl<T: Clone> Grid<T> {
    /// rows×cols grid with every cell set to `fill`.
    pub fn filled(rows: usize, cols: usize, fill: T) -> Grid<T> {
        Grid {
            cols,
            v: vec![fill; rows.checked_mul(cols).expect("grid size overflow")],
        }
    }

    /// Build from a nested `Vec<Vec<T>>` (every row must have equal length).
    /// Exists for the dense-oracle tests and small hand-written fixtures.
    pub fn from_nested(rows: &[Vec<T>]) -> Grid<T> {
        let cols = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut v = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            v.extend_from_slice(r);
        }
        Grid { cols, v }
    }
}

impl<T> Grid<T> {
    pub fn rows(&self) -> usize {
        if self.cols == 0 {
            0
        } else {
            self.v.len() / self.cols
        }
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// One row as a contiguous slice.
    pub fn row(&self, r: usize) -> &[T] {
        &self.v[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.v[r * self.cols..(r + 1) * self.cols]
    }

    /// The whole payload, row-major.
    pub fn as_slice(&self) -> &[T] {
        &self.v
    }

    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.v
    }
}

impl<T> Index<(usize, usize)> for Grid<T> {
    type Output = T;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &T {
        debug_assert!(c < self.cols);
        &self.v[r * self.cols + c]
    }
}

impl<T> IndexMut<(usize, usize)> for Grid<T> {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut T {
        debug_assert!(c < self.cols);
        &mut self.v[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filled_and_indexing() {
        let mut g = Grid::filled(3, 4, 0.0f64);
        assert_eq!(g.rows(), 3);
        assert_eq!(g.cols(), 4);
        g[(1, 2)] = 7.5;
        assert_eq!(g[(1, 2)], 7.5);
        assert_eq!(g[(0, 0)], 0.0);
        assert_eq!(g.row(1), &[0.0, 0.0, 7.5, 0.0]);
        assert_eq!(g.as_slice().len(), 12);
    }

    #[test]
    fn from_nested_round_trips() {
        let nested = vec![vec![1u32, 2, 3], vec![4, 5, 6]];
        let g = Grid::from_nested(&nested);
        assert_eq!(g.rows(), 2);
        for (r, row) in nested.iter().enumerate() {
            for (c, &x) in row.iter().enumerate() {
                assert_eq!(g[(r, c)], x);
            }
        }
    }

    #[test]
    fn row_mut_writes_through() {
        let mut g = Grid::filled(2, 2, 1i64);
        g.row_mut(0)[1] = 9;
        assert_eq!(g[(0, 1)], 9);
        assert_eq!(g[(1, 1)], 1);
    }

    #[test]
    #[should_panic]
    fn out_of_range_row_panics() {
        let g = Grid::filled(2, 2, 0u8);
        let _ = g.row(2);
    }
}
