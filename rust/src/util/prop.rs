//! Property-based testing helper (the image has no `proptest`).
//!
//! [`check`] runs a property over `cases` randomly generated inputs; on
//! failure it performs greedy *shrinking* by retrying the property on
//! size-reduced regenerations (halving the generator's size hint) and
//! reports the smallest failing seed so the case replays deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath; the same property runs
//! // for real in this module's #[test]s.)
//! use fedtopo::util::prop::{check, Gen};
//! check("sort is idempotent", 200, |g: &mut Gen| {
//!     let mut v = g.vec_f64(0, 50);
//!     v.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     let w = { let mut w = v.clone(); w.sort_by(|a, b| a.partial_cmp(b).unwrap()); w };
//!     assert_eq!(v, w);
//! });
//! ```

use super::rng::Rng;

/// Generator handed to properties: a seeded RNG plus a size hint that the
/// shrinker lowers when hunting for minimal counterexamples.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Gen {
        Gen {
            rng: Rng::new(seed),
            size,
            seed,
        }
    }

    /// Integer in [lo, hi_cap) with the upper bound softened by `size`.
    pub fn usize(&mut self, lo: usize, hi_cap: usize) -> usize {
        let hi = lo + 1 + ((hi_cap.saturating_sub(lo + 1)) * self.size.min(100)) / 100;
        self.rng.range(lo, hi.max(lo + 1))
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    pub fn vec_f64(&mut self, min_len: usize, max_len: usize) -> Vec<f64> {
        let n = self.usize(min_len, max_len + 1);
        (0..n).map(|_| self.f64(-1e3, 1e3)).collect()
    }

    /// A connected undirected graph as an edge list over `n` nodes:
    /// random spanning tree + extra random edges.
    pub fn connected_graph(&mut self, min_n: usize, max_n: usize) -> (usize, Vec<(usize, usize)>) {
        let n = self.usize(min_n.max(2), max_n + 1);
        let mut edges = Vec::new();
        // Random spanning tree: attach node i to a random earlier node.
        for i in 1..n {
            let j = self.rng.usize(i);
            edges.push((j, i));
        }
        // Extra edges up to ~size% density.
        let extra = (n * self.size.min(100)) / 100;
        for _ in 0..extra {
            let a = self.rng.usize(n);
            let b = self.rng.usize(n);
            if a != b && !edges.contains(&(a.min(b), a.max(b))) {
                edges.push((a.min(b), a.max(b)));
            }
        }
        (n, edges)
    }
}

/// Run `prop` for `cases` random inputs. Panics (with the replay seed) on the
/// first failure after shrinking. The base seed can be overridden with
/// `FEDTOPO_PROP_SEED` for replay.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    let base: u64 = std::env::var("FEDTOPO_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xFED_0707);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let failed = run_once(&prop, seed, 100).is_some();
        if failed {
            // Shrink: lower the size hint; keep the smallest size that fails.
            let mut min_size = 100;
            let mut msg = run_once(&prop, seed, 100).unwrap();
            for size in [50, 25, 12, 6, 3, 1] {
                if let Some(m) = run_once(&prop, seed, size) {
                    min_size = size;
                    msg = m;
                }
            }
            panic!(
                "property '{name}' failed (seed={seed}, size={min_size}).\n\
                 replay with FEDTOPO_PROP_SEED and this case.\n{msg}"
            );
        }
    }
}

fn run_once<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    prop: &F,
    seed: u64,
    size: usize,
) -> Option<String> {
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen::new(seed, size);
        prop(&mut g);
    });
    match result {
        Ok(()) => None,
        Err(e) => Some(
            e.downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string()),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", 50, |g| {
            let v = g.vec_f64(0, 20);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        // Silence the default panic-hook spew from catch_unwind probes.
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = std::panic::catch_unwind(|| {
            check("always fails", 10, |g| {
                let v = g.vec_f64(1, 5);
                assert!(v.is_empty(), "non-empty input");
            });
        });
        std::panic::set_hook(hook);
        std::panic::resume_unwind(r.unwrap_err());
    }

    #[test]
    fn connected_graph_is_connected() {
        check("generated graphs connected", 50, |g| {
            let (n, edges) = g.connected_graph(2, 30);
            // Union-find connectivity check.
            let mut parent: Vec<usize> = (0..n).collect();
            fn find(p: &mut Vec<usize>, x: usize) -> usize {
                if p[x] != x {
                    let r = find(p, p[x]);
                    p[x] = r;
                }
                p[x]
            }
            for &(a, b) in &edges {
                let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
                parent[ra] = rb;
            }
            let root = find(&mut parent, 0);
            for i in 0..n {
                assert_eq!(find(&mut parent, i), root, "node {i} disconnected");
            }
        });
    }
}
