//! Deterministic, dependency-free data parallelism.
//!
//! The sweep engine and the Monte-Carlo estimators fan work out over a
//! scoped-thread pool, but every caller gets the **ordered-merge determinism
//! contract**: [`par_map_indexed`] returns `f(i, &items[i])` merged by input
//! index, so as long as `f` is a pure function of its item (and of a
//! per-item seed — see [`crate::util::rng::derive_seed`], never a shared
//! RNG), the output is bit-identical for *any* worker count, including 1.
//! `--jobs` is therefore purely a throughput knob; CI's determinism job
//! byte-compares experiment JSON across `--jobs 1` and `--jobs 4` to prove
//! it stays that way.
//!
//! Worker-count resolution (highest priority first):
//!
//! 1. the CLI `--jobs <n>` flag (every `fedtopo` subcommand; applied via
//!    [`set_jobs`] from `ExpConfig::from_args`);
//! 2. the `FEDTOPO_JOBS` environment variable;
//! 3. [`std::thread::available_parallelism`].
//!
//! A value of `0` at any level means "fall through to the next source".
//!
//! Nested calls do not multiply threads: a `par_map_indexed` issued from
//! inside a pool worker runs sequentially on that worker (the outer level
//! already owns the parallelism), which is invisible to callers precisely
//! because of the determinism contract.
//!
//! Panics in workers are propagated: the panic payload of the *smallest
//! panicking input index* is re-raised on the caller, so even failure is
//! deterministic across thread counts.
//!
//! # Intra-cell parallelism (PR 10)
//!
//! [`run_intracell`] parallelizes *inside* one sweep cell — the
//! row-partitioned max-plus step kernels and the landmark routing build.
//! It differs from [`par_map_indexed`] in two ways dictated by the callers:
//!
//! * **Resident pool, zero allocation per dispatch.** The per-round step
//!   kernels run inside loops whose warm rounds `benches/memory.rs` gates
//!   at zero heap allocations, so the scoped-thread + channel machinery of
//!   `par_map_indexed` (which allocates per call) is unusable. Intra-cell
//!   parts instead run on a lazily spawned resident pool: threads are
//!   created once (setup cost, counted outside the warm window) and every
//!   later dispatch is mutex/condvar handshakes and atomic part claiming —
//!   no allocation on any path except a worker panic.
//! * **Effects, not results.** `f(part)` writes into caller-owned disjoint
//!   output ranges; nothing is merged. Determinism is therefore structural:
//!   every part runs exactly once and parts never share output, so the
//!   bytes are identical for any worker count — including the sequential
//!   inline path the dispatch falls back to when gated.
//!
//! Resolution of the intra-cell worker count mirrors `--jobs` exactly:
//! `--intracell` > `FEDTOPO_INTRACELL` > the effective [`jobs`] value, with
//! `0` falling through; installed only via `SessionConfig::install`. The
//! nested-sequential rule extends across both mechanisms: on a pool worker
//! (cell-level *or* intra-cell) `run_intracell` runs its parts inline, so
//! wide sweep grids keep cell-level parallelism while single-cell grids and
//! resident `fedtopo serve` requests saturate the machine intra-cell.

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex, OnceLock};
use std::thread;

/// Explicit override installed by the CLI (`0` = no override).
static JOBS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Serializes unit tests that assert on the global override (cargo runs
/// tests of one binary concurrently; results never depend on the override,
/// but assertions *about* it do). Lock, don't touch, in any new test that
/// calls [`set_jobs`].
#[cfg(test)]
pub(crate) fn jobs_test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    /// True on pool worker threads; gates nested parallelism off.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Install (or with `0` clear) the CLI-level worker-count override.
pub fn set_jobs(n: usize) {
    JOBS_OVERRIDE.store(n, Ordering::Relaxed);
}

/// The effective worker count: CLI override > `FEDTOPO_JOBS` > available
/// parallelism. Always ≥ 1.
pub fn jobs() -> usize {
    match JOBS_OVERRIDE.load(Ordering::Relaxed) {
        0 => default_jobs(),
        n => n,
    }
}

fn default_jobs() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("FEDTOPO_JOBS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| thread::available_parallelism().map(|p| p.get()).unwrap_or(1))
    })
}

/// Explicit intra-cell override installed by the CLI (`0` = no override).
static INTRACELL_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Install (or with `0` clear) the CLI-level intra-cell worker override.
/// Mirror of [`set_jobs`]; called only from `SessionConfig::install`.
pub fn set_intracell(n: usize) {
    INTRACELL_OVERRIDE.store(n, Ordering::Relaxed);
}

/// The effective intra-cell worker count: CLI `--intracell` override >
/// `FEDTOPO_INTRACELL` > the effective [`jobs`] value. Always ≥ 1. Purely a
/// throughput knob — intra-cell output is byte-identical for any value.
pub fn intracell_jobs() -> usize {
    match INTRACELL_OVERRIDE.load(Ordering::Relaxed) {
        0 => default_intracell(),
        n => n,
    }
}

fn default_intracell() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    let env = *DEFAULT.get_or_init(|| {
        std::env::var("FEDTOPO_INTRACELL")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(0)
    });
    if env > 0 {
        env
    } else {
        jobs()
    }
}

// -- the resident intra-cell pool ------------------------------------------

/// One published dispatch: a type-erased `f(part)` plus its part count. The
/// data pointer targets the submitter's stack frame, which outlives the
/// dispatch because the submitter blocks until every part has run.
#[derive(Clone, Copy)]
struct IntracellTask {
    call: unsafe fn(*const (), usize),
    data: *const (),
    parts: usize,
}

// Safety: the pointers are only dereferenced between publish and the
// completion handshake, while the submitting frame is pinned.
unsafe impl Send for IntracellTask {}

struct IntracellState {
    /// Bumped once per dispatch; workers key their wakeup off it.
    epoch: u64,
    task: Option<IntracellTask>,
    /// Workers that have not yet finished the current epoch.
    active: usize,
    /// Resident worker threads spawned so far.
    spawned: usize,
    /// Smallest panicking part of the current epoch (allocates only when a
    /// part actually panicked — never on the warm path).
    panic: Option<(usize, Box<dyn Any + Send + 'static>)>,
}

struct IntracellPool {
    state: Mutex<IntracellState>,
    /// Wakes workers on a new epoch.
    start: Condvar,
    /// Wakes the submitter when the last worker checks in.
    done: Condvar,
    /// Next unclaimed part of the current epoch.
    cursor: AtomicUsize,
    /// Serializes dispatches; a contended submitter runs inline instead of
    /// queueing (output is identical either way — only throughput differs).
    submit: Mutex<()>,
}

fn intracell_pool() -> &'static IntracellPool {
    static POOL: OnceLock<IntracellPool> = OnceLock::new();
    POOL.get_or_init(|| IntracellPool {
        state: Mutex::new(IntracellState {
            epoch: 0,
            task: None,
            active: 0,
            spawned: 0,
            panic: None,
        }),
        start: Condvar::new(),
        done: Condvar::new(),
        cursor: AtomicUsize::new(0),
        submit: Mutex::new(()),
    })
}

fn intracell_worker(pool: &'static IntracellPool) {
    IN_POOL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = pool.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.task.expect("intracell: epoch bumped without a task");
                }
                st = pool.start.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        run_claimed_parts(pool, &task);
        let mut st = pool.state.lock().unwrap_or_else(|e| e.into_inner());
        st.active -= 1;
        if st.active == 0 {
            pool.done.notify_all();
        }
    }
}

/// Claim parts off the shared cursor until the epoch is drained. Panics are
/// recorded (smallest part wins) instead of unwinding through the pool.
fn run_claimed_parts(pool: &IntracellPool, task: &IntracellTask) {
    loop {
        let p = pool.cursor.fetch_add(1, Ordering::Relaxed);
        if p >= task.parts {
            break;
        }
        let run = catch_unwind(AssertUnwindSafe(|| unsafe { (task.call)(task.data, p) }));
        if let Err(payload) = run {
            let mut st = pool.state.lock().unwrap_or_else(|e| e.into_inner());
            match &st.panic {
                Some((q, _)) if *q <= p => {}
                _ => st.panic = Some((p, payload)),
            }
        }
    }
}

unsafe fn intracell_trampoline<F: Fn(usize) + Sync>(data: *const (), part: usize) {
    (*(data as *const F))(part)
}

/// Run `f(part)` once for every `part in 0..parts` on the resident
/// intra-cell pool. `f` must confine its effects to per-part disjoint
/// state; under that contract the result is byte-identical for any worker
/// count (see the module docs). Falls back to a sequential inline loop when
/// the effective worker count is 1, when called from any pool worker
/// (nested-sequential rule), or when another dispatch is in flight.
/// Allocation-free after the pool threads exist; a part's panic is
/// re-raised on the caller (smallest panicking part wins).
pub fn run_intracell<F: Fn(usize) + Sync>(parts: usize, f: F) {
    run_intracell_with(intracell_jobs(), parts, f)
}

/// [`run_intracell`] with an explicit worker count (tests pin the
/// invariance by comparing worker counts through this entry).
pub fn run_intracell_with<F: Fn(usize) + Sync>(workers: usize, parts: usize, f: F) {
    let workers = workers.min(parts);
    if workers <= 1 || IN_POOL.with(|c| c.get()) {
        for p in 0..parts {
            f(p);
        }
        return;
    }
    let pool = intracell_pool();
    let Ok(_submit) = pool.submit.try_lock() else {
        for p in 0..parts {
            f(p);
        }
        return;
    };

    let task = IntracellTask {
        call: intracell_trampoline::<F>,
        data: &f as *const F as *const (),
        parts,
    };
    {
        let mut st = pool.state.lock().unwrap_or_else(|e| e.into_inner());
        // The submitter claims parts too, so `workers` claimers need
        // `workers - 1` resident threads. Growth allocates; steady state
        // does not (the zero-alloc warm-round gates run after warmup).
        while st.spawned < workers - 1 {
            thread::Builder::new()
                .name("fedtopo-intracell".to_string())
                .spawn(move || intracell_worker(intracell_pool()))
                .expect("intracell: spawn worker");
            st.spawned += 1;
        }
        st.panic = None;
        st.task = Some(task);
        st.active = st.spawned;
        // Publishing the cursor under the state lock orders it before any
        // worker observes the new epoch.
        pool.cursor.store(0, Ordering::Relaxed);
        st.epoch += 1;
        pool.start.notify_all();
    }

    run_claimed_parts(pool, &task);

    let payload = {
        let mut st = pool.state.lock().unwrap_or_else(|e| e.into_inner());
        while st.active > 0 {
            st = pool.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.task = None;
        st.panic.take()
    };
    if let Some((_, p)) = payload {
        resume_unwind(p);
    }
}

enum Msg<R> {
    Done(usize, R),
    Panicked(usize, Box<dyn Any + Send + 'static>),
}

/// Map `f` over `items` on the global [`jobs`]-sized pool; results are
/// merged in input order (see the module docs for the determinism contract).
pub fn par_map_indexed<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    par_map_indexed_with(jobs(), items, f)
}

/// [`par_map_indexed`] with an explicit worker count (tests pin the
/// jobs-invariance by comparing `jobs ∈ {1, 2, 7}` through this entry).
pub fn par_map_indexed_with<T, R, F>(jobs: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let workers = if n == 0 { 0 } else { jobs.clamp(1, n) };
    if workers <= 1 || IN_POOL.with(|c| c.get()) {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }

    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let mut panics: Vec<(usize, Box<dyn Any + Send>)> = Vec::new();

    thread::scope(|s| {
        let (tx, rx) = mpsc::channel::<Msg<R>>();
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            s.spawn(move || {
                IN_POOL.with(|c| c.set(true));
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    match catch_unwind(AssertUnwindSafe(|| f(i, &items[i]))) {
                        Ok(r) => {
                            if tx.send(Msg::Done(i, r)).is_err() {
                                break;
                            }
                        }
                        Err(p) => {
                            let _ = tx.send(Msg::Panicked(i, p));
                            break;
                        }
                    }
                }
            });
        }
        drop(tx);
        for msg in rx {
            match msg {
                Msg::Done(i, r) => slots[i] = Some(r),
                Msg::Panicked(i, p) => panics.push((i, p)),
            }
        }
    });

    if !panics.is_empty() {
        // Deterministic failure: the smallest panicking index wins. The
        // work counter hands indices out monotonically, so the first
        // panicking item is always attempted and always recorded.
        panics.sort_by_key(|(i, _)| *i);
        resume_unwind(panics.swap_remove(0).1);
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.unwrap_or_else(|| panic!("parallel: item {i} produced no result")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_singleton() {
        let none: Vec<u32> = Vec::new();
        assert_eq!(par_map_indexed_with(8, &none, |_, &x: &u32| x), none);
        assert_eq!(par_map_indexed_with(8, &[5u32], |i, &x| (i, x)), vec![(0, 5)]);
    }

    #[test]
    fn order_preserved_for_any_worker_count() {
        let items: Vec<u64> = (0..101).collect();
        let reference: Vec<(usize, u64)> =
            items.iter().enumerate().map(|(i, &x)| (i, x * x + 1)).collect();
        for jobs in [1usize, 2, 3, 7, 32] {
            let got = par_map_indexed_with(jobs, &items, |i, &x| (i, x * x + 1));
            assert_eq!(got, reference, "jobs={jobs}");
        }
    }

    #[test]
    fn nested_calls_stay_correct() {
        let outer: Vec<u64> = (0..9).collect();
        let got = par_map_indexed_with(4, &outer, |_, &x| {
            let inner: Vec<u64> = (0..x + 1).collect();
            par_map_indexed_with(4, &inner, |_, &y| y).iter().sum::<u64>()
        });
        let want: Vec<u64> = outer.iter().map(|&x| x * (x + 1) / 2).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn panic_of_smallest_index_propagates() {
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let items: Vec<usize> = (0..16).collect();
        let r = catch_unwind(|| {
            par_map_indexed_with(3, &items, |i, &x| {
                if x >= 11 {
                    panic!("boom {i}");
                }
                x * 2
            })
        });
        std::panic::set_hook(hook);
        let payload = r.expect_err("worker panic must reach the caller");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("boom 11"), "unexpected payload: {msg}");
    }

    #[test]
    fn jobs_override_and_reset() {
        let _guard = jobs_test_guard();
        set_jobs(5);
        assert_eq!(jobs(), 5);
        set_jobs(0);
        assert!(jobs() >= 1, "auto resolution must be at least one worker");
    }

    #[test]
    fn intracell_override_resolves_and_falls_through_to_jobs() {
        let _guard = jobs_test_guard();
        set_intracell(3);
        assert_eq!(intracell_jobs(), 3);
        set_intracell(0);
        set_jobs(9);
        // no env var in the test harness: cleared override falls through to
        // the effective jobs value (unless FEDTOPO_INTRACELL is set).
        if std::env::var("FEDTOPO_INTRACELL")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .is_none()
        {
            assert_eq!(intracell_jobs(), 9);
        }
        set_jobs(0);
        assert!(intracell_jobs() >= 1);
    }

    #[test]
    fn run_intracell_runs_every_part_exactly_once_for_any_worker_count() {
        use std::sync::atomic::AtomicU32;
        for workers in [1usize, 2, 3, 7, 32] {
            let hits: Vec<AtomicU32> = (0..101).map(|_| AtomicU32::new(0)).collect();
            run_intracell_with(workers, hits.len(), |p| {
                hits[p].fetch_add(1, Ordering::Relaxed);
            });
            for (p, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "workers={workers} part {p}");
            }
        }
        // parts = 0 is a no-op
        run_intracell_with(8, 0, |_| panic!("no parts to run"));
    }

    #[test]
    fn run_intracell_is_sequential_on_pool_workers() {
        // Nested-sequential rule: inside a par_map worker, the intra-cell
        // dispatch must run inline on that worker's thread.
        let outer: Vec<usize> = (0..4).collect();
        let ids = par_map_indexed_with(4, &outer, |_, _| {
            let me = thread::current().id();
            let mut same_thread = true;
            run_intracell_with(8, 16, |_| {
                if thread::current().id() != me {
                    same_thread = false;
                }
            });
            same_thread
        });
        assert!(ids.into_iter().all(|ok| ok), "nested dispatch left the worker");
    }

    #[test]
    fn run_intracell_propagates_smallest_panicking_part() {
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = catch_unwind(|| {
            run_intracell_with(3, 16, |p| {
                if p >= 11 {
                    panic!("part {p}");
                }
            })
        });
        std::panic::set_hook(hook);
        let payload = r.expect_err("part panic must reach the caller");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.starts_with("part "), "unexpected payload: {msg}");
        // Any of 11..16 may panic, but the smallest recorded part wins; with
        // the claim cursor handing parts out monotonically, part 11 is
        // always attempted before the dispatch drains.
        assert_eq!(msg, "part 11", "smallest panicking part must win");
    }

    #[test]
    fn run_intracell_reuses_the_resident_pool_across_dispatches() {
        use std::collections::HashSet;
        use std::sync::Mutex as StdMutex;
        let seen: StdMutex<HashSet<thread::ThreadId>> = StdMutex::new(HashSet::new());
        for _ in 0..5 {
            run_intracell_with(4, 64, |_| {
                seen.lock().unwrap().insert(thread::current().id());
            });
        }
        // the same resident threads serve every dispatch: the distinct
        // thread count is bounded by workers (3 residents + submitters),
        // not by dispatches × workers
        let n = seen.lock().unwrap().len();
        assert!(n <= 4 + 4, "resident pool must be reused, saw {n} threads");
    }
}
