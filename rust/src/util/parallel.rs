//! Deterministic, dependency-free data parallelism.
//!
//! The sweep engine and the Monte-Carlo estimators fan work out over a
//! scoped-thread pool, but every caller gets the **ordered-merge determinism
//! contract**: [`par_map_indexed`] returns `f(i, &items[i])` merged by input
//! index, so as long as `f` is a pure function of its item (and of a
//! per-item seed — see [`crate::util::rng::derive_seed`], never a shared
//! RNG), the output is bit-identical for *any* worker count, including 1.
//! `--jobs` is therefore purely a throughput knob; CI's determinism job
//! byte-compares experiment JSON across `--jobs 1` and `--jobs 4` to prove
//! it stays that way.
//!
//! Worker-count resolution (highest priority first):
//!
//! 1. the CLI `--jobs <n>` flag (every `fedtopo` subcommand; applied via
//!    [`set_jobs`] from `ExpConfig::from_args`);
//! 2. the `FEDTOPO_JOBS` environment variable;
//! 3. [`std::thread::available_parallelism`].
//!
//! A value of `0` at any level means "fall through to the next source".
//!
//! Nested calls do not multiply threads: a `par_map_indexed` issued from
//! inside a pool worker runs sequentially on that worker (the outer level
//! already owns the parallelism), which is invisible to callers precisely
//! because of the determinism contract.
//!
//! Panics in workers are propagated: the panic payload of the *smallest
//! panicking input index* is re-raised on the caller, so even failure is
//! deterministic across thread counts.

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, OnceLock};
use std::thread;

/// Explicit override installed by the CLI (`0` = no override).
static JOBS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Serializes unit tests that assert on the global override (cargo runs
/// tests of one binary concurrently; results never depend on the override,
/// but assertions *about* it do). Lock, don't touch, in any new test that
/// calls [`set_jobs`].
#[cfg(test)]
pub(crate) fn jobs_test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    /// True on pool worker threads; gates nested parallelism off.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Install (or with `0` clear) the CLI-level worker-count override.
pub fn set_jobs(n: usize) {
    JOBS_OVERRIDE.store(n, Ordering::Relaxed);
}

/// The effective worker count: CLI override > `FEDTOPO_JOBS` > available
/// parallelism. Always ≥ 1.
pub fn jobs() -> usize {
    match JOBS_OVERRIDE.load(Ordering::Relaxed) {
        0 => default_jobs(),
        n => n,
    }
}

fn default_jobs() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("FEDTOPO_JOBS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| thread::available_parallelism().map(|p| p.get()).unwrap_or(1))
    })
}

enum Msg<R> {
    Done(usize, R),
    Panicked(usize, Box<dyn Any + Send + 'static>),
}

/// Map `f` over `items` on the global [`jobs`]-sized pool; results are
/// merged in input order (see the module docs for the determinism contract).
pub fn par_map_indexed<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    par_map_indexed_with(jobs(), items, f)
}

/// [`par_map_indexed`] with an explicit worker count (tests pin the
/// jobs-invariance by comparing `jobs ∈ {1, 2, 7}` through this entry).
pub fn par_map_indexed_with<T, R, F>(jobs: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let workers = if n == 0 { 0 } else { jobs.clamp(1, n) };
    if workers <= 1 || IN_POOL.with(|c| c.get()) {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }

    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let mut panics: Vec<(usize, Box<dyn Any + Send>)> = Vec::new();

    thread::scope(|s| {
        let (tx, rx) = mpsc::channel::<Msg<R>>();
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            s.spawn(move || {
                IN_POOL.with(|c| c.set(true));
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    match catch_unwind(AssertUnwindSafe(|| f(i, &items[i]))) {
                        Ok(r) => {
                            if tx.send(Msg::Done(i, r)).is_err() {
                                break;
                            }
                        }
                        Err(p) => {
                            let _ = tx.send(Msg::Panicked(i, p));
                            break;
                        }
                    }
                }
            });
        }
        drop(tx);
        for msg in rx {
            match msg {
                Msg::Done(i, r) => slots[i] = Some(r),
                Msg::Panicked(i, p) => panics.push((i, p)),
            }
        }
    });

    if !panics.is_empty() {
        // Deterministic failure: the smallest panicking index wins. The
        // work counter hands indices out monotonically, so the first
        // panicking item is always attempted and always recorded.
        panics.sort_by_key(|(i, _)| *i);
        resume_unwind(panics.swap_remove(0).1);
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.unwrap_or_else(|| panic!("parallel: item {i} produced no result")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_singleton() {
        let none: Vec<u32> = Vec::new();
        assert_eq!(par_map_indexed_with(8, &none, |_, &x: &u32| x), none);
        assert_eq!(par_map_indexed_with(8, &[5u32], |i, &x| (i, x)), vec![(0, 5)]);
    }

    #[test]
    fn order_preserved_for_any_worker_count() {
        let items: Vec<u64> = (0..101).collect();
        let reference: Vec<(usize, u64)> =
            items.iter().enumerate().map(|(i, &x)| (i, x * x + 1)).collect();
        for jobs in [1usize, 2, 3, 7, 32] {
            let got = par_map_indexed_with(jobs, &items, |i, &x| (i, x * x + 1));
            assert_eq!(got, reference, "jobs={jobs}");
        }
    }

    #[test]
    fn nested_calls_stay_correct() {
        let outer: Vec<u64> = (0..9).collect();
        let got = par_map_indexed_with(4, &outer, |_, &x| {
            let inner: Vec<u64> = (0..x + 1).collect();
            par_map_indexed_with(4, &inner, |_, &y| y).iter().sum::<u64>()
        });
        let want: Vec<u64> = outer.iter().map(|&x| x * (x + 1) / 2).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn panic_of_smallest_index_propagates() {
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let items: Vec<usize> = (0..16).collect();
        let r = catch_unwind(|| {
            par_map_indexed_with(3, &items, |i, &x| {
                if x >= 11 {
                    panic!("boom {i}");
                }
                x * 2
            })
        });
        std::panic::set_hook(hook);
        let payload = r.expect_err("worker panic must reach the caller");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("boom 11"), "unexpected payload: {msg}");
    }

    #[test]
    fn jobs_override_and_reset() {
        let _guard = jobs_test_guard();
        set_jobs(5);
        assert_eq!(jobs(), 5);
        set_jobs(0);
        assert!(jobs() >= 1, "auto resolution must be at least one worker");
    }
}
