//! In-crate micro-benchmark harness (the image has no `criterion`).
//!
//! Benches are ordinary `harness = false` targets under `rust/benches/` that
//! call [`Bench::bench`]. The harness does criterion-style warmup, adaptive
//! iteration-count calibration to a target measurement time, and reports
//! mean / stddev / median / p95 per benchmark, plus an optional throughput
//! line. Results can also be dumped as JSON for EXPERIMENTS.md §Perf.
//!
//! Environment lives at the CLI boundary only: [`quick_mode`] reads
//! `FEDTOPO_BENCH_QUICK` (parsing the *value* — `0`/empty/`false`/`off`
//! disable, anything else enables; bare presence used to enable, which made
//! `FEDTOPO_BENCH_QUICK=0` a quick run) and [`Bench::new`] feeds it to the
//! env-free [`Bench::configured`]. Tests construct via `configured`
//! directly — no process-global `set_var` races under the parallel test
//! harness. [`Bench::to_json`] emits the versioned [`BENCH_SCHEMA`] dump;
//! [`Bench::dump_json_if_requested`] writes it to `$FEDTOPO_BENCH_JSON` so
//! CI can archive a `BENCH_<pr>.json` perf trajectory (see `bench/perf.md`).

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Summary;

/// Version tag of the [`Bench::to_json`] dump shape. Bump when fields
/// change meaning; CI's schema sanity check and `bench/perf.md` key off it.
pub const BENCH_SCHEMA: &str = "fedtopo-bench/v1";

/// Parse a `FEDTOPO_BENCH_QUICK`-style value: unset, empty, `0`, `false`,
/// or `off` (any case, surrounding whitespace ignored) mean **off**;
/// anything else means on.
fn parse_quick(value: Option<&str>) -> bool {
    match value {
        None => false,
        Some(v) => !matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "" | "0" | "false" | "off"
        ),
    }
}

/// Is quick mode (CI smoke budgets) requested via `FEDTOPO_BENCH_QUICK`?
/// The one shared helper every bench target routes through.
pub fn quick_mode() -> bool {
    parse_quick(std::env::var("FEDTOPO_BENCH_QUICK").ok().as_deref())
}

/// One registered benchmark's measurements.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-sample wall time in nanoseconds (each sample = `iters` calls).
    pub ns_per_iter: Vec<f64>,
    pub summary: Summary,
    pub throughput: Option<(f64, &'static str)>,
}

/// Benchmark harness configuration + collected results.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    samples: usize,
    filter: Option<String>,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new()
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bench {
    /// The CLI-boundary constructor: quick mode from [`quick_mode`], filter
    /// from `cargo bench --bench x -- <substring>`.
    pub fn new() -> Bench {
        Bench::configured(
            quick_mode(),
            std::env::args().nth(1).filter(|a| !a.starts_with('-')),
        )
    }

    /// Env-free construction (explicit quick-mode injection); `new()` is
    /// this plus the environment. Tests use it directly so no test ever
    /// mutates process globals.
    pub fn configured(quick: bool, filter: Option<String>) -> Bench {
        Bench {
            warmup: if quick {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(300)
            },
            measure: if quick {
                Duration::from_millis(100)
            } else {
                Duration::from_millis(1000)
            },
            samples: if quick { 10 } else { 30 },
            filter,
            results: Vec::new(),
        }
    }

    /// Run one benchmark. `f` is invoked repeatedly; keep it side-effect-free
    /// and return a value so it cannot be optimized away.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        self.bench_with_throughput(name, None, &mut f)
    }

    /// Like [`Bench::bench`], reporting `units` of work per iteration (e.g.
    /// bytes mixed, edges scanned) as derived throughput.
    pub fn bench_throughput<T>(
        &mut self,
        name: &str,
        units: f64,
        unit_name: &'static str,
        mut f: impl FnMut() -> T,
    ) {
        self.bench_with_throughput(name, Some((units, unit_name)), &mut f)
    }

    fn bench_with_throughput<T>(
        &mut self,
        name: &str,
        throughput: Option<(f64, &'static str)>,
        f: &mut dyn FnMut() -> T,
    ) {
        if let Some(ref filt) = self.filter {
            if !name.contains(filt.as_str()) {
                return;
            }
        }
        // Warmup + calibration: find iters so one sample ≈ measure/samples.
        let warm_deadline = Instant::now() + self.warmup;
        let mut iters = 1u64;
        let mut once = Duration::ZERO;
        while Instant::now() < warm_deadline {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            once = t0.elapsed() / iters.max(1) as u32;
            if once < Duration::from_micros(10) {
                iters = (iters * 2).min(1 << 20);
            }
        }
        let target = self.measure / self.samples as u32;
        let iters = if once.is_zero() {
            iters
        } else {
            ((target.as_nanos() / once.as_nanos().max(1)) as u64).clamp(1, 1 << 24)
        };

        let mut ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let summary = Summary::of(&ns);
        let result = BenchResult {
            name: name.to_string(),
            ns_per_iter: ns,
            summary,
            throughput,
        };
        print_result(&result);
        self.results.push(result);
    }

    /// Print a compact trailing report (and return it for logging).
    pub fn finish(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n{} benchmarks completed\n", self.results.len()));
        out
    }

    /// Machine-readable dump of every result — the one JSON shape all
    /// `harness = false` benches share (EXPERIMENTS.md §Perf tooling)
    /// instead of hand-rolling their own report plumbing. The dump is
    /// versioned ([`BENCH_SCHEMA`]); the *set of fields* is deterministic
    /// while the timing values are machine-dependent, so consumers (CI's
    /// sanity check) gate on schema and names, never on wall-clock numbers.
    pub fn to_json(&self) -> Json {
        let entries = self.results.iter().map(|r| {
            let mut fields = vec![
                ("name", Json::str(&r.name)),
                ("mean_ns", Json::num(r.summary.mean)),
                ("std_ns", Json::num(r.summary.std)),
                ("median_ns", Json::num(r.summary.median)),
                ("p95_ns", Json::num(r.summary.p95)),
                ("samples", Json::num(r.ns_per_iter.len() as f64)),
            ];
            if let Some((units, unit)) = r.throughput {
                fields.push(("units_per_iter", Json::num(units)));
                fields.push(("unit", Json::str(unit)));
            }
            Json::obj(fields)
        });
        Json::obj(vec![
            ("schema", Json::str(BENCH_SCHEMA)),
            ("benchmarks", Json::arr(entries)),
        ])
    }

    /// If `FEDTOPO_BENCH_JSON=<path>` is set (and non-empty), write the
    /// [`Bench::to_json`] dump there and return the path — how CI archives
    /// `BENCH_<pr>.json` artifacts without scraping stdout. Panics on write
    /// failure (a bench target has no error channel CI would notice).
    pub fn dump_json_if_requested(&self) -> Option<String> {
        let path = std::env::var("FEDTOPO_BENCH_JSON").ok().filter(|p| !p.is_empty())?;
        let body = format!("{}\n", self.to_json());
        std::fs::write(&path, body)
            .unwrap_or_else(|e| panic!("FEDTOPO_BENCH_JSON: cannot write {path}: {e}"));
        Some(path)
    }
}

fn human_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:7.1} ns")
    } else if ns < 1e6 {
        format!("{:7.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:7.2} ms", ns / 1e6)
    } else {
        format!("{:7.2} s ", ns / 1e9)
    }
}

fn print_result(r: &BenchResult) {
    let s = &r.summary;
    let mut line = format!(
        "{:<54} {}  ±{:>5.1}%  (median {}, p95 {})",
        r.name,
        human_ns(s.mean),
        100.0 * s.std / s.mean.max(1e-12),
        human_ns(s.median),
        human_ns(s.p95),
    );
    if let Some((units, name)) = r.throughput {
        let per_sec = units / (s.mean / 1e9);
        let h = if per_sec > 1e9 {
            format!("{:.2} G{name}/s", per_sec / 1e9)
        } else if per_sec > 1e6 {
            format!("{:.2} M{name}/s", per_sec / 1e6)
        } else if per_sec > 1e3 {
            format!("{:.2} k{name}/s", per_sec / 1e3)
        } else {
            format!("{per_sec:.2} {name}/s")
        };
        line.push_str(&format!("  [{h}]"));
    }
    println!("{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quick budgets shrunk further — tests never touch the environment
    /// (constructor injection; `set_var` here used to race the parallel
    /// test harness).
    fn test_bench() -> Bench {
        let mut b = Bench::configured(true, None);
        b.warmup = Duration::from_millis(5);
        b.measure = Duration::from_millis(20);
        b.samples = 5;
        b
    }

    #[test]
    fn quick_mode_parses_value_not_presence() {
        assert!(!parse_quick(None));
        for off in ["", "0", "false", "off", " 0 ", "OFF", "False"] {
            assert!(!parse_quick(Some(off)), "{off:?} must disable quick mode");
        }
        for on in ["1", "true", "yes", "2", "on"] {
            assert!(parse_quick(Some(on)), "{on:?} must enable quick mode");
        }
    }

    #[test]
    fn configured_quick_budgets_are_smaller() {
        let quick = Bench::configured(true, None);
        let full = Bench::configured(false, None);
        assert!(quick.warmup < full.warmup);
        assert!(quick.measure < full.measure);
        assert!(quick.samples < full.samples);
        let filtered = Bench::configured(true, Some("only_this".to_string()));
        assert_eq!(filtered.filter.as_deref(), Some("only_this"));
    }

    #[test]
    fn bench_measures_something() {
        let mut b = test_bench();
        b.bench("noop_sum", || (0..100u64).sum::<u64>());
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].summary.mean > 0.0);
    }

    #[test]
    fn json_dump_roundtrips() {
        let mut b = test_bench();
        b.bench_throughput("sum_100", 100.0, "adds", || (0..100u64).sum::<u64>());
        let v = Json::parse(&b.to_json().to_string()).unwrap();
        assert_eq!(v.get("schema").as_str(), Some(BENCH_SCHEMA));
        let entries = v.get("benchmarks").as_arr().unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("name").as_str(), Some("sum_100"));
        assert!(entries[0].get("mean_ns").as_f64().unwrap() > 0.0);
        assert_eq!(entries[0].get("unit").as_str(), Some("adds"));
    }

    #[test]
    fn human_ns_formats() {
        assert!(human_ns(5.0).contains("ns"));
        assert!(human_ns(5.0e3).contains("µs"));
        assert!(human_ns(5.0e6).contains("ms"));
        assert!(human_ns(5.0e9).contains("s"));
    }
}
