//! Minimal JSON parser / writer.
//!
//! Used for the AOT artifact manifests (`artifacts/manifest.json`) written by
//! `python/compile/aot.py` and for experiment result dumps. Supports the full
//! JSON grammar except `\u` surrogate pairs beyond the BMP (not needed for
//! manifests); numbers are parsed as f64 with an i64 fast path.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization is
/// deterministic — experiment dumps diff cleanly across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: src.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e18 => Some(*n as i64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` that returns `Null` for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn f64_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.i,
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a maximal run of plain bytes (UTF-8 passes through).
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// -- serialization ---------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"shapes":[[2,3],[4]],"name":"w_0","dtype":"f32","n":1207000}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""été""#).unwrap();
        assert_eq!(v.as_str(), Some("été"));
    }

    #[test]
    fn integer_display_no_decimal() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 7, "f": 1.5, "b": true}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(7));
        assert_eq!(v.get("n").as_i64(), Some(7));
        assert_eq!(v.get("f").as_i64(), None);
        assert_eq!(v.get("b").as_bool(), Some(true));
        assert_eq!(v.get("missing"), &Json::Null);
    }
}
