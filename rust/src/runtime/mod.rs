//! PJRT runtime — load AOT artifacts and execute them from the Rust hot
//! path. Python never runs at request time.
//!
//! * [`manifest`] — the `artifacts/manifest.json` contract with aot.py.
//! * [`client`] — PJRT CPU client + executable cache + literal marshalling.
//! * [`trainer`] — [`trainer::XlaTrainer`], the production
//!   [`crate::fl::dpasgd::LocalTrainer`].

pub mod manifest;
pub mod client;
pub mod trainer;
