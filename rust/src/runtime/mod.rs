//! PJRT runtime — load AOT artifacts and execute them from the Rust hot
//! path. Python never runs at request time.
//!
//! * [`manifest`] — the `artifacts/manifest.json` contract with aot.py.
//! * `client` — PJRT CPU client + executable cache + literal marshalling.
//! * `trainer` — `XlaTrainer`, the production
//!   [`crate::fl::dpasgd::LocalTrainer`].

//! The PJRT pieces need the external `xla` binding crate plus compiled HLO
//! artifacts; neither ships in this image, so `client` and `trainer` are
//! gated behind the off-by-default `xla` cargo feature (hence no doc links
//! to them here — they are absent from the default-feature docs).
//! [`manifest`] (pure JSON) is always available, and every consumer falls
//! back to the closed-form quadratic trainer when the feature is off.

pub mod manifest;
#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod trainer;
