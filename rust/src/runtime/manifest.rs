//! AOT artifact manifest — the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! `artifacts/manifest.json` describes, per model, the flat parameter count,
//! batch shapes/dtypes, and the four HLO-text artifact files (init / train /
//! eval / consensus). Parsing it here means the runtime marshals `Literal`s
//! without re-deriving anything from Python.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Input dtype of the training batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum XDtype {
    F32,
    I32,
}

/// One model's manifest entry.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub param_count: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    pub x_dtype: XDtype,
    pub consensus_k: usize,
    pub init_file: PathBuf,
    pub train_file: PathBuf,
    pub eval_file: PathBuf,
    pub consensus_file: PathBuf,
}

impl ModelManifest {
    /// Per-sample feature count (x_shape without the batch axis).
    pub fn x_sample_elems(&self) -> usize {
        self.x_shape[1..].iter().product::<usize>().max(1)
    }
    pub fn y_sample_elems(&self) -> usize {
        self.y_shape[1..].iter().product::<usize>().max(1)
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub fingerprint: String,
    pub models: Vec<ModelManifest>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Manifest::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest.json is not valid JSON")?;
        let version = root.get("version").as_usize().context("missing version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let fingerprint = root
            .get("fingerprint")
            .as_str()
            .unwrap_or("unknown")
            .to_string();
        let models_obj = root
            .get("models")
            .as_obj()
            .context("missing models object")?;
        let mut models = Vec::new();
        for (name, m) in models_obj {
            let shape = |key: &str| -> Result<Vec<usize>> {
                m.get(key)
                    .as_arr()
                    .with_context(|| format!("{name}: missing {key}"))?
                    .iter()
                    .map(|v| v.as_usize().context("bad dim"))
                    .collect()
            };
            let arts = m.get("artifacts");
            let art = |key: &str| -> Result<PathBuf> {
                Ok(dir.join(
                    arts.get(key)
                        .as_str()
                        .with_context(|| format!("{name}: missing artifact {key}"))?,
                ))
            };
            let x_dtype = match m.get("x_dtype").as_str() {
                Some("f32") => XDtype::F32,
                Some("i32") => XDtype::I32,
                other => bail!("{name}: bad x_dtype {other:?}"),
            };
            models.push(ModelManifest {
                name: name.clone(),
                param_count: m
                    .get("param_count")
                    .as_usize()
                    .with_context(|| format!("{name}: missing param_count"))?,
                batch: m.get("batch").as_usize().context("missing batch")?,
                eval_batch: m
                    .get("eval_batch")
                    .as_usize()
                    .context("missing eval_batch")?,
                x_shape: shape("x_shape")?,
                y_shape: shape("y_shape")?,
                x_dtype,
                consensus_k: m
                    .get("consensus_k")
                    .as_usize()
                    .context("missing consensus_k")?,
                init_file: art("init")?,
                train_file: art("train")?,
                eval_file: art("eval")?,
                consensus_file: art("consensus")?,
            });
        }
        Ok(Manifest {
            fingerprint,
            models,
            dir: dir.to_path_buf(),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| {
                format!(
                    "model '{name}' not in manifest (have {:?})",
                    self.models.iter().map(|m| &m.name).collect::<Vec<_>>()
                )
            })
    }

    /// Default artifacts directory: `$FEDTOPO_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("FEDTOPO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "fingerprint": "abc123",
      "models": {
        "mlp": {
          "param_count": 50826, "batch": 32, "eval_batch": 256,
          "x_shape": [32, 64], "y_shape": [32], "x_dtype": "f32",
          "consensus_k": 8,
          "meta": {"dim": 64},
          "artifacts": {"init": "mlp_init.hlo.txt", "train": "mlp_train.hlo.txt",
                        "eval": "mlp_eval.hlo.txt", "consensus": "mlp_consensus.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.fingerprint, "abc123");
        let mlp = m.model("mlp").unwrap();
        assert_eq!(mlp.param_count, 50826);
        assert_eq!(mlp.x_shape, vec![32, 64]);
        assert_eq!(mlp.x_dtype, XDtype::F32);
        assert_eq!(mlp.x_sample_elems(), 64);
        assert_eq!(mlp.y_sample_elems(), 1);
        assert!(mlp.train_file.ends_with("mlp_train.hlo.txt"));
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 99");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f16\"");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("mlp").is_ok());
        for model in &m.models {
            assert!(model.train_file.exists(), "{:?}", model.train_file);
            assert!(model.init_file.exists());
            assert!(model.eval_file.exists());
            assert!(model.consensus_file.exists());
        }
    }
}
