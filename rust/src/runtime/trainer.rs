//! [`XlaTrainer`] — the production [`LocalTrainer`]: per-silo SGD steps and
//! evaluation run as AOT-compiled JAX/Pallas computations via PJRT.
//!
//! Python never runs here: the trainer consumes `artifacts/*.hlo.txt` and
//! the manifest. Batches come from the Rust-side federated dataset
//! ([`crate::fl::data::FedDataset`] for the MLP; [`TokenDataset`]-style
//! synthetic corpora for the char-LM can be plugged through the same
//! interface).

use super::client::{f32_literal, i32_literal, Executable, XlaRuntime};
use super::manifest::{Manifest, ModelManifest, XDtype};
use crate::fl::data::FedDataset;
use crate::fl::dpasgd::{LocalTrainer, Params};
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};
use std::rc::Rc;

/// XLA-backed trainer for the MLP classifier over a [`FedDataset`].
pub struct XlaTrainer {
    model: ModelManifest,
    init_exe: Rc<Executable>,
    train_exe: Rc<Executable>,
    eval_exe: Rc<Executable>,
    data: FedDataset,
    pub lr: f32,
    /// wall-time spent inside PJRT execute (perf accounting).
    pub execute_ns: u128,
    pub steps_run: u64,
}

impl XlaTrainer {
    /// Load the `model` artifacts and bind them to a dataset.
    pub fn new(
        rt: &mut XlaRuntime,
        manifest: &Manifest,
        model: &str,
        data: FedDataset,
        lr: f32,
    ) -> Result<XlaTrainer> {
        let m = manifest.model(model)?.clone();
        ensure!(
            m.x_dtype == XDtype::F32,
            "XlaTrainer drives f32-feature models; '{model}' wants {:?}",
            m.x_dtype
        );
        ensure!(
            m.x_shape[1..] == [data.dim],
            "dataset dim {} != model input {:?}",
            data.dim,
            &m.x_shape[1..]
        );
        Ok(XlaTrainer {
            init_exe: rt.load(&m.init_file).context("loading init")?,
            train_exe: rt.load(&m.train_file).context("loading train")?,
            eval_exe: rt.load(&m.eval_file).context("loading eval")?,
            model: m,
            data,
            lr,
            execute_ns: 0,
            steps_run: 0,
        })
    }

    pub fn manifest(&self) -> &ModelManifest {
        &self.model
    }

    /// Mean PJRT execute latency per training step, ms.
    pub fn mean_step_ms(&self) -> f64 {
        if self.steps_run == 0 {
            0.0
        } else {
            self.execute_ns as f64 / 1e6 / self.steps_run as f64
        }
    }
}

impl LocalTrainer for XlaTrainer {
    fn param_count(&self) -> usize {
        self.model.param_count
    }

    fn init(&mut self, _silo: usize, seed: u64) -> Result<Params> {
        let outs = self
            .init_exe
            .run(&[xla::Literal::scalar(seed as i32)])
            .context("init")?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    fn step(&mut self, silo: usize, params: &mut Params, rng: &mut Rng) -> Result<f32> {
        let (bx, by) = self.data.batch(silo, self.model.batch, rng);
        let t0 = std::time::Instant::now();
        let outs = self.train_exe.run(&[
            f32_literal(params, &[self.model.param_count])?,
            f32_literal(&bx, &self.model.x_shape)?,
            i32_literal(&by, &self.model.y_shape)?,
            xla::Literal::scalar(self.lr),
        ])?;
        self.execute_ns += t0.elapsed().as_nanos();
        self.steps_run += 1;
        *params = outs[0].to_vec::<f32>()?;
        Ok(outs[1].to_vec::<f32>()?[0])
    }

    fn eval(&mut self, params: &Params) -> Result<(f32, f32)> {
        // Evaluate in eval_batch chunks over the shared test set; average.
        let e = self.model.eval_batch;
        let test = &self.data.test;
        let chunks = (test.len() / e).max(1);
        let mut loss_sum = 0.0f32;
        let mut acc_sum = 0.0f32;
        let params_lit = f32_literal(params, &[self.model.param_count])?;
        for c in 0..chunks {
            let lo = c * e;
            let mut bx = Vec::with_capacity(e * test.dim);
            let mut by = Vec::with_capacity(e);
            for i in 0..e {
                let idx = (lo + i) % test.len();
                bx.extend_from_slice(test.row(idx));
                by.push(test.y[idx]);
            }
            let outs = self.eval_exe.run(&[
                params_lit.clone(),
                f32_literal(&bx, &[e, test.dim])?,
                i32_literal(&by, &[e])?,
            ])?;
            loss_sum += outs[0].to_vec::<f32>()?[0];
            acc_sum += outs[1].to_vec::<f32>()?[0];
        }
        Ok((loss_sum / chunks as f32, acc_sum / chunks as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::data::{DataConfig, FedDataset};
    use crate::fl::dpasgd::{run, DpasgdConfig};
    use crate::topology::{design, OverlayKind};
    use std::path::Path;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn xla_trainer_learns_on_one_silo() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let mut rt = XlaRuntime::cpu().unwrap();
        let data = FedDataset::synthesize(&DataConfig {
            num_silos: 2,
            dim: 64,
            num_classes: 10,
            test_samples: 256,
            ..DataConfig::default()
        });
        let mut tr = XlaTrainer::new(&mut rt, &manifest, "mlp", data, 0.1).unwrap();
        let mut params = tr.init(0, 7).unwrap();
        let (_, acc0) = tr.eval(&params).unwrap();
        let mut rng = Rng::new(3);
        let mut losses = Vec::new();
        for _ in 0..60 {
            losses.push(tr.step(0, &mut params, &mut rng).unwrap());
        }
        let (_, acc1) = tr.eval(&params).unwrap();
        assert!(
            acc1 > acc0 + 0.2,
            "accuracy {acc0} → {acc1}, losses {:?}",
            &losses[..5]
        );
        assert!(losses.last().unwrap() < &losses[0]);
        assert!(tr.mean_step_ms() > 0.0);
    }

    #[test]
    fn full_dpasgd_over_ring_with_xla_trainer() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let mut rt = XlaRuntime::cpu().unwrap();
        let n = 5;
        let data = FedDataset::synthesize(&DataConfig {
            num_silos: n,
            dim: 64,
            num_classes: 10,
            alpha: 0.3, // strongly non-iid
            test_samples: 256,
            ..DataConfig::default()
        });
        // tiny delay model just to design a ring over n silos
        let net = crate::netsim::underlay::Underlay::builtin("gaia").unwrap();
        let wl = crate::fl::workloads::Workload::femnist();
        let full = crate::netsim::delay::DelayModel::new(&net, &wl, 1, 1e9, 1e9);
        let dm = crate::netsim::delay::DelayModel::with_parts(
            1,
            wl.model_bits,
            vec![wl.tc_ms; n],
            vec![1e9; n],
            vec![1e9; n],
            crate::netsim::routing::Routes::from_dense(
                &vec![vec![10.0; n]; n],
                &vec![vec![1e9; n]; n],
                &vec![vec![1; n]; n],
                Vec::new(),
            ),
        );
        let overlay = design(OverlayKind::Ring, &dm, 0.5).unwrap();
        let mut tr = XlaTrainer::new(&mut rt, &manifest, "mlp", data, 0.1).unwrap();
        let report = run(
            &mut tr,
            &overlay,
            &DpasgdConfig {
                rounds: 30,
                s: 2,
                eval_every: 29,
                ..Default::default()
            },
        )
        .unwrap();
        let last = report.records.last().unwrap();
        assert!(last.test_acc.unwrap() > 0.5, "acc={:?}", last.test_acc);
        assert!(report.final_train_loss() < report.records[0].train_loss);
        let _ = full;
    }
}
