//! PJRT client wrapper: load HLO-text artifacts, compile once, execute many.
//!
//! Follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. One [`Executable`] per artifact; compiled
//! executables are cached by the [`XlaRuntime`] so repeated designs/training
//! runs in one process never recompile.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Process-wide PJRT CPU runtime with an executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, std::rc::Rc<Executable>>,
}

/// A compiled computation ready to run.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub source: PathBuf,
}

impl XlaRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::info!(
            "PJRT ready: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(XlaRuntime {
            client,
            cache: HashMap::new(),
        })
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&mut self, path: &Path) -> Result<std::rc::Rc<Executable>> {
        if let Some(exe) = self.cache.get(path) {
            return Ok(exe.clone());
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        crate::debug!(
            "compiled {:?} in {:.0} ms",
            path.file_name().unwrap_or_default(),
            t0.elapsed().as_secs_f64() * 1e3
        );
        let exe = std::rc::Rc::new(Executable {
            exe,
            source: path.to_path_buf(),
        });
        self.cache.insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }
}

impl Executable {
    /// Execute with literal inputs; returns the flattened tuple outputs.
    /// (aot.py lowers with `return_tuple=True`, so the single result is a
    /// tuple literal we decompose.)
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {:?}", self.source))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(lit.to_tuple()?)
    }
}

/// Build an f32 literal of the given shape.
pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    anyhow::ensure!(data.len() == n, "shape {shape:?} needs {n} elems, got {}", data.len());
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    anyhow::ensure!(data.len() == n, "shape {shape:?} needs {n} elems, got {}", data.len());
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn literals_shape_and_roundtrip() {
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(l.element_count(), 6);
        let back = l.to_vec::<f32>().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(f32_literal(&[1.0], &[2, 3]).is_err());
        let s = f32_literal(&[7.5], &[]).unwrap();
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.5]);
    }

    #[test]
    fn end_to_end_mlp_train_step() {
        // Requires `make artifacts`; skips otherwise (CI runs it first).
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let mlp = manifest.model("mlp").unwrap();
        let mut rt = XlaRuntime::cpu().unwrap();

        // init
        let init = rt.load(&mlp.init_file).unwrap();
        let out = init.run(&[xla::Literal::scalar(42i32)]).unwrap();
        assert_eq!(out.len(), 1);
        let params = out[0].to_vec::<f32>().unwrap();
        assert_eq!(params.len(), mlp.param_count);

        // train one step on a synthetic batch
        let train = rt.load(&mlp.train_file).unwrap();
        let bx: Vec<f32> = (0..mlp.x_shape.iter().product::<usize>())
            .map(|i| ((i % 13) as f32 - 6.0) * 0.1)
            .collect();
        let by: Vec<i32> = (0..mlp.y_shape.iter().product::<usize>())
            .map(|i| (i % 4) as i32)
            .collect();
        let outs = train
            .run(&[
                f32_literal(&params, &[mlp.param_count]).unwrap(),
                f32_literal(&bx, &mlp.x_shape).unwrap(),
                i32_literal(&by, &mlp.y_shape).unwrap(),
                xla::Literal::scalar(0.05f32),
            ])
            .unwrap();
        assert_eq!(outs.len(), 2);
        let new_params = outs[0].to_vec::<f32>().unwrap();
        let loss = outs[1].to_vec::<f32>().unwrap()[0];
        assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
        assert_eq!(new_params.len(), params.len());
        assert!(new_params.iter().zip(&params).any(|(a, b)| a != b));

        // executable cache hit
        let again = rt.load(&mlp.train_file).unwrap();
        assert!(std::rc::Rc::ptr_eq(&train, &again));
    }

    #[test]
    fn consensus_artifact_matches_rust_mixer() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let mlp = manifest.model("mlp").unwrap();
        let mut rt = XlaRuntime::cpu().unwrap();
        let cons = rt.load(&mlp.consensus_file).unwrap();

        let k = mlp.consensus_k;
        let p = mlp.param_count;
        let mut rng = crate::util::rng::Rng::new(5);
        let stacked: Vec<f32> = (0..k * p).map(|_| rng.f32() - 0.5).collect();
        let mut weights = vec![0.0f32; k];
        weights[0] = 0.5;
        weights[1] = 0.25;
        weights[2] = 0.25;

        let outs = cons
            .run(&[
                f32_literal(&stacked, &[k, p]).unwrap(),
                f32_literal(&weights, &[k]).unwrap(),
            ])
            .unwrap();
        let xla_mix = outs[0].to_vec::<f32>().unwrap();

        // Rust-side reference
        let mut expect = vec![0.0f32; p];
        for (kk, &w) in weights.iter().enumerate() {
            crate::fl::consensus::axpy(w, &stacked[kk * p..(kk + 1) * p], &mut expect);
        }
        for (a, b) in xla_mix.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
