//! Leader process: binds the topology designer, the network simulator, and
//! the XLA training runtime into one experiment — the production analogue of
//! the paper's "PyTorch trains as fast as the cluster permits, the network
//! simulator reconstructs the real timeline".
//!
//! [`run_experiment`] runs DPASGD with a [`LocalTrainer`] and then the
//! max-plus recurrence replays the same round sequence on the modelled
//! network, producing loss-vs-round *and* loss-vs-wall-clock curves (Fig. 2)
//! from a single [`ExperimentReport`].
//!
//! [`run_experiment`] is the *static reference path*: train first, replay
//! the timeline after. The coupled engine ([`crate::fl::trainsim`]) fuses
//! the two loops per round (and handles dynamic scenarios + adaptive
//! re-design); under the identity scenario the two agree bit-for-bit on
//! the (round, loss) sequence, which `tests/train.rs` pins. Fig. 2 routes
//! through the engine since PR 4; this path remains for the e2e example
//! and as the equivalence oracle.

use crate::fl::dpasgd::{self, DpasgdConfig, LocalTrainer, TrainReport};
use crate::netsim::delay::DelayModel;
use crate::topology::Overlay;
use anyhow::Result;

/// A completed training experiment: algorithmic + temporal views.
#[derive(Clone, Debug)]
pub struct ExperimentReport {
    pub overlay: String,
    pub cycle_time_ms: f64,
    pub train: TrainReport,
    /// simulated wall-clock (ms) at which each round completed.
    pub wallclock_ms: Vec<f64>,
}

impl ExperimentReport {
    /// (round, wallclock_ms, train_loss) triples for plotting.
    pub fn curve(&self) -> Vec<(usize, f64, f32)> {
        self.train
            .records
            .iter()
            .map(|r| (r.round, self.wallclock_ms[r.round + 1], r.train_loss))
            .collect()
    }

    /// Simulated time to reach an evaluated accuracy target, if reached.
    pub fn time_to_accuracy_ms(&self, target: f32) -> Option<f64> {
        self.train
            .rounds_to_accuracy(target)
            .map(|k| self.wallclock_ms[k + 1])
    }
}

/// Run one (overlay × trainer) experiment.
pub fn run_experiment(
    trainer: &mut dyn LocalTrainer,
    overlay: &Overlay,
    dm: &DelayModel,
    cfg: &DpasgdConfig,
) -> Result<ExperimentReport> {
    let t0 = std::time::Instant::now();
    let train = dpasgd::run(trainer, overlay, cfg)?;
    crate::info!(
        "trained {} rounds on {} in {:.1}s (real)",
        cfg.rounds,
        overlay.kind().name(),
        t0.elapsed().as_secs_f64()
    );

    // Reconstruct the simulated timeline for the same round sequence
    // (Algorithm 3, specialised per overlay family).
    let wallclock_ms = overlay.wallclock_ms(dm, cfg.rounds, cfg.seed);

    Ok(ExperimentReport {
        overlay: overlay.kind().name().to_string(),
        cycle_time_ms: overlay.cycle_time_ms(dm),
        train,
        wallclock_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::dpasgd::QuadraticTrainer;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;
    use crate::topology::{design_with_underlay, OverlayKind};

    #[test]
    fn wallclock_consistent_with_cycle_time() {
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let overlay = design_with_underlay(OverlayKind::Ring, &dm, &net, 0.5).unwrap();
        let mut tr = QuadraticTrainer::new(11, 4, 1);
        let cfg = DpasgdConfig {
            rounds: 120,
            eval_every: 0,
            ..Default::default()
        };
        let rep = run_experiment(&mut tr, &overlay, &dm, &cfg).unwrap();
        assert_eq!(rep.wallclock_ms.len(), 121);
        // asymptotic slope ≈ cycle time
        let slope = (rep.wallclock_ms[120] - rep.wallclock_ms[60]) / 60.0;
        assert!(
            (slope - rep.cycle_time_ms).abs() < 0.05 * rep.cycle_time_ms,
            "slope {slope} vs τ {}",
            rep.cycle_time_ms
        );
        assert_eq!(rep.curve().len(), 120);
    }

    #[test]
    fn matcha_wallclock_replay_monotone() {
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let overlay =
            design_with_underlay(OverlayKind::MatchaPlus, &dm, &net, 0.5).unwrap();
        let mut tr = QuadraticTrainer::new(11, 4, 1);
        let cfg = DpasgdConfig {
            rounds: 50,
            eval_every: 0,
            ..Default::default()
        };
        let rep = run_experiment(&mut tr, &overlay, &dm, &cfg).unwrap();
        assert!(rep.wallclock_ms.windows(2).all(|w| w[1] >= w[0]));
        // matcha average cycle time should be in the ballpark of the slope
        let slope = (rep.wallclock_ms[50] - rep.wallclock_ms[25]) / 25.0;
        assert!(slope > 0.0);
        assert!((slope - rep.cycle_time_ms).abs() < 0.5 * rep.cycle_time_ms);
    }

    #[test]
    fn faster_overlay_reaches_target_sooner_in_time() {
        // The paper's core claim end-to-end: same trainer, same rounds — the
        // RING reaches the accuracy target in less *simulated time* than the
        // STAR even though per-round convergence is comparable.
        let net = Underlay::builtin("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 100e6, 1e9);
        let cfg = DpasgdConfig {
            rounds: 150,
            eval_every: 5,
            ..Default::default()
        };
        let mut times = Vec::new();
        for kind in [OverlayKind::Star, OverlayKind::Ring] {
            let overlay = design_with_underlay(kind, &dm, &net, 0.5).unwrap();
            let mut tr = QuadraticTrainer::new(11, 8, 3);
            let rep = run_experiment(&mut tr, &overlay, &dm, &cfg).unwrap();
            let t = rep
                .time_to_accuracy_ms(0.45)
                .expect("both reach the target");
            times.push(t);
        }
        assert!(
            times[1] < 0.7 * times[0],
            "ring {} ms vs star {} ms",
            times[1],
            times[0]
        );
    }
}
