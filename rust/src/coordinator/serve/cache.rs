//! The design cache: memoized request results keyed by the canonical
//! request, invalidated by underlay fingerprint on `measure` drift reports.
//!
//! **Cache state is never semantics** (the PR-7 rule, extended to the
//! daemon): every cached value is the result of a pure function of the
//! request, so a hit returns byte-identical output to a cold miss, and the
//! capacity knob (`fedtopo serve --cache`) can only change CPU time. The
//! point of *invalidation* is freshness bookkeeping for clients that poll:
//! a `measure` request reporting drift on an underlay evicts every entry
//! whose design depended on that underlay, so the next `design` recomputes
//! (and, once measured delay models flow in, recomputes against fresh
//! numbers).

use crate::netsim::underlay::Underlay;
use crate::util::json::Json;
use std::collections::HashMap;

/// 64-bit FNV-1a over an underlay's full identity: name, silo count, every
/// site (name + coordinate bits), and every core edge (endpoints + weight
/// bits). Two underlays share a fingerprint iff they are the same network,
/// so `measure` invalidation is exact for builtins and synth specs alike.
pub fn fingerprint(net: &Underlay) -> u64 {
    let mut h = Fnv::new();
    h.bytes(net.name.as_bytes());
    h.u64(net.sites.len() as u64);
    for s in &net.sites {
        h.bytes(s.name.as_bytes());
        h.u64(s.lat.to_bits());
        h.u64(s.lon.to_bits());
    }
    for &(u, v, w) in net.core.edges().iter() {
        h.u64(u as u64);
        h.u64(v as u64);
        h.u64(w.to_bits());
    }
    h.0
}

struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
}

struct Entry {
    result: Json,
    /// Fingerprints of every underlay the result depends on.
    fingerprints: Vec<u64>,
    /// LRU stamp: bumped on every hit; the minimum is evicted at capacity.
    stamp: u64,
}

/// LRU map from canonical request key to memoized result.
pub struct DesignCache {
    capacity: usize,
    entries: HashMap<String, Entry>,
    clock: u64,
    hits: u64,
    misses: u64,
    invalidated: u64,
}

impl DesignCache {
    /// `capacity` 0 disables caching entirely (every lookup misses).
    pub fn new(capacity: usize) -> DesignCache {
        DesignCache {
            capacity,
            entries: HashMap::new(),
            clock: 0,
            hits: 0,
            misses: 0,
            invalidated: 0,
        }
    }

    /// Look up a canonical request key; a hit returns the memoized result
    /// (byte-identical to recomputing, by construction).
    pub fn get(&mut self, key: &str) -> Option<Json> {
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(key) {
            Some(e) => {
                e.stamp = clock;
                self.hits += 1;
                Some(e.result.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Memoize a computed result with the underlay fingerprints it depends
    /// on; evicts the least-recently-used entry past capacity.
    pub fn put(&mut self, key: String, result: Json, fingerprints: Vec<u64>) {
        if self.capacity == 0 {
            return;
        }
        self.clock += 1;
        self.entries.insert(
            key,
            Entry {
                result,
                fingerprints,
                stamp: self.clock,
            },
        );
        while self.entries.len() > self.capacity {
            let lru = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
                .expect("non-empty past capacity");
            self.entries.remove(&lru);
        }
    }

    /// Drop every entry depending on the given underlay fingerprint
    /// (a `measure` request reported drift). Returns the eviction count.
    pub fn invalidate_fingerprint(&mut self, fp: u64) -> usize {
        let stale: Vec<String> = self
            .entries
            .iter()
            .filter(|(_, e)| e.fingerprints.contains(&fp))
            .map(|(k, _)| k.clone())
            .collect();
        for k in &stale {
            self.entries.remove(k);
        }
        self.invalidated += stale.len() as u64;
        stale.len()
    }

    /// Diagnostic counters (the `stats` request; deliberately *not* part of
    /// any byte-pinned response).
    pub fn stats(&self) -> Json {
        Json::obj(vec![
            ("capacity", Json::num(self.capacity as f64)),
            ("entries", Json::num(self.entries.len() as f64)),
            ("hits", Json::num(self.hits as f64)),
            ("misses", Json::num(self.misses as f64)),
            ("invalidated", Json::num(self.invalidated as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_distinguishes_underlays_and_is_stable() {
        let a = Underlay::by_name("gaia").unwrap();
        let b = Underlay::by_name("geant").unwrap();
        let c = Underlay::by_name("synth:waxman:50:seed7").unwrap();
        let c2 = Underlay::by_name("synth:waxman:50:seed7").unwrap();
        let c3 = Underlay::by_name("synth:waxman:50:seed8").unwrap();
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_ne!(fingerprint(&a), fingerprint(&c));
        assert_eq!(fingerprint(&c), fingerprint(&c2), "same spec, same print");
        assert_ne!(fingerprint(&c), fingerprint(&c3), "seed changes the print");
    }

    #[test]
    fn lru_evicts_oldest_and_counts() {
        let mut c = DesignCache::new(2);
        c.put("a".into(), Json::num(1.0), vec![1]);
        c.put("b".into(), Json::num(2.0), vec![2]);
        assert_eq!(c.get("a"), Some(Json::num(1.0))); // a now fresher than b
        c.put("c".into(), Json::num(3.0), vec![3]);
        assert_eq!(c.get("b"), None, "b was LRU");
        assert_eq!(c.get("a"), Some(Json::num(1.0)));
        assert_eq!(c.get("c"), Some(Json::num(3.0)));
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = DesignCache::new(0);
        c.put("a".into(), Json::num(1.0), vec![]);
        assert_eq!(c.get("a"), None);
    }

    #[test]
    fn invalidate_by_fingerprint_is_exact() {
        let mut c = DesignCache::new(8);
        c.put("a".into(), Json::num(1.0), vec![10, 20]);
        c.put("b".into(), Json::num(2.0), vec![20]);
        c.put("d".into(), Json::num(3.0), vec![30]);
        assert_eq!(c.invalidate_fingerprint(20), 2);
        assert_eq!(c.get("a"), None);
        assert_eq!(c.get("b"), None);
        assert_eq!(c.get("d"), Some(Json::num(3.0)));
        assert_eq!(c.invalidate_fingerprint(99), 0);
    }
}
