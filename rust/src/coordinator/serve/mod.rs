//! `fedtopo serve` — a resident multi-tenant coordinator daemon.
//!
//! One process designs, simulates, and stress-tests overlays for many
//! clients without paying process startup, underlay resolution, or route
//! computation per request. The daemon is a thin TCP shell around the same
//! library calls the one-shot CLI makes — **every response is byte-identical
//! to the corresponding CLI invocation**, invariant under cache capacity,
//! cache state, request batching, concurrency, and arrival order. The
//! invariant holds by construction: each request's result is a pure
//! function of the request object alone (per-cell seeds derive via
//! [`crate::util::rng::derive_seed`] from the request's own `seed`, exactly
//! as in the CLI — batch position and arrival order never enter seeding),
//! and the [`cache`] memoizes only such pure results.
//!
//! # Protocol: `fedtopo-serve/v1`
//!
//! Newline-delimited JSON over TCP (hand-rolled on `std::net`; the image
//! has no async runtime and does not need one — requests are CPU-bound and
//! fan out onto the `--jobs` pool, so a thread per connection is plenty).
//!
//! On startup the daemon prints one line to stdout and flushes:
//!
//! ```text
//! {"addr":"127.0.0.1:7878","event":"listening","protocol":"fedtopo-serve/v1"}
//! ```
//!
//! (`--addr 127.0.0.1:0` binds an ephemeral port; parse `addr` from this
//! line — the integration tests and the CI smoke job do.)
//!
//! Each request is one line: a JSON object with a `"kind"` plus parameters,
//! or a JSON **array** of such objects (a batch). Each response is one line:
//!
//! ```text
//! {"id":<echo>,"ok":true,"result":<document>}
//! {"error":"<message>","id":<echo>,"ok":false}
//! ```
//!
//! `"id"` is echoed verbatim (any JSON value; defaults to `null`) and never
//! enters the computation or the cache key. A batch produces one response
//! line per element, **in input order**, computed concurrently on the jobs
//! pool via [`crate::util::parallel::par_map_indexed`] (ordered merge — the
//! same deterministic fan-out the sweep engine uses).
//!
//! Threading (PR 10): connection threads are *not* pool workers, so a
//! single (non-batch) request is exactly where intra-cell parallelism
//! engages — large kernels row-partition across the intra-cell pool
//! ([`crate::util::parallel::run_intracell`], sized by `--intracell` /
//! `FEDTOPO_INTRACELL`, falling through to `--jobs`). Batch elements run
//! *on* pool workers and therefore keep the sequential kernels per the
//! PR-3 nested-sequential rule. Either way responses are byte-identical —
//! the CI determinism job compares single-cell `design` responses across
//! jobs/intracell settings at 100k silos.
//!
//! ## Request kinds
//!
//! | kind         | one-shot equivalent                    | result document |
//! |--------------|----------------------------------------|-----------------|
//! | `design`     | `fedtopo scale --networks ... --json`  | the scale report (`family` = `custom`) |
//! | `simulate`   | `fedtopo train --json`                 | the train report |
//! | `robustness` | `fedtopo robustness`                   | the robustness report |
//! | `cycle-time` | `fedtopo design` (one network×overlay) | `{cycle_time_ms, network, overlay, silos}` |
//! | `measure`    | —                                      | drift report → cache invalidation |
//! | `capabilities` | `fedtopo help` name lists            | protocol + the [`crate::spec`] registry |
//! | `stats`      | —                                      | cache diagnostics (not byte-pinned) |
//! | `ping`       | —                                      | `{"pong":true}` |
//! | `shutdown`   | —                                      | ack, then the daemon drains and exits |
//!
//! Parameters (all optional, CLI defaults apply; string-list parameters
//! accept a JSON array or a comma-separated string, like the CLI):
//!
//! * `design`: `networks` (`["gaia"]`), `overlays` (`"all"`), `backends`
//!   (`["backend:scalar"]`), `workload` (`"inaturalist"`), `s` (1),
//!   `access_bps` (10e9), `core_bps` (1e9), `cb` (0.5), `seed` (7).
//! * `simulate`: the `train` grid — `networks`, `workloads`, `backends`
//!   (`["backend:scalar"]`), `overlays`, `scenarios`
//!   (`["scenario:identity"]`), `seeds` (`[7]`), `s`,
//!   `access_bps`, `core_bps`, `cb`, `rounds` (60), `eval_every` (5),
//!   `window` (20), `threshold` (absent = ∞ = static), `target_acc` (0.5),
//!   `dim` (16).
//! * `robustness`: `network`, `workload`, `overlays`, `backends`
//!   (`["backend:scalar"]`), `actions` (`["design"]`; add `"reroute"` to
//!   race the path-re-solving arm), `scenario`
//!   (`"scenario:straggler:3:x10"`), `rounds` (200), `window` (20),
//!   `threshold` (1.3), `s`, `access_bps`, `core_bps`, `cb`, `seed`.
//! * `cycle-time`: `network`, `overlay` (`"ring"`), `workload`, `s`,
//!   `access_bps`, `core_bps`, `cb`.
//! * `measure`: `network` (required) — a client reporting measured drift on
//!   an underlay. Every cached design depending on that underlay's
//!   fingerprint is evicted, so the next request recomputes.
//!
//! ## Caching
//!
//! `design` / `simulate` / `robustness` / `cycle-time` results are memoized
//! in an LRU keyed by the canonical request object (minus `id` / `stream`:
//! `fedtopo serve --cache N`, 0 disables). Because every cached value is
//! pure, a hit is byte-identical to a cold miss — the envelope carries **no**
//! cached-or-not marker (that would break the invariant); hit/miss counters
//! live behind the separate `stats` kind, which is diagnostic and
//! deliberately not byte-pinned.
//!
//! ## Streaming
//!
//! A non-batch `simulate` whose grid is a single cell (one network × one
//! workload × one backend × one overlay × one scenario × one seed) may set
//! `"stream": k`
//! to receive the evaluated loss-curve knots as they would appear, `k`
//! knots per event line, **before** the final response:
//!
//! ```text
//! {"chunk":0,"event":"rounds","id":1,"points":[[round,sim_ms,loss,acc],...]}
//! {"chunk":1,"event":"rounds","id":1,"points":[...]}
//! {"id":1,"ok":true,"result":<train report>}
//! ```
//!
//! The final line is byte-identical to the non-streamed response. Streaming
//! is restricted to single-cell grids because CRN pairing derives per-cell
//! seeds from the cell's position in its grid ([`SweepSpec::crn_index`]) —
//! a cell streamed out of a larger grid would not reproduce the one-shot
//! bytes. Streamed requests bypass the cache (events always emitted);
//! `"stream"` inside a batch is an error.
//!
//! [`SweepSpec::crn_index`]: crate::coordinator::experiments::sweep::SweepSpec::crn_index

pub mod cache;
mod server;

pub use server::serve;

use crate::coordinator::experiments as exp;
use crate::fl::workloads::Workload;
use crate::netsim::underlay::Underlay;
use crate::topology::{design_with_underlay, OverlayKind};
use crate::util::json::Json;
use crate::util::parallel::par_map_indexed;
use anyhow::{anyhow, Result};
use cache::{fingerprint, DesignCache};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Protocol identifier, echoed in the listening line and `capabilities`.
pub const PROTOCOL: &str = "fedtopo-serve/v1";

/// The request kinds, for `capabilities`.
pub const REQUEST_KINDS: &[&str] = &[
    "design", "simulate", "robustness", "cycle-time", "measure", "capabilities", "stats", "ping",
    "shutdown",
];

/// The daemon's transport-free core: all protocol handling minus sockets,
/// so tests can drive it in-process and the TCP layer stays trivial.
pub struct ServeCore {
    cache: Mutex<DesignCache>,
    shutdown: AtomicBool,
}

impl ServeCore {
    pub fn new(cache_capacity: usize) -> ServeCore {
        ServeCore {
            cache: Mutex::new(DesignCache::new(cache_capacity)),
            shutdown: AtomicBool::new(false),
        }
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Handle one input line; returns the output lines (one response per
    /// request, preceded by event lines when streaming).
    pub fn handle_line(&self, line: &str) -> Vec<String> {
        let parsed = match Json::parse(line) {
            Ok(v) => v,
            Err(e) => return vec![error_line(&Json::Null, &format!("bad request line: {e}"))],
        };
        match parsed {
            // A batch: one response per element, input order, computed
            // concurrently (ordered merge keeps the order deterministic).
            Json::Arr(reqs) => par_map_indexed(&reqs, |_, req| {
                if !matches!(req.get("stream"), Json::Null) {
                    return error_line(req.get("id"), "streaming is not allowed in a batch");
                }
                self.respond(req)
            }),
            Json::Obj(_) => match stream_chunk(&parsed) {
                Some(Ok(k)) => self.respond_streaming(&parsed, k),
                Some(Err(msg)) => vec![error_line(parsed.get("id"), &msg)],
                None => vec![self.respond(&parsed)],
            },
            _ => vec![error_line(&Json::Null, "request must be an object or an array")],
        }
    }

    /// One request → one canonical response line.
    fn respond(&self, req: &Json) -> String {
        let id = req.get("id");
        match self.dispatch(req) {
            Ok(result) => ok_line(id, result),
            Err(e) => error_line(id, &format!("{e:#}")),
        }
    }

    fn dispatch(&self, req: &Json) -> Result<Json> {
        let kinds = REQUEST_KINDS.join("|");
        let kind = req
            .get("kind")
            .as_str()
            .ok_or_else(|| anyhow!("request needs a string 'kind' (one of {kinds})"))?;
        match kind {
            "design" | "simulate" | "robustness" | "cycle-time" => self.cached(req, kind),
            "measure" => self.measure(req),
            "capabilities" => Ok(capabilities_doc()),
            "stats" => Ok(self.cache.lock().expect("cache lock").stats()),
            "ping" => Ok(Json::obj(vec![("pong", Json::Bool(true))])),
            "shutdown" => {
                self.shutdown.store(true, Ordering::SeqCst);
                Ok(Json::obj(vec![("shutting_down", Json::Bool(true))]))
            }
            other => Err(anyhow!(
                "unknown request kind '{other}' (one of {})",
                REQUEST_KINDS.join("|")
            )),
        }
    }

    /// The memoized path: canonical-key lookup, compute on miss. Purity of
    /// the handlers is what makes a hit byte-identical to a miss.
    fn cached(&self, req: &Json, kind: &str) -> Result<Json> {
        let key = cache_key(req);
        if let Some(hit) = self.cache.lock().expect("cache lock").get(&key) {
            return Ok(hit);
        }
        let (result, fps) = match kind {
            "design" => design(req)?,
            "simulate" => simulate(req)?,
            "robustness" => robustness(req)?,
            "cycle-time" => cycle_time(req)?,
            _ => unreachable!("cached() is called for cacheable kinds only"),
        };
        self.cache
            .lock()
            .expect("cache lock")
            .put(key, result.clone(), fps);
        Ok(result)
    }

    /// `measure`: a drift report on an underlay — evict every cached result
    /// that depends on it.
    fn measure(&self, req: &Json) -> Result<Json> {
        let spec = req
            .get("network")
            .as_str()
            .ok_or_else(|| anyhow!("measure needs a string 'network'"))?;
        let net = Underlay::by_name(spec)?;
        let fp = fingerprint(&net);
        let n = self
            .cache
            .lock()
            .expect("cache lock")
            .invalidate_fingerprint(fp);
        Ok(Json::obj(vec![
            ("fingerprint", Json::str(&format!("{fp:016x}"))),
            ("invalidated", Json::num(n as f64)),
            ("network", Json::str(spec)),
        ]))
    }

    /// Streamed single-cell `simulate`: event lines, then the canonical
    /// final response (identical bytes to the non-streamed path).
    fn respond_streaming(&self, req: &Json, chunk_len: usize) -> Vec<String> {
        let id = req.get("id");
        match simulate_streamed(req, id, chunk_len) {
            Ok(lines) => lines,
            Err(e) => vec![error_line(id, &format!("{e:#}"))],
        }
    }
}

// -- response envelopes ----------------------------------------------------

fn ok_line(id: &Json, result: Json) -> String {
    Json::obj(vec![
        ("id", id.clone()),
        ("ok", Json::Bool(true)),
        ("result", result),
    ])
    .to_string()
}

fn error_line(id: &Json, msg: &str) -> String {
    Json::obj(vec![
        ("error", Json::str(msg)),
        ("id", id.clone()),
        ("ok", Json::Bool(false)),
    ])
    .to_string()
}

/// Canonical cache key: the request object minus the non-semantic keys
/// (`id`, `stream`), serialized (BTreeMap keeps keys sorted).
fn cache_key(req: &Json) -> String {
    let mut m: BTreeMap<String, Json> = req.as_obj().cloned().unwrap_or_default();
    m.remove("id");
    m.remove("stream");
    Json::Obj(m).to_string()
}

/// `Some(Ok(k))` when the request asks for streaming with chunk size `k`.
fn stream_chunk(req: &Json) -> Option<Result<usize, String>> {
    match req.get("stream") {
        Json::Null => None,
        v => Some(match v.as_usize() {
            Some(k) if k > 0 => Ok(k),
            _ => Err("'stream' must be a positive integer (knots per event line)".to_string()),
        }),
    }
}

// -- parameter extraction --------------------------------------------------
//
// All parameters are optional with the CLI defaults; a present-but-wrong
// type is an error (never silently defaulted).

fn p_str(req: &Json, key: &str, default: &str) -> Result<String> {
    match req.get(key) {
        Json::Null => Ok(default.to_string()),
        v => v
            .as_str()
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow!("'{key}' must be a string")),
    }
}

fn p_f64(req: &Json, key: &str, default: f64) -> Result<f64> {
    match req.get(key) {
        Json::Null => Ok(default),
        Json::Num(n) => Ok(*n),
        // accept the CLI's human spellings too ("10G", "inf")
        Json::Str(s) => crate::util::cli::parse_f64_human(s)
            .ok_or_else(|| anyhow!("'{key}': cannot parse '{s}' as a number")),
        _ => Err(anyhow!("'{key}' must be a number")),
    }
}

fn p_usize(req: &Json, key: &str, default: usize) -> Result<usize> {
    match req.get(key) {
        Json::Null => Ok(default),
        v => v
            .as_usize()
            .ok_or_else(|| anyhow!("'{key}' must be a non-negative integer")),
    }
}

fn p_u64(req: &Json, key: &str, default: u64) -> Result<u64> {
    match req.get(key) {
        Json::Null => Ok(default),
        v => v
            .as_i64()
            .and_then(|n| u64::try_from(n).ok())
            .ok_or_else(|| anyhow!("'{key}' must be a non-negative integer")),
    }
}

/// String-list parameter: a JSON array of strings, or one comma-separated
/// string (the CLI spelling).
fn p_str_list(req: &Json, key: &str, default: &[&str]) -> Result<Vec<String>> {
    match req.get(key) {
        Json::Null => Ok(default.iter().map(|s| s.to_string()).collect()),
        Json::Str(s) => Ok(s.split(',').map(|p| p.trim().to_string()).collect()),
        Json::Arr(items) => items
            .iter()
            .map(|v| {
                v.as_str()
                    .map(|s| s.to_string())
                    .ok_or_else(|| anyhow!("'{key}' must contain strings"))
            })
            .collect(),
        _ => Err(anyhow!("'{key}' must be an array of strings or a comma-separated string")),
    }
}

/// Overlay-kind list (`"all"` expands like the CLI's `--overlays all`).
fn p_kinds(req: &Json, key: &str) -> Result<Vec<OverlayKind>> {
    let names = p_str_list(req, key, &["all"])?;
    if names.len() == 1 && names[0] == "all" {
        return Ok(OverlayKind::all().to_vec());
    }
    names.iter().map(|n| OverlayKind::by_name(n)).collect()
}

fn p_seeds(req: &Json, key: &str, default: u64) -> Result<Vec<u64>> {
    match req.get(key) {
        Json::Null => Ok(vec![default]),
        Json::Arr(items) => items
            .iter()
            .map(|v| {
                v.as_i64()
                    .and_then(|n| u64::try_from(n).ok())
                    .ok_or_else(|| anyhow!("'{key}' must contain non-negative integers"))
            })
            .collect(),
        Json::Str(s) => s
            .split(',')
            .map(|p| {
                p.trim()
                    .parse::<u64>()
                    .map_err(|_| anyhow!("'{key}': bad seed '{}'", p.trim()))
            })
            .collect(),
        _ => Err(anyhow!("'{key}' must be an array of integers or a comma-separated string")),
    }
}

/// Fingerprints of every underlay a result depends on (for `measure`
/// invalidation). Resolution cost is dwarfed by the experiment itself.
fn fingerprints_of(specs: &[String]) -> Result<Vec<u64>> {
    let mut fps: Vec<u64> = specs
        .iter()
        .map(|s| Underlay::by_name(s).map(|n| fingerprint(&n)))
        .collect::<Result<_>>()?;
    fps.sort_unstable();
    fps.dedup();
    Ok(fps)
}

// -- request handlers ------------------------------------------------------
//
// Each returns (result document, underlay fingerprints). The documents are
// the *same* `to_json` payloads the CLI prints — byte-identity is not an
// aspiration, it is the same code path.

/// `design` ↔ `fedtopo scale --networks <csv> --overlays <csv> --json`.
fn design(req: &Json) -> Result<(Json, Vec<u64>)> {
    let specs = p_str_list(req, "networks", &["gaia"])?;
    let kinds = p_kinds(req, "overlays")?;
    let backends = p_str_list(req, "backends", &["backend:scalar"])?;
    let wl = Workload::by_name(&p_str(req, "workload", "inaturalist"))?;
    let s = p_usize(req, "s", 1)?;
    let access_bps = p_f64(req, "access_bps", 10e9)?;
    let core_bps = p_f64(req, "core_bps", 1e9)?;
    let c_b = p_f64(req, "cb", 0.5)?;
    let seed = p_u64(req, "seed", 7)?;
    let rows = exp::scale::sweep_rows_specs_kinds_backends(
        specs.clone(),
        kinds,
        backends,
        &wl,
        s,
        access_bps,
        core_bps,
        c_b,
        seed,
    )?;
    // the CLI uses family "custom" whenever --networks is given
    let doc = exp::scale::to_json("custom", &wl, s, access_bps, core_bps, c_b, seed, &rows);
    Ok((doc, fingerprints_of(&specs)?))
}

/// The `simulate` request's [`exp::train::TrainConfig`] (CLI defaults).
fn train_config(req: &Json) -> Result<exp::train::TrainConfig> {
    Ok(exp::train::TrainConfig {
        networks: p_str_list(req, "networks", &["gaia"])?,
        workloads: p_str_list(req, "workloads", &["inaturalist"])?
            .iter()
            .map(|n| Workload::by_name(n))
            .collect::<Result<_>>()?,
        backends: p_str_list(req, "backends", &["backend:scalar"])?,
        kinds: p_kinds(req, "overlays")?,
        scenarios: p_str_list(req, "scenarios", &["scenario:identity"])?,
        seeds: p_seeds(req, "seeds", p_u64(req, "seed", 7)?)?,
        s: p_usize(req, "s", 1)?,
        access_bps: p_f64(req, "access_bps", 10e9)?,
        core_bps: p_f64(req, "core_bps", 1e9)?,
        c_b: p_f64(req, "cb", 0.5)?,
        rounds: p_usize(req, "rounds", 60)?,
        eval_every: p_usize(req, "eval_every", 5)?,
        window: p_usize(req, "window", 20)?,
        threshold: p_f64(req, "threshold", f64::INFINITY)?,
        target_acc: p_f64(req, "target_acc", 0.5)? as f32,
        dim: p_usize(req, "dim", 16)?,
    })
}

/// `simulate` ↔ `fedtopo train --json`.
fn simulate(req: &Json) -> Result<(Json, Vec<u64>)> {
    let cfg = train_config(req)?;
    let rows = exp::train::run(&cfg)?;
    let fps = fingerprints_of(&cfg.networks)?;
    Ok((exp::train::to_json(&cfg, &rows), fps))
}

/// Streamed `simulate`: run the (single) cell, emit the loss-curve knots as
/// event lines, then the canonical response.
fn simulate_streamed(req: &Json, id: &Json, chunk_len: usize) -> Result<Vec<String>> {
    let cfg = train_config(req)?;
    let cells = cfg.networks.len()
        * cfg.workloads.len()
        * cfg.backends.len()
        * cfg.kinds.len()
        * cfg.scenarios.len()
        * cfg.seeds.len();
    if cells != 1 {
        return Err(anyhow!(
            "streaming needs a single-cell grid (got {cells} cells): CRN pairing derives \
             per-cell seeds from the grid position, so a streamed cell inside a larger \
             grid would not reproduce the one-shot bytes"
        ));
    }
    let rows = exp::train::run(&cfg)?;
    let mut lines = Vec::new();
    for (i, knots) in rows[0].curve.chunks(chunk_len).enumerate() {
        let points = knots.iter().map(|&(round, ms, loss, acc)| {
            Json::arr(vec![
                Json::num(round as f64),
                Json::num(ms),
                Json::num(loss as f64),
                Json::num(acc as f64),
            ])
        });
        lines.push(
            Json::obj(vec![
                ("chunk", Json::num(i as f64)),
                ("event", Json::str("rounds")),
                ("id", id.clone()),
                ("points", Json::arr(points)),
            ])
            .to_string(),
        );
    }
    lines.push(ok_line(id, exp::train::to_json(&cfg, &rows)));
    Ok(lines)
}

/// The `robustness` request's `actions` list → the re-route flag (the CLI's
/// `--actions` normalization: `design` is always raced, `reroute` opts in).
fn p_reroute(req: &Json) -> Result<bool> {
    let mut reroute = false;
    for a in p_str_list(req, "actions", &["design"])? {
        match a.as_str() {
            "design" => {}
            "reroute" => reroute = true,
            other => {
                return Err(anyhow!(
                    "'actions': unknown action '{other}' (expected design|reroute)"
                ))
            }
        }
    }
    Ok(reroute)
}

/// `robustness` ↔ `fedtopo robustness` (stdout JSON).
fn robustness(req: &Json) -> Result<(Json, Vec<u64>)> {
    let cfg = exp::robustness::RobustnessConfig {
        network: p_str(req, "network", "gaia")?,
        workload: Workload::by_name(&p_str(req, "workload", "inaturalist"))?,
        s: p_usize(req, "s", 1)?,
        access_bps: p_f64(req, "access_bps", 10e9)?,
        core_bps: p_f64(req, "core_bps", 1e9)?,
        c_b: p_f64(req, "cb", 0.5)?,
        scenario: p_str(req, "scenario", "scenario:straggler:3:x10")?,
        rounds: p_usize(req, "rounds", 200)?,
        window: p_usize(req, "window", 20)?,
        threshold: p_f64(req, "threshold", 1.3)?,
        seed: p_u64(req, "seed", 7)?,
        kinds: p_kinds(req, "overlays")?,
        backends: p_str_list(req, "backends", &["backend:scalar"])?,
        reroute: p_reroute(req)?,
    };
    let rows = exp::robustness::run(&cfg)?;
    let fps = fingerprints_of(std::slice::from_ref(&cfg.network))?;
    Ok((exp::robustness::to_json(&cfg, &rows), fps))
}

/// `cycle-time`: one (network × overlay) design + its τ.
fn cycle_time(req: &Json) -> Result<(Json, Vec<u64>)> {
    let network = p_str(req, "network", "gaia")?;
    let kind = OverlayKind::by_name(&p_str(req, "overlay", "ring"))?;
    let wl = Workload::by_name(&p_str(req, "workload", "inaturalist"))?;
    let s = p_usize(req, "s", 1)?;
    let access_bps = p_f64(req, "access_bps", 10e9)?;
    let core_bps = p_f64(req, "core_bps", 1e9)?;
    let c_b = p_f64(req, "cb", 0.5)?;
    let net = Underlay::by_name(&network)?;
    let dm = crate::netsim::delay::DelayModel::new(&net, &wl, s, access_bps, core_bps);
    let overlay = design_with_underlay(kind, &dm, &net, c_b)?;
    let doc = Json::obj(vec![
        ("cycle_time_ms", Json::num(overlay.cycle_time_ms(&dm))),
        ("network", Json::str(&network)),
        ("overlay", Json::str(kind.name())),
        ("silos", Json::num(net.n_silos() as f64)),
    ]);
    Ok((doc, vec![fingerprint(&net)]))
}

/// The `capabilities` document: protocol + request kinds + the resolver
/// registry (same single source `--help` renders from).
fn capabilities_doc() -> Json {
    Json::obj(vec![
        ("protocol", Json::str(PROTOCOL)),
        ("requests", Json::arr(REQUEST_KINDS.iter().map(|k| Json::str(k)))),
        ("spec", crate::spec::capabilities()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(src: &str) -> Json {
        Json::parse(src).unwrap()
    }

    #[test]
    fn ping_and_capabilities() {
        let core = ServeCore::new(4);
        let out = core.handle_line(r#"{"kind":"ping","id":7}"#);
        assert_eq!(out, vec![r#"{"id":7,"ok":true,"result":{"pong":true}}"#.to_string()]);
        let caps = core.handle_line(r#"{"kind":"capabilities"}"#);
        assert_eq!(caps.len(), 1);
        let doc = Json::parse(&caps[0]).unwrap();
        assert_eq!(doc.get("result").get("protocol").as_str(), Some(PROTOCOL));
        // the registry renders into capabilities (satellite: single source)
        let spec = doc.get("result").get("spec");
        for kind in ["network", "overlay", "workload", "scenario", "backend"] {
            assert!(spec.get(kind).as_obj().is_some(), "missing {kind}");
        }
    }

    #[test]
    fn unknown_kind_and_bad_line_are_error_envelopes() {
        let core = ServeCore::new(4);
        let out = core.handle_line(r#"{"kind":"frobnicate","id":"x"}"#);
        let doc = Json::parse(&out[0]).unwrap();
        assert_eq!(doc.get("ok").as_bool(), Some(false));
        assert_eq!(doc.get("id").as_str(), Some("x"));
        assert!(doc.get("error").as_str().unwrap().contains("frobnicate"));

        let bad = core.handle_line("not json at all");
        let doc = Json::parse(&bad[0]).unwrap();
        assert_eq!(doc.get("ok").as_bool(), Some(false));
        assert_eq!(doc.get("id"), &Json::Null);
    }

    #[test]
    fn resolver_errors_surface_with_suggestions() {
        let core = ServeCore::new(4);
        let out = core.handle_line(r#"{"kind":"cycle-time","network":"gaiaa"}"#);
        let doc = Json::parse(&out[0]).unwrap();
        let msg = doc.get("error").as_str().unwrap();
        assert!(msg.contains("cannot resolve network 'gaiaa'"), "{msg}");
        assert!(msg.contains("did you mean 'gaia'?"), "{msg}");
    }

    #[test]
    fn cycle_time_hit_is_byte_identical_to_miss() {
        let core = ServeCore::new(4);
        let line = r#"{"id":1,"kind":"cycle-time","network":"gaia","overlay":"ring"}"#;
        let cold = core.handle_line(line);
        let warm = core.handle_line(line);
        assert_eq!(cold, warm);
        // and a zero-capacity core (cache disabled) produces the same bytes
        let uncached = ServeCore::new(0).handle_line(line);
        assert_eq!(cold, uncached);
    }

    #[test]
    fn id_and_stream_never_enter_the_cache_key() {
        let a = cache_key(&req(r#"{"id":1,"kind":"ping","stream":4}"#));
        let b = cache_key(&req(r#"{"id":"zz","kind":"ping"}"#));
        assert_eq!(a, b);
        assert_eq!(a, r#"{"kind":"ping"}"#);
    }

    #[test]
    fn batch_preserves_input_order_and_matches_sequential() {
        let core = ServeCore::new(8);
        let batch = r#"[{"id":0,"kind":"cycle-time","network":"gaia","overlay":"ring"},
                        {"id":1,"kind":"cycle-time","network":"gaia","overlay":"star"},
                        {"id":2,"kind":"ping"}]"#
            .replace('\n', " ");
        let out = core.handle_line(&batch);
        assert_eq!(out.len(), 3);
        for (i, line) in out.iter().enumerate() {
            assert_eq!(Json::parse(line).unwrap().get("id").as_usize(), Some(i));
        }
        // sequential singles on a fresh core: same bytes (cache/batch purity)
        let fresh = ServeCore::new(8);
        let seq: Vec<String> = [
            r#"{"id":0,"kind":"cycle-time","network":"gaia","overlay":"ring"}"#,
            r#"{"id":1,"kind":"cycle-time","network":"gaia","overlay":"star"}"#,
            r#"{"id":2,"kind":"ping"}"#,
        ]
        .iter()
        .map(|l| fresh.handle_line(l).remove(0))
        .collect();
        assert_eq!(out, seq);
    }

    #[test]
    fn stream_in_batch_is_an_error() {
        let core = ServeCore::new(4);
        let out = core.handle_line(r#"[{"id":5,"kind":"ping","stream":2}]"#);
        let doc = Json::parse(&out[0]).unwrap();
        assert_eq!(doc.get("ok").as_bool(), Some(false));
        assert_eq!(doc.get("id").as_usize(), Some(5));
    }

    #[test]
    fn streamed_simulate_final_line_matches_plain() {
        let core = ServeCore::new(4);
        let plain = core.handle_line(
            r#"{"id":3,"kind":"simulate","overlays":"ring","rounds":6,"eval_every":2,"workloads":"femnist"}"#,
        );
        let streamed = core.handle_line(
            r#"{"id":3,"kind":"simulate","overlays":"ring","rounds":6,"eval_every":2,"workloads":"femnist","stream":2}"#,
        );
        assert!(streamed.len() > 1, "expected event lines before the response");
        assert_eq!(streamed.last(), plain.last());
        for ev in &streamed[..streamed.len() - 1] {
            let doc = Json::parse(ev).unwrap();
            assert_eq!(doc.get("event").as_str(), Some("rounds"));
            assert_eq!(doc.get("id").as_usize(), Some(3));
            assert!(!doc.get("points").as_arr().unwrap().is_empty());
        }
    }

    #[test]
    fn streaming_a_multi_cell_grid_is_rejected() {
        let core = ServeCore::new(4);
        let out = core.handle_line(
            r#"{"kind":"simulate","overlays":"ring,star","rounds":4,"stream":2}"#,
        );
        let doc = Json::parse(&out[0]).unwrap();
        assert_eq!(doc.get("ok").as_bool(), Some(false));
        assert!(doc.get("error").as_str().unwrap().contains("single-cell"), "{}", out[0]);
    }

    #[test]
    fn measure_invalidates_matching_designs_only() {
        let core = ServeCore::new(8);
        let gaia = r#"{"kind":"cycle-time","network":"gaia","overlay":"ring"}"#;
        let geant = r#"{"kind":"cycle-time","network":"geant","overlay":"ring"}"#;
        core.handle_line(gaia);
        core.handle_line(geant);
        let out = core.handle_line(r#"{"kind":"measure","network":"gaia"}"#);
        let doc = Json::parse(&out[0]).unwrap();
        assert_eq!(doc.get("result").get("invalidated").as_usize(), Some(1));
        // geant's entry survived; gaia recomputes to the same bytes anyway
        let stats = Json::parse(&core.handle_line(r#"{"kind":"stats"}"#)[0]).unwrap();
        assert_eq!(stats.get("result").get("entries").as_usize(), Some(1));
    }

    #[test]
    fn shutdown_acks_and_latches() {
        let core = ServeCore::new(4);
        assert!(!core.is_shutdown());
        let out = core.handle_line(r#"{"kind":"shutdown"}"#);
        assert!(out[0].contains("\"shutting_down\":true"), "{}", out[0]);
        assert!(core.is_shutdown());
    }
}
