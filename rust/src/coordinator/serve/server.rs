//! The TCP shell around [`super::ServeCore`]: bind, announce, then a thread
//! per connection reading request lines and writing response lines. All
//! protocol behavior (and all determinism reasoning) lives in the core —
//! this file only moves bytes.

use super::ServeCore;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;

/// Run the daemon until a `shutdown` request: bind `addr` (port 0 =
/// ephemeral), print the one-line listening announcement to stdout, and
/// serve connections.
pub fn serve(addr: &str, cache_capacity: usize) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("cannot bind '{addr}'"))?;
    let local = listener.local_addr().context("local_addr")?;
    let core = Arc::new(ServeCore::new(cache_capacity));

    // The announcement is itself canonical JSON: clients (tests, the CI
    // smoke job) parse `addr` from it to find an ephemeral port.
    println!(
        "{}",
        Json::obj(vec![
            ("addr", Json::str(&local.to_string())),
            ("event", Json::str("listening")),
            ("protocol", Json::str(super::PROTOCOL)),
        ])
    );
    std::io::stdout().flush().ok();

    let mut handles = Vec::new();
    for conn in listener.incoming() {
        if core.is_shutdown() {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        // A connection racing the shutdown latch gets dropped unserved.
        if core.is_shutdown() {
            break;
        }
        let core = Arc::clone(&core);
        handles.push(thread::spawn(move || handle_conn(&core, stream, local)));
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// One connection: read request lines, write the core's response lines.
/// Client-side I/O errors just end the connection (never the daemon).
fn handle_conn(core: &ServeCore, stream: TcpStream, listen_addr: SocketAddr) {
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let mut io_ok = true;
        for out in core.handle_line(&line) {
            if writeln!(writer, "{out}").is_err() {
                io_ok = false;
                break;
            }
        }
        if !io_ok || writer.flush().is_err() {
            break;
        }
        if core.is_shutdown() {
            // The acceptor is blocked in `accept()`; a throwaway self-
            // connection wakes it so it can observe the latch and drain.
            let _ = TcpStream::connect(listen_addr);
            break;
        }
    }
}
