//! Leader / coordinator layer: configuration, the training-experiment
//! driver, and the per-table/figure experiment harness.

pub mod config;
pub mod leader;
pub mod experiments;
pub mod serve;
