//! Experiment configuration: one place that turns CLI options into the
//! (underlay, workload, delay-model) triple every experiment consumes.

use crate::fl::workloads::Workload;
use crate::netsim::delay::DelayModel;
use crate::netsim::underlay::Underlay;
use crate::util::cli::Args;
use anyhow::Result;

/// Shared experiment configuration.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    pub network: String,
    pub workload: Workload,
    pub s: usize,
    pub access_bps: f64,
    pub core_bps: f64,
    pub c_b: f64,
    pub seed: u64,
}

impl ExpConfig {
    /// Parse the common options (each subcommand adds its own on top).
    ///
    /// Side effect: applies the `--jobs` option to the global
    /// [`crate::util::parallel`] pool and `--route-cache` to the tiered
    /// routing row cache — the single point where the CLI level of each
    /// resolution order (CLI > env > default) is installed; `0` (the
    /// default) clears the CLI override so the env/default levels apply.
    /// Both are performance switches: output is bit-identical for any value.
    pub fn from_args(args: &Args) -> Result<ExpConfig> {
        crate::util::parallel::set_jobs(args.usize_or("jobs", 0).map_err(anyhow::Error::msg)?);
        crate::netsim::routing::set_row_cache_capacity(
            args.usize_or("route-cache", 0).map_err(anyhow::Error::msg)?,
        );
        Ok(ExpConfig {
            network: args.str_or("network", "gaia"),
            workload: Workload::by_name(&args.str_or("workload", "inaturalist"))?,
            s: args.usize_or("s", 1).map_err(anyhow::Error::msg)?,
            access_bps: args.f64_or("access", 10e9).map_err(anyhow::Error::msg)?,
            core_bps: args.f64_or("core", 1e9).map_err(anyhow::Error::msg)?,
            c_b: args.f64_or("cb", 0.5).map_err(anyhow::Error::msg)?,
            seed: args.u64_or("seed", 7).map_err(anyhow::Error::msg)?,
        })
    }

    pub fn underlay(&self) -> Result<Underlay> {
        Underlay::by_name(&self.network)
    }

    pub fn delay_model(&self, net: &Underlay) -> DelayModel {
        DelayModel::new(net, &self.workload, self.s, self.access_bps, self.core_bps)
    }

    /// Common option specs shared across subcommands.
    pub fn common_opts() -> Vec<crate::util::cli::OptSpec> {
        use crate::util::cli::opt;
        vec![
            opt(
                "network",
                "underlay: gaia|aws-na|geant|exodus|ebone or synth:<family>:<n>[:seed<u64>]",
                Some("gaia"),
            ),
            opt("workload", "Table-2 workload name", Some("inaturalist")),
            opt("s", "local computation steps per round", Some("1")),
            opt("access", "access link capacity, bps (e.g. 10G, 100M)", Some("10e9")),
            opt("core", "core link capacity, bps", Some("1e9")),
            opt("cb", "MATCHA communication budget C_b", Some("0.5")),
            opt("seed", "deterministic seed", Some("7")),
            opt(
                "jobs",
                "worker threads for sweeps (0 = FEDTOPO_JOBS env, then auto); \
                 output is bit-identical for any value",
                Some("0"),
            ),
            opt(
                "route-cache",
                "tiered-routing row cache capacity, rows (0 = \
                 FEDTOPO_ROUTE_CACHE env, then 128); output is bit-identical \
                 for any value",
                Some("0"),
            ),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    #[test]
    fn defaults_and_overrides() {
        // from_args touches the global jobs override — serialize with the
        // other jobs-asserting tests
        let _guard = crate::util::parallel::jobs_test_guard();
        let specs = ExpConfig::common_opts();
        let argv: Vec<String> = ["--network", "geant", "--access", "100M", "--s", "5"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse("t", &argv, &specs).unwrap();
        let cfg = ExpConfig::from_args(&args).unwrap();
        assert_eq!(cfg.network, "geant");
        assert_eq!(cfg.access_bps, 100e6);
        assert_eq!(cfg.s, 5);
        assert_eq!(cfg.core_bps, 1e9);
        assert_eq!(cfg.workload.name, "inaturalist");
        let net = cfg.underlay().unwrap();
        assert_eq!(net.n_silos(), 40);
        let dm = cfg.delay_model(&net);
        assert_eq!(dm.s, 5);
    }

    #[test]
    fn jobs_option_installs_the_cli_override() {
        let _guard = crate::util::parallel::jobs_test_guard();
        let specs = ExpConfig::common_opts();
        let argv: Vec<String> = ["--jobs", "3"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse("t", &argv, &specs).unwrap();
        ExpConfig::from_args(&args).unwrap();
        assert_eq!(crate::util::parallel::jobs(), 3);
        crate::util::parallel::set_jobs(0); // restore auto for other tests
    }

    #[test]
    fn route_cache_option_installs_the_cli_override() {
        let _guard = crate::util::parallel::jobs_test_guard();
        let specs = ExpConfig::common_opts();
        let argv: Vec<String> = ["--route-cache", "9"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse("t", &argv, &specs).unwrap();
        ExpConfig::from_args(&args).unwrap();
        assert_eq!(crate::netsim::routing::row_cache_capacity(), 9);
        crate::netsim::routing::set_row_cache_capacity(0); // restore default
    }

    #[test]
    fn bad_workload_rejected() {
        let _guard = crate::util::parallel::jobs_test_guard();
        let specs = ExpConfig::common_opts();
        let argv: Vec<String> = ["--workload", "imagenet"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse("t", &argv, &specs).unwrap();
        assert!(ExpConfig::from_args(&args).is_err());
    }
}
