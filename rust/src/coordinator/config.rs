//! Experiment configuration: one place that turns CLI options into the
//! (underlay, workload, delay-model) triple every experiment consumes —
//! and the CLI-free [`SessionConfig`] builder that owns the process-level
//! performance switches.

use crate::fl::workloads::Workload;
use crate::netsim::delay::DelayModel;
use crate::netsim::underlay::Underlay;
use crate::util::cli::Args;
use anyhow::Result;

/// CLI-free session settings: every process-global performance switch as a
/// plain field, so `fedtopo serve`, tests, and library embedders configure
/// a session without `Args` or env reads.
///
/// This extends the PR-6 env-at-the-CLI-boundary rule: the *CLI* level of
/// each resolution order (CLI > env > default) is populated only by
/// [`SessionConfig::from_args`], and [`SessionConfig::install`] is the
/// single-writer path onto the globals ([`crate::util::parallel::set_jobs`]
/// and [`crate::netsim::routing::set_row_cache_capacity`]). All fields are
/// performance switches — output is bit-identical for any values.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SessionConfig {
    /// Worker threads for sweeps; 0 = fall through to `FEDTOPO_JOBS`, then
    /// `available_parallelism`.
    pub jobs: usize,
    /// Tiered-routing row cache capacity, rows; 0 = fall through to
    /// `FEDTOPO_ROUTE_CACHE`, then the built-in default.
    pub route_cache_rows: usize,
    /// Intra-cell worker threads (row-partitioned max-plus kernels and the
    /// landmark routing build); 0 = fall through to `FEDTOPO_INTRACELL`,
    /// then the effective `jobs` value. Resolution mirrors `jobs`.
    pub intracell: usize,
    /// Micro-benchmark quick mode (CI smoke budgets) as a plain field; the
    /// bench CLI boundary (`FEDTOPO_BENCH_QUICK`) populates it via
    /// [`crate::util::bench::quick_mode`].
    pub bench_quick: bool,
    /// Bench name filter (substring), as a plain field.
    pub bench_filter: Option<String>,
}

impl SessionConfig {
    pub fn new() -> SessionConfig {
        SessionConfig::default()
    }

    /// Builder: worker-thread count (0 = env/auto).
    pub fn with_jobs(mut self, n: usize) -> SessionConfig {
        self.jobs = n;
        self
    }

    /// Builder: routing row-cache capacity (0 = env/default).
    pub fn with_route_cache_rows(mut self, rows: usize) -> SessionConfig {
        self.route_cache_rows = rows;
        self
    }

    /// Builder: intra-cell worker-thread count (0 = env, then `jobs`).
    pub fn with_intracell(mut self, n: usize) -> SessionConfig {
        self.intracell = n;
        self
    }

    /// Builder: bench quick mode.
    pub fn with_bench_quick(mut self, quick: bool) -> SessionConfig {
        self.bench_quick = quick;
        self
    }

    /// Install the session onto the process globals — the single-writer
    /// path for `set_jobs` / `set_row_cache_capacity`. Idempotent; `0`
    /// clears the CLI-level override so the env/default levels apply.
    pub fn install(&self) {
        crate::util::parallel::set_jobs(self.jobs);
        crate::util::parallel::set_intracell(self.intracell);
        crate::netsim::routing::set_row_cache_capacity(self.route_cache_rows);
    }

    /// An env-free bench harness honoring the session's bench knobs.
    pub fn bench(&self) -> crate::util::bench::Bench {
        crate::util::bench::Bench::configured(self.bench_quick, self.bench_filter.clone())
    }

    /// Populate from parsed CLI options (`--jobs`, `--route-cache`). This
    /// merely *fills fields* — call [`SessionConfig::install`] to apply.
    pub fn from_args(args: &Args) -> Result<SessionConfig> {
        Ok(SessionConfig {
            jobs: args.usize_or("jobs", 0).map_err(anyhow::Error::msg)?,
            route_cache_rows: args.usize_or("route-cache", 0).map_err(anyhow::Error::msg)?,
            intracell: args.usize_or("intracell", 0).map_err(anyhow::Error::msg)?,
            ..SessionConfig::default()
        })
    }

    /// The session-level option specs (`--jobs`, `--route-cache`), shared
    /// by [`ExpConfig::common_opts`] and the `serve` subcommand.
    pub fn opts() -> Vec<crate::util::cli::OptSpec> {
        use crate::util::cli::opt;
        vec![
            opt(
                "jobs",
                "worker threads for sweeps (0 = FEDTOPO_JOBS env, then auto); \
                 output is bit-identical for any value",
                Some("0"),
            ),
            opt(
                "route-cache",
                "tiered-routing row cache capacity, rows (0 = \
                 FEDTOPO_ROUTE_CACHE env, then 128); output is bit-identical \
                 for any value",
                Some("0"),
            ),
            opt(
                "intracell",
                "intra-cell worker threads for row-partitioned kernels and \
                 landmark builds (0 = FEDTOPO_INTRACELL env, then --jobs); \
                 output is bit-identical for any value",
                Some("0"),
            ),
        ]
    }
}

/// Shared experiment configuration.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    pub network: String,
    pub workload: Workload,
    pub s: usize,
    pub access_bps: f64,
    pub core_bps: f64,
    pub c_b: f64,
    pub seed: u64,
}

impl ExpConfig {
    /// Parse the common options (each subcommand adds its own on top).
    ///
    /// Side effect: populates a [`SessionConfig`] from `--jobs` /
    /// `--route-cache` and installs it — the single point where the CLI
    /// level of each resolution order (CLI > env > default) is applied;
    /// `0` (the default) clears the CLI override so the env/default levels
    /// apply. Both are performance switches: output is bit-identical for
    /// any value.
    pub fn from_args(args: &Args) -> Result<ExpConfig> {
        SessionConfig::from_args(args)?.install();
        Ok(ExpConfig {
            network: args.str_or("network", "gaia"),
            workload: Workload::by_name(&args.str_or("workload", "inaturalist"))?,
            s: args.usize_or("s", 1).map_err(anyhow::Error::msg)?,
            access_bps: args.f64_or("access", 10e9).map_err(anyhow::Error::msg)?,
            core_bps: args.f64_or("core", 1e9).map_err(anyhow::Error::msg)?,
            c_b: args.f64_or("cb", 0.5).map_err(anyhow::Error::msg)?,
            seed: args.u64_or("seed", 7).map_err(anyhow::Error::msg)?,
        })
    }

    pub fn underlay(&self) -> Result<Underlay> {
        Underlay::by_name(&self.network)
    }

    pub fn delay_model(&self, net: &Underlay) -> DelayModel {
        DelayModel::new(net, &self.workload, self.s, self.access_bps, self.core_bps)
    }

    /// Common option specs shared across subcommands. Name lists render
    /// from the [`crate::spec`] registry so `--help` can never drift from
    /// the parsers.
    pub fn common_opts() -> Vec<crate::util::cli::OptSpec> {
        use crate::spec::Resolve;
        use crate::util::cli::opt;
        let mut specs = vec![
            opt("network", format!("underlay: {}", Underlay::grammar()), Some("gaia")),
            opt(
                "workload",
                format!("Table-2 workload: {}", Workload::grammar()),
                Some("inaturalist"),
            ),
            opt("s", "local computation steps per round", Some("1")),
            opt("access", "access link capacity, bps (e.g. 10G, 100M)", Some("10e9")),
            opt("core", "core link capacity, bps", Some("1e9")),
            opt("cb", "MATCHA communication budget C_b", Some("0.5")),
            opt("seed", "deterministic seed", Some("7")),
        ];
        specs.extend(SessionConfig::opts());
        specs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    #[test]
    fn defaults_and_overrides() {
        // from_args touches the global jobs override — serialize with the
        // other jobs-asserting tests
        let _guard = crate::util::parallel::jobs_test_guard();
        let specs = ExpConfig::common_opts();
        let argv: Vec<String> = ["--network", "geant", "--access", "100M", "--s", "5"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse("t", &argv, &specs).unwrap();
        let cfg = ExpConfig::from_args(&args).unwrap();
        assert_eq!(cfg.network, "geant");
        assert_eq!(cfg.access_bps, 100e6);
        assert_eq!(cfg.s, 5);
        assert_eq!(cfg.core_bps, 1e9);
        assert_eq!(cfg.workload.name, "inaturalist");
        let net = cfg.underlay().unwrap();
        assert_eq!(net.n_silos(), 40);
        let dm = cfg.delay_model(&net);
        assert_eq!(dm.s, 5);
    }

    #[test]
    fn jobs_option_installs_the_cli_override() {
        let _guard = crate::util::parallel::jobs_test_guard();
        let specs = ExpConfig::common_opts();
        let argv: Vec<String> = ["--jobs", "3"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse("t", &argv, &specs).unwrap();
        ExpConfig::from_args(&args).unwrap();
        assert_eq!(crate::util::parallel::jobs(), 3);
        crate::util::parallel::set_jobs(0); // restore auto for other tests
    }

    #[test]
    fn route_cache_option_installs_the_cli_override() {
        let _guard = crate::util::parallel::jobs_test_guard();
        let specs = ExpConfig::common_opts();
        let argv: Vec<String> = ["--route-cache", "9"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse("t", &argv, &specs).unwrap();
        ExpConfig::from_args(&args).unwrap();
        assert_eq!(crate::netsim::routing::row_cache_capacity(), 9);
        crate::netsim::routing::set_row_cache_capacity(0); // restore default
    }

    #[test]
    fn session_config_builds_without_args_or_env() {
        let _guard = crate::util::parallel::jobs_test_guard();
        let sc = SessionConfig::new().with_jobs(2).with_route_cache_rows(5);
        sc.install();
        assert_eq!(crate::util::parallel::jobs(), 2);
        assert_eq!(crate::netsim::routing::row_cache_capacity(), 5);
        // 0 clears the CLI-level override (env/default levels apply again)
        SessionConfig::new().install();
        crate::util::parallel::set_jobs(0);
        crate::netsim::routing::set_row_cache_capacity(0);
    }

    #[test]
    fn from_args_populates_session_fields_only() {
        let specs = SessionConfig::opts();
        let argv: Vec<String> = ["--jobs", "4", "--route-cache", "11", "--intracell", "2"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse("t", &argv, &specs).unwrap();
        let sc = SessionConfig::from_args(&args).unwrap();
        // populating is side-effect-free; only install() touches globals
        assert_eq!(
            sc,
            SessionConfig::new().with_jobs(4).with_route_cache_rows(11).with_intracell(2)
        );
    }

    #[test]
    fn intracell_option_installs_the_cli_override() {
        let _guard = crate::util::parallel::jobs_test_guard();
        let specs = ExpConfig::common_opts();
        let argv: Vec<String> = ["--intracell", "6"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse("t", &argv, &specs).unwrap();
        ExpConfig::from_args(&args).unwrap();
        assert_eq!(crate::util::parallel::intracell_jobs(), 6);
        crate::util::parallel::set_intracell(0); // restore fall-through
    }

    #[test]
    fn common_opts_render_names_from_the_registry() {
        let specs = ExpConfig::common_opts();
        let network = specs.iter().find(|s| s.name == "network").unwrap();
        assert!(network.help.contains("gaia"), "{}", network.help);
        assert!(network.help.contains("synth:<family>"), "{}", network.help);
        let workload = specs.iter().find(|s| s.name == "workload").unwrap();
        assert!(workload.help.contains("femnist"), "{}", workload.help);
    }

    #[test]
    fn bad_workload_rejected() {
        let _guard = crate::util::parallel::jobs_test_guard();
        let specs = ExpConfig::common_opts();
        let argv: Vec<String> = ["--workload", "imagenet"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse("t", &argv, &specs).unwrap();
        assert!(ExpConfig::from_args(&args).is_err());
    }
}
