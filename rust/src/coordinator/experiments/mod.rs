//! The experiment harness: one module per paper table/figure, all grids
//! declared as [`sweep::SweepSpec`]s and executed on the deterministic
//! `--jobs` pool (see [`crate::util::parallel`]).
//!
//! | module          | reproduces          | subcommand(s)                  |
//! |-----------------|---------------------|--------------------------------|
//! | [`sweep`]       | — (the engine)      | backs every grid below         |
//! | [`cycle_table`] | Tables 3, 6, 7, 9   | `table3` `table6` `table7` `table9` `cycle-table` |
//! | [`fig2`]        | Figure 2            | `fig2`                         |
//! | [`fig3`]        | Figures 3a, 3b      | `fig3a` `fig3b`                |
//! | [`fig4`]        | Figure 4            | `fig4`                         |
//! | [`table10`]     | Table 10            | `table10`                      |
//! | [`bandwidth`]   | App. G Figure 7     | `bandwidth-dist`               |
//! | [`scale`]       | beyond the paper    | `scale`                        |
//! | [`robustness`]  | beyond the paper    | `robustness`                   |
//! | [`train`]       | beyond the paper    | `train`                        |

pub mod sweep;
pub mod cycle_table;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod table10;
pub mod bandwidth;
pub mod scale;
pub mod robustness;
pub mod train;
