//! Fig. 4 — throughput speedup vs STAR as local steps s grow (Exodus).
//!
//! As s increases, `s·T_c(i)` dominates Eq. (3) and all overlays' cycle
//! times converge — communication design matters most when communication
//! dominates.

use super::sweep::{ModelAxis, SweepSpec};
use crate::fl::workloads::Workload;
use crate::topology::{design_with_underlay, OverlayKind};
use crate::util::table::Table;
use anyhow::Result;

pub const S_SWEEP: [usize; 6] = [1, 2, 4, 8, 16, 32];

const KINDS: [OverlayKind; 4] = [
    OverlayKind::MatchaPlus,
    OverlayKind::Mst,
    OverlayKind::DeltaMbst,
    OverlayKind::Ring,
];

/// speedup-vs-STAR per overlay kind for each s. The (s × designer) grid —
/// STAR included as its own cell — routes through [`SweepSpec`] on the
/// `--jobs` pool; speedups are formed after the ordered merge.
pub fn sweep(
    network: &str,
    wl: &Workload,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
) -> Result<Vec<(usize, Vec<(OverlayKind, f64)>)>> {
    let kinds: Vec<OverlayKind> =
        std::iter::once(OverlayKind::Star).chain(KINDS).collect();
    let spec = SweepSpec {
        underlays: vec![network.to_string()],
        models: S_SWEEP
            .iter()
            .map(|&s| ModelAxis {
                s,
                access_bps,
                core_bps,
            })
            .collect(),
        kinds,
        scenarios: vec!["scenario:identity".to_string()],
        seeds: vec![0],
        workloads: vec![wl.clone()],
        backends: vec!["backend:scalar".to_string()],
        c_b,
    };
    let cells = spec.run(|cell, ctx| {
        let tau =
            design_with_underlay(cell.kind, &ctx.dm, &ctx.net, spec.c_b)?.cycle_time_ms(&ctx.dm);
        Ok((cell.model_idx, cell.kind, tau))
    })?;
    let mut star = vec![f64::NAN; S_SWEEP.len()];
    let mut taus: Vec<Vec<(OverlayKind, f64)>> = vec![Vec::new(); S_SWEEP.len()];
    for (mi, kind, tau) in cells {
        if kind == OverlayKind::Star {
            star[mi] = tau;
        } else {
            taus[mi].push((kind, tau));
        }
    }
    Ok(S_SWEEP
        .iter()
        .zip(taus)
        .enumerate()
        .map(|(mi, (&s, kinds_tau))| {
            (
                s,
                kinds_tau
                    .into_iter()
                    .map(|(k, tau)| (k, star[mi] / tau))
                    .collect(),
            )
        })
        .collect())
}

pub fn run(network: &str, wl: &Workload, access_bps: f64, core_bps: f64, c_b: f64) -> Result<Table> {
    let data = sweep(network, wl, access_bps, core_bps, c_b)?;
    let mut t = Table::new(
        &format!("Fig 4: throughput speedup vs STAR on {network} ({} access)", access_bps / 1e9),
        &["s", "MATCHA+", "MST", "d-MBST", "RING"],
    );
    for (s, speedups) in &data {
        let mut cells = vec![s.to_string()];
        for k in KINDS {
            let v = speedups.iter().find(|(kk, _)| *kk == k).unwrap().1;
            cells.push(format!("{v:.2}x"));
        }
        t.row(cells);
    }
    t.note("paper: speedups shrink toward 1x as s·T_c dominates the delay");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedups_decay_with_s() {
        let data = sweep("exodus", &Workload::inaturalist(), 1e9, 1e9, 0.5).unwrap();
        let ring_at = |i: usize| {
            data[i]
                .1
                .iter()
                .find(|(k, _)| *k == OverlayKind::Ring)
                .unwrap()
                .1
        };
        assert!(ring_at(0) > ring_at(5), "{} !> {}", ring_at(0), ring_at(5));
        assert!(ring_at(5) >= 0.9, "never slower than STAR: {}", ring_at(5));
        assert!(ring_at(0) > 2.0, "s=1 ring speedup {}", ring_at(0));
    }
}
