//! Tables 3 / 6 / 7 / 9 — cycle time of the six overlays on each network.
//!
//! `fedtopo table3` reproduces the paper's Table 3 (iNaturalist, 1 Gbps
//! core, 10 Gbps access, s = 1); `table6`/`table7` change s to 5/10;
//! `table9` switches to Full-iNaturalist with 1 Gbps access. The optional
//! training-speedup columns re-run a fast proxy training per overlay to
//! measure rounds-to-target, then multiply by the cycle time (exactly the
//! paper's "training time = cycle time × #rounds" decomposition).

use super::sweep::{ModelAxis, SweepSpec};
use crate::fl::dpasgd::{run as train, DpasgdConfig, QuadraticTrainer};
use crate::fl::workloads::Workload;
use crate::netsim::underlay::Underlay;
use crate::topology::{design_with_underlay, OverlayKind};
use crate::util::table::Table;
use anyhow::Result;

/// One network's row of cycle times (ms), in Table-3 column order.
#[derive(Clone, Debug)]
pub struct CycleRow {
    pub network: String,
    pub silos: usize,
    pub links: usize,
    pub tau: Vec<(OverlayKind, f64)>,
}

impl CycleRow {
    pub fn tau_of(&self, kind: OverlayKind) -> f64 {
        self.tau
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, t)| *t)
            .unwrap_or(f64::NAN)
    }
}

/// Compute cycle times for all six overlays on one network.
pub fn cycle_row(
    network: &str,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
) -> Result<CycleRow> {
    let mut rows = cycle_rows(&[network], wl, s, access_bps, core_bps, c_b)?;
    Ok(rows.pop().expect("one network in, one row out"))
}

/// The full networks × `OverlayKind::all()` grid through the sweep engine
/// (cells run on the `--jobs` pool; values are bit-identical to the old
/// per-network loop for any worker count).
pub fn cycle_rows(
    networks: &[&str],
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
) -> Result<Vec<CycleRow>> {
    let spec = SweepSpec::new(
        networks.iter().map(|n| n.to_string()).collect(),
        OverlayKind::all().to_vec(),
        wl.clone(),
        ModelAxis {
            s,
            access_bps,
            core_bps,
        },
        c_b,
        0, // unused: every cell here is deterministic by construction
    );
    let cells = spec.run(|cell, ctx| {
        let overlay = design_with_underlay(cell.kind, &ctx.dm, &ctx.net, spec.c_b)?;
        Ok((
            cell.underlay_idx,
            cell.kind,
            overlay.cycle_time_ms(&ctx.dm),
            ctx.net.n_silos(),
            ctx.net.n_links(),
        ))
    })?;
    let mut rows: Vec<CycleRow> = networks
        .iter()
        .map(|n| CycleRow {
            network: n.to_string(),
            silos: 0,
            links: 0,
            tau: Vec::new(),
        })
        .collect();
    for (ui, kind, tau, silos, links) in cells {
        rows[ui].silos = silos;
        rows[ui].links = links;
        rows[ui].tau.push((kind, tau));
    }
    Ok(rows)
}

/// Proxy rounds-to-target for the training-speedup columns: DPASGD on the
/// closed-form quadratic objective (the paper's observation that rounds are
/// weakly topology-sensitive makes any convex proxy adequate here; the full
/// neural run is `fedtopo fig2`).
fn proxy_rounds(net: &Underlay, dm: &crate::netsim::delay::DelayModel, kind: OverlayKind, c_b: f64) -> Result<usize> {
    let overlay = design_with_underlay(kind, dm, net, c_b)?;
    let mut tr = QuadraticTrainer::new(net.n_silos(), 16, 11);
    let cfg = DpasgdConfig {
        rounds: 400,
        s: dm.s,
        eval_every: 2,
        ..Default::default()
    };
    let report = train(&mut tr, &overlay, &cfg)?;
    Ok(report.rounds_to_accuracy(0.60).unwrap_or(cfg.rounds))
}

/// Render the full table across networks.
pub fn run(
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    networks: &[&str],
    with_training: bool,
) -> Result<Table> {
    let mut header = vec![
        "Network", "Silos", "Links", "STAR", "MATCHA", "MATCHA+", "MST", "d-MBST", "RING",
        "Ring speedup vs STAR",
    ];
    if with_training {
        header.push("Ring TRAINING speedup vs STAR");
    }
    let mut t = Table::new(
        &format!(
            "Cycle time (ms): {} (M={:.2} Mbit), {} Gbps core, {} access, s={}",
            wl.name,
            wl.model_mbits(),
            core_bps / 1e9,
            human_bps(access_bps),
            s
        ),
        &header,
    );
    let rows = cycle_rows(networks, wl, s, access_bps, core_bps, c_b)?;
    for (name, row) in networks.iter().zip(&rows) {
        let star = row.tau_of(OverlayKind::Star);
        let ring = row.tau_of(OverlayKind::Ring);
        let mut cells = vec![
            row.network.clone(),
            row.silos.to_string(),
            row.links.to_string(),
        ];
        for kind in OverlayKind::all() {
            cells.push(format!("{:.0}", row.tau_of(kind)));
        }
        cells.push(format!("{:.2}x", star / ring));
        if with_training {
            let net = Underlay::builtin(name)?;
            let dm =
                crate::netsim::delay::DelayModel::new(&net, wl, s, access_bps, core_bps);
            let r_star = proxy_rounds(&net, &dm, OverlayKind::Star, c_b)? as f64;
            let r_ring = proxy_rounds(&net, &dm, OverlayKind::Ring, c_b)? as f64;
            cells.push(format!("{:.2}x", (star * r_star) / (ring * r_ring)));
        }
        t.row(cells);
    }
    t.note("paper Table 3 reference (10G access, s=1): Gaia ring 118 / star 391 (2.65x-3.3x); Ebone ring 95 / star 902 (8.8x)");
    Ok(t)
}

fn human_bps(bps: f64) -> String {
    if bps >= 1e9 {
        format!("{:.0} Gbps", bps / 1e9)
    } else {
        format!("{:.0} Mbps", bps / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_row_ordering_gaia() {
        let row = cycle_row("gaia", &Workload::inaturalist(), 1, 10e9, 1e9, 0.5).unwrap();
        let star = row.tau_of(OverlayKind::Star);
        let ring = row.tau_of(OverlayKind::Ring);
        let mst = row.tau_of(OverlayKind::Mst);
        assert!(ring < star, "ring {ring} < star {star}");
        assert!(mst < star);
        // paper: ring ≈ 118 ms on Gaia — our delay model should land in the
        // same decade (who-wins + rough magnitude, not absolute match)
        assert!(ring > 30.0 && ring < 400.0, "ring τ = {ring}");
    }

    #[test]
    fn table_renders_all_networks() {
        let t = run(
            &Workload::inaturalist(),
            1,
            10e9,
            1e9,
            0.5,
            &["gaia", "geant"],
            false,
        )
        .unwrap();
        let s = t.render();
        assert!(s.contains("gaia"));
        assert!(s.contains("geant"));
        assert!(s.contains("RING"));
    }

    #[test]
    fn s_grows_cycle_times_converge() {
        // Fig. 4 / Tables 6-7 effect: larger s makes overlays more similar.
        let r1 = cycle_row("geant", &Workload::inaturalist(), 1, 10e9, 1e9, 0.5).unwrap();
        let r10 = cycle_row("geant", &Workload::inaturalist(), 10, 10e9, 1e9, 0.5).unwrap();
        let spread = |r: &CycleRow| {
            r.tau_of(OverlayKind::Star) / r.tau_of(OverlayKind::Ring)
        };
        assert!(spread(&r10) < spread(&r1), "{} !< {}", spread(&r10), spread(&r1));
    }
}
