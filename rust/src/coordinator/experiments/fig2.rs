//! Fig. 2 — convergence vs communication rounds and vs wall-clock time.
//!
//! Trains the AOT-compiled MLP with DPASGD over four overlays on one
//! underlay (default AWS North America, 100 Mbps access — the paper's
//! setting) on a synthetic non-iid federated dataset, stamping each round
//! with its simulated wall-clock. The two views together are the paper's
//! core evidence: per-round convergence is weakly topology-sensitive, so
//! throughput (cycle time) decides training time.
//!
//! Since PR 4 the run routes through the coupled engine
//! ([`crate::fl::trainsim`]) under the identity scenario with re-design
//! disabled — the bespoke train-then-reconstruct loop is retired. The STAR
//! keeps its non-pipelined FedAvg closed form (`star_closed_form`), exactly
//! as the old `Overlay::wallclock_ms` replay did.
//!
//! Without artifacts (no `make artifacts` yet) it falls back to the
//! closed-form quadratic trainer and says so.

use crate::coordinator::leader::ExperimentReport;
#[cfg(feature = "xla")]
use crate::fl::data::{DataConfig, FedDataset};
use crate::fl::dpasgd::{LocalTrainer, QuadraticTrainer};
use crate::fl::trainsim::{self, TrainSimConfig};
use crate::fl::workloads::Workload;
use crate::netsim::delay::DelayModel;
use crate::netsim::scenario::Scenario;
use crate::netsim::underlay::Underlay;
#[cfg(feature = "xla")]
use crate::runtime::client::XlaRuntime;
use crate::runtime::manifest::Manifest;
#[cfg(feature = "xla")]
use crate::runtime::trainer::XlaTrainer;
use crate::topology::OverlayKind;
use crate::util::table::Table;
use anyhow::Result;

const KINDS: [OverlayKind; 4] = [
    OverlayKind::Star,
    OverlayKind::MatchaPlus,
    OverlayKind::Mst,
    OverlayKind::Ring,
];

pub struct Fig2Config {
    pub network: String,
    pub workload: Workload,
    pub access_bps: f64,
    pub core_bps: f64,
    pub rounds: usize,
    pub s: usize,
    pub c_b: f64,
    pub seed: u64,
    pub lr: f32,
    /// force the quadratic fallback even when artifacts exist.
    pub force_proxy: bool,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Fig2Config {
            network: "aws-na".to_string(),
            workload: Workload::inaturalist(),
            access_bps: 100e6,
            core_bps: 1e9,
            rounds: 100,
            s: 1,
            c_b: 0.5,
            seed: 7,
            lr: 0.1,
            force_proxy: false,
        }
    }
}

/// One overlay through the coupled engine; identity scenario, re-design
/// off, STAR timed with the FedAvg closed form — the Fig.-2 setting.
fn run_one(
    trainer: &mut dyn LocalTrainer,
    kind: OverlayKind,
    dm: &DelayModel,
    net: &Underlay,
    cfg: &Fig2Config,
) -> Result<ExperimentReport> {
    let tcfg = TrainSimConfig {
        rounds: cfg.rounds,
        s: cfg.s,
        seed: cfg.seed,
        eval_every: (cfg.rounds / 10).max(1),
        ring_half_weights: false,
        c_b: cfg.c_b,
        star_closed_form: true,
        ..Default::default()
    };
    let rep = trainsim::run(trainer, kind, dm, net, &Scenario::identity(), &tcfg)?;
    Ok(ExperimentReport {
        overlay: kind.name().to_string(),
        cycle_time_ms: rep.lambda_star_ms(),
        wallclock_ms: rep.completion_ms,
        train: rep.train,
    })
}

/// Run all four overlays; returns one report per overlay.
pub fn run_all(cfg: &Fig2Config) -> Result<Vec<ExperimentReport>> {
    let net = Underlay::builtin(&cfg.network)?;
    let dm = DelayModel::new(&net, &cfg.workload, cfg.s, cfg.access_bps, cfg.core_bps);
    let n = net.n_silos();

    let artifacts = Manifest::default_dir();
    let use_xla = cfg!(feature = "xla")
        && !cfg.force_proxy
        && artifacts.join("manifest.json").exists();
    #[cfg(feature = "xla")]
    let mut rt = if use_xla { Some(XlaRuntime::cpu()?) } else { None };
    #[cfg(feature = "xla")]
    let manifest = use_xla.then(|| Manifest::load(&artifacts)).transpose()?;
    if !use_xla {
        crate::warn_!("no artifacts found (or `xla` feature off) — falling back to the quadratic proxy trainer (run `make artifacts` + build with --features xla for the real model)");
    }

    let mut reports = Vec::new();
    for kind in KINDS {
        #[cfg(feature = "xla")]
        let report = if let (Some(rt), Some(manifest)) = (rt.as_mut(), manifest.as_ref()) {
            let data = FedDataset::synthesize(&DataConfig {
                num_silos: n,
                dim: 64,
                num_classes: 10,
                seed: cfg.seed, // same data for every overlay
                ..DataConfig::default()
            });
            let mut trainer = XlaTrainer::new(rt, manifest, "mlp", data, cfg.lr)?;
            let rep = run_one(&mut trainer, kind, &dm, &net, cfg)?;
            crate::info!(
                "{}: mean PJRT step {:.2} ms over {} steps",
                kind.name(),
                trainer.mean_step_ms(),
                trainer.steps_run
            );
            rep
        } else {
            let mut trainer = QuadraticTrainer::new(n, 32, cfg.seed);
            run_one(&mut trainer, kind, &dm, &net, cfg)?
        };
        #[cfg(not(feature = "xla"))]
        let report = {
            let mut trainer = QuadraticTrainer::new(n, 32, cfg.seed);
            run_one(&mut trainer, kind, &dm, &net, cfg)?
        };
        reports.push(report);
    }
    Ok(reports)
}

/// Render the two Fig.-2 views as tables (rounds view + wall-clock view).
pub fn render(reports: &[ExperimentReport], rounds: usize) -> (Table, Table) {
    let checkpoints: Vec<usize> = (0..=10).map(|i| i * rounds / 10).collect();

    let mut by_round = Table::new(
        "Fig 2 (top): train loss vs communication round",
        &["Round", "STAR", "MATCHA+", "MST", "RING"],
    );
    for &k in &checkpoints {
        if k == 0 {
            continue;
        }
        let mut cells = vec![k.to_string()];
        for r in reports {
            cells.push(format!("{:.4}", r.train.records[k - 1].train_loss));
        }
        by_round.row(cells);
    }

    let mut by_time = Table::new(
        "Fig 2 (bottom): simulated wall-clock (s) to reach each round",
        &["Round", "STAR", "MATCHA+", "MST", "RING"],
    );
    for &k in &checkpoints {
        if k == 0 {
            continue;
        }
        let mut cells = vec![k.to_string()];
        for r in reports {
            cells.push(format!("{:.1}", r.wallclock_ms[k] / 1e3));
        }
        by_time.row(cells);
    }
    by_time.note("same losses per round, ~cycle-time-ratio faster in wall-clock — the paper's central claim");
    (by_round, by_time)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proxy_fig2_shows_throughput_separation() {
        let cfg = Fig2Config {
            rounds: 60,
            force_proxy: true,
            network: "gaia".to_string(),
            ..Default::default()
        };
        let reports = run_all(&cfg).unwrap();
        assert_eq!(reports.len(), 4);
        // losses comparable at final round: every overlay converges well
        // below its starting loss (the quadratic proxy's per-topology
        // steady-state floors differ more than neural nets' do, so the
        // cross-overlay comparison is loose here; `fedtopo fig2` with
        // artifacts runs the real MLP).
        let finals: Vec<f32> = reports.iter().map(|r| r.train.final_train_loss()).collect();
        for (r, &f) in reports.iter().zip(&finals) {
            let start = r.train.records[0].train_loss;
            assert!(f < 0.2 * start, "{}: {start} → {f}", r.overlay);
        }
        // but wall-clock separated: STAR slowest, RING fastest
        let star_t = reports[0].wallclock_ms[60];
        let ring_t = reports[3].wallclock_ms[60];
        assert!(
            ring_t < 0.7 * star_t,
            "ring {ring_t} ms vs star {star_t} ms"
        );
        let (a, b) = render(&reports, 60);
        assert!(a.render().contains("Round"));
        assert!(b.render().contains("wall-clock"));
    }
}
