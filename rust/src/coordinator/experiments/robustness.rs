//! `fedtopo robustness` — static vs adaptive designers under dynamic
//! network scenarios.
//!
//! For every requested [`OverlayKind`], run the same scenario stream twice
//! through the [`crate::topology::adaptive`] loop — once with re-design
//! disabled (the static overlay the paper would deploy) and once with the
//! monitor armed — and report time-to-round-R for both, as JSON (the
//! primary, machine-readable output) and optionally as a table.
//!
//! The headline configuration is `--network gaia --scenario
//! scenario:straggler:3:x10`: three silos slow down 10× mid-deployment; the
//! statically designed trees keep routing through them while the adaptive
//! loop re-measures, pushes the stragglers to the leaves, and re-converges
//! to the compute floor.
//!
//! `--actions design,reroute` adds a third arm per cell that reacts by
//! re-solving the underlay routes instead of the overlay
//! ([`AdaptiveAction::Reroute`], SmartFLow's layer), and each row then
//! reports which action won. `--backends` runs the whole comparison under a
//! message-level communication backend (`backend:grpc`, `backend:rdma`, …);
//! both default to the pre-existing report shape (`design` only,
//! `backend:scalar`) byte for byte.

use super::sweep::{ModelAxis, SweepSpec};
use crate::fl::workloads::Workload;
use crate::netsim::backend;
use crate::netsim::scenario::Scenario;
use crate::topology::adaptive::{run_adaptive, AdaptiveAction, AdaptiveConfig};
use crate::topology::OverlayKind;
use crate::util::json::Json;
use crate::util::parallel::par_map_indexed;
use crate::util::table::Table;
use anyhow::Result;

/// Full configuration of one robustness run.
#[derive(Clone, Debug)]
pub struct RobustnessConfig {
    pub network: String,
    pub workload: Workload,
    pub s: usize,
    pub access_bps: f64,
    pub core_bps: f64,
    pub c_b: f64,
    pub scenario: String,
    pub rounds: usize,
    pub window: usize,
    pub threshold: f64,
    pub seed: u64,
    pub kinds: Vec<OverlayKind>,
    /// Communication backends to run the comparison under (a sweep axis;
    /// one row per backend × kind). `["backend:scalar"]` reproduces the
    /// pre-backend report byte for byte.
    pub backends: Vec<String>,
    /// Also run the SmartFLow-style re-route arm and report which action
    /// wins per row. The re-design arm always runs — it is the experiment's
    /// subject; `false` keeps the two-arm report shape unchanged.
    pub reroute: bool,
}

/// One designer's static-vs-adaptive outcome.
#[derive(Clone, Debug)]
pub struct RobustnessRow {
    pub kind: OverlayKind,
    /// Canonical backend spec this row ran under (`backend:scalar`, …).
    pub backend: String,
    /// Cycle time the initial (base-model) design promised, ms.
    pub designed_tau_ms: f64,
    /// Time-to-round-R of the static overlay under the scenario, ms.
    pub static_ms: f64,
    /// Time-to-round-R of the adaptive (re-design) loop, ms.
    pub adaptive_ms: f64,
    /// Rounds at which the adaptive loop re-designed.
    pub redesign_rounds: Vec<usize>,
    /// Time-to-round-R of the re-route arm, when requested.
    pub reroute_ms: Option<f64>,
    /// Rounds at which the re-route arm re-solved the routes.
    pub reroute_rounds: Vec<usize>,
}

impl RobustnessRow {
    pub fn speedup(&self) -> f64 {
        self.static_ms / self.adaptive_ms.max(1e-9)
    }

    pub fn adaptive_beats_static(&self) -> bool {
        self.adaptive_ms < self.static_ms
    }

    /// Which arm finished round R first (ties go to the cheaper action:
    /// static beats both reactions, re-design beats re-route only by
    /// strictly finishing earlier).
    pub fn best_action(&self) -> &'static str {
        match self.reroute_ms {
            Some(rr) if rr < self.adaptive_ms && rr < self.static_ms => "reroute",
            _ if self.adaptive_ms < self.static_ms => "design",
            _ => "static",
        }
    }
}

/// Run the experiment: one row per backend × overlay kind, through the
/// sweep engine.
///
/// The (backends × kinds) axes are the grid; inside each cell the static
/// and the adaptive **timelines are replicated onto pool workers** (two, or
/// three with the re-route arm; ordered merge — the deterministic pool runs
/// nested calls sequentially when the outer grid already saturates it). All
/// cells share `base_seed` deliberately (common random numbers: every kind
/// and every arm faces the *same* scenario realization, so rows compare
/// designers and actions, not noise, and a kind's row does not depend on
/// which other kinds were requested). Each cell still builds its own
/// process from that seed — no RNG state is ever shared across cells, which
/// is what the determinism contract actually requires.
pub fn run(cfg: &RobustnessConfig) -> Result<Vec<RobustnessRow>> {
    let spec = SweepSpec {
        underlays: vec![cfg.network.clone()],
        models: vec![ModelAxis {
            s: cfg.s,
            access_bps: cfg.access_bps,
            core_bps: cfg.core_bps,
        }],
        kinds: cfg.kinds.clone(),
        scenarios: vec![cfg.scenario.clone()],
        seeds: vec![cfg.seed],
        workloads: vec![cfg.workload.clone()],
        backends: cfg.backends.clone(),
        c_b: cfg.c_b,
    };
    spec.run(|cell, ctx| {
        let scenario = Scenario::by_name(&cell.scenario)?;
        let acfg = AdaptiveConfig {
            window: cfg.window,
            threshold: cfg.threshold,
            c_b: cfg.c_b,
            seed: cell.base_seed,
            action: AdaptiveAction::Redesign,
        };
        let mut arms = vec![acfg.static_baseline(), acfg.clone()];
        if cfg.reroute {
            arms.push(AdaptiveConfig {
                action: AdaptiveAction::Reroute,
                ..acfg.clone()
            });
        }
        let mut runs = par_map_indexed(&arms, |_, arm| {
            run_adaptive(cell.kind, &ctx.dm, &ctx.net, &scenario, cfg.rounds, arm)
        })
        .into_iter();
        let stat = runs.next().expect("static arm")?;
        let adaptive = runs.next().expect("re-design arm")?;
        let reroute = runs.next().transpose()?;
        Ok(RobustnessRow {
            kind: cell.kind,
            backend: cell.backend.clone(),
            designed_tau_ms: stat.designed_tau_ms[0],
            static_ms: stat.total_ms(),
            adaptive_ms: adaptive.total_ms(),
            redesign_rounds: adaptive.redesign_rounds,
            reroute_ms: reroute.as_ref().map(|r| r.total_ms()),
            reroute_rounds: reroute.map(|r| r.redesign_rounds).unwrap_or_default(),
        })
    })
}

/// Serialize a run to the machine-readable report. The backend and action
/// fields appear only when the run asked for a non-default backend axis or
/// the re-route arm — a default run's JSON is byte-identical to the
/// pre-backend report.
pub fn to_json(cfg: &RobustnessConfig, rows: &[RobustnessRow]) -> Json {
    let default_backend = backend::axis_is_default(&cfg.backends);
    let overlays = rows.iter().map(|r| {
        let mut f = vec![("overlay", Json::str(r.kind.name()))];
        if !default_backend {
            f.push(("backend", Json::str(&r.backend)));
        }
        f.extend([
            ("designed_tau_ms", Json::num(r.designed_tau_ms)),
            ("static_ms", Json::num(r.static_ms)),
            ("adaptive_ms", Json::num(r.adaptive_ms)),
            ("speedup", Json::num(r.speedup())),
            (
                "redesign_rounds",
                Json::arr(r.redesign_rounds.iter().map(|&k| Json::num(k as f64))),
            ),
            ("adaptive_beats_static", Json::Bool(r.adaptive_beats_static())),
        ]);
        if let Some(rr) = r.reroute_ms {
            f.push(("reroute_ms", Json::num(rr)));
            f.push((
                "reroute_rounds",
                Json::arr(r.reroute_rounds.iter().map(|&k| Json::num(k as f64))),
            ));
            f.push(("best_action", Json::str(r.best_action())));
        }
        Json::obj(f)
    });
    let best = rows
        .iter()
        .max_by(|a, b| a.speedup().partial_cmp(&b.speedup()).unwrap());
    let mut fields = vec![
        ("experiment", Json::str("robustness")),
        ("network", Json::str(&cfg.network)),
        ("scenario", Json::str(&cfg.scenario)),
        ("workload", Json::str(cfg.workload.name)),
        ("s", Json::num(cfg.s as f64)),
        ("access_bps", Json::num(cfg.access_bps)),
        ("core_bps", Json::num(cfg.core_bps)),
        ("cb", Json::num(cfg.c_b)),
        ("rounds", Json::num(cfg.rounds as f64)),
        ("window", Json::num(cfg.window as f64)),
        ("threshold", Json::num(cfg.threshold)),
        ("seed", Json::num(cfg.seed as f64)),
    ];
    if !default_backend {
        fields.push((
            "backends",
            Json::arr(cfg.backends.iter().map(|b| Json::str(b))),
        ));
    }
    if cfg.reroute {
        fields.push((
            "actions",
            Json::arr(["design", "reroute"].iter().map(|a| Json::str(a))),
        ));
    }
    fields.push(("overlays", Json::arr(overlays)));
    if let Some(b) = best {
        fields.push((
            "best",
            Json::obj(vec![
                ("overlay", Json::str(b.kind.name())),
                ("speedup", Json::num(b.speedup())),
            ]),
        ));
    }
    Json::obj(fields)
}

/// Human-readable rendering of the same rows. Backend / re-route columns
/// appear only when the run asked for them.
pub fn to_table(cfg: &RobustnessConfig, rows: &[RobustnessRow]) -> Table {
    let default_backend = backend::axis_is_default(&cfg.backends);
    let mut headers = vec!["Overlay"];
    if !default_backend {
        headers.push("Backend");
    }
    headers.extend([
        "designed τ (ms)",
        "static t_R (s)",
        "adaptive t_R (s)",
        "speedup",
        "re-designs",
    ]);
    if cfg.reroute {
        headers.extend(["reroute t_R (s)", "best action"]);
    }
    let mut t = Table::new(
        &format!(
            "Robustness on {} under {} (R={}, window={}, threshold={})",
            cfg.network, cfg.scenario, cfg.rounds, cfg.window, cfg.threshold
        ),
        &headers,
    );
    for r in rows {
        let mut row = vec![r.kind.name().to_string()];
        if !default_backend {
            row.push(r.backend.clone());
        }
        row.extend([
            format!("{:.1}", r.designed_tau_ms),
            format!("{:.1}", r.static_ms / 1e3),
            format!("{:.1}", r.adaptive_ms / 1e3),
            format!("{:.2}x", r.speedup()),
            format!("{:?}", r.redesign_rounds),
        ]);
        if cfg.reroute {
            match r.reroute_ms {
                Some(v) => row.push(format!("{:.1}", v / 1e3)),
                None => row.push("-".to_string()),
            }
            row.push(r.best_action().to_string());
        }
        t.row(row);
    }
    t.note(
        "static = same loop with the re-design threshold at ∞; both arms share \
         the scenario stream and the Eq.-(4) recurrence",
    );
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(scenario: &str, kinds: Vec<OverlayKind>) -> RobustnessConfig {
        RobustnessConfig {
            network: "gaia".to_string(),
            workload: Workload::inaturalist(),
            s: 1,
            access_bps: 10e9,
            core_bps: 1e9,
            c_b: 0.5,
            scenario: scenario.to_string(),
            rounds: 120,
            window: 20,
            threshold: 1.3,
            seed: 7,
            kinds,
            backends: vec!["backend:scalar".to_string()],
            reroute: false,
        }
    }

    #[test]
    fn acceptance_straggler_adaptive_beats_static_on_gaia() {
        // ISSUE-2 acceptance: `fedtopo robustness --network gaia --scenario
        // scenario:straggler:3:x10` must report the adaptive designer
        // beating the static overlay on time-to-round-R. MST is the provable
        // case: the base design routes through a straggler–straggler edge
        // (τ ≈ 433 ms) that the re-design removes (τ' ≈ 254 ms, the compute
        // floor). δ-MBST rides along with a no-worse guarantee — its base
        // winner can be the degree-2 ham-path, whose degraded rate may
        // already sit at the floor.
        let cfg = cfg(
            "scenario:straggler:3:x10",
            vec![OverlayKind::Mst, OverlayKind::DeltaMbst],
        );
        let rows = run(&cfg).unwrap();
        let mst = &rows[0];
        assert!(
            mst.adaptive_ms < 0.9 * mst.static_ms,
            "mst: adaptive {} vs static {}",
            mst.adaptive_ms,
            mst.static_ms
        );
        assert!(!mst.redesign_rounds.is_empty(), "mst never re-designed");
        let mbst = &rows[1];
        assert!(
            mbst.adaptive_ms <= mbst.static_ms * 1.001,
            "delta-mbst: adaptive {} worse than static {}",
            mbst.adaptive_ms,
            mbst.static_ms
        );
        let json = to_json(&cfg, &rows).to_string();
        assert!(json.contains("\"adaptive_beats_static\":true"));
        assert!(json.contains("\"scenario\":\"scenario:straggler:3:x10\""));
        // the report round-trips through the JSON parser
        let v = Json::parse(&json).unwrap();
        assert_eq!(v.get("network").as_str(), Some("gaia"));
        assert_eq!(v.get("overlays").as_arr().unwrap().len(), rows.len());
    }

    #[test]
    fn identity_scenario_is_a_tie_for_static_kinds() {
        let cfg = cfg("scenario:identity", vec![OverlayKind::Ring]);
        let rows = run(&cfg).unwrap();
        assert_eq!(rows[0].redesign_rounds, Vec::<usize>::new());
        assert_eq!(
            rows[0].static_ms.to_bits(),
            rows[0].adaptive_ms.to_bits(),
            "identity: both arms must realize the identical trajectory"
        );
    }

    #[test]
    fn table_renders_all_kinds() {
        let cfg = cfg("scenario:congestion:30:x4", OverlayKind::all().to_vec());
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 6);
        let s = to_table(&cfg, &rows).render();
        assert!(s.contains("matcha+"));
        assert!(s.contains("speedup"));
        // default run: no backend / re-route columns, no backend JSON fields
        assert!(!s.contains("Backend"));
        let json = to_json(&cfg, &rows).to_string();
        assert!(!json.contains("\"backend"));
        assert!(!json.contains("\"reroute_ms\""));
        assert!(!json.contains("\"actions\""));
    }

    #[test]
    fn reroute_arm_reports_and_redesign_wins_on_straggler() {
        // Under the spatially uniform builtin scenarios re-routing solves
        // the same shortest paths again, so its arm realizes the static
        // trajectory exactly — the report must show re-design winning, and
        // the re-route total matching static bit for bit (the documented
        // negative result).
        let mut c = cfg("scenario:straggler:3:x10", vec![OverlayKind::Mst]);
        c.reroute = true;
        let rows = run(&c).unwrap();
        let r = &rows[0];
        let rr = r.reroute_ms.expect("re-route arm must run");
        assert_eq!(rr.to_bits(), r.static_ms.to_bits());
        assert!(!r.reroute_rounds.is_empty(), "monitor must fire in the arm");
        assert_eq!(r.best_action(), "design");
        let json = to_json(&c, &rows).to_string();
        assert!(json.contains("\"actions\":[\"design\",\"reroute\"]"));
        assert!(json.contains("\"best_action\":\"design\""));
        let table = to_table(&c, &rows).render();
        assert!(table.contains("best action"));
    }

    #[test]
    fn backend_axis_adds_rows_and_labels_them() {
        let mut c = cfg("scenario:identity", vec![OverlayKind::Mst, OverlayKind::Ring]);
        c.backends = vec!["backend:scalar".to_string(), "backend:grpc".to_string()];
        let rows = run(&c).unwrap();
        assert_eq!(rows.len(), 4, "2 backends × 2 kinds");
        assert_eq!(rows[0].backend, "backend:scalar");
        assert_eq!(rows[2].backend, "backend:grpc");
        assert_eq!(rows[0].kind, rows[2].kind);
        // the per-message overhead slows every arm down
        assert!(rows[2].static_ms > rows[0].static_ms);
        let json = to_json(&c, &rows).to_string();
        assert!(json.contains("\"backends\":[\"backend:scalar\",\"backend:grpc\"]"));
        assert!(json.contains("\"backend\":\"backend:grpc\""));
        let table = to_table(&c, &rows).render();
        assert!(table.contains("Backend"));
    }
}
