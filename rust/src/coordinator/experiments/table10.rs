//! Table 10 — RING speedup vs MATCHA across communication budgets C_b.
//!
//! MATCHA runs on three base graphs (the underlay, the δ-MBST tree, the
//! undirected RING) with C_b ∈ {0.1 … 1.0}, at 10 Gbps and 100 Mbps access.
//! The paper's conclusion: no C_b choice lets MATCHA beat the directed RING
//! (Géant's MST corner aside).

use crate::fl::workloads::Workload;
use crate::graph::UnGraph;
use crate::netsim::delay::DelayModel;
use crate::netsim::underlay::Underlay;
use crate::topology::matcha::MatchaOverlay;
use crate::topology::{design_with_underlay, mbst, ring, OverlayKind};
use crate::util::table::Table;
use anyhow::Result;

pub const CB_SWEEP: [f64; 7] = [1.0, 0.8, 0.6, 0.5, 0.4, 0.2, 0.1];

/// The three MATCHA base graphs of Table 10.
fn base_graphs(net: &Underlay, dm: &DelayModel) -> Vec<(&'static str, UnGraph)> {
    let tree = mbst::design_named(dm).1;
    // undirected version of the ring (MATCHA uses bidirectional matchings)
    let ring_digraph = ring::design(dm, false);
    let mut ring_un = UnGraph::new(dm.n);
    for (u, v, _) in ring_digraph.edges() {
        if !ring_un.has_edge(u, v) {
            ring_un.add_edge(u, v, 1.0);
        }
    }
    vec![
        ("MATCHA over underlay", net.core.clone()),
        ("MATCHA over d-MBST", tree),
        ("MATCHA over RING", ring_un),
    ]
}

/// RING-speedup-vs-MATCHA rows for one access capacity.
pub fn speedup_rows(
    network: &str,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
) -> Result<Vec<(String, Vec<f64>)>> {
    let net = Underlay::builtin(network)?;
    let dm = DelayModel::new(&net, wl, s, access_bps, core_bps);
    let ring_tau = design_with_underlay(OverlayKind::Ring, &dm, &net, 0.5)?
        .cycle_time_ms(&dm);
    let mut rows = Vec::new();
    for (label, base) in base_graphs(&net, &dm) {
        let mut speedups = Vec::new();
        for &cb in &CB_SWEEP {
            let m = MatchaOverlay::over_graph(&base, cb);
            let tau = m.average_cycle_time_ms(&dm, 600, 0xAB1E);
            speedups.push(tau / ring_tau);
        }
        rows.push((label.to_string(), speedups));
    }
    Ok(rows)
}

pub fn run(network: &str, wl: &Workload, s: usize, core_bps: f64) -> Result<Table> {
    let mut t = Table::new(
        &format!("Table 10: RING speedup vs MATCHA on {network} (rows ×2 access capacities)"),
        &[
            "Base graph / C_b", "1.0", "0.8", "0.6", "0.5", "0.4", "0.2", "0.1",
        ],
    );
    for (access, tag) in [(10e9, "10G"), (100e6, "100M")] {
        for (label, speedups) in speedup_rows(network, wl, s, access, core_bps)? {
            let mut cells = vec![format!("[{tag}] {label}")];
            cells.extend(speedups.iter().map(|v| format!("{v:.2}x")));
            t.row(cells);
        }
    }
    t.note("values are τ_MATCHA / τ_RING — >1 means the RING wins (paper: RING wins everywhere on AWS-NA)");
    t.note("sparse-base MATCHA with tiny C_b trades communication for cycle time; the paper's training-speedup metric charges the extra rounds that saves");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_beats_matcha_at_slow_access() {
        let rows =
            speedup_rows("aws-na", &Workload::inaturalist(), 1, 100e6, 1e9).unwrap();
        // over the underlay, every C_b leaves MATCHA slower than RING
        let (label, speedups) = &rows[0];
        assert!(label.contains("underlay"));
        for (cb, sp) in CB_SWEEP.iter().zip(speedups) {
            assert!(*sp > 1.0, "C_b={cb}: speedup {sp} ≤ 1");
        }
    }

    #[test]
    fn lower_cb_narrows_gap() {
        let rows =
            speedup_rows("aws-na", &Workload::inaturalist(), 1, 100e6, 1e9).unwrap();
        let speedups = &rows[0].1;
        // C_b=1.0 (all matchings) is worse for MATCHA than C_b=0.2
        assert!(speedups[0] > speedups[5], "{speedups:?}");
    }
}
