//! Fig. 3 — effect of access link capacity on cycle time (Géant).
//!
//! * `fig3a`: all access links swept together from 10 Mbps to 10 Gbps.
//! * `fig3b`: the STAR hub keeps a fixed 10 Gbps link while the others are
//!   swept (the heterogeneous setting where the STAR partially recovers).

use super::sweep::{ModelAxis, SweepSpec};
use crate::fl::workloads::Workload;
use crate::topology::{design_with_underlay, star, OverlayKind};
use crate::util::table::Table;
use anyhow::Result;

pub const SWEEP_BPS: [f64; 7] = [10e6, 100e6, 500e6, 1e9, 2e9, 6e9, 10e9];

const KINDS: [OverlayKind; 5] = [
    OverlayKind::Star,
    OverlayKind::MatchaPlus,
    OverlayKind::Mst,
    OverlayKind::DeltaMbst,
    OverlayKind::Ring,
];

/// One sweep point: capacity → cycle time per overlay kind. The
/// (capacity × designer) grid is the [`SweepSpec`] model axis, run on the
/// `--jobs` pool; the Fig.-3b hub override is applied per cell on a clone
/// of the shared model (hub chosen from the unmodified per-capacity model,
/// exactly as the old sequential loop did).
pub fn sweep(
    network: &str,
    wl: &Workload,
    s: usize,
    core_bps: f64,
    c_b: f64,
    hub_fixed_bps: Option<f64>,
) -> Result<Vec<(f64, Vec<(OverlayKind, f64)>)>> {
    let spec = SweepSpec {
        underlays: vec![network.to_string()],
        models: SWEEP_BPS
            .iter()
            .map(|&access_bps| ModelAxis {
                s,
                access_bps,
                core_bps,
            })
            .collect(),
        kinds: KINDS.to_vec(),
        scenarios: vec!["scenario:identity".to_string()],
        seeds: vec![0],
        workloads: vec![wl.clone()],
        backends: vec!["backend:scalar".to_string()],
        c_b,
    };
    let cells = spec.run(|cell, ctx| {
        let tau = if let Some(hub_bps) = hub_fixed_bps {
            let mut dm = ctx.dm.clone();
            let hub = star::choose_hub(&dm);
            dm.set_access(hub, hub_bps, hub_bps);
            design_with_underlay(cell.kind, &dm, &ctx.net, spec.c_b)?.cycle_time_ms(&dm)
        } else {
            design_with_underlay(cell.kind, &ctx.dm, &ctx.net, spec.c_b)?
                .cycle_time_ms(&ctx.dm)
        };
        Ok((cell.model_idx, cell.kind, tau))
    })?;
    let mut out: Vec<(f64, Vec<(OverlayKind, f64)>)> =
        SWEEP_BPS.iter().map(|&a| (a, Vec::new())).collect();
    for (mi, kind, tau) in cells {
        out[mi].1.push((kind, tau));
    }
    Ok(out)
}

pub fn run(network: &str, wl: &Workload, s: usize, core_bps: f64, c_b: f64, variant_b: bool) -> Result<Table> {
    let hub = variant_b.then_some(10e9);
    let data = sweep(network, wl, s, core_bps, c_b, hub)?;
    let title = if variant_b {
        format!("Fig 3b: cycle time vs access capacity on {network} (hub fixed at 10 Gbps)")
    } else {
        format!("Fig 3a: cycle time vs access capacity on {network}")
    };
    let mut t = Table::new(
        &title,
        &["Access", "STAR", "MATCHA+", "MST", "d-MBST", "RING", "RING speedup vs STAR"],
    );
    for (access, taus) in &data {
        let get = |k: OverlayKind| taus.iter().find(|(kk, _)| *kk == k).unwrap().1;
        let mut cells = vec![if *access >= 1e9 {
            format!("{:.0}G", access / 1e9)
        } else {
            format!("{:.0}M", access / 1e6)
        }];
        for k in KINDS {
            cells.push(format!("{:.0}", get(k)));
        }
        cells.push(format!("{:.1}x", get(OverlayKind::Star) / get(OverlayKind::Ring)));
        t.row(cells);
    }
    t.note("paper: RING leads below ~6 Gbps; with the hub kept fast the STAR recovers to ~2x of RING (Fig 3b)");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_advantage_grows_as_access_shrinks() {
        let data = sweep("geant", &Workload::inaturalist(), 1, 1e9, 0.5, None).unwrap();
        let speedup = |point: &(f64, Vec<(OverlayKind, f64)>)| {
            let get = |k: OverlayKind| point.1.iter().find(|(kk, _)| *kk == k).unwrap().1;
            get(OverlayKind::Star) / get(OverlayKind::Ring)
        };
        let slow = speedup(&data[0]); // 10 Mbps
        let fast = speedup(&data[data.len() - 1]); // 10 Gbps
        assert!(
            slow > 2.0 * fast,
            "speedup should grow as access slows: slow={slow} fast={fast}"
        );
        // App. B: slow-access speedup approaches 2N (= 80 on Géant)
        assert!(slow > 10.0, "slow-access speedup {slow}");
    }

    #[test]
    fn hub_fix_helps_star() {
        let plain = sweep("geant", &Workload::inaturalist(), 1, 1e9, 0.5, None).unwrap();
        let fixed =
            sweep("geant", &Workload::inaturalist(), 1, 1e9, 0.5, Some(10e9)).unwrap();
        // at 100 Mbps access the fixed-hub STAR must be faster than plain
        let star_at = |d: &[(f64, Vec<(OverlayKind, f64)>)], i: usize| {
            d[i].1
                .iter()
                .find(|(k, _)| *k == OverlayKind::Star)
                .unwrap()
                .1
        };
        assert!(star_at(&fixed, 1) < star_at(&plain, 1));
    }

    #[test]
    fn table_renders() {
        let t = run("geant", &Workload::inaturalist(), 1, 1e9, 0.5, false).unwrap();
        assert!(t.render().contains("10M"));
    }
}
