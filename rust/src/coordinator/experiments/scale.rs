//! `fedtopo scale` — designer τ and solver wall-time vs N on synthetic
//! underlays.
//!
//! The paper stops at 87 silos; this sweep drives every `OverlayKind`
//! across seeded synthetic underlays (see [`crate::netsim::synth`]) of
//! growing size and reports, per (family, N):
//!
//! * cycle time τ of each designed overlay (ms) — do Table 3's orderings
//!   survive at scale?
//! * total design+evaluate wall-time per overlay kind (ms);
//! * Karp vs Howard wall-time on the RING delay digraph, the head-to-head
//!   behind the [`crate::maxplus::HOWARD_MIN_N`] dispatch threshold.
//!
//! The (size × designer) grid routes through [`SweepSpec`], so cells run on
//! the `--jobs` pool. The machine-readable report ([`to_json`]) contains
//! **only deterministic fields** (τ, N, links — never wall-clock timings):
//! CI's determinism job byte-compares it across `--jobs 1` and `--jobs 4`,
//! including the PR-5 large-N smoke (`--networks synth:ba:2000`).
//!
//! PR 5: the sweep is really over underlay *specs* ([`sweep_rows_specs`] —
//! `fedtopo scale --networks synth:ba:2000,gaia` takes arbitrary
//! `Underlay::by_name` names), `--family/--sizes` being the convenience
//! spelling; with the flat graph core the sizes may go to 20 000+ silos,
//! where Karp's Θ(V²) tables are skipped ([`KARP_BENCH_MAX_N`]) and only
//! the sparse Howard side of the head-to-head is timed.

use super::sweep::{ModelAxis, SweepSpec};
use crate::fl::workloads::Workload;
use crate::maxplus::{cycle_time_with, CycleSolver};
use crate::topology::{design_with_underlay, OverlayKind};
use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::Result;
use std::time::Instant;

/// Largest N on which the Karp side of the solver head-to-head is timed:
/// Karp allocates Θ(V²) walk tables (~134 MB of f64 at 4096 nodes, 3+ GB
/// at 20 000), so past this the diagnostic reports only Howard and renders
/// the Karp column `n/a`. Never part of the deterministic JSON.
pub const KARP_BENCH_MAX_N: usize = 4096;

/// One (family, N) measurement — per backend when `--backends` names more
/// than one.
#[derive(Clone, Debug)]
pub struct ScaleRow {
    pub spec: String,
    /// Backend spec this row's delays were priced under (as requested on
    /// the axis; `backend:scalar` on every pre-backend path).
    pub backend: String,
    pub n: usize,
    pub links: usize,
    /// (kind, τ ms, design+evaluate wall ms)
    pub overlays: Vec<(OverlayKind, f64, f64)>,
    /// Karp wall-time on the RING delay digraph, ms.
    pub karp_ms: f64,
    /// Howard wall-time on the same digraph, ms.
    pub howard_ms: f64,
}

impl ScaleRow {
    pub fn tau_of(&self, kind: OverlayKind) -> f64 {
        self.overlays
            .iter()
            .find(|(k, _, _)| *k == kind)
            .map(|(_, t, _)| *t)
            .unwrap_or(f64::NAN)
    }

    pub fn solver_speedup(&self) -> f64 {
        self.karp_ms / self.howard_ms.max(1e-9)
    }
}

/// Time `f` with a few repetitions for sub-millisecond stability; returns
/// the best-of-reps wall milliseconds.
fn time_ms<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    best
}

/// The sizes × designers grid as a [`SweepSpec`].
pub fn spec_for(
    family: &str,
    sizes: &[usize],
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> SweepSpec {
    spec_for_specs(
        sizes
            .iter()
            .map(|n| format!("synth:{family}:{n}:seed{seed}"))
            .collect(),
        wl,
        s,
        access_bps,
        core_bps,
        c_b,
        seed,
    )
}

/// The underlay-specs × designers grid as a [`SweepSpec`] (specs are
/// anything [`crate::netsim::underlay::Underlay::by_name`] resolves).
pub fn spec_for_specs(
    specs: Vec<String>,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> SweepSpec {
    spec_for_specs_kinds(
        specs,
        OverlayKind::all().to_vec(),
        wl,
        s,
        access_bps,
        core_bps,
        c_b,
        seed,
    )
}

/// [`spec_for_specs`] restricted to a designer subset (`--overlays`, PR 7:
/// the O(N²)-weight-scan designers — MST/GPT/δ-MBST/Ring — are what a
/// 100 000-silo sweep must be able to leave out).
#[allow(clippy::too_many_arguments)]
pub fn spec_for_specs_kinds(
    specs: Vec<String>,
    kinds: Vec<OverlayKind>,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> SweepSpec {
    spec_for_specs_kinds_backends(
        specs,
        kinds,
        vec!["backend:scalar".to_string()],
        wl,
        s,
        access_bps,
        core_bps,
        c_b,
        seed,
    )
}

/// [`spec_for_specs_kinds`] with an explicit `--backends` axis (PR 9):
/// every (spec × backend) pair becomes a row, pricing the same underlay's
/// arcs under each message-level backend.
#[allow(clippy::too_many_arguments)]
pub fn spec_for_specs_kinds_backends(
    specs: Vec<String>,
    kinds: Vec<OverlayKind>,
    backends: Vec<String>,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> SweepSpec {
    let mut spec = SweepSpec::new(
        specs,
        kinds,
        wl.clone(),
        ModelAxis {
            s,
            access_bps,
            core_bps,
        },
        c_b,
        seed,
    );
    spec.backends = backends;
    spec
}

/// Run the grid on the jobs pool and assemble one [`ScaleRow`] per size;
/// the Karp/Howard head-to-head is timed sequentially afterwards (wall
/// clock is a diagnostic, never part of the deterministic report).
pub fn sweep_rows(
    family: &str,
    sizes: &[usize],
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> Result<Vec<ScaleRow>> {
    let specs: Vec<String> = sizes
        .iter()
        .map(|n| format!("synth:{family}:{n}:seed{seed}"))
        .collect();
    sweep_rows_specs(specs, wl, s, access_bps, core_bps, c_b, seed)
}

/// [`sweep_rows`] over explicit underlay specs (`--networks`): any
/// `Underlay::by_name` name per row, builtins and synth specs alike.
pub fn sweep_rows_specs(
    specs: Vec<String>,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> Result<Vec<ScaleRow>> {
    sweep_rows_specs_kinds(
        specs,
        OverlayKind::all().to_vec(),
        wl,
        s,
        access_bps,
        core_bps,
        c_b,
        seed,
    )
}

/// [`sweep_rows_specs`] restricted to a designer subset. When RING is not
/// among `kinds` the Karp/Howard head-to-head has no delay digraph to time,
/// so both columns come back NaN (rendered `n/a`; never in the JSON).
#[allow(clippy::too_many_arguments)]
pub fn sweep_rows_specs_kinds(
    specs: Vec<String>,
    kinds: Vec<OverlayKind>,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> Result<Vec<ScaleRow>> {
    sweep_rows_specs_kinds_backends(
        specs,
        kinds,
        vec!["backend:scalar".to_string()],
        wl,
        s,
        access_bps,
        core_bps,
        c_b,
        seed,
    )
}

/// [`sweep_rows_specs_kinds`] with an explicit `--backends` axis: one
/// [`ScaleRow`] per (spec × backend), underlay-major — so the τ columns of
/// adjacent rows compare backends on the same network. The solver
/// head-to-head runs per row (the RING delay digraph's weights are
/// backend-conditional).
#[allow(clippy::too_many_arguments)]
pub fn sweep_rows_specs_kinds_backends(
    specs: Vec<String>,
    kinds: Vec<OverlayKind>,
    backends: Vec<String>,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> Result<Vec<ScaleRow>> {
    let spec = spec_for_specs_kinds_backends(
        specs, kinds, backends, wl, s, access_bps, core_bps, c_b, seed,
    );
    let cells = spec.run(|cell, ctx| {
        let t0 = Instant::now();
        let overlay = design_with_underlay(cell.kind, &ctx.dm, &ctx.net, spec.c_b)?;
        let tau = overlay.cycle_time_ms(&ctx.dm);
        // The RING cell also hands its delay digraph back so the solver
        // head-to-head below reuses it instead of re-resolving the
        // underlay, its all-pairs routes, and the designer.
        let ring_dd = match (cell.kind, overlay.static_graph()) {
            (OverlayKind::Ring, Some(g)) => Some(ctx.dm.delay_digraph(g)),
            _ => None,
        };
        Ok((
            cell.underlay_idx * spec.backends.len() + cell.backend_idx,
            cell.kind,
            tau,
            t0.elapsed().as_secs_f64() * 1e3,
            ctx.net.n_silos(),
            ctx.net.n_links(),
            ring_dd,
        ))
    })?;

    let mut rows: Vec<ScaleRow> = Vec::with_capacity(spec.underlays.len() * spec.backends.len());
    for spec_name in &spec.underlays {
        for backend in &spec.backends {
            rows.push(ScaleRow {
                spec: spec_name.clone(),
                backend: backend.clone(),
                n: 0,
                links: 0,
                overlays: Vec::new(),
                karp_ms: 0.0,
                howard_ms: 0.0,
            });
        }
    }
    let mut ring_dds: Vec<Option<crate::maxplus::DelayDigraph>> = Vec::new();
    ring_dds.resize_with(rows.len(), || None);
    for (ri, kind, tau, design_ms, n_silos, links, ring_dd) in cells {
        rows[ri].n = n_silos;
        rows[ri].links = links;
        rows[ri].overlays.push((kind, tau, design_ms));
        if ring_dd.is_some() {
            ring_dds[ri] = ring_dd;
        }
    }

    // Solver head-to-head on the RING's delay digraph (ring + self-loops:
    // the canonical sparse instance the dispatch threshold is tuned for).
    // Timed sequentially; wall clock never enters the deterministic report.
    // Karp's Θ(V²) tables are skipped past KARP_BENCH_MAX_N (NaN → "n/a").
    for (row, dd) in rows.iter_mut().zip(ring_dds) {
        // No RING in the designer subset → nothing to time.
        let Some(dd) = dd else {
            row.karp_ms = f64::NAN;
            row.howard_ms = f64::NAN;
            continue;
        };
        let reps = (2000 / row.n.max(1)).clamp(1, 20);
        row.karp_ms = if row.n <= KARP_BENCH_MAX_N {
            time_ms(reps, || cycle_time_with(&dd, CycleSolver::Karp))
        } else {
            f64::NAN
        };
        row.howard_ms = time_ms(reps, || cycle_time_with(&dd, CycleSolver::Howard));
    }
    Ok(rows)
}

/// Measure one synthetic underlay size.
pub fn measure(
    family: &str,
    n: usize,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> Result<ScaleRow> {
    let mut rows = sweep_rows(family, &[n], wl, s, access_bps, core_bps, c_b, seed)?;
    Ok(rows.pop().expect("one size in, one row out"))
}

/// True when `rows` ran under a non-default backend axis — the signal for
/// [`to_json`] / [`render`] to surface backend fields. A default axis (one
/// backend resolving to `backend:scalar`) keeps both outputs byte-identical
/// to their pre-backend shapes.
fn rows_have_backend_axis(rows: &[ScaleRow]) -> bool {
    let mut axis: Vec<String> = Vec::new();
    for r in rows {
        if !axis.contains(&r.backend) {
            axis.push(r.backend.clone());
        }
    }
    !rows.is_empty() && !crate::netsim::backend::axis_is_default(&axis)
}

/// The deterministic machine-readable report: configuration + per-size τ of
/// every designer. Wall-clock fields are deliberately absent so the bytes
/// are identical for any `--jobs` (the CI determinism gate). Rows gain a
/// `backend` field only on a non-default `--backends` axis.
pub fn to_json(
    family: &str,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
    rows: &[ScaleRow],
) -> Json {
    let show_backend = rows_have_backend_axis(rows);
    let row_objs = rows.iter().map(|r| {
        let mut f = vec![("spec", Json::str(&r.spec))];
        if show_backend {
            f.push(("backend", Json::str(&r.backend)));
        }
        f.extend([
            ("n", Json::num(r.n as f64)),
            ("links", Json::num(r.links as f64)),
            (
                "tau_ms",
                Json::obj(
                    r.overlays
                        .iter()
                        .map(|(k, tau, _)| (k.name(), Json::num(*tau)))
                        .collect(),
                ),
            ),
        ]);
        Json::obj(f)
    });
    Json::obj(vec![
        ("experiment", Json::str("scale")),
        ("family", Json::str(family)),
        ("workload", Json::str(wl.name)),
        ("s", Json::num(s as f64)),
        ("access_bps", Json::num(access_bps)),
        ("core_bps", Json::num(core_bps)),
        ("cb", Json::num(c_b)),
        ("seed", Json::num(seed as f64)),
        ("rows", Json::arr(row_objs)),
    ])
}

/// Run the sweep and render it.
pub fn run(
    family: &str,
    sizes: &[usize],
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> Result<Table> {
    let rows = sweep_rows(family, sizes, wl, s, access_bps, core_bps, c_b, seed)?;
    Ok(render(family, wl, s, access_bps, c_b, seed, &rows))
}

/// Render assembled rows (shared by the CLI and `benches/scale.rs`).
pub fn render(
    family: &str,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    c_b: f64,
    seed: u64,
    rows: &[ScaleRow],
) -> Table {
    // Column set = the designers the rows actually ran (the `--overlays`
    // subset); an empty sweep falls back to the full palette.
    let kinds: Vec<OverlayKind> = if rows.is_empty() {
        OverlayKind::all().to_vec()
    } else {
        OverlayKind::all()
            .iter()
            .copied()
            .filter(|k| rows.iter().any(|r| r.overlays.iter().any(|(rk, _, _)| rk == k)))
            .collect()
    };
    let show_backend = rows_have_backend_axis(rows);
    let mut header = vec!["N".to_string(), "Links".to_string()];
    if show_backend {
        header.push("Backend".to_string());
    }
    for kind in &kinds {
        header.push(format!("τ {} (ms)", kind.name()));
    }
    header.extend([
        "design Σ (ms)".to_string(),
        "Karp (ms)".to_string(),
        "Howard (ms)".to_string(),
        "Karp/Howard".to_string(),
    ]);
    let header_refs: Vec<&str> = header.iter().map(|h| h.as_str()).collect();
    let mut t = Table::new(
        &format!(
            "Scale sweep on synth:{family} (workload {}, s={s}, {} Gbps access, C_b={c_b}, seed {seed})",
            wl.name,
            access_bps / 1e9
        ),
        &header_refs,
    );
    for row in rows {
        let mut cells = vec![row.n.to_string(), row.links.to_string()];
        if show_backend {
            cells.push(row.backend.clone());
        }
        for &kind in &kinds {
            cells.push(format!("{:.0}", row.tau_of(kind)));
        }
        let design_total: f64 = row.overlays.iter().map(|(_, _, ms)| ms).sum();
        cells.push(format!("{design_total:.0}"));
        if row.howard_ms.is_nan() {
            // RING not designed: no delay digraph, no solver head-to-head.
            cells.push("n/a".to_string());
            cells.push("n/a".to_string());
            cells.push("n/a".to_string());
        } else if row.karp_ms.is_nan() {
            cells.push("n/a".to_string());
            cells.push(format!("{:.3}", row.howard_ms));
            cells.push("n/a".to_string());
        } else {
            cells.push(format!("{:.3}", row.karp_ms));
            cells.push(format!("{:.3}", row.howard_ms));
            cells.push(format!("{:.1}x", row.solver_speedup()));
        }
        t.row(cells);
    }
    t.note(&format!(
        "solver columns: max-cycle-mean on the RING delay digraph; dispatch switches to Howard at N ≥ {}; Karp timing skipped past N = {KARP_BENCH_MAX_N} (Θ(V²) tables)",
        crate::maxplus::HOWARD_MIN_N
    ));
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_small_sizes_all_kinds_finite() {
        let row = measure("waxman", 40, &Workload::inaturalist(), 1, 10e9, 1e9, 0.5, 7).unwrap();
        assert_eq!(row.n, 40);
        assert_eq!(row.overlays.len(), OverlayKind::all().len());
        for &(kind, tau, design_ms) in &row.overlays {
            assert!(tau.is_finite() && tau > 0.0, "{kind:?}: τ={tau}");
            assert!(design_ms >= 0.0);
        }
        assert!(row.karp_ms > 0.0 && row.howard_ms > 0.0);
    }

    #[test]
    fn table_renders() {
        let t = run(
            "grid",
            &[30, 50],
            &Workload::inaturalist(),
            1,
            10e9,
            1e9,
            0.5,
            7,
        )
        .unwrap();
        let s = t.render();
        assert!(s.contains("synth:grid"));
        assert!(s.contains("Karp/Howard"));
    }

    #[test]
    fn json_report_has_only_deterministic_fields() {
        let rows =
            sweep_rows("waxman", &[20, 30], &Workload::inaturalist(), 1, 10e9, 1e9, 0.5, 7)
                .unwrap();
        let j = to_json("waxman", &Workload::inaturalist(), 1, 10e9, 1e9, 0.5, 7, &rows);
        let s = j.to_string();
        assert!(!s.contains("karp"), "wall-clock fields must stay out: {s}");
        assert!(!s.contains("design_ms"));
        let v = Json::parse(&s).unwrap();
        assert_eq!(v.get("rows").as_arr().unwrap().len(), 2);
        let tau = v.get("rows").as_arr().unwrap()[0].get("tau_ms");
        for kind in OverlayKind::all() {
            assert!(tau.get(kind.name()).as_f64().unwrap() > 0.0, "{kind:?}");
        }
    }

    #[test]
    fn networks_specs_path_matches_family_path_bitwise() {
        let wl = Workload::inaturalist();
        let a = sweep_rows("waxman", &[30], &wl, 1, 10e9, 1e9, 0.5, 7).unwrap();
        let b = sweep_rows_specs(
            vec!["synth:waxman:30:seed7".to_string()],
            &wl,
            1,
            10e9,
            1e9,
            0.5,
            7,
        )
        .unwrap();
        assert_eq!(a.len(), b.len());
        assert_eq!(a[0].spec, b[0].spec);
        assert_eq!(a[0].n, 30);
        assert_eq!(b[0].n, 30);
        for (x, y) in a[0].overlays.iter().zip(&b[0].overlays) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "{:?}", x.0);
        }
        // builtins resolve too
        let g = sweep_rows_specs(vec!["gaia".to_string()], &wl, 1, 10e9, 1e9, 0.5, 7).unwrap();
        assert_eq!(g[0].n, 11);
        assert_eq!(g[0].overlays.len(), OverlayKind::all().len());
    }

    #[test]
    fn overlay_subset_matches_full_sweep_and_skips_head_to_head() {
        // --overlays star,matcha: the subset's τ values are the full
        // sweep's bit for bit (cells are independent); without RING the
        // Karp/Howard head-to-head is NaN and renders n/a.
        let wl = Workload::inaturalist();
        let spec = vec!["synth:waxman:40:seed7".to_string()];
        let kinds = vec![OverlayKind::Star, OverlayKind::Matcha];
        let sub =
            sweep_rows_specs_kinds(spec.clone(), kinds, &wl, 1, 10e9, 1e9, 0.5, 7).unwrap();
        assert_eq!(sub[0].overlays.len(), 2);
        assert!(sub[0].karp_ms.is_nan() && sub[0].howard_ms.is_nan());
        let full = sweep_rows_specs(spec, &wl, 1, 10e9, 1e9, 0.5, 7).unwrap();
        for &(k, tau, _) in &sub[0].overlays {
            assert_eq!(tau.to_bits(), full[0].tau_of(k).to_bits(), "{k:?}");
        }
        let t = render("waxman", &wl, 1, 10e9, 0.5, 7, &sub);
        let s = t.render();
        assert!(s.contains("τ star"));
        assert!(!s.contains("τ ring"));
        assert!(s.contains("n/a"));
    }

    #[test]
    fn backend_axis_adds_rows_and_stays_out_of_default_output() {
        let wl = Workload::inaturalist();
        let rows = sweep_rows_specs_kinds_backends(
            vec!["gaia".to_string()],
            vec![OverlayKind::Mst, OverlayKind::Ring],
            vec!["backend:scalar".to_string(), "backend:grpc".to_string()],
            &wl,
            1,
            10e9,
            1e9,
            0.5,
            7,
        )
        .unwrap();
        assert_eq!(rows.len(), 2, "1 spec × 2 backends");
        assert_eq!(rows[0].backend, "backend:scalar");
        assert_eq!(rows[1].backend, "backend:grpc");
        // per-message overhead prices every designed overlay strictly up
        for kind in [OverlayKind::Mst, OverlayKind::Ring] {
            assert!(rows[1].tau_of(kind) > rows[0].tau_of(kind), "{kind:?}");
        }
        // the scalar row matches the pre-backend path bit for bit
        let base = sweep_rows_specs_kinds(
            vec!["gaia".to_string()],
            vec![OverlayKind::Mst, OverlayKind::Ring],
            &wl,
            1,
            10e9,
            1e9,
            0.5,
            7,
        )
        .unwrap();
        for (a, b) in rows[0].overlays.iter().zip(&base[0].overlays) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "{:?}", a.0);
        }
        // non-default axis surfaces backend fields; default keeps them out
        let j = to_json("custom", &wl, 1, 10e9, 1e9, 0.5, 7, &rows).to_string();
        assert!(j.contains("\"backend\":\"backend:grpc\""));
        assert!(!to_json("custom", &wl, 1, 10e9, 1e9, 0.5, 7, &base)
            .to_string()
            .contains("\"backend\""));
        let t = render("custom", &wl, 1, 10e9, 0.5, 7, &rows).render();
        assert!(t.contains("Backend"));
    }

    #[test]
    fn paper_orderings_survive_on_synthetic_midsize() {
        // Table-3 shape on a 150-silo Waxman underlay (above the Howard
        // dispatch threshold): trees/ring beat the star.
        let row = measure("waxman", 150, &Workload::inaturalist(), 1, 10e9, 1e9, 0.5, 7).unwrap();
        let star = row.tau_of(OverlayKind::Star);
        assert!(row.tau_of(OverlayKind::Ring) < star);
        assert!(row.tau_of(OverlayKind::Mst) < star);
    }
}
