//! `fedtopo scale` — designer τ and solver wall-time vs N on synthetic
//! underlays.
//!
//! The paper stops at 87 silos; this sweep drives every `OverlayKind`
//! across seeded synthetic underlays (see [`crate::netsim::synth`]) of
//! growing size and reports, per (family, N):
//!
//! * cycle time τ of each designed overlay (ms) — do Table 3's orderings
//!   survive at scale?
//! * total design+evaluate wall-time per overlay kind (ms);
//! * Karp vs Howard wall-time on the RING delay digraph, the head-to-head
//!   behind the [`crate::maxplus::HOWARD_MIN_N`] dispatch threshold.

use crate::fl::workloads::Workload;
use crate::maxplus::{cycle_time_with, CycleSolver};
use crate::netsim::delay::DelayModel;
use crate::netsim::underlay::Underlay;
use crate::topology::{design_with_underlay, OverlayKind};
use crate::util::table::Table;
use anyhow::Result;
use std::time::Instant;

/// One (family, N) measurement.
#[derive(Clone, Debug)]
pub struct ScaleRow {
    pub spec: String,
    pub n: usize,
    pub links: usize,
    /// (kind, τ ms, design+evaluate wall ms)
    pub overlays: Vec<(OverlayKind, f64, f64)>,
    /// Karp wall-time on the RING delay digraph, ms.
    pub karp_ms: f64,
    /// Howard wall-time on the same digraph, ms.
    pub howard_ms: f64,
}

impl ScaleRow {
    pub fn tau_of(&self, kind: OverlayKind) -> f64 {
        self.overlays
            .iter()
            .find(|(k, _, _)| *k == kind)
            .map(|(_, t, _)| *t)
            .unwrap_or(f64::NAN)
    }

    pub fn solver_speedup(&self) -> f64 {
        self.karp_ms / self.howard_ms.max(1e-9)
    }
}

/// Time `f` with a few repetitions for sub-millisecond stability; returns
/// the best-of-reps wall milliseconds.
fn time_ms<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    best
}

/// Measure one synthetic underlay size.
#[allow(clippy::too_many_arguments)]
pub fn measure(
    family: &str,
    n: usize,
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> Result<ScaleRow> {
    let spec = format!("synth:{family}:{n}:seed{seed}");
    let net = Underlay::by_name(&spec)?;
    let dm = DelayModel::new(&net, wl, s, access_bps, core_bps);

    let mut overlays = Vec::new();
    let mut ring = None;
    for kind in OverlayKind::all() {
        let t0 = Instant::now();
        let overlay = design_with_underlay(kind, &dm, &net, c_b)?;
        let tau = overlay.cycle_time_ms(&dm);
        overlays.push((kind, tau, t0.elapsed().as_secs_f64() * 1e3));
        if kind == OverlayKind::Ring {
            ring = Some(overlay);
        }
    }

    // Solver head-to-head on the RING's delay digraph (ring + self-loops:
    // the canonical sparse instance the dispatch threshold is tuned for).
    let ring = ring.expect("OverlayKind::all() contains Ring");
    let dd = dm.delay_digraph(ring.static_graph().expect("ring is static"));
    let reps = (2000 / n.max(1)).clamp(1, 20);
    let karp_ms = time_ms(reps, || cycle_time_with(&dd, CycleSolver::Karp));
    let howard_ms = time_ms(reps, || cycle_time_with(&dd, CycleSolver::Howard));

    Ok(ScaleRow {
        spec,
        n,
        links: net.n_links(),
        overlays,
        karp_ms,
        howard_ms,
    })
}

/// Run the sweep and render it.
#[allow(clippy::too_many_arguments)]
pub fn run(
    family: &str,
    sizes: &[usize],
    wl: &Workload,
    s: usize,
    access_bps: f64,
    core_bps: f64,
    c_b: f64,
    seed: u64,
) -> Result<Table> {
    let mut header = vec!["N".to_string(), "Links".to_string()];
    for kind in OverlayKind::all() {
        header.push(format!("τ {} (ms)", kind.name()));
    }
    header.extend([
        "design Σ (ms)".to_string(),
        "Karp (ms)".to_string(),
        "Howard (ms)".to_string(),
        "Karp/Howard".to_string(),
    ]);
    let header_refs: Vec<&str> = header.iter().map(|h| h.as_str()).collect();
    let mut t = Table::new(
        &format!(
            "Scale sweep on synth:{family} (workload {}, s={s}, {} Gbps access, C_b={c_b}, seed {seed})",
            wl.name,
            access_bps / 1e9
        ),
        &header_refs,
    );
    for &n in sizes {
        let row = measure(family, n, wl, s, access_bps, core_bps, c_b, seed)?;
        let mut cells = vec![row.n.to_string(), row.links.to_string()];
        for kind in OverlayKind::all() {
            cells.push(format!("{:.0}", row.tau_of(kind)));
        }
        let design_total: f64 = row.overlays.iter().map(|(_, _, ms)| ms).sum();
        cells.push(format!("{design_total:.0}"));
        cells.push(format!("{:.3}", row.karp_ms));
        cells.push(format!("{:.3}", row.howard_ms));
        cells.push(format!("{:.1}x", row.solver_speedup()));
        t.row(cells);
    }
    t.note(&format!(
        "solver columns: max-cycle-mean on the RING delay digraph; dispatch switches to Howard at N ≥ {}",
        crate::maxplus::HOWARD_MIN_N
    ));
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_small_sizes_all_kinds_finite() {
        let row = measure("waxman", 40, &Workload::inaturalist(), 1, 10e9, 1e9, 0.5, 7).unwrap();
        assert_eq!(row.n, 40);
        assert_eq!(row.overlays.len(), OverlayKind::all().len());
        for &(kind, tau, design_ms) in &row.overlays {
            assert!(tau.is_finite() && tau > 0.0, "{kind:?}: τ={tau}");
            assert!(design_ms >= 0.0);
        }
        assert!(row.karp_ms > 0.0 && row.howard_ms > 0.0);
    }

    #[test]
    fn table_renders() {
        let t = run(
            "grid",
            &[30, 50],
            &Workload::inaturalist(),
            1,
            10e9,
            1e9,
            0.5,
            7,
        )
        .unwrap();
        let s = t.render();
        assert!(s.contains("synth:grid"));
        assert!(s.contains("Karp/Howard"));
    }

    #[test]
    fn paper_orderings_survive_on_synthetic_midsize() {
        // Table-3 shape on a 150-silo Waxman underlay (above the Howard
        // dispatch threshold): trees/ring beat the star.
        let row = measure("waxman", 150, &Workload::inaturalist(), 1, 10e9, 1e9, 0.5, 7).unwrap();
        let star = row.tau_of(OverlayKind::Star);
        assert!(row.tau_of(OverlayKind::Ring) < star);
        assert!(row.tau_of(OverlayKind::Mst) < star);
    }
}
