//! `fedtopo train` — wall-clock time-to-accuracy across the full grid.
//!
//! Drives the coupled training-and-timeline engine
//! ([`crate::fl::trainsim`]) over a (underlays × workloads × backends ×
//! designers × scenarios × seeds) [`SweepSpec`] grid on the `--jobs` pool,
//! and reports
//! per cell: the designed cycle time λ*, the evaluated loss-curve knots
//! stamped with *simulated* wall-clock, the simulated time to a target
//! accuracy, and the adaptive re-design trace.
//!
//! Determinism: the JSON report contains only simulated quantities (never
//! CPU wall-clock), every stochastic stream derives from the cell's seeds,
//! and results merge in enumeration order — so the bytes are identical for
//! any `--jobs` (gated by CI's `determinism` job, like `scale` and
//! `robustness`).
//!
//! CRN pairing rule (PR 4): all designers in the same (underlay × workload
//! × backend × scenario × seed) slice share the stream
//! `derive_seed(base_seed, crn_index)` ([`SweepSpec::crn_index`]) for
//! trainer initialization, the scenario process, and MATCHA round sampling
//! — so comparing rows across the designer axis compares *topologies*, not
//! noise realizations, while distinct slices stay independent.

use super::sweep::{ModelAxis, SweepSpec};
use crate::fl::dpasgd::QuadraticTrainer;
use crate::fl::trainsim::{self, TrainSimConfig};
use crate::fl::workloads::Workload;
use crate::netsim::backend;
use crate::netsim::scenario::Scenario;
use crate::topology::OverlayKind;
use crate::util::json::Json;
use crate::util::rng::derive_seed;
use crate::util::table::Table;
use anyhow::Result;

/// Full configuration of one `fedtopo train` run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub networks: Vec<String>,
    pub workloads: Vec<Workload>,
    /// Communication backends (`backend:` specs); `["backend:scalar"]`
    /// keeps the report byte-identical to the pre-backend grid.
    pub backends: Vec<String>,
    pub kinds: Vec<OverlayKind>,
    pub scenarios: Vec<String>,
    pub seeds: Vec<u64>,
    pub s: usize,
    pub access_bps: f64,
    pub core_bps: f64,
    pub c_b: f64,
    pub rounds: usize,
    pub eval_every: usize,
    /// Monitor window for adaptive re-design (rounds).
    pub window: usize,
    /// Re-design threshold; `INFINITY` = static designs only.
    pub threshold: f64,
    /// Accuracy target for the time-to-accuracy metric.
    pub target_acc: f32,
    /// Proxy-model dimension (the closed-form quadratic trainer).
    pub dim: usize,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            networks: vec!["gaia".to_string()],
            workloads: vec![Workload::inaturalist()],
            backends: vec!["backend:scalar".to_string()],
            kinds: OverlayKind::all().to_vec(),
            scenarios: vec!["scenario:identity".to_string()],
            seeds: vec![7],
            s: 1,
            access_bps: 10e9,
            core_bps: 1e9,
            c_b: 0.5,
            rounds: 60,
            eval_every: 5,
            window: 20,
            threshold: f64::INFINITY,
            target_acc: 0.5,
            dim: 16,
        }
    }
}

/// One grid cell's outcome. Simulated quantities only — CPU wall-clock
/// never enters a row (the determinism contract).
#[derive(Clone, Debug)]
pub struct TrainRow {
    pub network: String,
    pub workload: &'static str,
    /// Canonical backend spec this cell ran under.
    pub backend: String,
    pub kind: OverlayKind,
    pub scenario: String,
    pub seed: u64,
    pub silos: usize,
    /// The initial design's promised cycle time λ* (ms).
    pub lambda_star_ms: f64,
    pub redesign_rounds: Vec<usize>,
    pub initial_train_loss: f32,
    pub final_train_loss: f32,
    pub rounds_to_target: Option<usize>,
    /// Simulated time (ms) to the first evaluated accuracy ≥ target.
    pub time_to_target_ms: Option<f64>,
    /// Simulated time (ms) for the full horizon.
    pub total_ms: f64,
    /// Evaluated loss-curve knots: (round, sim_ms, loss, accuracy).
    pub curve: Vec<(usize, f64, f32, f32)>,
}

impl TrainRow {
    pub fn loss_decreased(&self) -> bool {
        self.final_train_loss < self.initial_train_loss
    }
}

/// Run the grid: one engine call per cell, on the `--jobs` pool.
pub fn run(cfg: &TrainConfig) -> Result<Vec<TrainRow>> {
    let spec = SweepSpec {
        underlays: cfg.networks.clone(),
        workloads: cfg.workloads.clone(),
        models: vec![ModelAxis {
            s: cfg.s,
            access_bps: cfg.access_bps,
            core_bps: cfg.core_bps,
        }],
        kinds: cfg.kinds.clone(),
        scenarios: cfg.scenarios.clone(),
        seeds: cfg.seeds.clone(),
        backends: cfg.backends.clone(),
        c_b: cfg.c_b,
    };
    spec.run(|cell, ctx| {
        // CRN pairing: every designer in this (underlay × workload ×
        // backend × scenario × seed) slice draws the same stream.
        let pair_seed = derive_seed(cell.base_seed, spec.crn_index(cell));
        let scenario = Scenario::by_name(&cell.scenario)?;
        let mut trainer = QuadraticTrainer::new(ctx.net.n_silos(), cfg.dim, pair_seed);
        let tcfg = TrainSimConfig {
            rounds: cfg.rounds,
            s: cfg.s,
            seed: pair_seed,
            eval_every: cfg.eval_every,
            ring_half_weights: false,
            c_b: cfg.c_b,
            window: cfg.window,
            threshold: cfg.threshold,
            star_closed_form: false,
        };
        let rep = trainsim::run(&mut trainer, cell.kind, &ctx.dm, &ctx.net, &scenario, &tcfg)?;
        let rounds_to_target = rep.train.rounds_to_accuracy(cfg.target_acc);
        Ok(TrainRow {
            network: cell.underlay.clone(),
            workload: spec.workloads[cell.workload_idx].name,
            backend: cell.backend.clone(),
            kind: cell.kind,
            scenario: cell.scenario.clone(),
            seed: cell.base_seed,
            silos: ctx.net.n_silos(),
            lambda_star_ms: rep.lambda_star_ms(),
            redesign_rounds: rep.redesign_rounds.clone(),
            initial_train_loss: rep.train.records[0].train_loss,
            final_train_loss: rep.train.final_train_loss(),
            rounds_to_target,
            time_to_target_ms: rep.time_to_accuracy_ms(cfg.target_acc),
            total_ms: rep.total_ms(),
            curve: rep
                .eval_points()
                .iter()
                .map(|p| (p.round, p.sim_ms, p.loss, p.acc))
                .collect(),
        })
    })
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::num(x),
        None => Json::Null,
    }
}

/// The deterministic machine-readable report. `threshold` serializes as
/// `null` when infinite (JSON has no `inf`); every other field is a pure
/// function of the configuration and the seeds. Backend fields appear only
/// on a non-default `--backends` axis — the default report is
/// byte-identical to the pre-backend grid.
pub fn to_json(cfg: &TrainConfig, rows: &[TrainRow]) -> Json {
    let default_backend = backend::axis_is_default(&cfg.backends);
    let cells = rows.iter().map(|r| {
        let curve = r.curve.iter().map(|&(round, sim_ms, loss, acc)| {
            Json::obj(vec![
                ("round", Json::num(round as f64)),
                ("sim_ms", Json::num(sim_ms)),
                ("loss", Json::num(loss as f64)),
                ("acc", Json::num(acc as f64)),
            ])
        });
        let mut f = vec![
            ("network", Json::str(&r.network)),
            ("workload", Json::str(r.workload)),
        ];
        if !default_backend {
            f.push(("backend", Json::str(&r.backend)));
        }
        f.extend([
            ("overlay", Json::str(r.kind.name())),
            ("scenario", Json::str(&r.scenario)),
            ("seed", Json::num(r.seed as f64)),
            ("silos", Json::num(r.silos as f64)),
            ("lambda_star_ms", Json::num(r.lambda_star_ms)),
            (
                "redesign_rounds",
                Json::arr(r.redesign_rounds.iter().map(|&k| Json::num(k as f64))),
            ),
            ("initial_train_loss", Json::num(r.initial_train_loss as f64)),
            ("final_train_loss", Json::num(r.final_train_loss as f64)),
            ("loss_decreased", Json::Bool(r.loss_decreased())),
            (
                "rounds_to_target",
                opt_num(r.rounds_to_target.map(|k| k as f64)),
            ),
            ("time_to_target_ms", opt_num(r.time_to_target_ms)),
            ("total_ms", Json::num(r.total_ms)),
            ("curve", Json::arr(curve)),
        ]);
        Json::obj(f)
    });
    Json::obj(vec![
        ("experiment", Json::str("train")),
        ("rounds", Json::num(cfg.rounds as f64)),
        ("s", Json::num(cfg.s as f64)),
        ("eval_every", Json::num(cfg.eval_every as f64)),
        ("access_bps", Json::num(cfg.access_bps)),
        ("core_bps", Json::num(cfg.core_bps)),
        ("cb", Json::num(cfg.c_b)),
        ("window", Json::num(cfg.window as f64)),
        (
            "threshold",
            if cfg.threshold.is_finite() {
                Json::num(cfg.threshold)
            } else {
                Json::Null
            },
        ),
        ("target_acc", Json::num(cfg.target_acc as f64)),
        ("dim", Json::num(cfg.dim as f64)),
        ("grid", {
            let mut g = vec![
                (
                    "networks",
                    Json::arr(cfg.networks.iter().map(|n| Json::str(n))),
                ),
                (
                    "workloads",
                    Json::arr(cfg.workloads.iter().map(|w| Json::str(w.name))),
                ),
            ];
            if !default_backend {
                g.push((
                    "backends",
                    Json::arr(cfg.backends.iter().map(|b| Json::str(b))),
                ));
            }
            g.extend([
                (
                    "overlays",
                    Json::arr(cfg.kinds.iter().map(|k| Json::str(k.name()))),
                ),
                (
                    "scenarios",
                    Json::arr(cfg.scenarios.iter().map(|s| Json::str(s))),
                ),
                (
                    "seeds",
                    Json::arr(cfg.seeds.iter().map(|&s| Json::num(s as f64))),
                ),
            ]);
            Json::obj(g)
        }),
        ("cells", Json::arr(cells)),
        (
            "all_loss_decreased",
            Json::Bool(rows.iter().all(|r| r.loss_decreased())),
        ),
    ])
}

/// Human-readable rendering of the same rows. A Backend column appears
/// only on a non-default `--backends` axis.
pub fn to_table(cfg: &TrainConfig, rows: &[TrainRow]) -> Table {
    let default_backend = backend::axis_is_default(&cfg.backends);
    let mut headers = vec!["Network", "Workload"];
    if !default_backend {
        headers.push("Backend");
    }
    headers.extend([
        "Scenario",
        "Overlay",
        "λ* (ms)",
        "t_target (s)",
        "rounds",
        "t_total (s)",
        "final loss",
        "re-designs",
    ]);
    let mut t = Table::new(
        &format!(
            "Time-to-accuracy (target {:.2}) over {} rounds, s={}",
            cfg.target_acc, cfg.rounds, cfg.s
        ),
        &headers,
    );
    for r in rows {
        let mut row = vec![r.network.clone(), r.workload.to_string()];
        if !default_backend {
            row.push(r.backend.clone());
        }
        row.extend([
            r.scenario.clone(),
            r.kind.name().to_string(),
            format!("{:.1}", r.lambda_star_ms),
            r.time_to_target_ms
                .map(|v| format!("{:.1}", v / 1e3))
                .unwrap_or_else(|| "—".to_string()),
            r.rounds_to_target
                .map(|k| k.to_string())
                .unwrap_or_else(|| "—".to_string()),
            format!("{:.1}", r.total_ms / 1e3),
            format!("{:.4}", r.final_train_loss),
            format!("{:?}", r.redesign_rounds),
        ]);
        t.row(row);
    }
    t.note(
        "all times are simulated wall-clock from the Eq.-(4) recurrence over \
         the scenario-perturbed delay digraphs; λ* is the initial design's \
         promised cycle time",
    );
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> TrainConfig {
        TrainConfig {
            kinds: vec![OverlayKind::Star, OverlayKind::Mst, OverlayKind::Ring],
            rounds: 40,
            ..Default::default()
        }
    }

    #[test]
    fn grid_runs_and_losses_fall_everywhere() {
        let cfg = small_cfg();
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(
                r.loss_decreased(),
                "{:?}: {} !< {}",
                r.kind,
                r.final_train_loss,
                r.initial_train_loss
            );
            assert!(r.lambda_star_ms > 0.0);
            assert!(r.total_ms > 0.0);
            assert!(!r.curve.is_empty());
            assert!(r.redesign_rounds.is_empty(), "threshold ∞ must stay static");
        }
    }

    #[test]
    fn crn_pairing_gives_every_designer_the_same_trainer_start() {
        // Same slice ⇒ same initial loss (trainer init is seed-determined
        // and round-0 losses are evaluated from the same start).
        let cfg = small_cfg();
        let rows = run(&cfg).unwrap();
        let first = rows[0].initial_train_loss;
        for r in &rows {
            assert_eq!(
                r.initial_train_loss.to_bits(),
                first.to_bits(),
                "{:?} saw a different trainer start",
                r.kind
            );
        }
    }

    #[test]
    fn scenario_axis_and_json_roundtrip() {
        let mut cfg = small_cfg();
        cfg.kinds = vec![OverlayKind::Mst];
        cfg.scenarios = vec![
            "scenario:identity".to_string(),
            "scenario:straggler:3:x10".to_string(),
        ];
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 2);
        // the straggler slows the simulated clock, not the per-round math
        assert!(rows[1].total_ms > rows[0].total_ms);
        let s = to_json(&cfg, &rows).to_string();
        assert!(!s.to_lowercase().contains("inf"), "no bare inf in JSON: {s}");
        let v = Json::parse(&s).unwrap();
        assert_eq!(v.get("experiment").as_str(), Some("train"));
        assert_eq!(v.get("threshold"), &Json::Null);
        assert_eq!(v.get("all_loss_decreased").as_bool(), Some(true));
        let cells = v.get("cells").as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(
            cells[1].get("scenario").as_str(),
            Some("scenario:straggler:3:x10")
        );
        assert!(cells[0].get("curve").as_arr().unwrap().len() > 2);
    }

    #[test]
    fn adaptive_threshold_beats_static_under_straggler() {
        let mut cfg = small_cfg();
        cfg.kinds = vec![OverlayKind::Mst];
        cfg.scenarios = vec!["scenario:straggler:3:x10".to_string()];
        cfg.rounds = 200;
        cfg.eval_every = 10;
        let stat = run(&cfg).unwrap();
        cfg.threshold = 1.3;
        let adap = run(&cfg).unwrap();
        assert!(!adap[0].redesign_rounds.is_empty());
        assert!(
            adap[0].total_ms < 0.9 * stat[0].total_ms,
            "adaptive {} !< static {}",
            adap[0].total_ms,
            stat[0].total_ms
        );
    }

    #[test]
    fn table_renders() {
        let cfg = small_cfg();
        let rows = run(&cfg).unwrap();
        let s = to_table(&cfg, &rows).render();
        assert!(s.contains("Time-to-accuracy"));
        assert!(s.contains("ring"));
        // default backend axis leaves both report shapes untouched
        assert!(!s.contains("Backend"));
        assert!(!to_json(&cfg, &rows).to_string().contains("\"backend"));
    }

    #[test]
    fn backend_axis_slows_the_simulated_clock_and_labels_cells() {
        let mut cfg = small_cfg();
        cfg.kinds = vec![OverlayKind::Mst];
        cfg.backends = vec!["backend:scalar".to_string(), "backend:grpc".to_string()];
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].backend, "backend:scalar");
        assert_eq!(rows[1].backend, "backend:grpc");
        // backends are distinct CRN slices (like workloads): the per-message
        // overhead slows the simulated wall-clock regardless of the stream
        assert!(rows[1].total_ms > rows[0].total_ms);
        assert!(rows[1].lambda_star_ms > rows[0].lambda_star_ms);
        let s = to_json(&cfg, &rows).to_string();
        assert!(s.contains("\"backends\":[\"backend:scalar\",\"backend:grpc\"]"));
        assert!(s.contains("\"backend\":\"backend:grpc\""));
        assert!(to_table(&cfg, &rows).render().contains("Backend"));
    }
}
