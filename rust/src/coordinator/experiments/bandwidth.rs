//! Fig. 7 — available-bandwidth distribution sanity check.
//!
//! With 1 Gbps core links and fair-share routing, the per-silo-pair
//! available bandwidths on a sparse underlay (Géant) spread over tens of
//! Mbps → 1 Gbps — "the same variability observed in real networks"
//! (paper App. G, comparing to Gaia's measurements).

use crate::netsim::routing::{BwModel, Routes};
use crate::netsim::underlay::Underlay;
use crate::util::stats::percentile_sorted;
use crate::util::table::Table;
use anyhow::Result;

pub fn run(network: &str, core_bps: f64) -> Result<Table> {
    let net = Underlay::builtin(network)?;
    let routes = Routes::compute(&net, core_bps, BwModel::FairShare);
    let mut dist = routes.abw_distribution();
    dist.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mbps: Vec<f64> = dist.iter().map(|b| b / 1e6).collect();

    let mut t = Table::new(
        &format!(
            "Fig 7: available bandwidth across {} silo pairs on {network} ({} Gbps cores)",
            mbps.len(),
            core_bps / 1e9
        ),
        &["Percentile", "Available bandwidth (Mbps)"],
    );
    for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 100.0] {
        t.row(vec![
            format!("p{p:.0}"),
            format!("{:.0}", percentile_sorted(&mbps, p)),
        ]);
    }
    // histogram in decades
    let buckets = [
        (0.0, 50.0),
        (50.0, 100.0),
        (100.0, 250.0),
        (250.0, 500.0),
        (500.0, 1000.0),
        (1000.0, f64::INFINITY),
    ];
    for (lo, hi) in buckets {
        let count = mbps.iter().filter(|&&b| b >= lo && b < hi).count();
        let bar = "#".repeat(count * 60 / mbps.len().max(1));
        t.row(vec![format!("{lo:.0}-{hi:.0} Mbps: {count}"), bar]);
    }
    t.note("paper Fig 7b (Gaia measurements) spans ~tens of Mbps to ~1 Gbps");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geant_distribution_spreads() {
        let t = run("geant", 1e9).unwrap();
        let s = t.render();
        assert!(s.contains("p50"));
    }
}
