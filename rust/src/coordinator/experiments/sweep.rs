//! The unified sweep engine: every experiment grid is a [`SweepSpec`].
//!
//! The paper's evaluation — and everything this repo has grown beyond it —
//! is a cartesian grid: underlays × workloads × delay-model points ×
//! designers × scenarios × seeds. Before PR 3 each experiment hand-rolled
//! its own nested loops over that grid, single-threaded; now `cycle_table`,
//! `scale`, `robustness`, `fig3`, `fig4` and `train` all declare a
//! `SweepSpec` and hand [`SweepSpec::run`] a per-cell closure.
//!
//! Determinism contract (see [`crate::util::parallel`]):
//!
//! * cells are enumerated row-major in declaration order (underlays, then
//!   workloads, then backends, then models, then kinds, then scenarios,
//!   then seeds) and results are merged back in that order, so output is
//!   bit-identical for any `--jobs`;
//! * every cell gets its own seed `derive_seed(base_seed, index)`
//!   ([`crate::util::rng::derive_seed`]) — never a shared RNG — so no cell
//!   can observe scheduling;
//! * paired comparisons across designers (robustness, `fedtopo train`)
//!   derive their stream from [`SweepSpec::crn_index`] instead — the cell's
//!   position with the designer axis collapsed — so every designer in the
//!   same (underlay × workload × backend × model × scenario × seed) slice
//!   faces the *same* realization (common random numbers) while distinct
//!   slices stay independent;
//! * on error, the *first cell in enumeration order* that failed wins, so
//!   error reporting is deterministic too.
//!
//! Each distinct (underlay × workload × backend × model) combination is
//! resolved once — underlay generation/parsing plus the all-pairs routing
//! of [`DelayModel::new`] — in parallel, and shared read-only across the
//! cells that use it. The workloads axis (PR 4) is what lets `fedtopo
//! train` sweep time-to-accuracy across model-size/computation points in
//! one grid; single-workload experiments keep their PR-3 cell indices
//! unchanged. The backends axis (PR 9) makes λ\* backend-conditional the
//! same way; single-backend grids — every pre-PR-9 caller — keep their
//! PR-4 cell and CRN indices unchanged.

use crate::fl::workloads::Workload;
use crate::maxplus::recurrence::Timeline;
use crate::netsim::backend::BackendProfile;
use crate::netsim::delay::DelayModel;
use crate::netsim::scenario::{
    simulate_scenario, simulate_scenario_batched, RoundState, Scenario,
};
use crate::netsim::underlay::Underlay;
use crate::topology::{design_with_underlay, OverlayKind};
use crate::util::parallel::par_map_indexed;
use crate::util::rng::derive_seed;
use anyhow::Result;

/// One point on the delay-model axis (the knobs of [`DelayModel::new`]
/// beyond the underlay itself). Fig. 3 sweeps `access_bps`, Fig. 4 sweeps
/// `s`; most experiments use a single point.
#[derive(Clone, Copy, Debug)]
pub struct ModelAxis {
    /// Local computation steps per round.
    pub s: usize,
    /// Access link capacity, bit/s.
    pub access_bps: f64,
    /// Core link capacity, bit/s.
    pub core_bps: f64,
}

/// A declarative experiment grid.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Underlay names, resolved through [`Underlay::by_name`] (builtins and
    /// `synth:<family>:<n>[:seed<u64>]` specs alike).
    pub underlays: Vec<String>,
    /// Workloads (at least one). Most experiments sweep a single workload;
    /// `fedtopo train` uses this as a real axis.
    pub workloads: Vec<Workload>,
    /// Communication-backend specs for
    /// [`crate::netsim::backend::BackendProfile::by_name`]; the default
    /// single-element `["backend:scalar"]` axis keeps pre-backend grids
    /// byte-identical.
    pub backends: Vec<String>,
    /// Delay-model points (at least one).
    pub models: Vec<ModelAxis>,
    /// Overlay designers.
    pub kinds: Vec<OverlayKind>,
    /// Scenario specs for [`crate::netsim::scenario::Scenario::by_name`];
    /// static experiments use `["scenario:identity"]`.
    pub scenarios: Vec<String>,
    /// Base seeds; each cell derives its own stream from `(base, index)`.
    pub seeds: Vec<u64>,
    /// MATCHA communication budget forwarded to the designers.
    pub c_b: f64,
}

/// One cell of the grid, fully addressed.
#[derive(Clone, Debug)]
pub struct SweepCell {
    /// Position in enumeration order (also the seed-derivation index).
    pub index: usize,
    pub underlay_idx: usize,
    pub workload_idx: usize,
    pub backend_idx: usize,
    pub model_idx: usize,
    pub underlay: String,
    pub backend: String,
    pub kind: OverlayKind,
    pub scenario: String,
    pub base_seed: u64,
    /// `derive_seed(base_seed, index)` — the stream to draw from when a
    /// cell wants randomness *independent* of every other cell (the
    /// per-item rule). Paired comparisons that want common random numbers
    /// across designers use `derive_seed(base_seed, crn_index)` (see
    /// [`SweepSpec::crn_index`]) or `base_seed` itself (robustness) instead;
    /// what no cell may ever use is an RNG shared across cells.
    pub cell_seed: u64,
}

/// Resolved (underlay, delay model) shared by all cells addressing it.
pub struct SweepCtx {
    pub net: Underlay,
    pub dm: DelayModel,
}

impl SweepSpec {
    /// Minimal grid: one workload, one model point, the identity scenario,
    /// one base seed.
    pub fn new(
        underlays: Vec<String>,
        kinds: Vec<OverlayKind>,
        workload: Workload,
        model: ModelAxis,
        c_b: f64,
        seed: u64,
    ) -> SweepSpec {
        SweepSpec {
            underlays,
            workloads: vec![workload],
            backends: vec!["backend:scalar".to_string()],
            models: vec![model],
            kinds,
            scenarios: vec!["scenario:identity".to_string()],
            seeds: vec![seed],
            c_b,
        }
    }

    /// Enumerate the grid row-major in declaration order.
    pub fn cells(&self) -> Vec<SweepCell> {
        let mut out = Vec::with_capacity(
            self.underlays.len()
                * self.workloads.len()
                * self.backends.len()
                * self.models.len()
                * self.kinds.len()
                * self.scenarios.len()
                * self.seeds.len(),
        );
        let mut index = 0usize;
        for (ui, u) in self.underlays.iter().enumerate() {
            for wi in 0..self.workloads.len() {
                for (bi, b) in self.backends.iter().enumerate() {
                    for mi in 0..self.models.len() {
                        for &kind in &self.kinds {
                            for sc in &self.scenarios {
                                for &seed in &self.seeds {
                                    out.push(SweepCell {
                                        index,
                                        underlay_idx: ui,
                                        workload_idx: wi,
                                        backend_idx: bi,
                                        model_idx: mi,
                                        underlay: u.clone(),
                                        backend: b.clone(),
                                        kind,
                                        scenario: sc.clone(),
                                        base_seed: seed,
                                        cell_seed: derive_seed(seed, index as u64),
                                    });
                                    index += 1;
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The CRN pairing index of a cell: its enumeration position with the
    /// designer axis collapsed, so every kind in the same (underlay ×
    /// workload × backend × model × scenario × seed) slice maps to the same value.
    /// `derive_seed(base_seed, crn_index)` is the paired-comparison stream
    /// of the PR-4 convention: designers face identical trainer inits and
    /// scenario realizations, while distinct slices stay independent.
    pub fn crn_index(&self, cell: &SweepCell) -> u64 {
        let inner = self.scenarios.len() * self.seeds.len();
        let head = ((cell.underlay_idx * self.workloads.len() + cell.workload_idx)
            * self.backends.len()
            + cell.backend_idx)
            * self.models.len()
            + cell.model_idx;
        (head * inner + cell.index % inner) as u64
    }

    /// Execute the grid on the [`crate::util::parallel`] pool: resolve each
    /// distinct (underlay × workload × backend × model) context once, then run `f`
    /// over every cell, merging results (and picking the winning error) in
    /// enumeration order.
    pub fn run<T, F>(&self, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(&SweepCell, &SweepCtx) -> Result<T> + Sync,
    {
        let resolved = self.resolve_ctxs()?;
        let cells = self.cells();
        let results: Vec<Result<T>> = par_map_indexed(&cells, |_, cell| {
            f(cell, &resolved[self.ctx_index(cell)])
        });
        let mut out = Vec::with_capacity(results.len());
        for r in results {
            out.push(r?);
        }
        Ok(out)
    }

    /// Resolve every distinct (underlay × workload × backend × model)
    /// context in parallel, in enumeration order (first failing combo wins).
    fn resolve_ctxs(&self) -> Result<Vec<SweepCtx>> {
        let n_workloads = self.workloads.len();
        let n_backends = self.backends.len();
        let n_models = self.models.len();
        let combos: Vec<(usize, usize, usize, usize)> = (0..self.underlays.len())
            .flat_map(|ui| {
                (0..n_workloads).flat_map(move |wi| {
                    (0..n_backends).flat_map(move |bi| {
                        (0..n_models).map(move |mi| (ui, wi, bi, mi))
                    })
                })
            })
            .collect();
        let ctxs: Vec<Result<SweepCtx>> = par_map_indexed(&combos, |_, &(ui, wi, bi, mi)| {
            let net = Underlay::by_name(&self.underlays[ui])?;
            let backend = BackendProfile::by_name(&self.backends[bi])?;
            let m = self.models[mi];
            let dm = DelayModel::new(&net, &self.workloads[wi], m.s, m.access_bps, m.core_bps)
                .with_backend(backend);
            Ok(SweepCtx { net, dm })
        });
        let mut resolved = Vec::with_capacity(ctxs.len());
        for c in ctxs {
            resolved.push(c?);
        }
        Ok(resolved)
    }

    /// Index of `cell`'s context in [`SweepSpec::resolve_ctxs`]'s output.
    fn ctx_index(&self, cell: &SweepCell) -> usize {
        ((cell.underlay_idx * self.workloads.len() + cell.workload_idx)
            * self.backends.len()
            + cell.backend_idx)
            * self.models.len()
            + cell.model_idx
    }

    /// Execute the grid as *timeline* cells: design each distinct
    /// (underlay × workload × backend × model × kind) group's overlay once, realize
    /// every (scenario × seed) cell of the group as a `rounds`-round
    /// [`Timeline`], and hand `f` the cell, its context, and its timeline.
    ///
    /// This is the PR-6 batched dispatch point. Cells are enumerated
    /// row-major with scenarios × seeds innermost, so each group is one
    /// contiguous chunk of [`SweepSpec::cells`] sharing a single designed
    /// overlay — i.e. a single CSR *structure* — and differing only in
    /// weights. With `batch = true`, groups whose designer is static run
    /// all their lanes through one
    /// [`crate::maxplus::recurrence::step_csr_batched_into`] pass per round;
    /// with `batch = false` (or for round-varying designers — the MATCHA
    /// family re-samples its graph every round, so there is no shared
    /// structure to batch) every cell steps the per-cell path. Both modes
    /// draw lane seeds from the same CRN stream
    /// (`derive_seed(base_seed, crn_index)`, the PR-4 pairing), and the
    /// batched kernel is bit-identical to the per-cell one per lane, so the
    /// output is **byte-identical with the fast path on or off** (pinned in
    /// the tests below) — `batch` is a performance switch, never a semantics
    /// switch.
    ///
    /// Threading (PR 10): the groups fan out across the `--jobs` pool via
    /// [`par_map_indexed`], and per PR 3 anything *inside* a pool worker runs
    /// sequentially — including the intra-cell row partitioning the step
    /// kernels would otherwise use ([`crate::util::parallel::run_intracell`]
    /// inlines when called from a pool worker). So a sweep is parallel at
    /// cell granularity and each cell's kernel is the sequential oracle;
    /// intra-cell workers only engage for single-cell entry points (one-shot
    /// CLI designs, serve requests handled outside the batch fan-out).
    pub fn run_timelines<T, F>(&self, rounds: usize, batch: bool, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(&SweepCell, &SweepCtx, &Timeline) -> Result<T> + Sync,
    {
        let resolved = self.resolve_ctxs()?;
        let cells = self.cells();
        let block = (self.scenarios.len() * self.seeds.len()).max(1);
        let groups: Vec<&[SweepCell]> = cells.chunks(block).collect();
        let results: Vec<Result<Vec<T>>> = par_map_indexed(&groups, |_, group| {
            let ctx = &resolved[self.ctx_index(&group[0])];
            self.run_timeline_group(ctx, group, rounds, batch, &f)
        });
        let mut out = Vec::with_capacity(cells.len());
        for r in results {
            out.extend(r?);
        }
        Ok(out)
    }

    /// One structure-shared group of [`SweepSpec::run_timelines`]: all cells
    /// share `group[0]`'s designed overlay; lanes are the group's
    /// (scenario × seed) axis.
    fn run_timeline_group<T, F>(
        &self,
        ctx: &SweepCtx,
        group: &[SweepCell],
        rounds: usize,
        batch: bool,
        f: &F,
    ) -> Result<Vec<T>>
    where
        F: Fn(&SweepCell, &SweepCtx, &Timeline) -> Result<T>,
    {
        let overlay = design_with_underlay(group[0].kind, &ctx.dm, &ctx.net, self.c_b)?;
        let lanes: Vec<(Scenario, u64)> = group
            .iter()
            .map(|cell| {
                Ok((
                    Scenario::by_name(&cell.scenario)?,
                    derive_seed(cell.base_seed, self.crn_index(cell)),
                ))
            })
            .collect::<Result<_>>()?;
        let timelines: Vec<Timeline> = match overlay.static_graph() {
            Some(g) if batch => simulate_scenario_batched(&ctx.dm, g, &lanes, rounds),
            Some(g) => lanes
                .iter()
                .map(|(sc, seed)| simulate_scenario(&ctx.dm, g, sc, rounds, *seed))
                .collect(),
            None => lanes
                .iter()
                .map(|(sc, seed)| {
                    let mut proc = sc.process(ctx.dm.n, *seed);
                    let mut st = RoundState::unperturbed(ctx.dm.n, 0);
                    Timeline::simulate_dynamic(ctx.dm.n, rounds, |k| {
                        proc.advance_into(&mut st);
                        st.delay_digraph(&ctx.dm, &overlay.round_graph(k, *seed))
                    })
                })
                .collect(),
        };
        group
            .iter()
            .zip(&timelines)
            .map(|(cell, tl)| f(cell, ctx, tl))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::design_with_underlay;

    fn gaia_spec(kinds: Vec<OverlayKind>) -> SweepSpec {
        SweepSpec::new(
            vec!["gaia".to_string()],
            kinds,
            Workload::inaturalist(),
            ModelAxis {
                s: 1,
                access_bps: 10e9,
                core_bps: 1e9,
            },
            0.5,
            7,
        )
    }

    #[test]
    fn cells_enumerate_row_major_with_derived_seeds() {
        let mut spec = gaia_spec(vec![OverlayKind::Star, OverlayKind::Ring]);
        spec.underlays.push("geant".to_string());
        spec.scenarios.push("scenario:drift:0.3".to_string());
        spec.seeds = vec![7, 8];
        let cells = spec.cells();
        // 2 underlays × 1 workload × 1 model × 2 kinds × 2 scenarios × 2 seeds
        assert_eq!(cells.len(), 16);
        // row-major: underlay outermost, seeds innermost
        assert_eq!(cells[0].underlay, "gaia");
        assert_eq!(cells[0].kind, OverlayKind::Star);
        assert_eq!(cells[0].scenario, "scenario:identity");
        assert_eq!(cells[0].base_seed, 7);
        assert_eq!(cells[1].base_seed, 8);
        assert_eq!(cells[2].scenario, "scenario:drift:0.3");
        assert_eq!(cells[4].kind, OverlayKind::Ring);
        assert_eq!(cells[8].underlay, "geant");
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
            assert_eq!(c.workload_idx, 0);
            assert_eq!(c.cell_seed, crate::util::rng::derive_seed(c.base_seed, i as u64));
        }
    }

    #[test]
    fn workload_axis_enumerates_between_underlays_and_models() {
        let mut spec = gaia_spec(vec![OverlayKind::Ring]);
        spec.workloads = vec![Workload::inaturalist(), Workload::femnist()];
        spec.seeds = vec![7, 8];
        let cells = spec.cells();
        // 1 underlay × 2 workloads × 1 model × 1 kind × 1 scenario × 2 seeds
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].workload_idx, 0);
        assert_eq!(cells[1].workload_idx, 0);
        assert_eq!(cells[2].workload_idx, 1);
        assert_eq!(cells[3].workload_idx, 1);
        // run resolves a distinct delay model per workload
        let taus = spec
            .run(|cell, ctx| Ok((cell.workload_idx, ctx.dm.model_bits)))
            .unwrap();
        assert_eq!(taus[0].1, Workload::inaturalist().model_bits);
        assert_eq!(taus[2].1, Workload::femnist().model_bits);
    }

    #[test]
    fn backend_axis_enumerates_between_workloads_and_models() {
        let mut spec = gaia_spec(vec![OverlayKind::Ring]);
        spec.backends = vec!["backend:scalar".to_string(), "backend:grpc".to_string()];
        spec.seeds = vec![7, 8];
        let cells = spec.cells();
        // 1 underlay × 1 workload × 2 backends × 1 model × 1 kind × 1 scenario × 2 seeds
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].backend_idx, 0);
        assert_eq!(cells[1].backend_idx, 0);
        assert_eq!(cells[2].backend_idx, 1);
        assert_eq!(cells[3].backend_idx, 1);
        assert_eq!(cells[2].backend, "backend:grpc");
        // run resolves a distinct delay model per backend: gRPC prices the
        // same arc strictly above scalar (per-message overhead)
        let rows = spec
            .run(|cell, ctx| Ok((cell.backend_idx, ctx.dm.d_o(0, 1, 1, 1))))
            .unwrap();
        assert_eq!(rows[0].1.to_bits(), rows[1].1.to_bits());
        assert!(rows[2].1 > rows[0].1, "grpc {} vs scalar {}", rows[2].1, rows[0].1);
    }

    #[test]
    fn single_backend_grid_keeps_pr4_crn_indices() {
        // every pre-PR-9 caller has a one-element backends axis: the CRN
        // index must reduce to the PR-4 formula exactly.
        let mut spec = gaia_spec(vec![OverlayKind::Star, OverlayKind::Ring]);
        spec.underlays.push("geant".to_string());
        spec.scenarios.push("scenario:drift:0.3".to_string());
        spec.seeds = vec![7, 8];
        assert_eq!(spec.backends, vec!["backend:scalar".to_string()]);
        let inner = spec.scenarios.len() * spec.seeds.len();
        for c in spec.cells() {
            let pr4_head = (c.underlay_idx * spec.workloads.len() + c.workload_idx)
                * spec.models.len()
                + c.model_idx;
            assert_eq!(spec.crn_index(&c), (pr4_head * inner + c.index % inner) as u64);
        }
    }

    #[test]
    fn crn_index_collapses_exactly_the_designer_axis() {
        let mut spec = gaia_spec(vec![OverlayKind::Star, OverlayKind::Mst, OverlayKind::Ring]);
        spec.underlays.push("geant".to_string());
        spec.workloads = vec![Workload::inaturalist(), Workload::femnist()];
        spec.backends = vec!["backend:scalar".to_string(), "backend:rdma".to_string()];
        spec.scenarios.push("scenario:drift:0.3".to_string());
        spec.seeds = vec![7, 8];
        let cells = spec.cells();
        use std::collections::BTreeMap;
        #[allow(clippy::type_complexity)]
        let mut by_slice: BTreeMap<(usize, usize, usize, usize, String, u64), Vec<u64>> =
            BTreeMap::new();
        for c in &cells {
            by_slice
                .entry((
                    c.underlay_idx,
                    c.workload_idx,
                    c.backend_idx,
                    c.model_idx,
                    c.scenario.clone(),
                    c.base_seed,
                ))
                .or_default()
                .push(spec.crn_index(c));
        }
        // same slice ⇒ same CRN index for every designer
        let mut seen = std::collections::BTreeSet::new();
        for (slice, idxs) in by_slice {
            assert_eq!(idxs.len(), spec.kinds.len(), "{slice:?}");
            assert!(idxs.windows(2).all(|w| w[0] == w[1]), "{slice:?}: {idxs:?}");
            // distinct slices ⇒ distinct CRN indices
            assert!(seen.insert(idxs[0]), "{slice:?} reuses crn {}", idxs[0]);
        }
    }

    #[test]
    fn run_matches_sequential_reference_bitwise() {
        let spec = gaia_spec(vec![OverlayKind::Star, OverlayKind::Mst, OverlayKind::Ring]);
        let got = spec
            .run(|cell, ctx| {
                let overlay = design_with_underlay(cell.kind, &ctx.dm, &ctx.net, spec.c_b)?;
                Ok((cell.kind, overlay.cycle_time_ms(&ctx.dm)))
            })
            .unwrap();
        // sequential reference, bespoke-loop style
        let net = Underlay::by_name("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        for (i, kind) in [OverlayKind::Star, OverlayKind::Mst, OverlayKind::Ring]
            .into_iter()
            .enumerate()
        {
            let tau = design_with_underlay(kind, &dm, &net, 0.5)
                .unwrap()
                .cycle_time_ms(&dm);
            assert_eq!(got[i].0, kind);
            assert_eq!(got[i].1.to_bits(), tau.to_bits(), "{kind:?}");
        }
    }

    #[test]
    fn run_timelines_is_batch_invariant_and_jobs_invariant() {
        // The ISSUE-6 acceptance pin: the batched fast path is a performance
        // switch, never a semantics switch — output is byte-identical with
        // batch on vs off, and across --jobs 1/4, including a MATCHA group
        // (round-varying structure ⇒ per-cell fallback in both modes).
        let mut spec =
            gaia_spec(vec![OverlayKind::Mst, OverlayKind::Ring, OverlayKind::MatchaPlus]);
        spec.scenarios = vec![
            "scenario:straggler:3:x10".to_string(),
            "scenario:drift:0.3+churn:p0.05".to_string(),
        ];
        spec.seeds = vec![7, 8];
        let run = |jobs: usize, batch: bool| {
            let _guard = crate::util::parallel::jobs_test_guard();
            crate::util::parallel::set_jobs(jobs);
            let rows: Vec<(usize, Vec<u64>)> = spec
                .run_timelines(25, batch, |cell, _ctx, tl| {
                    let mut bits = Vec::with_capacity(26 * tl.n());
                    for k in 0..=25 {
                        for i in 0..tl.n() {
                            bits.push(tl.at(k, i).to_bits());
                        }
                    }
                    Ok((cell.index, bits))
                })
                .unwrap();
            crate::util::parallel::set_jobs(0);
            rows
        };
        let a = run(1, true);
        let b = run(4, true);
        let c = run(1, false);
        let d = run(4, false);
        assert_eq!(a, b, "--jobs must not change batched output");
        assert_eq!(c, d, "--jobs must not change per-cell output");
        assert_eq!(a, c, "batch fast path must be byte-identical to per-cell");
        // 1 underlay × 1 workload × 1 model × 3 kinds × 2 scenarios × 2 seeds
        assert_eq!(a.len(), 12);
        for (i, (idx, _)) in a.iter().enumerate() {
            assert_eq!(*idx, i, "results must merge in enumeration order");
        }
    }

    #[test]
    fn run_timelines_matches_sequential_reference_bitwise() {
        // Each batched cell equals a bespoke simulate_scenario call with the
        // CRN-paired seed on the group's designed overlay.
        let mut spec = gaia_spec(vec![OverlayKind::Mst]);
        spec.scenarios = vec![
            "scenario:identity".to_string(),
            "scenario:straggler:3:x10".to_string(),
        ];
        spec.seeds = vec![7, 9];
        let got = spec
            .run_timelines(20, true, |cell, _ctx, tl| {
                Ok((cell.scenario.clone(), tl.round_completion(20)))
            })
            .unwrap();
        let net = Underlay::by_name("gaia").unwrap();
        let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let overlay = design_with_underlay(OverlayKind::Mst, &dm, &net, 0.5).unwrap();
        let g = overlay.static_graph().unwrap();
        let cells = spec.cells();
        assert_eq!(got.len(), cells.len());
        for (row, cell) in got.iter().zip(&cells) {
            let sc = Scenario::by_name(&cell.scenario).unwrap();
            let seed = derive_seed(cell.base_seed, spec.crn_index(cell));
            let tl = simulate_scenario(&dm, g, &sc, 20, seed);
            assert_eq!(
                row.1.to_bits(),
                tl.round_completion(20).to_bits(),
                "{} / seed {}",
                row.0,
                cell.base_seed
            );
        }
    }

    #[test]
    fn bad_underlay_errors_deterministically() {
        let mut spec = gaia_spec(vec![OverlayKind::Ring]);
        spec.underlays = vec!["nope-net".to_string(), "also-bad".to_string()];
        let err = spec.run(|_, _| Ok(())).unwrap_err().to_string();
        assert!(err.contains("nope-net"), "first bad underlay must win: {err}");
    }

    #[test]
    fn cell_errors_pick_first_in_order() {
        let spec = gaia_spec(OverlayKind::all().to_vec());
        let err = spec
            .run(|cell, _| {
                if cell.index >= 2 {
                    anyhow::bail!("cell {} failed", cell.index)
                }
                Ok(cell.index)
            })
            .unwrap_err()
            .to_string();
        assert_eq!(err, "cell 2 failed");
    }
}
