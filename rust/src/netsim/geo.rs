//! Geodesic distances and the latency model.
//!
//! The paper estimates link latency from geography using the regression of
//! Gueye et al. (IMC'04): `latency_ms = 0.0085 · distance_km + 4` (App. F).
//! Distances between sites are great-circle (haversine) distances.

/// Mean Earth radius in kilometres.
pub const EARTH_RADIUS_KM: f64 = 6371.0;

/// A geographic site: a named point on the globe.
#[derive(Clone, Debug, PartialEq)]
pub struct Site {
    pub name: String,
    pub lat: f64,
    pub lon: f64,
}

impl Site {
    pub fn new(name: &str, lat: f64, lon: f64) -> Site {
        assert!((-90.0..=90.0).contains(&lat), "bad latitude {lat}");
        assert!((-180.0..=180.0).contains(&lon), "bad longitude {lon}");
        Site {
            name: name.to_string(),
            lat,
            lon,
        }
    }
}

/// Great-circle distance between two (lat, lon) points, in km.
pub fn haversine_km(lat1: f64, lon1: f64, lat2: f64, lon2: f64) -> f64 {
    let (phi1, phi2) = (lat1.to_radians(), lat2.to_radians());
    let dphi = (lat2 - lat1).to_radians();
    let dlambda = (lon2 - lon1).to_radians();
    let a = (dphi / 2.0).sin().powi(2) + phi1.cos() * phi2.cos() * (dlambda / 2.0).sin().powi(2);
    2.0 * EARTH_RADIUS_KM * a.sqrt().atan2((1.0 - a).sqrt())
}

/// Distance between two sites, in km.
pub fn distance_km(a: &Site, b: &Site) -> f64 {
    haversine_km(a.lat, a.lon, b.lat, b.lon)
}

/// Link latency from distance: `0.0085 · km + 4` milliseconds (Gueye et al.
/// constraint-based geolocation regression, as used in the paper's App. F).
pub fn latency_ms(dist_km: f64) -> f64 {
    0.0085 * dist_km + 4.0
}

/// Site-to-site single-link latency.
pub fn link_latency_ms(a: &Site, b: &Site) -> f64 {
    latency_ms(distance_km(a, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_distance() {
        assert!(haversine_km(48.85, 2.35, 48.85, 2.35) < 1e-9);
    }

    #[test]
    fn paris_london_about_344km() {
        let d = haversine_km(48.8566, 2.3522, 51.5074, -0.1278);
        assert!((d - 344.0).abs() < 10.0, "d={d}");
    }

    #[test]
    fn newyork_tokyo_about_10850km() {
        let d = haversine_km(40.7128, -74.0060, 35.6762, 139.6503);
        assert!((d - 10850.0).abs() < 100.0, "d={d}");
    }

    #[test]
    fn antipodal_near_half_circumference() {
        let d = haversine_km(0.0, 0.0, 0.0, 180.0);
        let half = std::f64::consts::PI * EARTH_RADIUS_KM;
        assert!((d - half).abs() < 1.0);
    }

    #[test]
    fn symmetry() {
        let d1 = haversine_km(10.0, 20.0, -30.0, 140.0);
        let d2 = haversine_km(-30.0, 140.0, 10.0, 20.0);
        assert!((d1 - d2).abs() < 1e-9);
    }

    #[test]
    fn latency_model_constants() {
        assert_eq!(latency_ms(0.0), 4.0);
        assert!((latency_ms(1000.0) - 12.5).abs() < 1e-12);
    }

    #[test]
    fn site_validation() {
        let s = Site::new("Paris", 48.85, 2.35);
        assert_eq!(s.name, "Paris");
    }

    #[test]
    #[should_panic(expected = "bad latitude")]
    fn site_rejects_bad_lat() {
        Site::new("nope", 123.0, 0.0);
    }
}
