//! Underlay topologies: the five networks of Table 3 plus GML import and a
//! deterministic ISP-topology generator.
//!
//! | name    | silos | links | provenance in the paper                    |
//! |---------|-------|-------|--------------------------------------------|
//! | gaia    | 11    | 55    | full mesh over Gaia/AWS region locations    |
//! | aws-na  | 22    | 231   | full mesh over AWS North-America sites      |
//! | geant   | 40    | 61    | Topology Zoo (Géant, European NREN)         |
//! | exodus  | 79    | 147   | Rocketfuel ISP 3967 (US)                    |
//! | ebone   | 87    | 161   | Rocketfuel ISP 1755 (Europe)                |
//!
//! **Substitution note (see DESIGN.md §3):** the image has no network
//! access, so the Rocketfuel/Topology-Zoo GML files are replaced by
//! deterministic reconstructions with the *paper's exact node and link
//! counts*: routers are spawned around real PoP cities of each ISP and
//! wired as geodesic-MST + shortest-fill, which reproduces the delay
//! distribution that drives every cycle-time result. Real GML files can be
//! dropped in via [`Underlay::from_gml`] without code changes.
//!
//! Beyond Table 3, [`Underlay::by_name`] also resolves seeded synthetic
//! specs (`synth:waxman:500:seed7`, see [`super::synth`]) so larger
//! scenario studies use the same entry point.

use super::geo::{distance_km, Site};
use super::gml;
use crate::graph::mst::prim;
use crate::graph::UnGraph;
use anyhow::{bail, Context, Result};

/// An underlay: router sites (silo i attaches to router i through its access
/// link) and the core network (edge weights = geodesic distance in km).
#[derive(Clone, Debug)]
pub struct Underlay {
    pub name: String,
    pub sites: Vec<Site>,
    pub core: UnGraph,
}

impl Underlay {
    pub fn n_silos(&self) -> usize {
        self.sites.len()
    }

    pub fn n_links(&self) -> usize {
        self.core.m()
    }

    /// All built-in network names (Table 3 order).
    pub fn builtin_names() -> &'static [&'static str] {
        &["gaia", "aws-na", "geant", "exodus", "ebone"]
    }

    /// Resolve any underlay name: a Table-3 builtin, or a seeded synthetic
    /// spec `synth:<family>:<n>[:seed<u64>]` (see [`super::synth`]). This is
    /// the single entry point the CLI, experiments, and tests go through —
    /// a thin delegate into the [`crate::spec::Resolve`] registry, so every
    /// call site shares the registry's pinned error format and suggestions.
    ///
    /// # Examples
    ///
    /// ```
    /// use fedtopo::netsim::underlay::Underlay;
    ///
    /// // a Table-3 builtin and a seeded synthetic generator spec
    /// assert_eq!(Underlay::by_name("gaia").unwrap().n_silos(), 11);
    /// assert_eq!(Underlay::by_name("synth:waxman:50:seed7").unwrap().n_silos(), 50);
    ///
    /// // typos get the registry's uniform error with a suggestion
    /// let err = Underlay::by_name("gaiaa").unwrap_err().to_string();
    /// assert!(err.starts_with("cannot resolve network 'gaiaa'"));
    /// assert!(err.ends_with("did you mean 'gaia'?"));
    /// ```
    pub fn by_name(name: &str) -> Result<Underlay> {
        <Underlay as crate::spec::Resolve>::resolve(name)
    }

    /// Construct an underlay by name (alias of [`Underlay::by_name`], kept
    /// for the many call sites that predate the synth generators).
    pub fn builtin(name: &str) -> Result<Underlay> {
        Self::by_name(name)
    }

    /// Load an underlay from a Topology Zoo / Rocketfuel GML document.
    /// Nodes without coordinates are rejected (the latency model needs
    /// geography); use the built-ins or patch the file.
    pub fn from_gml(name: &str, src: &str) -> Result<Underlay> {
        let g = gml::parse_graph(src)?;
        let idx = gml::dense_index(&g);
        let mut sites = Vec::with_capacity(g.nodes.len());
        for n in &g.nodes {
            let lat = n
                .lat
                .with_context(|| format!("node '{}' lacks Latitude", n.label))?;
            let lon = n
                .lon
                .with_context(|| format!("node '{}' lacks Longitude", n.label))?;
            sites.push(Site::new(&n.label, lat, lon));
        }
        let mut core = UnGraph::new(sites.len());
        for e in &g.edges {
            let (u, v) = (idx[&e.source], idx[&e.target]);
            if u != v && !core.has_edge(u, v) {
                core.add_edge(u, v, distance_km(&sites[u], &sites[v]));
            }
        }
        if !core.is_connected() {
            bail!("underlay '{name}' is not connected");
        }
        Ok(Underlay {
            name: name.to_string(),
            sites,
            core,
        })
    }

    /// Export to GML (round-trips through [`Underlay::from_gml`]).
    pub fn to_gml(&self) -> String {
        let nodes = self
            .sites
            .iter()
            .enumerate()
            .map(|(i, s)| gml::GmlNode {
                id: i as i64,
                label: s.name.clone(),
                lat: Some(s.lat),
                lon: Some(s.lon),
            })
            .collect();
        let edges = self
            .core
            .edges()
            .iter()
            .map(|&(u, v, _)| gml::GmlEdge {
                source: u as i64,
                target: v as i64,
            })
            .collect();
        gml::write_graph(
            &gml::GmlGraph { nodes, edges },
            &self.name,
        )
    }
}

impl crate::spec::Resolve for Underlay {
    const KIND: &'static str = "network";

    fn names() -> Vec<&'static str> {
        Underlay::builtin_names().to_vec()
    }

    fn aliases() -> Vec<&'static str> {
        vec!["aws"]
    }

    fn grammar() -> String {
        format!(
            "{} or synth:<family>:<n>[:seed<u64>] (family: {})",
            Underlay::builtin_names().join("|"),
            super::synth::families().join("|"),
        )
    }

    fn parse_spec(input: &str) -> Result<Underlay, crate::spec::ResolveError> {
        use crate::spec::{Resolve, ResolveError};
        if let Some(spec) = input.strip_prefix("synth:") {
            return super::synth::from_spec(spec);
        }
        match input {
            "gaia" => Ok(full_mesh("gaia", gaia_sites())),
            "aws-na" | "aws" => Ok(full_mesh("aws-na", aws_na_sites())),
            "geant" => Ok(sparse_from_sites("geant", geant_sites(), 61)),
            "exodus" => Ok(isp_like("exodus", &exodus_pops(), 79, 147, 0xE70D05)),
            "ebone" => Ok(isp_like("ebone", &ebone_pops(), 87, 161, 0xEB07E)),
            other => {
                let mut candidates = Underlay::builtin_names().to_vec();
                candidates.push("aws");
                Err(ResolveError::new(Self::KIND, input, "unknown network")
                    .expected(Underlay::grammar())
                    .suggest(other, &candidates))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Construction helpers
// ---------------------------------------------------------------------------

/// Full mesh over the given sites (the paper's synthetic Gaia / AWS-NA
/// underlays: "we consider a full-meshed underlay", App. G.1).
fn full_mesh(name: &str, sites: Vec<Site>) -> Underlay {
    let n = sites.len();
    let mut core = UnGraph::new(n);
    for i in 0..n {
        for j in i + 1..n {
            core.add_edge(i, j, distance_km(&sites[i], &sites[j]));
        }
    }
    Underlay {
        name: name.to_string(),
        sites,
        core,
    }
}

/// Sparse network: geodesic MST + shortest non-tree edges until `links`.
/// Deterministic; matches the paper's node/link counts for Géant.
fn sparse_from_sites(name: &str, sites: Vec<Site>, links: usize) -> Underlay {
    let mesh = full_mesh(name, sites);
    let tree = prim(&mesh.core).expect("full mesh is connected");
    let mut core = tree;
    // candidate extra edges sorted by distance, deterministic tie-break
    let mut cands: Vec<(usize, usize, f64)> = mesh
        .core
        .edges()
        .iter()
        .cloned()
        .filter(|&(u, v, _)| !core.has_edge(u, v))
        .collect();
    cands.sort_by(|a, b| {
        a.2.partial_cmp(&b.2)
            .unwrap()
            .then_with(|| (a.0, a.1).cmp(&(b.0, b.1)))
    });
    for (u, v, w) in cands {
        if core.m() >= links {
            break;
        }
        core.add_edge(u, v, w);
    }
    assert_eq!(core.m(), links, "not enough candidates for target links");
    Underlay {
        name: mesh.name,
        sites: mesh.sites,
        core,
    }
}

/// Rocketfuel-style router-level ISP: spawn `n` routers cycling through the
/// ISP's PoP cities with deterministic jitter (a PoP hosts several routers),
/// then wire MST + shortest-fill to the paper's link count.
fn isp_like(name: &str, pops: &[(&str, f64, f64)], n: usize, links: usize, seed: u64) -> Underlay {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut sites = Vec::with_capacity(n);
    for k in 0..n {
        let (city, lat, lon) = pops[k % pops.len()];
        let copy = k / pops.len();
        // ≤ ~30 km jitter: routers of one PoP are metro-area colocated.
        let jlat = (rng.f64() - 0.5) * 0.5;
        let jlon = (rng.f64() - 0.5) * 0.5;
        sites.push(Site::new(
            &format!("{city}-r{copy}"),
            (lat + jlat).clamp(-89.9, 89.9),
            lon + jlon,
        ));
    }
    sparse_from_sites(name, sites, links)
}

// ---------------------------------------------------------------------------
// Site catalogues
// ---------------------------------------------------------------------------

fn gaia_sites() -> Vec<Site> {
    // The 11 Gaia sites = AWS regions of Hsieh et al. (NSDI'17).
    [
        ("Virginia", 39.04, -77.49),
        ("California", 37.35, -121.95),
        ("Oregon", 45.84, -119.70),
        ("Ireland", 53.35, -6.26),
        ("Frankfurt", 50.11, 8.68),
        ("Tokyo", 35.68, 139.69),
        ("Seoul", 37.57, 126.98),
        ("Singapore", 1.35, 103.82),
        ("Sydney", -33.87, 151.21),
        ("Mumbai", 19.08, 72.88),
        ("SaoPaulo", -23.55, -46.63),
    ]
    .iter()
    .map(|&(n, la, lo)| Site::new(n, la, lo))
    .collect()
}

fn aws_na_sites() -> Vec<Site> {
    // 22 AWS North-America region/edge cities.
    [
        ("Ashburn", 39.04, -77.49),
        ("Columbus", 39.96, -83.00),
        ("SanJose", 37.34, -121.89),
        ("Boardman", 45.84, -119.70),
        ("Montreal", 45.50, -73.57),
        ("Toronto", 43.65, -79.38),
        ("Calgary", 51.05, -114.07),
        ("Queretaro", 20.59, -100.39),
        ("NewYork", 40.71, -74.01),
        ("Newark", 40.74, -74.17),
        ("Boston", 42.36, -71.06),
        ("Philadelphia", 39.95, -75.17),
        ("Atlanta", 33.75, -84.39),
        ("Miami", 25.76, -80.19),
        ("Chicago", 41.88, -87.63),
        ("Dallas", 32.78, -96.80),
        ("Houston", 29.76, -95.37),
        ("Denver", 39.74, -104.99),
        ("Phoenix", 33.45, -112.07),
        ("LosAngeles", 34.05, -118.24),
        ("Seattle", 47.61, -122.33),
        ("Minneapolis", 44.98, -93.27),
    ]
    .iter()
    .map(|&(n, la, lo)| Site::new(n, la, lo))
    .collect()
}

fn geant_sites() -> Vec<Site> {
    // 40 Géant points of presence (European NREN capitals/hubs).
    [
        ("Amsterdam", 52.37, 4.90),
        ("London", 51.51, -0.13),
        ("Paris", 48.86, 2.35),
        ("Frankfurt", 50.11, 8.68),
        ("Geneva", 46.20, 6.14),
        ("Milan", 45.46, 9.19),
        ("Vienna", 48.21, 16.37),
        ("Prague", 50.08, 14.44),
        ("Budapest", 47.50, 19.04),
        ("Madrid", 40.42, -3.70),
        ("Lisbon", 38.72, -9.14),
        ("Dublin", 53.35, -6.26),
        ("Brussels", 50.85, 4.35),
        ("Luxembourg", 49.61, 6.13),
        ("Copenhagen", 55.68, 12.57),
        ("Stockholm", 59.33, 18.07),
        ("Helsinki", 60.17, 24.94),
        ("Oslo", 59.91, 10.75),
        ("Warsaw", 52.23, 21.01),
        ("Bratislava", 48.15, 17.11),
        ("Ljubljana", 46.06, 14.51),
        ("Zagreb", 45.81, 15.98),
        ("Bucharest", 44.43, 26.10),
        ("Sofia", 42.70, 23.32),
        ("Athens", 37.98, 23.73),
        ("Rome", 41.90, 12.50),
        ("Zurich", 47.37, 8.54),
        ("Tallinn", 59.44, 24.75),
        ("Riga", 56.95, 24.11),
        ("Vilnius", 54.69, 25.28),
        ("Nicosia", 35.19, 33.38),
        ("Valletta", 35.90, 14.51),
        ("Belgrade", 44.79, 20.45),
        ("Podgorica", 42.44, 19.26),
        ("Skopje", 41.99, 21.43),
        ("Tirana", 41.33, 19.82),
        ("Chisinau", 47.01, 28.86),
        ("Kyiv", 50.45, 30.52),
        ("Istanbul", 41.01, 28.98),
        ("Marseille", 43.30, 5.37),
    ]
    .iter()
    .map(|&(n, la, lo)| Site::new(n, la, lo))
    .collect()
}

fn exodus_pops() -> Vec<(&'static str, f64, f64)> {
    // Exodus Communications PoP cities (Rocketfuel AS3967, US backbone).
    vec![
        ("PaloAlto", 37.44, -122.14),
        ("SantaClara", 37.35, -121.95),
        ("ElSegundo", 33.92, -118.40),
        ("Irvine", 33.68, -117.83),
        ("Oakland", 37.80, -122.27),
        ("Sacramento", 38.58, -121.49),
        ("Seattle", 47.61, -122.33),
        ("Portland", 45.52, -122.68),
        ("Chicago", 41.88, -87.63),
        ("Austin", 30.27, -97.74),
        ("Dallas", 32.78, -96.80),
        ("Houston", 29.76, -95.37),
        ("Atlanta", 33.75, -84.39),
        ("Miami", 25.76, -80.19),
        ("Tampa", 27.95, -82.46),
        ("Herndon", 38.97, -77.39),
        ("JerseyCity", 40.73, -74.08),
        ("NewYork", 40.71, -74.01),
        ("Boston", 42.36, -71.06),
        ("Waltham", 42.38, -71.24),
        ("Philadelphia", 39.95, -75.17),
        ("Toronto", 43.65, -79.38),
        ("Denver", 39.74, -104.99),
        ("Phoenix", 33.45, -112.07),
    ]
}

fn ebone_pops() -> Vec<(&'static str, f64, f64)> {
    // Ebone PoP cities (Rocketfuel AS1755, pan-European backbone).
    vec![
        ("London", 51.51, -0.13),
        ("Paris", 48.86, 2.35),
        ("Amsterdam", 52.37, 4.90),
        ("Frankfurt", 50.11, 8.68),
        ("Brussels", 50.85, 4.35),
        ("Geneva", 46.20, 6.14),
        ("Zurich", 47.37, 8.54),
        ("Milan", 45.46, 9.19),
        ("Vienna", 48.21, 16.37),
        ("Stockholm", 59.33, 18.07),
        ("Copenhagen", 55.68, 12.57),
        ("Oslo", 59.91, 10.75),
        ("Madrid", 40.42, -3.70),
        ("Barcelona", 41.39, 2.17),
        ("Lisbon", 38.72, -9.14),
        ("Dublin", 53.35, -6.26),
        ("Hamburg", 53.55, 9.99),
        ("Munich", 48.14, 11.58),
        ("Berlin", 52.52, 13.40),
        ("Prague", 50.08, 14.44),
        ("Warsaw", 52.23, 21.01),
        ("Budapest", 47.50, 19.04),
        ("Rome", 41.90, 12.50),
        ("Helsinki", 60.17, 24.94),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_node_and_link_counts() {
        // The paper's Table 3 "Silos"/"Links" columns, exactly.
        for (name, silos, links) in [
            ("gaia", 11, 55),
            ("aws-na", 22, 231),
            ("geant", 40, 61),
            ("exodus", 79, 147),
            ("ebone", 87, 161),
        ] {
            let u = Underlay::builtin(name).unwrap();
            assert_eq!(u.n_silos(), silos, "{name} silos");
            assert_eq!(u.n_links(), links, "{name} links");
            assert!(u.core.is_connected(), "{name} connected");
        }
    }

    #[test]
    fn deterministic_construction() {
        let a = Underlay::builtin("exodus").unwrap();
        let b = Underlay::builtin("exodus").unwrap();
        assert_eq!(a.core.edges(), b.core.edges());
        assert_eq!(a.sites, b.sites);
    }

    #[test]
    fn unknown_name_rejected() {
        assert!(Underlay::builtin("arpanet").is_err());
    }

    #[test]
    fn gml_roundtrip() {
        let u = Underlay::builtin("gaia").unwrap();
        let text = u.to_gml();
        let u2 = Underlay::from_gml("gaia", &text).unwrap();
        assert_eq!(u2.n_silos(), 11);
        assert_eq!(u2.n_links(), 55);
        // weights recomputed from coordinates → identical
        for (e1, e2) in u.core.edges().iter().zip(u2.core.edges()) {
            assert_eq!(e1.0, e2.0);
            assert_eq!(e1.1, e2.1);
            assert!((e1.2 - e2.2).abs() < 1e-9);
        }
    }

    #[test]
    fn gaia_spans_continents() {
        let u = Underlay::builtin("gaia").unwrap();
        // Sydney–Ireland should be > 15000 km
        let d = u.core.weight(3, 8).unwrap();
        assert!(d > 15000.0, "d={d}");
    }

    #[test]
    fn geant_distances_reasonable() {
        let u = Underlay::builtin("geant").unwrap();
        // every core link is intra-European: < 3600 km
        for &(_, _, w) in u.core.edges() {
            assert!(w < 3600.0, "link too long: {w} km");
            assert!(w > 0.0);
        }
    }

    #[test]
    fn isp_networks_sparse() {
        for name in ["geant", "exodus", "ebone"] {
            let u = Underlay::builtin(name).unwrap();
            let full = u.n_silos() * (u.n_silos() - 1) / 2;
            assert!(u.n_links() * 4 < full, "{name} should be sparse");
        }
    }

    #[test]
    fn from_gml_rejects_disconnected() {
        let src = "graph [ node [ id 0 label \"a\" Latitude 0 Longitude 0 ] node [ id 1 label \"b\" Latitude 1 Longitude 1 ] ]";
        assert!(Underlay::from_gml("x", src).is_err());
    }
}
