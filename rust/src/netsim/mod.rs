//! Network simulator: underlays, routing, and the Eq. (3) delay model.
//!
//! The paper evaluates on five underlays (Table 3) — Gaia and AWS North
//! America (full-meshed synthetic networks over data-center locations),
//! Géant (European research network), and the Rocketfuel-inferred Exodus and
//! Ebone ISP backbones. Silos sit behind access links attached to underlay
//! routers; messages route along latency-shortest paths; the available
//! bandwidth of a route follows the configured [`routing::BwModel`].
//!
//! Beyond the paper's five networks, [`synth`] generates seeded synthetic
//! underlays (Waxman, Barabási–Albert, random-geometric, k-ary grid) up to
//! N = 50 000 silos (PR 5 raised the cap from 5 000 when the flat graph
//! core removed the designer/simulator memory walls), addressable next to
//! the builtins via `synth:<family>:<n>[:seed<u64>]` names.
//!
//! Beyond static delays, [`scenario`] describes *time-varying* operating
//! conditions — bandwidth drift, periodic congestion, straggler silos,
//! link/silo churn, correlated regional outages — addressed next to the
//! underlay names via `scenario:<family>:<args>` specs
//! (`scenario:straggler:3:x10`).
//!
//! * [`geo`] — haversine distances + the `0.0085·km + 4` ms latency model.
//! * [`underlay`] — built-in networks, ISP generator, GML import/export.
//! * [`synth`] — seeded synthetic underlay generators (`synth:` specs).
//! * [`gml`] — Graph Modelling Language parser/writer.
//! * [`routing`] — all-pairs routes: `l(i,j)` and `A(i',j')`, flat-stored
//!   (grids + one path arena; see the module's memory-layout docs).
//! * [`delay`] — Eq. (3) delays + max-plus digraph materialization (arc
//!   list and reusable CSR forms).
//! * [`backend`] — message-level communication backends (`backend:` specs):
//!   chunking, per-message overhead, pipelining; `backend:scalar` is the
//!   bit-identical default.
//! * [`timeline`] — Algorithm 3 wall-clock reconstruction (batch +
//!   zero-alloc incremental stepper).
//! * [`scenario`] — time-varying perturbations (`scenario:` specs) + the
//!   dynamic wall-clock simulation (in-place CSR reweighting; dense
//!   oracle retained).

pub mod geo;
pub mod gml;
pub mod underlay;
pub mod synth;
pub mod routing;
pub mod delay;
pub mod backend;
pub mod timeline;
pub mod scenario;
