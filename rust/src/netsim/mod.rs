//! Network simulator: underlays, routing, and the Eq. (3) delay model.
//!
//! The paper evaluates on five underlays (Table 3) — Gaia and AWS North
//! America (full-meshed synthetic networks over data-center locations),
//! Géant (European research network), and the Rocketfuel-inferred Exodus and
//! Ebone ISP backbones. Silos sit behind access links attached to underlay
//! routers; messages route along latency-shortest paths; the available
//! bandwidth of a route follows the configured [`routing::BwModel`].
//!
//! * [`geo`] — haversine distances + the `0.0085·km + 4` ms latency model.
//! * [`underlay`] — built-in networks, ISP generator, GML import/export.
//! * [`gml`] — Graph Modelling Language parser/writer.
//! * [`routing`] — all-pairs routes: `l(i,j)` and `A(i',j')`.
//! * [`delay`] — Eq. (3) delays + max-plus digraph materialization.
//! * [`timeline`] — Algorithm 3 wall-clock reconstruction.

pub mod geo;
pub mod gml;
pub mod underlay;
pub mod routing;
pub mod delay;
pub mod timeline;
