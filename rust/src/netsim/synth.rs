//! Seeded synthetic underlay generators — scenario studies beyond Table 3.
//!
//! The paper evaluates on five fixed networks (11–87 silos). Follow-up work
//! (multigraph topologies, SmartFLow) measures topology design on far larger
//! and more varied underlays, so the repo grows four classic random-network
//! families, each emitting a fully geo-plausible [`Underlay`] (random sites
//! on the globe, link weights = geodesic km) up to N = [`MAX_SILOS`]:
//!
//! | family   | wiring                                                    |
//! |----------|-----------------------------------------------------------|
//! | `waxman` | Waxman 1988: P(u,v) = β·exp(−d/αL), ∪ geodesic MST        |
//! | `ba`     | Barabási–Albert preferential attachment (m = 2)           |
//! | `geo`    | random geometric: all pairs within the MST bottleneck     |
//! | `grid`   | k-ary 2-D grid over a continental bounding box            |
//!
//! Every family is **deterministic given its spec** and **connected by
//! construction**: `waxman`/`geo` union the geodesic MST, `ba`/`grid`
//! attach each node to the existing component.
//!
//! ## Naming scheme
//!
//! Specs are strings `synth:<family>:<n>[:seed<u64>]` (default seed 7),
//! resolved by [`Underlay::by_name`] alongside the builtin names, so every
//! designer, experiment, and CLI flag accepts e.g.
//! `--network synth:waxman:500:seed7`.

use super::geo::{distance_km, Site, EARTH_RADIUS_KM};
use super::underlay::Underlay;
use crate::graph::UnGraph;
use crate::spec::ResolveError;
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Largest N a spec may request. The PR-5 flat-storage refactor (CSR delay
/// digraphs, implicit-Kₙ designers, arena-backed routing) removed the
/// memory walls that used to cap specs at 5 000 silos, and PR 7's tiered
/// routing (lazy LRU rows + landmark regions past `ROUTES_DENSE_MAX_N`)
/// removed the last O(N²) routing product, so the hard stop is now
/// 100 000. Generation *time* (PR 10): `ba` and `grid` are O(n) wiring;
/// `geo` bins sites into a 3-D chord grid and scans only candidate cells
/// within the connection radius; `waxman` draws exactly one RNG value per
/// pair (the pinned stream forbids anything sub-quadratic) but skips the
/// haversine for the ~60% of draws that can never connect and chord-bounds
/// most of the rest, so the per-pair constant is a few flops, not trig.
/// The geodesic MST each of those unions in remains an O(n²) Prim.
pub const MAX_SILOS: usize = 100_000;

/// The supported generator families.
pub fn families() -> &'static [&'static str] {
    &["waxman", "ba", "geo", "grid"]
}

/// Parse and build `"<family>:<n>[:seed<u64>]"` (the `synth:` prefix is
/// stripped by [`Underlay::by_name`]). Errors render in the uniform
/// [`crate::spec`] registry format with the caller's full `synth:`-prefixed
/// input echoed.
pub fn from_spec(spec: &str) -> Result<Underlay, ResolveError> {
    use crate::spec::Resolve;
    let input = format!("synth:{spec}");
    let err = |reason: String| {
        ResolveError::new(Underlay::KIND, &input, reason).expected(Underlay::grammar())
    };
    let parts: Vec<&str> = spec.split(':').collect();
    if parts.len() < 2 || parts.len() > 3 {
        return Err(err("bad synth spec shape".to_string()));
    }
    let family = parts[0];
    let n: usize = match parts[1].parse() {
        Ok(n) => n,
        Err(_) => return Err(err(format!("bad silo count '{}'", parts[1]))),
    };
    let seed: u64 = match parts.get(2) {
        None => 7,
        Some(s) => match s.strip_prefix("seed").and_then(|v| v.parse().ok()) {
            Some(v) => v,
            None => return Err(err(format!("bad seed '{s}' (use seed<u64>)"))),
        },
    };
    generate(family, n, seed).map_err(|e| err(e.to_string()).suggest(family, families()))
}

/// Build one synthetic underlay. The emitted name is the canonical spec
/// (`synth:<family>:<n>:seed<seed>`), so the underlay round-trips through
/// [`Underlay::by_name`].
pub fn generate(family: &str, n: usize, seed: u64) -> Result<Underlay> {
    if !(3..=MAX_SILOS).contains(&n) {
        bail!("synth underlay needs 3 ≤ n ≤ {MAX_SILOS}, got {n}");
    }
    let mut rng = spec_rng(family, n, seed);
    let (sites, core) = match family {
        "waxman" => waxman(n, &mut rng),
        "ba" => barabasi_albert(n, &mut rng),
        "geo" => random_geometric(n, &mut rng),
        "grid" => grid(n, &mut rng),
        other => bail!("unknown synth family '{other}'"),
    };
    debug_assert!(core.is_connected(), "{family}:{n} generator must connect");
    Ok(Underlay {
        name: format!("synth:{family}:{n}:seed{seed}"),
        sites,
        core,
    })
}

/// The deterministic per-spec RNG every generator consumes, decorrelated
/// across (family, n, seed) specs. Factored out so the all-pairs oracle
/// pins in tests replay the exact stream [`generate`] uses.
fn spec_rng(family: &str, n: usize, seed: u64) -> Rng {
    let fam_tag: u64 = family.bytes().fold(0xF00Du64, |h, b| {
        h.wrapping_mul(0x100000001B3).wrapping_add(b as u64)
    });
    Rng::new(seed ^ fam_tag ^ (n as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// 3-D unit vector of a site. The straight-line chord between unit vectors
/// is a *strictly monotone* proxy for the great-circle distance
/// (`chord = 2·sin(d / 2R)`), so chord comparisons order pairs exactly like
/// geodesic comparisons — at three subtractions and three multiplies per
/// pair instead of haversine trigonometry.
fn unit_vec(s: &Site) -> [f64; 3] {
    let (phi, lam) = (s.lat.to_radians(), s.lon.to_radians());
    [phi.cos() * lam.cos(), phi.cos() * lam.sin(), phi.sin()]
}

/// Squared chord length between two unit vectors.
#[inline]
fn chord_sq(a: &[f64; 3], b: &[f64; 3]) -> f64 {
    let (dx, dy, dz) = (a[0] - b[0], a[1] - b[1], a[2] - b[2]);
    dx * dx + dy * dy + dz * dz
}

/// Unit-sphere chord corresponding to a geodesic distance in km (capped at
/// the antipodal chord, 2).
#[inline]
fn chord_of_km(d_km: f64) -> f64 {
    2.0 * (d_km / (2.0 * EARTH_RADIUS_KM)).min(std::f64::consts::FRAC_PI_2).sin()
}

/// Relative slack applied to every chord-space prefilter bound. Chord and
/// haversine round differently at the ~1e-15 level; 1e-9 dominates that by
/// six orders of magnitude while rejecting essentially nothing extra, so
/// the exact haversine test downstream sees every pair it would have seen
/// under an all-pairs scan — the basis of the bit-identity pins below.
const CHORD_SLACK: f64 = 1e-9;

/// Exact maximum pairwise geodesic distance: a cheap chord² argmax scan,
/// then exact haversines over only the near-max candidate set. Equals the
/// all-pairs `distance_km` max bit for bit (max folds are order-free, and
/// the slack guarantees the true argmax pair is among the candidates).
fn max_pair_distance_km(sites: &[Site], uv: &[[f64; 3]]) -> f64 {
    let n = sites.len();
    let mut max_c = 0.0f64;
    for i in 0..n {
        for j in i + 1..n {
            let c = chord_sq(&uv[i], &uv[j]);
            if c > max_c {
                max_c = c;
            }
        }
    }
    let thr = max_c * (1.0 - CHORD_SLACK);
    let mut l_max = 0.0f64;
    for i in 0..n {
        for j in i + 1..n {
            if chord_sq(&uv[i], &uv[j]) >= thr {
                l_max = l_max.max(distance_km(&sites[i], &sites[j]));
            }
        }
    }
    l_max
}

/// Random sites over the inhabited latitude band, uniform in longitude.
fn random_sites(n: usize, rng: &mut Rng) -> Vec<Site> {
    (0..n)
        .map(|i| {
            let lat = -55.0 + 120.0 * rng.f64(); // [-55, 65)
            let lon = -180.0 + 360.0 * rng.f64(); // [-180, 180)
            Site::new(&format!("s{i}"), lat, lon)
        })
        .collect()
}

/// Dense O(n²) Prim over the implicit geodesic metric — O(n) memory, no
/// materialized complete graph. Returns the tree edges (u, v, km).
fn geodesic_mst(sites: &[Site]) -> Vec<(usize, usize, f64)> {
    let n = sites.len();
    let mut in_tree = vec![false; n];
    let mut best_d = vec![f64::INFINITY; n];
    let mut best_u = vec![0usize; n];
    in_tree[0] = true;
    for v in 1..n {
        best_d[v] = distance_km(&sites[0], &sites[v]);
    }
    let mut edges = Vec::with_capacity(n - 1);
    for _ in 1..n {
        let mut v_star = usize::MAX;
        let mut d_star = f64::INFINITY;
        for v in 0..n {
            if !in_tree[v] && best_d[v] < d_star {
                d_star = best_d[v];
                v_star = v;
            }
        }
        edges.push((best_u[v_star], v_star, d_star));
        in_tree[v_star] = true;
        for v in 0..n {
            if !in_tree[v] {
                let d = distance_km(&sites[v_star], &sites[v]);
                if d < best_d[v] {
                    best_d[v] = d;
                    best_u[v] = v_star;
                }
            }
        }
    }
    edges
}

const WAXMAN_ALPHA: f64 = 0.1;
const WAXMAN_BETA: f64 = 0.4;

/// Waxman 1988 random graph ∪ geodesic MST (the MST guarantees
/// connectivity without distorting the Waxman degree distribution).
///
/// Bit-identical to the naive all-pairs scan ([`waxman_all_pairs`], the
/// pinned oracle) with a fraction of the haversines: the RNG stream is one
/// draw per (i, j>i) pair in pair order — unchanged — but the draw happens
/// *first*. `p = β·exp(−d/αL) ≤ β`, so a draw `u ≥ β` can never connect and
/// skips the distance entirely (~60% of pairs at β = 0.4); the rest are
/// chord-bounded — `u < p ⟺ d < −αL·ln(u/β)` in exact arithmetic, so a
/// pair whose chord exceeds that threshold's chord (plus [`CHORD_SLACK`])
/// is rejected without trigonometry, and only the survivors evaluate the
/// oracle's exact `u < β·exp(−distance_km/αL)` comparison.
fn waxman(n: usize, rng: &mut Rng) -> (Vec<Site>, UnGraph) {
    let sites = random_sites(n, rng);
    let uv: Vec<[f64; 3]> = sites.iter().map(unit_vec).collect();
    let l_max = max_pair_distance_km(&sites, &uv);
    let scale = WAXMAN_ALPHA * l_max;
    let mut core = UnGraph::new(n);
    for i in 0..n {
        for j in i + 1..n {
            let u = rng.f64();
            if u >= WAXMAN_BETA {
                continue;
            }
            // chord-space prefilter; u → 0 caps at the antipodal chord and
            // rejects nothing, so the exact test below still decides.
            let d_thr = -scale * (u / WAXMAN_BETA).ln();
            let c_thr = chord_of_km(d_thr) * (1.0 + CHORD_SLACK);
            if chord_sq(&uv[i], &uv[j]) > c_thr * c_thr {
                continue;
            }
            let d = distance_km(&sites[i], &sites[j]);
            let p = WAXMAN_BETA * (-d / scale).exp();
            if u < p {
                core.add_edge(i, j, d);
            }
        }
    }
    for (u, v, d) in geodesic_mst(&sites) {
        if !core.has_edge(u, v) {
            core.add_edge(u, v, d);
        }
    }
    (sites, core)
}

/// The pre-PR-10 all-pairs Waxman scan, kept verbatim as the bit-identity
/// oracle the tests pin [`waxman`] against (same RNG stream: one draw per
/// pair in pair order).
#[cfg(test)]
fn waxman_all_pairs(n: usize, rng: &mut Rng) -> (Vec<Site>, UnGraph) {
    let sites = random_sites(n, rng);
    let mut l_max = 0.0f64;
    for i in 0..n {
        for j in i + 1..n {
            l_max = l_max.max(distance_km(&sites[i], &sites[j]));
        }
    }
    let mut core = UnGraph::new(n);
    for i in 0..n {
        for j in i + 1..n {
            let d = distance_km(&sites[i], &sites[j]);
            let p = WAXMAN_BETA * (-d / (WAXMAN_ALPHA * l_max)).exp();
            if rng.f64() < p {
                core.add_edge(i, j, d);
            }
        }
    }
    for (u, v, d) in geodesic_mst(&sites) {
        if !core.has_edge(u, v) {
            core.add_edge(u, v, d);
        }
    }
    (sites, core)
}

/// Barabási–Albert preferential attachment with m = 2 links per new node
/// (seeded from a 3-clique); connected by construction.
fn barabasi_albert(n: usize, rng: &mut Rng) -> (Vec<Site>, UnGraph) {
    let m = 2.min(n - 1);
    let sites = random_sites(n, rng);
    let mut core = UnGraph::new(n);
    // Degree-proportional sampling pool: one entry per edge endpoint.
    let mut pool: Vec<usize> = Vec::with_capacity(2 * m * n);
    let k0 = (m + 1).min(n);
    for i in 0..k0 {
        for j in i + 1..k0 {
            core.add_edge(i, j, distance_km(&sites[i], &sites[j]));
            pool.push(i);
            pool.push(j);
        }
    }
    for v in k0..n {
        let mut chosen: Vec<usize> = Vec::with_capacity(m);
        let mut guard = 0;
        while chosen.len() < m && guard < 64 * m {
            guard += 1;
            let t = pool[rng.usize(pool.len())];
            if t != v && !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        // Degenerate fallback (tiny pools): attach to the lowest-degree
        // nodes deterministically.
        let mut u = 0;
        while chosen.len() < m {
            if u != v && !chosen.contains(&u) {
                chosen.push(u);
            }
            u += 1;
        }
        for &t in &chosen {
            core.add_edge(v, t, distance_km(&sites[v], &sites[t]));
            pool.push(v);
            pool.push(t);
        }
    }
    (sites, core)
}

/// Random geometric graph: every pair within the geodesic-MST bottleneck
/// radius. Superset of the MST ⇒ connected.
///
/// PR 10: instead of scanning all pairs, sites are binned into a uniform
/// 3-D grid over their unit vectors with cell edge = the radius's chord, so
/// any connectable pair lies in adjacent cells; only those candidates
/// (chord-prefiltered with [`CHORD_SLACK`], then the oracle's exact
/// `distance_km ≤ radius` test) are visited, in ascending (i, then j) order
/// so edge ids match the all-pairs scan exactly. No RNG is consumed in the
/// pair phase, so the stream is trivially unchanged. Bit-identity is pinned
/// against [`random_geometric_all_pairs`].
fn random_geometric(n: usize, rng: &mut Rng) -> (Vec<Site>, UnGraph) {
    let sites = random_sites(n, rng);
    let mst = geodesic_mst(&sites);
    let radius = mst.iter().map(|&(_, _, d)| d).fold(0.0f64, f64::max);
    let uv: Vec<[f64; 3]> = sites.iter().map(unit_vec).collect();
    let c_r = chord_of_km(radius) * (1.0 + CHORD_SLACK);
    let cell = c_r.max(1e-12);
    let key = |v: &[f64; 3]| {
        (
            (v[0] / cell).floor() as i32,
            (v[1] / cell).floor() as i32,
            (v[2] / cell).floor() as i32,
        )
    };
    let mut bins: HashMap<(i32, i32, i32), Vec<u32>> = HashMap::new();
    for (i, v) in uv.iter().enumerate() {
        bins.entry(key(v)).or_default().push(i as u32);
    }
    let mut core = UnGraph::new(n);
    let c_r2 = c_r * c_r;
    let mut cand: Vec<u32> = Vec::new();
    for i in 0..n {
        cand.clear();
        let (kx, ky, kz) = key(&uv[i]);
        for dx in -1..=1 {
            for dy in -1..=1 {
                for dz in -1..=1 {
                    let Some(bin) = bins.get(&(kx + dx, ky + dy, kz + dz)) else {
                        continue;
                    };
                    for &j in bin {
                        if (j as usize) > i && chord_sq(&uv[i], &uv[j as usize]) <= c_r2 {
                            cand.push(j);
                        }
                    }
                }
            }
        }
        cand.sort_unstable();
        for &j in &cand {
            let d = distance_km(&sites[i], &sites[j as usize]);
            if d <= radius {
                core.add_edge(i, j as usize, d);
            }
        }
    }
    (sites, core)
}

/// The pre-PR-10 all-pairs geometric scan, kept verbatim as the
/// bit-identity oracle.
#[cfg(test)]
fn random_geometric_all_pairs(n: usize, rng: &mut Rng) -> (Vec<Site>, UnGraph) {
    let sites = random_sites(n, rng);
    let mst = geodesic_mst(&sites);
    let radius = mst.iter().map(|&(_, _, d)| d).fold(0.0f64, f64::max);
    let mut core = UnGraph::new(n);
    for i in 0..n {
        for j in i + 1..n {
            let d = distance_km(&sites[i], &sites[j]);
            if d <= radius {
                core.add_edge(i, j, d);
            }
        }
    }
    (sites, core)
}

/// Near-square 2-D grid (4-neighbor) over a continental box with small
/// deterministic jitter so no two link lengths tie exactly.
fn grid(n: usize, rng: &mut Rng) -> (Vec<Site>, UnGraph) {
    let cols = (n as f64).sqrt().ceil() as usize;
    let rows = n.div_ceil(cols);
    let (lat0, lat1) = (50.0, 25.0);
    let (lon0, lon1) = (-120.0, -70.0);
    let dlat = (lat1 - lat0) / rows.max(2) as f64;
    let dlon = (lon1 - lon0) / cols.max(2) as f64;
    let sites: Vec<Site> = (0..n)
        .map(|k| {
            let (r, c) = (k / cols, k % cols);
            let jlat = (rng.f64() - 0.5) * 0.02 * dlat.abs();
            let jlon = (rng.f64() - 0.5) * 0.02 * dlon.abs();
            Site::new(
                &format!("g{r}x{c}"),
                (lat0 + r as f64 * dlat + jlat).clamp(-89.9, 89.9),
                lon0 + c as f64 * dlon + jlon,
            )
        })
        .collect();
    let mut core = UnGraph::new(n);
    for k in 0..n {
        if k % cols > 0 {
            core.add_edge(k - 1, k, distance_km(&sites[k - 1], &sites[k]));
        }
        if k >= cols {
            core.add_edge(k - cols, k, distance_km(&sites[k - cols], &sites[k]));
        }
    }
    (sites, core)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_roundtrips_through_by_name() {
        let u = Underlay::by_name("synth:waxman:50:seed7").unwrap();
        assert_eq!(u.name, "synth:waxman:50:seed7");
        assert_eq!(u.n_silos(), 50);
        // default seed applies
        let v = Underlay::by_name("synth:waxman:50").unwrap();
        assert_eq!(v.name, u.name);
        assert_eq!(v.core.edges(), u.core.edges());
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(from_spec("waxman").is_err()); // no n
        assert!(from_spec("waxman:abc").is_err()); // bad n
        assert!(from_spec("waxman:50:7").is_err()); // seed without prefix
        assert!(from_spec("waxman:50:seedx").is_err()); // bad seed value
        assert!(from_spec("smallworld:50").is_err()); // unknown family
        assert!(from_spec("waxman:2").is_err()); // too small
        assert!(from_spec(&format!("waxman:{}", MAX_SILOS + 1)).is_err());
        assert!(from_spec("waxman:50:seed1:extra").is_err());
    }

    #[test]
    fn determinism_same_spec_identical_underlay() {
        for family in families() {
            let a = generate(family, 80, 42).unwrap();
            let b = generate(family, 80, 42).unwrap();
            assert_eq!(a.sites, b.sites, "{family} sites");
            assert_eq!(a.core.edges(), b.core.edges(), "{family} edges");
            assert_eq!(a.n_links(), b.n_links(), "{family} link count");
            let km = |u: &Underlay| u.core.total_weight();
            assert_eq!(km(&a).to_bits(), km(&b).to_bits(), "{family} total km");
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate("waxman", 60, 1).unwrap();
        let b = generate("waxman", 60, 2).unwrap();
        assert_ne!(a.core.edges(), b.core.edges());
    }

    #[test]
    fn all_families_connected_at_scale() {
        for family in families() {
            for n in [50usize, 200, 1000] {
                let u = generate(family, n, 7).unwrap();
                assert_eq!(u.n_silos(), n, "{family}:{n}");
                assert!(u.core.is_connected(), "{family}:{n} disconnected");
                assert!(u.n_links() >= n - 1, "{family}:{n} too few links");
                // geo-plausible: every link a real positive distance
                for &(_, _, km) in u.core.edges() {
                    assert!(km > 0.0 && km < 21000.0, "{family}:{n} link {km} km");
                }
            }
        }
    }

    #[test]
    fn waxman_sparser_than_mesh_denser_than_tree() {
        let u = generate("waxman", 300, 7).unwrap();
        let full = 300 * 299 / 2;
        assert!(u.n_links() < full / 4, "links={}", u.n_links());
        assert!(u.n_links() > 350, "links={}", u.n_links());
    }

    #[test]
    fn ba_has_hubs() {
        let u = generate("ba", 300, 7).unwrap();
        // preferential attachment grows heavy-tailed degrees
        assert!(u.core.max_degree() >= 10, "Δ={}", u.core.max_degree());
        assert_eq!(u.n_links(), 3 + (300 - 3) * 2);
    }

    #[test]
    fn grid_is_lattice() {
        let u = generate("grid", 100, 7).unwrap();
        assert_eq!(u.n_links(), 2 * 10 * 9); // 10×10 4-neighbor lattice
        assert!(u.core.max_degree() <= 4);
    }

    #[test]
    fn waxman_prefilter_is_bit_identical_to_the_all_pairs_scan() {
        // ISSUE 10 pin: the chord-prefiltered generator must reproduce the
        // naive all-pairs scan bit for bit — sites, edge list (order
        // included), and total km — at both a small and a large n.
        for n in [50usize, 1000] {
            let u = generate("waxman", n, 7).unwrap();
            let (sites, core) = waxman_all_pairs(n, &mut spec_rng("waxman", n, 7));
            assert_eq!(u.sites, sites, "waxman:{n} sites");
            assert_eq!(u.core.edges(), core.edges(), "waxman:{n} edges");
            assert_eq!(
                u.core.total_weight().to_bits(),
                core.total_weight().to_bits(),
                "waxman:{n} total km"
            );
        }
    }

    #[test]
    fn geo_grid_binning_is_bit_identical_to_the_all_pairs_scan() {
        for n in [50usize, 1000] {
            let u = generate("geo", n, 7).unwrap();
            let (sites, core) = random_geometric_all_pairs(n, &mut spec_rng("geo", n, 7));
            assert_eq!(u.sites, sites, "geo:{n} sites");
            assert_eq!(u.core.edges(), core.edges(), "geo:{n} edges");
            assert_eq!(
                u.core.total_weight().to_bits(),
                core.total_weight().to_bits(),
                "geo:{n} total km"
            );
        }
    }

    #[test]
    fn determinism_of_designed_cycle_times() {
        // The ISSUE's determinism satellite: same spec ⇒ identical RING and
        // MST cycle times across two independent constructions — once below
        // and once above the Karp/Howard dispatch threshold.
        use crate::fl::workloads::Workload;
        use crate::netsim::delay::DelayModel;
        use crate::topology::{design_with_underlay, OverlayKind};
        for n in [60usize, 150] {
            let spec = format!("synth:waxman:{n}:seed7");
            let tau = |kind| {
                let net = Underlay::by_name(&spec).unwrap();
                let dm = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
                design_with_underlay(kind, &dm, &net, 0.5)
                    .unwrap()
                    .cycle_time_ms(&dm)
            };
            for kind in [OverlayKind::Ring, OverlayKind::Mst] {
                let a = tau(kind);
                let b = tau(kind);
                assert!(a.is_finite() && a > 0.0, "{spec}/{kind:?}: τ={a}");
                assert_eq!(a.to_bits(), b.to_bits(), "{spec}/{kind:?} nondeterministic");
            }
        }
    }
}
